"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle, sweeping
shapes / dtypes / table geometries, plus numeric-contract tests vs the exact fns."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.approx import ApproxConfig, from_spec
from repro.approx.jax_table import eval_table_slope
from repro.core import build_table, get_function
from repro.kernels.ops import table_lookup
from repro.kernels.ref import table_lookup_ref

RNG = np.random.default_rng(42)


def _table(name="silu", ea=1e-4, alg="hierarchical", omega=0.2):
    return from_spec(build_table(name, ea, algorithm=alg, omega=omega))


SHAPES = [
    (8,),  # sub-lane
    (128,),  # one lane row
    (513,),  # pad + slice
    (4, 96),
    (2, 3, 257),  # odd everything
    (1, 8192),  # multiple row blocks
    (16, 1024),
    (2, 2, 2, 130),
]


class TestPallasVsOracle:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_shapes_f32(self, shape):
        jt = _table()
        x = jnp.asarray(RNG.normal(0, 5, size=shape).astype(np.float32))
        got = table_lookup(jt, x)
        want = table_lookup_ref(jt, x)
        assert got.shape == x.shape and got.dtype == x.dtype
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6, rtol=0)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
    def test_dtypes(self, dtype):
        jt = _table("gelu")
        x = jnp.asarray(RNG.normal(0, 3, size=(4, 384)).astype(np.float32)).astype(dtype)
        got = table_lookup(jt, x)
        want = table_lookup_ref(jt, x)
        assert got.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32),
            np.asarray(want, dtype=np.float32),
            atol=2e-2 if dtype != jnp.float32 else 1e-6,
            rtol=0,
        )

    @pytest.mark.parametrize(
        "name,alg,ea",
        [
            ("log", "binary", 1.22e-4),
            ("exp", "sequential", 1e-5),
            ("tanh", "hierarchical", 1e-4),
            ("sigmoid_sym", "hierarchical", 1e-5),
            ("gauss", "sequential", 1e-4),
            ("gelu", "hierarchical", 1e-4),
            ("softplus", "binary", 1e-3),
        ],
    )
    def test_table_geometries(self, name, alg, ea):
        """Different functions -> different #intervals / footprints / domains."""
        fn = get_function(name)
        jt = from_spec(build_table(name, ea, algorithm=alg, omega=0.15))
        lo, hi = fn.interval
        x = jnp.asarray(
            RNG.uniform(lo - 0.1 * (hi - lo), hi + 0.1 * (hi - lo), size=(3, 640)).astype(
                np.float32
            )
        )
        for ex in (False, True):
            got = table_lookup(jt, x, extrapolate=ex)
            want = table_lookup_ref(jt, x, extrapolate=ex)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-6
            )

    def test_block_geometry_sweep(self):
        from repro.kernels.table_lookup import table_lookup_pallas

        jt = _table()
        x = jnp.asarray(RNG.normal(0, 5, size=(5000,)).astype(np.float32))
        want = table_lookup_ref(jt, x)
        for block_rows, lane in [(8, 128), (32, 256), (256, 512), (1024, 128)]:
            got = table_lookup_pallas(jt, x, block_rows=block_rows, lane=lane)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    def test_inside_interval_error_bound(self):
        """Kernel output obeys the paper's Ea bound inside the interval (f32 slack)."""
        ea = 1e-4
        for name in ["gelu", "silu", "tanh", "sigmoid_sym"]:
            fn = get_function(name)
            jt = from_spec(build_table(name, ea, algorithm="sequential", omega=0.15))
            lo, hi = fn.interval
            xs = jnp.asarray(np.linspace(lo, hi - 1e-4, 20001, dtype=np.float32))
            y = table_lookup(jt, xs)
            exact = np.asarray(fn.f(np.asarray(xs, dtype=np.float64)))
            err = float(np.max(np.abs(np.asarray(y, dtype=np.float64) - exact)))
            assert err <= ea + 1e-5, (name, err)


class TestGradients:
    def test_table_slope_matches_fd(self):
        """custom_jvp slope == finite difference of the surrogate (away from knots)."""
        cfg = ApproxConfig(mode="table_ref", e_a=1e-4)
        f = cfg.unary("gelu")
        x = jnp.asarray(RNG.uniform(-6, 6, size=(256,)).astype(np.float32))
        g = jax.vmap(jax.grad(f))(x)
        eps = 1e-3
        fd = (f(x + eps) - f(x - eps)) / (2 * eps)
        # knot crossings make a few FD samples disagree; compare medians robustly
        diff = np.abs(np.asarray(g) - np.asarray(fd))
        assert np.percentile(diff, 90) < 1e-2

    def test_exact_grad_mode(self):
        cfg = ApproxConfig(mode="table_ref", e_a=1e-3, exact_grad=True)
        f = cfg.unary("tanh")
        x = jnp.linspace(-3, 3, 101)
        g = jax.vmap(jax.grad(f))(x)
        np.testing.assert_allclose(
            np.asarray(g), 1 - np.tanh(np.asarray(x)) ** 2, atol=1e-5
        )

    def test_grad_through_pallas(self):
        cfg = ApproxConfig(mode="table_pallas", e_a=1e-4)
        f = cfg.unary("silu")
        x = jnp.asarray(RNG.normal(0, 2, size=(33, 65)).astype(np.float32))
        loss = lambda v: (f(v) ** 2).sum()
        g = jax.grad(loss)(x)
        assert g.shape == x.shape and bool(jnp.all(jnp.isfinite(g)))

    def test_slope_zero_outside_when_clamped(self):
        jt = _table("tanh", ea=1e-4)
        s = eval_table_slope(jt, jnp.asarray([-100.0, 100.0]))
        np.testing.assert_allclose(np.asarray(s), [0.0, 0.0], atol=1e-7)


class TestSoftmaxBackend:
    def test_table_softmax_close_and_normalized(self):
        cfg = ApproxConfig(mode="table_ref", e_a=1e-6, softmax_table=True)
        x = jnp.asarray(RNG.normal(0, 4, size=(8, 128)).astype(np.float32))
        sm = cfg.softmax(x)
        np.testing.assert_allclose(np.asarray(sm.sum(-1)), 1.0, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(sm), np.asarray(jax.nn.softmax(x)), atol=5e-4
        )

    def test_table_softmax_masked(self):
        cfg = ApproxConfig(mode="table_ref", e_a=1e-6, softmax_table=True)
        x = jnp.asarray(RNG.normal(0, 2, size=(4, 16)).astype(np.float32))
        mask = jnp.arange(16) < 9
        sm = cfg.softmax(x, where=mask[None, :])
        assert float(sm[:, 9:].max()) == 0.0
        np.testing.assert_allclose(np.asarray(sm.sum(-1)), 1.0, atol=1e-5)


class TestFusedGradKernel:
    def test_fused_matches_separate(self):
        from repro.kernels.table_grad import table_lookup_grad_pallas
        from repro.approx.jax_table import eval_table_ref, eval_table_slope

        for name, ex in [("gelu", True), ("tanh", False), ("sigmoid_sym", False)]:
            jt = from_spec(build_table(name, 1e-4, algorithm="hierarchical",
                                       omega=0.2))
            x = jnp.asarray(RNG.normal(0, 4, size=(7, 193)).astype(np.float32))
            y, dy = table_lookup_grad_pallas(jt, x, extrapolate=ex)
            np.testing.assert_allclose(
                np.asarray(y), np.asarray(eval_table_ref(jt, x, extrapolate=ex)),
                atol=1e-6)
            np.testing.assert_allclose(
                np.asarray(dy),
                np.asarray(eval_table_slope(jt, x, extrapolate=ex)), atol=1e-6)

    def test_pallas_grad_path_uses_fused(self):
        cfg = ApproxConfig(mode="table_pallas", e_a=1e-4)
        f = cfg.unary("silu")
        x = jnp.asarray(RNG.normal(0, 2, size=(256,)).astype(np.float32))
        y, vjp = jax.vjp(lambda v: f(v).sum(), x)
        (g,) = vjp(jnp.ones(()))
        g_ref = jax.vmap(jax.grad(ApproxConfig(mode="table_ref",
                                               e_a=1e-4).unary("silu")))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)
