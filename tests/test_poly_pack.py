"""PolyTablePack: the planner-built runtime artifact and its Pallas kernels.

What conformance doesn't already cover, checked here in detail:

  * VALUE bit parity of the static and routed kernels against their jnp
    oracles on mixed-degree / mixed-width packs (f32 + int8 + int16 members
    sharing one pack), including per-row routed dispatch over mixed fn_ids;
  * the lane-padding contract that makes those parities possible: a padded
    metadata lane dequantizes to exactly 0.0, so the kernels' uniform
    max-lanes Horner is bit-identical to each member's own degree-L Horner;
  * fused-grad slopes: compared with tight allclose, NOT bitwise — the
    derivative Horner step ``g*t + c*k`` has two products feeding one add,
    and XLA's FMA-contraction choice legitimately differs between the fused
    kernel module and the standalone slope oracle (a 1-ULP ambiguity; the
    VALUE path has a unique contraction and stays bitwise);
  * planner-budget plumbing through ApproxConfig.pack_budget.

Oracles are jitted on both sides of every parity check — eager jnp rounds
each op separately while XLA contracts the dequant FMA chains (the
test_quant_pack.py convention).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx.activations import ApproxConfig
from repro.approx.table_pack import (build_poly_pack, eval_poly_pack_ref,
                                     eval_poly_pack_slope,
                                     eval_routed_poly_ref,
                                     eval_routed_poly_slope, from_poly_layout)
from repro.core import poly_member, poly_pack_layout
from repro.kernels.routed_pack_lookup import (routed_poly_pack_grad_pallas,
                                              routed_poly_pack_lookup_pallas)
from repro.kernels.table_pack_lookup import (poly_pack_grad_pallas,
                                             poly_pack_lookup_pallas)

EA = 1e-4
AUTO_NAMES = ("gelu", "tanh", "exp_neg", "sigmoid_sym")
# one member per degree x a different code width each — the adversarial pack
MIXED = (("tanh", 1, 32), ("exp_neg", 3, 8), ("gelu", 2, 16))


@pytest.fixture(scope="module")
def auto_pack():
    return build_poly_pack(AUTO_NAMES, EA)


@pytest.fixture(scope="module")
def mixed_pack():
    members = [poly_member(n, EA, degree=d, bits=b) for n, d, b in MIXED]
    return from_poly_layout(poly_pack_layout(members))


def probe(rng, n=2100):
    return jnp.asarray(rng.uniform(-9, 9, n).astype(np.float32))


def _packs(auto_pack, mixed_pack):
    return ((auto_pack, AUTO_NAMES), (mixed_pack, tuple(m[0] for m in MIXED)))


class TestPackLayout:
    def test_mixed_pack_statics(self, mixed_pack):
        assert mixed_pack.degrees == tuple(m[1] for m in MIXED)
        assert mixed_pack.entry_bits == (32, 8, 16)
        assert mixed_pack.max_lanes == 4  # max degree 3 -> 4 coefficients

    def test_padded_lanes_dequantize_to_exact_zero(self, mixed_pack):
        """Lane l >= degree+1 of a member must have (zero, ramp, scale) all
        exactly 0.0: the kernels' uniform-lane Horner then sees 0*t + c = c
        through the padding, which is what makes the mixed-degree bit
        parities below possible at all."""
        lmax = mixed_pack.max_lanes
        for fid, name in enumerate(mixed_pack.names):
            lo = mixed_pack.lane_offset(fid)
            n = mixed_pack.n_intervals[fid]
            lanes = mixed_pack.degrees[fid] + 1
            for plane in (mixed_pack.zero, mixed_pack.ramp, mixed_pack.scale):
                rows = np.asarray(plane[lo * lmax:(lo + n) * lmax]
                                  ).reshape(n, lmax)
                np.testing.assert_array_equal(
                    rows[:, lanes:], 0.0, err_msg=f"{name} padding")

    def test_footprint_excludes_dummy_groups(self, mixed_pack, auto_pack):
        """Empty code width groups hold a 1-entry jnp dummy for pallas
        operand shapes; footprints must count only the LIVE groups."""
        groups = {8: mixed_pack.codes8, 16: mixed_pack.codes16,
                  32: mixed_pack.codes32}
        live = set(mixed_pack.entry_bits)  # all three here
        assert live == {8, 16, 32}
        by_hand = sum(groups[b].size * (b // 8) for b in live)
        assert mixed_pack.footprint_bytes == by_hand
        assert mixed_pack.footprint == sum(groups[b].size for b in live)
        # the auto pack leaves some group empty -> its dummy must not count
        auto_live = set(auto_pack.entry_bits)
        auto_groups = {8: auto_pack.codes8, 16: auto_pack.codes16,
                       32: auto_pack.codes32}
        assert auto_pack.footprint == sum(
            auto_groups[b].size for b in auto_live)


class TestStaticKernelParity:
    @pytest.mark.parametrize("extrapolate", [False, True])
    def test_value_bitwise(self, auto_pack, mixed_pack, extrapolate):
        rng = np.random.default_rng(0)
        for pack, names in _packs(auto_pack, mixed_pack):
            x = probe(rng)
            for name in names:
                want = jax.jit(lambda v, n=name: eval_poly_pack_ref(
                    pack, n, v, extrapolate=extrapolate))(x)
                got = poly_pack_lookup_pallas(pack, name, x,
                                              extrapolate=extrapolate)
                np.testing.assert_array_equal(
                    np.asarray(got), np.asarray(want),
                    err_msg=f"{name} extrapolate={extrapolate}")

    @pytest.mark.parametrize("extrapolate", [False, True])
    def test_fused_grad_value_bitwise_slope_close(self, auto_pack, mixed_pack,
                                                  extrapolate):
        rng = np.random.default_rng(1)
        for pack, names in _packs(auto_pack, mixed_pack):
            x = probe(rng)
            for name in names:
                y, dy = poly_pack_grad_pallas(pack, name, x,
                                              extrapolate=extrapolate)
                want_y = jax.jit(lambda v, n=name: eval_poly_pack_ref(
                    pack, n, v, extrapolate=extrapolate))(x)
                want_dy = jax.jit(lambda v, n=name: eval_poly_pack_slope(
                    pack, n, v, extrapolate=extrapolate))(x)
                np.testing.assert_array_equal(np.asarray(y),
                                              np.asarray(want_y), err_msg=name)
                # slope: tight allclose, not bitwise (see module docstring)
                np.testing.assert_allclose(np.asarray(dy),
                                           np.asarray(want_dy),
                                           rtol=1e-5, atol=1e-7, err_msg=name)
                assert np.isfinite(np.asarray(dy)).all()


class TestRoutedKernelParity:
    @pytest.mark.parametrize("extrapolate", [False, True])
    def test_mixed_ids_bitwise(self, auto_pack, mixed_pack, extrapolate):
        """Rows routed to DIFFERENT members in one call, kernel vs jitted
        routed oracle — and each routed row vs the member's static oracle."""
        rng = np.random.default_rng(2)
        for pack, names in _packs(auto_pack, mixed_pack):
            rows = 8
            ids = np.array([i % len(names) for i in range(rows)], np.int32)
            x = probe(rng, rows * 257).reshape(rows, 257)
            want = jax.jit(lambda v: eval_routed_poly_ref(
                pack, ids, v, extrapolate=extrapolate))(x)
            got = routed_poly_pack_lookup_pallas(pack, ids, x,
                                                 extrapolate=extrapolate)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            for r in range(rows):
                srow = jax.jit(lambda v, n=names[ids[r]]: eval_poly_pack_ref(
                    pack, n, v, extrapolate=extrapolate))(x[r])
                np.testing.assert_array_equal(np.asarray(want[r]),
                                              np.asarray(srow),
                                              err_msg=f"row {r}")

    def test_routed_grad(self, mixed_pack):
        rng = np.random.default_rng(3)
        names = tuple(m[0] for m in MIXED)
        ids = np.array([2, 0, 1, 2, 1, 0], np.int32)
        x = probe(rng, ids.size * 130).reshape(ids.size, 130)
        y, dy = routed_poly_pack_grad_pallas(mixed_pack, ids, x)
        want_y = jax.jit(lambda v: eval_routed_poly_ref(
            mixed_pack, ids, v))(x)
        want_dy = jax.jit(lambda v: eval_routed_poly_slope(
            mixed_pack, ids, v))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(want_y))
        np.testing.assert_allclose(np.asarray(dy), np.asarray(want_dy),
                                   rtol=1e-5, atol=1e-7)
        assert np.isfinite(np.asarray(dy)).all()
        assert names  # routed over every member above


class TestExtrapolation:
    def test_linear_tail(self, mixed_pack):
        """extrapolate=True continues the edge cell's tangent line: gelu far
        right must track the identity asymptote instead of saturating."""
        y = poly_pack_lookup_pallas(
            mixed_pack, "gelu", jnp.asarray([20.0], jnp.float32),
            extrapolate=True)
        assert abs(float(y[0]) - 20.0) < 0.05


class TestApproxConfigBudget:
    def test_pack_budget_plumbed_and_respected(self):
        cfg = ApproxConfig(mode="poly_pack", e_a=EA, pack_budget=4096)
        pack = cfg.poly_pack()
        assert pack.footprint_bytes <= 4096
        # distinct budgets are distinct cache keys -> distinct packs allowed
        free = ApproxConfig(mode="poly_pack", e_a=EA).poly_pack()
        assert free.names == pack.names

    def test_unary_and_grad_through_config(self):
        cfg = ApproxConfig(mode="poly_pack", e_a=EA)
        f = cfg.unary("gelu")
        x = jnp.linspace(-4, 4, 513, dtype=jnp.float32)[:-1]
        err = float(jnp.max(jnp.abs(f(x) - jax.nn.gelu(x, approximate=False))))
        assert err <= EA * 1.02 + 1e-5
        g = jax.grad(lambda v: f(v).sum())(x)
        assert bool(jnp.all(jnp.isfinite(g)))
