"""QuantPack validation: the error-budget split must keep |f - table| <= Ea end
to end for EVERY registered function, the dequantize-on-read Pallas kernels
must reproduce the quantized jnp oracle bit for bit, int8/int16 selection must
come out of the budget split automatically, and the byte accounting must be
entry-dtype-aware (regression for the hard-coded-f32 assumption)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import (
    ApproxConfig,
    eval_quant_pack_ref,
    eval_quant_pack_slope,
    from_quant_layout,
)
from repro.core import (
    build_table,
    chord_residual_ranges,
    function_names,
    get_function,
    plan_quant_member,
    quant_pack_layout,
    refine_for_quantization,
    vmem_cost_pack,
)
from repro.core.quantize import quant_rounding_limit
from repro.kernels.ops import quant_pack_lookup
from repro.kernels.table_pack_lookup import quant_pack_grad_pallas, quant_pack_lookup_pallas

RNG = np.random.default_rng(11)

EA = 1e-4
RHO = 0.9

# Planning runs the design flow + refinement twice (int8/int16 candidates) per
# function; share the members across the whole module.
_MEMBERS = {}


def member(name, **kw):
    key = (name, tuple(sorted(kw.items())))
    if key not in _MEMBERS:
        _MEMBERS[key] = plan_quant_member(name, EA, rho=RHO, **kw)
    return _MEMBERS[key]


def _probe(spec, n=2048):
    lo, hi, span = spec.lo, spec.hi, spec.hi - spec.lo
    return jnp.asarray(
        RNG.uniform(lo - 0.5 * span, hi + 0.5 * span, size=n).astype(np.float32))


class TestBudgetSplit:
    """The error-budget splitter: rho*Ea interpolation + (1-rho)*Ea rounding."""

    def test_width_selected_automatically(self):
        for name in ("gelu", "tanh", "exp_neg"):
            m = member(name)
            assert m.bits in (8, 16)
            assert m.rho == RHO and m.e_a == EA

    def test_interpolation_table_built_at_rho_ea(self):
        m = member("tanh")
        assert m.spec.e_a == pytest.approx(RHO * EA)

    def test_forced_widths(self):
        for bits in (8, 16):
            m = member("gelu", dtype=f"int{bits}")
            assert m.bits == bits
            lim = quant_rounding_limit((1 - RHO) * EA, bits)
            assert chord_residual_ranges(m.spec).max(initial=0.0) <= lim

    def test_codes_fit_signed_storage(self):
        for name in ("gelu", "sigmoid_sym"):
            m = member(name)
            lo, hi = -(2 ** (m.bits - 1)), 2 ** (m.bits - 1) - 1
            assert m.codes.min() >= lo and m.codes.max() <= hi

    def test_bad_rho_rejected(self):
        with pytest.raises(ValueError):
            plan_quant_member("gelu", EA, rho=1.5)

    def test_bad_dtype_rejected(self):
        with pytest.raises(ValueError):
            plan_quant_member("gelu", EA, dtype="int4")

    def test_infeasible_within_cap_raises(self):
        # gelu's chord residuals cannot reach the int8 budget with <= 2
        # sub-intervals; the splitter must say so instead of shipping a pack
        # that silently violates Ea.
        with pytest.raises(ValueError):
            plan_quant_member("gelu", EA, dtype="int8", cap=2)


class TestRefinement:
    """Quantization refinement: same piecewise-linear function, smaller residuals."""

    def test_partition_valid_and_residuals_bounded(self):
        ts = build_table("gelu", RHO * EA, algorithm="hierarchical", omega=0.3)
        limit = quant_rounding_limit((1 - RHO) * EA, 8)
        ref = refine_for_quantization(ts, limit)
        p = ref.boundaries
        assert p[0] == ts.boundaries[0] and p[-1] == ts.boundaries[-1]
        assert np.all(np.diff(p) > 0)
        assert chord_residual_ranges(ref).max(initial=0.0) <= limit

    def test_each_cut_duplicates_one_entry(self):
        ts = build_table("silu", RHO * EA, algorithm="hierarchical", omega=0.3)
        limit = quant_rounding_limit((1 - RHO) * EA, 8)
        ref = refine_for_quantization(ts, limit)
        assert ref.footprint == ts.footprint + (ref.n_intervals - ts.n_intervals)

    def test_evaluation_preserved(self):
        ts = build_table("tanh", RHO * EA, algorithm="hierarchical", omega=0.3)
        limit = quant_rounding_limit((1 - RHO) * EA, 8)
        ref = refine_for_quantization(ts, limit)
        assert ref.n_intervals > ts.n_intervals  # the cut actually happened
        xs = np.linspace(ts.lo, ts.hi - 1e-9, 20_001)
        np.testing.assert_allclose(ref.eval(xs), ts.eval(xs), atol=1e-12)

    def test_noop_when_budget_is_loose(self):
        ts = build_table("tanh", RHO * EA, algorithm="hierarchical", omega=0.3)
        assert refine_for_quantization(ts, limit=1e9) is ts

    def test_round_trip_within_rounding_budget(self):
        tol = (1 - RHO) * EA
        for name in ("gelu", "log"):
            m = member(name)
            err = np.max(np.abs(m.dequantize() - m.spec.values))
            assert err <= tol * (1 + 1e-9), (name, err)


class TestErrorBoundEndToEnd:
    """Acceptance: interpolation + quantization error <= Ea for every
    registered function, in f64 (oracle) and f32 (runtime)."""

    def test_every_registered_function_meets_ea_f64(self):
        for name in function_names():
            m = member(name)
            err = m.max_error_on_grid(n=20_001)
            assert err <= EA * (1 + 1e-6), (name, m.bits, err)

    def test_every_registered_function_meets_ea_f32_runtime(self):
        names = function_names()
        pack = from_quant_layout(quant_pack_layout([member(n) for n in names]))
        for name in names:
            fn = get_function(name)
            lo, hi = fn.interval
            xs = np.linspace(lo, hi, 4001)[:-1]
            got = np.asarray(
                eval_quant_pack_ref(pack, name, jnp.asarray(xs, jnp.float32)),
                dtype=np.float64)
            err = np.max(np.abs(got - np.asarray(fn.f(xs))))
            # f32 gathers/FMAs add rounding noise on top of the f64 bound,
            # relative to the function's magnitude (tan reaches ~14)
            scale = max(1.0, float(np.max(np.abs(fn.f(xs)))))
            assert err <= EA * 1.02 + 1e-5 * scale, (name, err)


class TestQuantKernel:
    """Pallas dequantize-on-read == the quantized jnp oracle, bitwise."""

    def test_kernel_bit_identical_to_oracle(self):
        names = ["gelu", "tanh", "sigmoid_sym", "exp_neg"]
        pack = from_quant_layout(quant_pack_layout([member(n) for n in names]))
        for name in names:
            x = _probe(member(name).spec)
            for ex in (False, True):
                want = jax.jit(
                    lambda v, n=name, e=ex: eval_quant_pack_ref(
                        pack, n, v, extrapolate=e))(x)
                got = quant_pack_lookup(pack, name, x, extrapolate=ex)
                np.testing.assert_array_equal(
                    np.asarray(got), np.asarray(want), err_msg=f"{name} ex={ex}")

    def test_mixed_width_pack_serves_both_vectors(self):
        members = [member("tanh", dtype="int8"), member("gelu", dtype="int16")]
        pack = from_quant_layout(quant_pack_layout(members))
        assert pack.entry_bits == (8, 16)
        for m in members:
            x = _probe(m.spec, n=512)
            got = np.asarray(quant_pack_lookup(pack, m.name, x))
            want = np.asarray(jax.jit(
                lambda v, n=m.name: eval_quant_pack_ref(pack, n, v))(x))
            np.testing.assert_array_equal(got, want, err_msg=m.name)

    def test_fused_grad_kernel(self):
        pack = from_quant_layout(quant_pack_layout(
            [member("gelu"), member("tanh")]))
        x = jnp.asarray(RNG.normal(0, 4, size=(7, 193)).astype(np.float32))
        for name, ex in [("gelu", True), ("tanh", False)]:
            y, dy = quant_pack_grad_pallas(pack, name, x, extrapolate=ex)
            np.testing.assert_array_equal(
                np.asarray(y),
                np.asarray(jax.jit(lambda v, n=name, e=ex: eval_quant_pack_ref(
                    pack, n, v, extrapolate=e))(x)))
            np.testing.assert_array_equal(
                np.asarray(dy),
                np.asarray(jax.jit(lambda v, n=name, e=ex: eval_quant_pack_slope(
                    pack, n, v, extrapolate=e))(x)))

    @pytest.mark.parametrize("shape", [(8,), (513,), (4, 96), (2, 3, 257)])
    def test_shapes(self, shape):
        pack = from_quant_layout(quant_pack_layout([member("silu")]))
        x = jnp.asarray(RNG.normal(0, 5, size=shape).astype(np.float32))
        got = quant_pack_lookup_pallas(pack, "silu", x)
        want = jax.jit(lambda v: eval_quant_pack_ref(pack, "silu", v))(x)
        assert got.shape == x.shape and got.dtype == x.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestApproxConfigQuantMode:
    def test_unary_and_grad_match_oracle_mode(self):
        cfg_k = ApproxConfig(mode="quant_pack", e_a=EA)
        cfg_r = ApproxConfig(mode="quant_pack_ref", e_a=EA)
        x = jnp.asarray(RNG.normal(0, 4, size=(300,)).astype(np.float32))
        for name in ("gelu", "silu", "tanh", "sigmoid", "exp"):
            a = np.asarray(jax.jit(cfg_k.unary(name))(x))
            b = np.asarray(jax.jit(cfg_r.unary(name))(x))
            np.testing.assert_array_equal(a, b, err_msg=name)
            # bit-parity needs jit on BOTH sides: eager jnp rounds the
            # ramp + scale*(c1-c0) separately while XLA fuses the FMA
            ga = np.asarray(jax.jit(jax.vmap(jax.grad(cfg_k.unary(name))))(x))
            gb = np.asarray(jax.jit(jax.vmap(jax.grad(cfg_r.unary(name))))(x))
            np.testing.assert_array_equal(ga, gb, err_msg=f"{name} grad")

    def test_pack_is_cached(self):
        cfg = ApproxConfig(mode="quant_pack", e_a=EA)
        assert cfg.quant_pack() is cfg.quant_pack()

    def test_forced_dtype_flows_through_config(self):
        cfg = ApproxConfig(mode="quant_pack_ref", e_a=EA, pack_dtype="int16")
        assert set(cfg.quant_pack().entry_bits) == {16}

    def test_missing_pack_member_raises(self):
        cfg = ApproxConfig(mode="quant_pack_ref", e_a=EA,
                           pack_functions=("gelu",))
        with pytest.raises(KeyError):
            cfg.unary("tanh")

    def test_quant_softmax(self):
        cfg = ApproxConfig(mode="quant_pack_ref", e_a=1e-5, softmax_table=True)
        x = jnp.asarray(RNG.normal(0, 4, size=(8, 128)).astype(np.float32))
        sm = cfg.softmax(x)
        np.testing.assert_allclose(np.asarray(sm.sum(-1)), 1.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sm),
                                   np.asarray(jax.nn.softmax(x)), atol=5e-4)


class TestSymmetricTanhRouting:
    """Satellite: every table-mode tanh is odd-extended by the backend, so the
    registry's [-8, 0) table serves gates/softcap on the full real line
    (previously positive inputs saturated to tanh(0) = 0)."""

    @pytest.mark.parametrize("mode", ["table_ref", "table_pack_ref",
                                      "quant_pack_ref", "table_pack",
                                      "quant_pack"])
    def test_tanh_correct_on_symmetric_domain(self, mode):
        f = ApproxConfig(mode=mode, e_a=EA).unary("tanh")
        xs = jnp.linspace(-7.5, 7.5, 301)
        err = np.max(np.abs(np.asarray(f(xs)) - np.tanh(np.asarray(xs))))
        assert err <= 2 * EA, (mode, err)

    def test_tanh_is_odd(self):
        f = ApproxConfig(mode="table_ref", e_a=EA).unary("tanh")
        xs = jnp.linspace(0.1, 7.5, 64)
        np.testing.assert_array_equal(np.asarray(f(-xs)), -np.asarray(f(xs)))

    def test_exact_mode_untouched(self):
        f = ApproxConfig(mode="exact").unary("tanh")
        xs = jnp.linspace(-3, 3, 32)
        np.testing.assert_array_equal(np.asarray(f(xs)),
                                      np.asarray(jnp.tanh(xs)))

    def test_gradient_flows_on_both_signs(self):
        f = ApproxConfig(mode="quant_pack_ref", e_a=EA).unary("tanh")
        g = jax.vmap(jax.grad(f))(jnp.asarray([-2.0, -0.5, 0.5, 2.0]))
        assert np.all(np.asarray(g) > 0)  # tanh' > 0 everywhere

    def test_gradient_survives_origin(self):
        # the sign/abs mirror had zero tangent at exactly 0; the where-based
        # mirror keeps the chain rule alive there (regression for
        # test_exact_grad_mode).  exact-grad mode: tanh'(0) = 1 exactly; the
        # default slope rule still zeroes x = 0 by the half-open-domain
        # address-clamp convention (boundaries are [-8, 0)), so probe nearby.
        f = ApproxConfig(mode="table_ref", e_a=EA, exact_grad=True).unary("tanh")
        g = float(jax.grad(f)(jnp.asarray(0.0)))
        assert g == pytest.approx(1.0, abs=1e-3)
        f2 = ApproxConfig(mode="table_ref", e_a=EA).unary("tanh")
        g2 = jax.vmap(jax.grad(f2))(jnp.asarray([-0.01, 0.01]))
        np.testing.assert_allclose(np.asarray(g2), 1.0, atol=1e-2)

    def test_odd_extension_accepts_scalars_and_keeps_dtype(self):
        from repro.approx import odd_extension

        assert float(odd_extension(jnp.tanh)(2.0)) == pytest.approx(
            np.tanh(2.0))
        x = jnp.asarray([-1.0, 0.0, 2.0], jnp.bfloat16)
        assert odd_extension(jnp.tanh)(x).dtype == jnp.bfloat16


class TestByteAccounting:
    """Satellite: entry-dtype-aware accounting (no hard-coded f32)."""

    def test_vmem_cost_pack_per_function_dtypes(self):
        c = vmem_cost_pack([100, 50], [3, 5], dtype_bytes=[1, 2])
        assert c.table_bytes == 100 * 1 + 50 * 2
        # padded planes: metadata set by the widest member
        assert c.meta_bytes == 2 * (4 * 5 + 1) * 4

    def test_vmem_cost_pack_ragged_meta(self):
        c = vmem_cost_pack([100, 50], [3, 5], dtype_bytes=[1, 2],
                           meta_lanes=7, ragged_meta=True)
        assert c.meta_bytes == (7 * 3 + 1) * 4 + (7 * 5 + 1) * 4

    def test_dtype_list_length_validated(self):
        with pytest.raises(ValueError):
            vmem_cost_pack([100, 50], [3, 5], dtype_bytes=[1])

    def test_layout_accounting_matches_cost_model(self):
        members = [member(n) for n in ("gelu", "tanh", "exp_neg")]
        layout = quant_pack_layout(members)
        c = layout.vmem()
        assert c.table_bytes == layout.footprint_bytes
        assert c.meta_bytes == layout.meta_bytes
        assert layout.footprint_bytes == sum(m.codes_bytes for m in members)

    def test_device_pack_accounting_ignores_dummy_width_group(self):
        # a single-width pack pads the unused group vector to length 1; the
        # device-side accounting must still agree with the layout's
        layout = quant_pack_layout([member("tanh", dtype="int8")])
        pack = from_quant_layout(layout)
        assert pack.codes16.shape[0] == 1  # the dummy operand exists...
        assert pack.footprint == layout.footprint  # ...but is not counted
        assert pack.footprint_bytes == layout.footprint_bytes

    def test_quantized_pack_at_least_2x_smaller_than_f32(self):
        """Regression pin: the auto-selected quantized pack's entry storage is
        >= 2x below the f32 pack at equal Ea (the acceptance headline)."""
        names = ("gelu", "silu", "tanh", "sigmoid_sym", "softplus", "exp_neg")
        layout = quant_pack_layout([member(n) for n in names])
        f32_bytes = 4 * sum(
            build_table(n, EA, algorithm="hierarchical", omega=0.3).footprint
            for n in names)
        assert 2 * layout.footprint_bytes <= f32_bytes, (
            layout.footprint_bytes, f32_bytes)
        # and int16 (the worst case of the menu) stays strictly below f32
        l16 = quant_pack_layout([member(n, dtype="int16") for n in names])
        assert l16.footprint_bytes < f32_bytes
