"""Unit tests for the dry-run/roofline tooling: HLO collective parsing, the
analytic cost model's invariants, and the roofline term arithmetic."""

import numpy as np
import pytest

from repro.models import SHAPES_BY_NAME, get_config, shapes_for
from repro.models.config import make_attn_geom


class TestCollectiveParser:
    def _parse(self, text):
        import importlib

        dr = importlib.import_module("repro.launch.dryrun")
        return dr.collective_bytes(text)

    def test_basic_ops(self):
        hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]
  %ar = f32[8,8]{1,0} all-reduce(%y), to_apply=%add
  %a2a = bf16[4,256]{1,0} all-to-all(%z), dimensions={0}
  %rs = f32[2,8]{1,0} reduce-scatter(%w), dimensions={0}
  %cp = u32[8]{0} collective-permute(%v), source_target_pairs={{0,1}}
"""
        out = self._parse(hlo)
        assert out["all-gather"] == 16 * 1024 * 2
        assert out["all-reduce"] == 8 * 8 * 4
        assert out["all-to-all"] == 4 * 256 * 2
        assert out["reduce-scatter"] == 2 * 8 * 4
        assert out["collective-permute"] == 8 * 4
        assert out["counts"]["all-gather"] == 1

    def test_start_counted_done_skipped(self):
        hlo = """
  %s = bf16[64]{0} all-gather-start(%x)
  %d = bf16[64]{0} all-gather-done(%s)
"""
        out = self._parse(hlo)
        assert out["counts"]["all-gather"] == 1
        assert out["all-gather"] == 64 * 2

    def test_tuple_result(self):
        hlo = "  %t = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), to_apply=%add\n"
        out = self._parse(hlo)
        assert out["all-reduce"] == 2 * 8 * 4


class TestAttnGeom:
    @pytest.mark.parametrize("h,g,exp", [
        (56, 8, (64, 16, 2, 0)),   # yi: pad q 56->64, repeat kv x2
        (64, 4, (64, 16, 4, 0)),   # qwen3: repeat x4
        (24, 2, (32, 16, 8, 0)),   # starcoder2
        (14, 2, (16, 16, 8, 0)),   # internvl
        (16, 8, (16, 16, 2, 0)),   # gemma3
        (12, 12, (16, 16, 1, 4)),  # whisper: zero-pad kv groups
        (16, 16, (16, 16, 1, 0)),  # deepseek MHA
        (32, 32, (32, 32, 1, 0)),  # stablelm/zamba2
    ])
    def test_normalization(self, h, g, exp):
        geom = make_attn_geom(h, g, 128)
        assert (geom.h_eff, geom.g_eff, geom.repeat, geom.g_zero_pad) == exp
        assert geom.h_eff % geom.g_eff == 0
        assert geom.g_eff % 16 == 0  # always shards the production model axis

    def test_mask_counts_real_heads(self):
        from repro.models.attention import head_mask

        for h, g in [(56, 8), (24, 2), (12, 12), (64, 4)]:
            geom = make_attn_geom(h, g, 128)
            m = np.asarray(head_mask(geom))
            assert m.sum() == h, (h, g, m.sum())


class TestCostModel:
    def _costs(self, arch, shape_name, **kw):
        from benchmarks.cost_model import cell_costs

        cfg = get_config(arch)
        shape = SHAPES_BY_NAME[shape_name]
        return cell_costs(cfg, shape, **kw)

    def test_terms_positive_all_cells(self):
        for arch in ("yi-34b", "qwen3-moe-235b-a22b", "xlstm-125m",
                     "zamba2-1.2b", "whisper-small", "gemma3-12b"):
            cfg = get_config(arch)
            for shape in shapes_for(cfg):
                from benchmarks.cost_model import cell_costs

                c = cell_costs(cfg, shape)
                assert c.flops_dev > 0 and c.hbm_bytes_dev > 0
                assert c.ideal_flops_dev > 0

    def test_train_flops_close_to_6nd(self):
        """Dense train analytic flops within [1, 2]x of 6*N*D (remat 4/3 + attn)."""
        c = self._costs("stablelm-3b", "train_4k")
        ratio = c.flops_dev / c.ideal_flops_dev
        assert 1.0 < ratio < 2.0, ratio

    def test_moe_uses_active_params(self):
        c = self._costs("qwen3-moe-235b-a22b", "train_4k")
        cfg = get_config("qwen3-moe-235b-a22b")
        # flops must track ACTIVE (~22B), not total (235B): 6*N_total*D would be
        # ~10x the analytic number
        dense_equiv = 6.0 * cfg.param_count() * 256 * 4096 / 256
        assert c.flops_dev < 0.5 * dense_equiv

    def test_decode_ideal_bytes_floor(self):
        c = self._costs("yi-34b", "decode_32k")
        assert 0 < c.ideal_bytes_dev <= c.hbm_bytes_dev

    def test_variants_reduce_collectives(self):
        base = self._costs("yi-34b", "train_4k", variant="base")
        fsdp = self._costs("yi-34b", "train_4k", variant="fsdp")
        assert fsdp.coll_bytes_dev < base.coll_bytes_dev
        qb = self._costs("qwen3-moe-235b-a22b", "train_4k", variant="base")
        ql = self._costs("qwen3-moe-235b-a22b", "train_4k", variant="limit4")
        assert ql.coll_bytes_dev < qb.coll_bytes_dev
        xb = self._costs("xlstm-125m", "train_4k", variant="base")
        xd = self._costs("xlstm-125m", "train_4k", variant="ddp")
        assert xd.coll_bytes_dev < 0.1 * xb.coll_bytes_dev

    def test_local_window_cheaper_than_global(self):
        """gemma3's 5:1 local:global must cost less attention than all-global."""
        from benchmarks.cost_model import forward_flops

        cfg = get_config("gemma3-12b")
        from repro.models.config import AttnConfig

        all_global = cfg.replace(attn=AttnConfig(qk_norm=True))
        tok = 32 * 32768.0
        assert forward_flops(cfg, tok, 32768) < forward_flops(all_global, tok,
                                                              32768)


class TestRoofline:
    def test_fraction_bounded(self):
        from benchmarks.roofline import analyze

        for arch in ("stablelm-3b", "zamba2-1.2b"):
            cfg = get_config(arch)
            for shape in shapes_for(cfg):
                a = analyze(arch, shape.name, "16x16")
                assert 0 <= a["roofline_fraction"] <= 1.05, (arch, shape.name, a)
                assert a["dominant"] in ("compute", "memory", "collective")

    def test_variant_improves_hillclimb_cells(self):
        from benchmarks.roofline import analyze

        assert (analyze("xlstm-125m", "train_4k", "16x16", "ddp")
                ["roofline_fraction"]
                > 10 * analyze("xlstm-125m", "train_4k", "16x16", "base")
                ["roofline_fraction"])
