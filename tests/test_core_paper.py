"""Paper-claims validation: worked examples from Secs. 4-5 and the resource models."""

import math

import numpy as np
import pytest

from repro.core import (
    PAPER_FORMATS,
    FixedPointFormat,
    SecondDerivMax,
    binary_split,
    bram_count,
    build_table,
    delta_for,
    footprint,
    get_function,
    hierarchical_split,
    run_flow,
    sequential_split,
    ttest2,
)

LOG_INTERVAL = (0.625, 15.625)


class TestReferenceApproach:
    def test_fig3_log_spacing(self):
        """Fig. 3: delta ~= 0.019 (we get 0.01976; the paper rounds)."""
        fn = get_function("log")
        d = delta_for(fn, 1.25e-4, *LOG_INTERVAL)
        assert d == pytest.approx(math.sqrt(8 * 1.25e-4 * 0.625**2), rel=1e-9)
        assert 0.019 <= d <= 0.020

    def test_fig3_log_footprint(self):
        """Fig. 3: M_F ~= 770 (exact value depends on delta rounding; paper uses
        delta=0.019 -> 791, delta=0.0195 -> 771; our analytic delta gives 760)."""
        r = run_flow("log", 1.25e-4, algorithm="reference")
        assert 730 <= r.reference_footprint <= 800

    def test_delta_min_over_subintervals(self):
        """Eq. 11: reference delta equals the min over any partition's deltas."""
        fn = get_function("log")
        oracle = SecondDerivMax(fn, *LOG_INTERVAL)
        d_all = delta_for(oracle, 1e-4, *LOG_INTERVAL)
        cuts = np.linspace(*LOG_INTERVAL, 7)
        d_sub = min(
            delta_for(oracle, 1e-4, float(a), float(b))
            for a, b in zip(cuts[:-1], cuts[1:])
        )
        assert d_all <= d_sub + 1e-12

    def test_linear_function_single_segment(self):
        """f''=0 => two breakpoints for any Ea."""
        from repro.core.functions import FunctionSpec

        lin = FunctionSpec(
            name="lin", f=lambda x, xp=np: 3 * x + 1,
            d2f=lambda x, xp=np: np.zeros_like(np.asarray(x, dtype=np.float64)),
            interval=(0.0, 1.0),
        )
        d = delta_for(lin, 1e-9, 0.0, 1.0)
        assert d == 1.0
        assert footprint(d, 0.0, 1.0) == 2


class TestWorkedExamples:
    """Sec. 5.1-5.3 worked examples, log(x), Ea=1.22e-4, omega=0.3."""

    EA = 1.22e-4

    def test_binary_partition_matches_paper(self):
        b = binary_split("log", self.EA, *LOG_INTERVAL, 0.3)
        np.testing.assert_allclose(
            b.partition, [0.625, 2.5, 4.375, 8.125, 15.625], rtol=1e-12
        )
        # paper: K={97,25,29,31}, MF=182; ours differs by ceil-rounding only
        assert abs(b.footprint - 182) <= 4
        np.testing.assert_array_less(np.abs(b.counts - [97, 25, 29, 31]), 2)

    def test_hierarchical_close_to_paper(self):
        h = hierarchical_split("log", self.EA, *LOG_INTERVAL, 0.3, epsilon=0.015)
        # paper: P={0.625,1.2106,2.9073,6.2556,15.625}, MF=161
        assert h.n_intervals == 4
        assert abs(h.footprint - 161) <= 6

    def test_sequential_close_to_paper(self):
        s = sequential_split("log", self.EA, *LOG_INTERVAL, 0.3, epsilon=0.3)
        # paper: 6 sub-intervals, MF=146
        assert s.n_intervals == 6
        assert abs(s.footprint - 146) <= 4
        np.testing.assert_allclose(s.partition[:4], [0.625, 0.925, 1.525, 2.425], rtol=1e-9)

    def test_ordering_matches_paper(self):
        """Paper: sequential < hierarchical < binary < reference on this example."""
        ref = run_flow("log", self.EA, algorithm="reference").reference_footprint
        b = binary_split("log", self.EA, *LOG_INTERVAL, 0.3).footprint
        h = hierarchical_split("log", self.EA, *LOG_INTERVAL, 0.3, epsilon=0.015).footprint
        s = sequential_split("log", self.EA, *LOG_INTERVAL, 0.3, epsilon=0.3).footprint
        assert s < h < b < ref
        assert (ref - b) / ref > 0.70  # paper: 76 %
        assert (ref - h) / ref > 0.75  # paper: 79 %
        assert (ref - s) / ref > 0.78  # paper: 81 %

    @pytest.mark.parametrize("alg", ["binary", "hierarchical", "sequential"])
    def test_partitions_are_valid(self, alg):
        from repro.core import split

        r = split(alg, "log", self.EA, *LOG_INTERVAL, 0.3)
        p = r.partition
        assert p[0] == LOG_INTERVAL[0] and p[-1] == LOG_INTERVAL[1]
        assert np.all(np.diff(p) > 0)
        assert len(r.spacings) == len(r.counts) == len(p) - 1
        assert np.all(r.counts >= 2)


class TestErrorBound:
    @pytest.mark.parametrize("alg", ["reference", "binary", "hierarchical", "sequential"])
    @pytest.mark.parametrize("name", ["log", "exp", "tanh", "sigmoid", "gauss"])
    def test_max_error_never_exceeds_ea(self, alg, name):
        ea = 1e-4
        ts = build_table(name, ea, algorithm=alg, omega=0.3)
        # float slack: table eval in f64, bound is analytic
        assert ts.max_error_on_grid(n=50_001) <= ea * (1 + 1e-6)

    def test_tan_steep_interval(self):
        ts = build_table("tan", 1e-3, -1.5, 0.0, algorithm="sequential")
        assert ts.max_error_on_grid(n=50_001) <= 1e-3 * (1 + 1e-6)

    def test_out_of_range_saturates(self):
        ts = build_table("sigmoid", 1e-4, -10.0, 0.0, algorithm="binary")
        fn = get_function("sigmoid")
        lo_val = ts.eval(np.array([-100.0]))[0]
        assert lo_val == pytest.approx(float(fn.f(np.array([-10.0]))[0]), abs=1e-3)
        assert np.isfinite(ts.eval(np.array([100.0]))[0])


class TestResourceModels:
    def test_bram_paper_formula(self):
        """Sec. 7.2.1: MF=15,644 and MF=8,798 both need 16 BRAMs (14 addr bits)."""
        assert bram_count(15_644) == 16
        assert bram_count(8_798) == 16
        assert bram_count(1024) == 1
        assert bram_count(1025) == 2
        assert bram_count(81_543) == 128  # tan reference table: 17 addr bits

    def test_bram_packed_widths(self):
        from repro.core import bram_count_packed

        assert bram_count_packed(16_384, 1) == 1
        assert bram_count_packed(8_192, 2) == 1
        assert bram_count_packed(1_024, 18) == 1
        assert bram_count_packed(513, 36) == 2

    def test_vmem_cost_fraction(self):
        from repro.core import vmem_cost

        c = vmem_cost(770, 4)
        assert c.table_bytes == 770 * 4
        assert c.padded_bytes % 512 == 0
        assert 0 < c.fraction < 1e-3

    def test_fixed_point_roundtrip(self):
        fmt = FixedPointFormat(1, 32, 27)
        x = np.array([-1.5, 0.0, 0.123456789, 1.999])
        q = fmt.quantize(x)
        assert np.max(np.abs(q - x)) <= fmt.quantization_error_bound()
        np.testing.assert_allclose(fmt.from_bits(fmt.to_bits(x)), q, rtol=0, atol=0)

    def test_fixed_point_saturation(self):
        fmt = FixedPointFormat(0, 8, 8)  # unsigned Q0.8: [0, 255/256]
        assert fmt.quantize(np.array([2.0]))[0] == fmt.max_value
        assert fmt.quantize(np.array([-2.0]))[0] == 0.0

    def test_paper_formats_table3(self):
        assert PAPER_FORMATS["log"][0] == FixedPointFormat(0, 32, 28)
        assert PAPER_FORMATS["tanh"][1] == FixedPointFormat(1, 32, 31)


class TestStudentT:
    def test_t_cdf_reference_values(self):
        from repro.core import t_cdf

        # classic table values
        assert t_cdf(0.0, 10) == pytest.approx(0.5, abs=1e-12)
        assert t_cdf(1.812, 10) == pytest.approx(0.95, abs=2e-3)
        assert t_cdf(2.045, 29) == pytest.approx(0.975, abs=2e-3)
        assert t_cdf(-2.045, 29) == pytest.approx(0.025, abs=2e-3)

    def test_ttest2_decisions(self):
        rng = np.random.default_rng(0)
        g1 = rng.normal(0.0, 1.0, 30)
        g2 = rng.normal(2.0, 1.0, 30)
        r = ttest2(g1, g2)
        assert r.reject("two") == 1
        assert r.reject("left") == 1  # mu1 < mu2
        assert r.reject("right") == 0
        same = ttest2(g1, rng.normal(0.0, 1.0, 30))
        assert same.reject("two") == 0

    def test_outperforms_convention(self):
        from repro.core import outperforms

        rng = np.random.default_rng(1)
        worse = rng.normal(10.0, 1.0, 30)
        better = rng.normal(12.0, 1.0, 30)
        assert outperforms(worse, better) == (0, 1)  # G2 outperforms G1
        assert outperforms(better, worse) == (1, 0)


class TestQuantizedPacking:
    """Beyond-paper: mixed-width table packing (the paper's stated future work)."""

    @pytest.mark.parametrize("name", ["log", "tanh", "gelu", "silu"])
    @pytest.mark.parametrize("ea", [9.5367e-7, 1e-4])
    def test_error_bound_holds_quantized(self, name, ea):
        from repro.core.packing import quantize_table

        fn = get_function(name)
        qt = quantize_table(name, ea, *fn.interval, omega=0.1)
        assert qt.max_error_on_grid(n=50_001) <= ea * 1.001

    def test_bit_savings_at_ml_ea(self):
        from repro.core.packing import quantize_table

        qt = quantize_table("gelu", 1e-4, -8.0, 8.0, omega=0.1)
        assert qt.footprint_bits < 0.5 * qt.footprint_bits_fp32

    def test_bram_menu_can_lose_at_tiny_ea(self):
        """Documented negative result: the physical BRAM menu rounds 21-23-bit
        requirements up to 36 at Ea~1e-6."""
        from repro.core.packing import BRAM_WIDTHS, quantize_table

        qt = quantize_table("log", 9.5367e-7, 0.625, 15.625, omega=0.1,
                            width_menu=BRAM_WIDTHS)
        assert qt.footprint_bits >= qt.footprint_bits_fp32  # 36 > 32

    def test_rho_tradeoff(self):
        """Smaller rho -> fewer entries (coarser table) but wider entries."""
        from repro.core.packing import quantize_table

        a = quantize_table("tanh", 1e-4, -8.0, 8.0, rho=0.9, omega=0.1)
        b = quantize_table("tanh", 1e-4, -8.0, 8.0, rho=0.5, omega=0.1)
        assert b.base.footprint > a.base.footprint  # tighter interp bound
        assert b.max_error_on_grid(n=20_001) <= 1e-4 * 1.001
