"""TablePack validation: the fused multi-function pack must reproduce the
per-table runtimes bit for bit (same f32 compare/gather/FMA sequence on the
same values; the pack only rebases BRAM addresses), one pallas_call must serve
any member function, and the pack/table memory accountings must agree."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import ApproxConfig, eval_table_ref, eval_table_slope, from_spec, pack_specs
from repro.approx.table_pack import eval_pack_ref, eval_pack_slope
from repro.core import (
    build_table,
    function_names,
    pack_layout,
    vmem_cost,
    vmem_cost_pack,
)
from repro.kernels.ops import table_lookup, table_pack_lookup
from repro.kernels.table_pack_lookup import table_pack_grad_pallas, table_pack_lookup_pallas

RNG = np.random.default_rng(7)

EA = 1e-4


def _specs(names, ea=EA):
    return [build_table(n, ea, algorithm="hierarchical", omega=0.2) for n in names]


def _probe(spec, n=2048):
    """Inputs spanning the table domain plus deep out-of-range tails."""
    lo, hi, span = spec.lo, spec.hi, spec.hi - spec.lo
    return jnp.asarray(
        RNG.uniform(lo - 0.5 * span, hi + 0.5 * span, size=n).astype(np.float32))


class TestPackParity:
    """Pack evaluation == per-table evaluation, bitwise, for EVERY registered
    function — including out-of-range saturation (the address clamp) and the
    extrapolate=True edge-segment semantics."""

    def test_bit_identical_to_per_table_ref(self):
        names = function_names()
        specs = _specs(names)
        pack = pack_specs(specs)
        for name, spec in zip(names, specs):
            jt = from_spec(spec)
            x = _probe(spec)
            for ex in (False, True):
                want = jax.jit(
                    lambda v, jt=jt, ex=ex: eval_table_ref(jt, v, extrapolate=ex))(x)
                got = jax.jit(
                    lambda v, n=name, ex=ex: eval_pack_ref(pack, n, v,
                                                           extrapolate=ex))(x)
                np.testing.assert_array_equal(
                    np.asarray(got), np.asarray(want), err_msg=f"{name} ex={ex}")

    def test_slope_bit_identical(self):
        names = function_names()
        specs = _specs(names)
        pack = pack_specs(specs)
        for name, spec in zip(names, specs):
            jt = from_spec(spec)
            x = _probe(spec, n=1024)
            for ex in (False, True):
                want = jax.jit(
                    lambda v, jt=jt, ex=ex: eval_table_slope(jt, v, extrapolate=ex))(x)
                got = jax.jit(
                    lambda v, n=name, ex=ex: eval_pack_slope(pack, n, v,
                                                             extrapolate=ex))(x)
                np.testing.assert_array_equal(
                    np.asarray(got), np.asarray(want), err_msg=f"{name} ex={ex}")

    def test_matches_tablespec_oracle(self):
        """Pack eval tracks the f64 numpy oracle at f32 resolution in-domain."""
        names = ["gelu", "tanh", "exp_neg"]
        specs = _specs(names)
        pack = pack_specs(specs)
        for name, spec in zip(names, specs):
            xs = np.linspace(spec.lo, spec.hi - 1e-4, 4001)
            want = spec.eval(xs)
            got = np.asarray(eval_pack_ref(pack, name,
                                           jnp.asarray(xs, jnp.float32)))
            scale = max(1.0, float(np.max(np.abs(want))))
            assert float(np.max(np.abs(got - want))) <= 1e-5 * scale, name

    def test_saturation_and_extrapolation_semantics(self):
        spec = _specs(["gelu"])[0]
        pack = pack_specs([spec])
        far = jnp.asarray([spec.lo - 50.0, spec.hi + 50.0], jnp.float32)
        sat = np.asarray(eval_pack_ref(pack, "gelu", far))
        # clamp: pinned to the edge breakpoint values
        np.testing.assert_allclose(sat, [spec.values[0], spec.values[-1]],
                                   rtol=1e-6)
        ext = np.asarray(eval_pack_ref(pack, "gelu", far, extrapolate=True))
        # linear tails: gelu(x) ~ 0 for x << 0 and ~ x for x >> 0
        assert abs(ext[0]) < 1e-2 and abs(ext[1] - (spec.hi + 50.0)) < 1e-2


class TestPackKernel:
    def test_one_pack_call_serves_many_functions(self):
        """Acceptance: ONE TablePack pallas_call (interpret off-TPU) serves >= 2
        distinct functions from a single packed values vector, bit-identical to
        the per-table oracle under jit."""
        names = ["gelu", "tanh", "sigmoid_sym", "exp_neg"]
        specs = _specs(names)
        pack = pack_specs(specs)
        x = jnp.asarray(RNG.normal(0, 5, size=(3, 257)).astype(np.float32))
        for name, spec in zip(names, specs):
            jt = from_spec(spec)
            want = jax.jit(lambda v, jt=jt: eval_table_ref(jt, v))(x)
            got = table_pack_lookup(pack, name, x)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=name)
            # and the pack kernel == the per-table kernel, bitwise
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(table_lookup(jt, x)),
                                          err_msg=f"{name} vs per-table kernel")

    @pytest.mark.parametrize("shape", [(8,), (513,), (4, 96), (2, 3, 257),
                                       (16, 1024)])
    def test_shapes(self, shape):
        pack = pack_specs(_specs(["silu", "tanh"]))
        x = jnp.asarray(RNG.normal(0, 5, size=shape).astype(np.float32))
        for name in ("silu", "tanh"):
            got = table_pack_lookup(pack, name, x)
            want = jax.jit(lambda v, n=name: eval_pack_ref(pack, n, v))(x)
            assert got.shape == x.shape and got.dtype == x.dtype
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_block_geometry_sweep(self):
        pack = pack_specs(_specs(["silu", "gelu"]))
        x = jnp.asarray(RNG.normal(0, 5, size=(5000,)).astype(np.float32))
        want = jax.jit(lambda v: eval_pack_ref(pack, "silu", v))(x)
        for block_rows, lane in [(8, 128), (32, 256), (256, 512), (1024, 128)]:
            got = table_pack_lookup_pallas(pack, "silu", x,
                                           block_rows=block_rows, lane=lane)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fused_grad_kernel(self):
        names = ["gelu", "tanh"]
        pack = pack_specs(_specs(names))
        x = jnp.asarray(RNG.normal(0, 4, size=(7, 193)).astype(np.float32))
        for name, ex in [("gelu", True), ("tanh", False)]:
            y, dy = table_pack_grad_pallas(pack, name, x, extrapolate=ex)
            np.testing.assert_array_equal(
                np.asarray(y),
                np.asarray(jax.jit(
                    lambda v, n=name, e=ex: eval_pack_ref(pack, n, v,
                                                          extrapolate=e))(x)))
            np.testing.assert_array_equal(
                np.asarray(dy),
                np.asarray(jax.jit(
                    lambda v, n=name, e=ex: eval_pack_slope(pack, n, v,
                                                            extrapolate=e))(x)))

    def test_unknown_function_raises(self):
        pack = pack_specs(_specs(["gelu"]))
        with pytest.raises(KeyError):
            pack.fn_id("log")


class TestApproxConfigPackMode:
    def test_unary_and_grad_match_table_ref(self):
        cfg_pack = ApproxConfig(mode="table_pack", e_a=EA, omega=0.2)
        cfg_ref = ApproxConfig(mode="table_ref", e_a=EA, omega=0.2)
        x = jnp.asarray(RNG.normal(0, 4, size=(300,)).astype(np.float32))
        for name in ("gelu", "silu", "tanh", "sigmoid", "exp", "softplus"):
            a = np.asarray(jax.jit(cfg_pack.unary(name))(x))
            b = np.asarray(jax.jit(cfg_ref.unary(name))(x))
            np.testing.assert_array_equal(a, b, err_msg=name)
            ga = np.asarray(jax.vmap(jax.grad(cfg_pack.unary(name)))(x))
            gb = np.asarray(jax.vmap(jax.grad(cfg_ref.unary(name)))(x))
            np.testing.assert_array_equal(ga, gb, err_msg=f"{name} grad")

    def test_pack_is_shared_across_unary_calls(self):
        cfg = ApproxConfig(mode="table_pack", e_a=EA, omega=0.2)
        assert cfg.pack() is cfg.pack()
        f1, f2 = cfg.unary("gelu"), cfg.unary("tanh")  # both trace fine
        x = jnp.ones((8,), jnp.float32)
        assert np.isfinite(np.asarray(f1(x))).all()
        assert np.isfinite(np.asarray(f2(x))).all()

    def test_missing_pack_member_raises(self):
        cfg = ApproxConfig(mode="table_pack", e_a=EA,
                           pack_functions=("gelu",))
        with pytest.raises(KeyError):
            cfg.unary("tanh")

    def test_pack_softmax(self):
        cfg = ApproxConfig(mode="table_pack", e_a=1e-6, softmax_table=True)
        x = jnp.asarray(RNG.normal(0, 4, size=(8, 128)).astype(np.float32))
        sm = cfg.softmax(x)
        np.testing.assert_allclose(np.asarray(sm.sum(-1)), 1.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sm),
                                   np.asarray(jax.nn.softmax(x)), atol=5e-4)


class TestMemoryAccounting:
    def test_table_memory_bytes_agrees_with_vmem_cost(self):
        """Regression: TableSpec.memory_bytes must count the same lanes (incl.
        seg_count) at the same width as bram.vmem_cost."""
        for name in ("gelu", "tanh", "log"):
            spec = build_table(name, EA, algorithm="hierarchical", omega=0.2)
            for db in (2, 4, 8):
                c = vmem_cost(spec.footprint, spec.n_intervals, dtype_bytes=db)
                assert spec.memory_bytes(db) == c.table_bytes + c.meta_bytes, (
                    name, db)

    def test_pack_cost_vs_per_table(self):
        specs = _specs(["gelu", "silu", "tanh", "sigmoid_sym", "exp_neg"])
        layout = pack_layout(specs)
        c = vmem_cost_pack([s.footprint for s in specs],
                           [s.n_intervals for s in specs])
        assert c.table_bytes == sum(s.footprint for s in specs) * 4
        assert layout.vmem().padded_bytes == c.padded_bytes
        per_table = sum(vmem_cost(s.footprint, s.n_intervals).padded_bytes
                        for s in specs)
        assert c.padded_bytes <= per_table  # one residency beats F paddings

    def test_vmem_cost_pack_validates(self):
        with pytest.raises(ValueError):
            vmem_cost_pack([], [])
        with pytest.raises(ValueError):
            vmem_cost_pack([10, 20], [2])


class TestPackLayout:
    def test_values_concatenation_and_offsets(self):
        specs = _specs(["gelu", "tanh", "exp_neg"])
        layout = pack_layout(specs)
        acc = 0
        for f, s in enumerate(specs):
            assert layout.value_offset[f] == acc
            np.testing.assert_array_equal(
                layout.values[acc : acc + s.footprint], s.values)
            n = s.n_intervals
            np.testing.assert_array_equal(layout.base[f, :n], s.base + acc)
            np.testing.assert_array_equal(layout.boundaries[f, : n + 1],
                                          s.boundaries)
            assert np.all(np.isinf(layout.boundaries[f, n + 1 :]))
            acc += s.footprint
        assert layout.footprint == acc

    def test_duplicate_names_rejected(self):
        s = _specs(["gelu"])[0]
        with pytest.raises(ValueError):
            pack_layout([s, s])

    def test_empty_pack_rejected(self):
        with pytest.raises(ValueError):
            pack_layout([])
