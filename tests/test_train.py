"""Training substrate: optimizer, data determinism, checkpoint/restart, loop
fault-tolerance, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import adamw
from repro.train import CheckpointManager, TrainConfig, make_train_step, run
from tests.test_archs import make_batch, reduced


@pytest.fixture()
def tiny_model():
    cfg = reduced("stablelm-3b").replace(n_layers=2)
    return build_model(cfg)


class TestAdamW:
    def test_descends_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0, clip_norm=0.0)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = adamw.init(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.15

    def test_clip_and_schedule(self):
        cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=10, total_steps=100)
        assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(adamw.schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-2)
        assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(
            1e-3, rel=1e-2)
        params = {"w": jnp.ones((4,))}
        st = adamw.init(params)
        _, _, m = adamw.update(cfg, params, {"w": 1e6 * jnp.ones((4,))}, st)
        assert float(m["grad_norm"]) > 1e5  # measured before clipping


class TestData:
    def test_deterministic_and_restartable(self):
        cfg = DataConfig(vocab=97, global_batch=8, seq_len=16, seed=7)
        a = SyntheticLM(cfg).batch_at(12)
        b = SyntheticLM(cfg).batch_at(12)  # fresh instance, same step
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = SyntheticLM(cfg).batch_at(13)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_targets_are_shifted_stream(self):
        cfg = DataConfig(vocab=97, global_batch=2, seq_len=16)
        b = SyntheticLM(cfg).batch_at(0)
        # targets[i] is the token following tokens[i] under the generator
        assert b["tokens"].shape == b["targets"].shape
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])

    def test_host_shard_partition(self):
        cfg = DataConfig(vocab=97, global_batch=8, seq_len=4)
        p = SyntheticLM(cfg)
        full = p.batch_at(0)
        parts = [p.host_shard(full, i, 4) for i in range(4)]
        np.testing.assert_array_equal(
            np.concatenate([x["tokens"] for x in parts]), full["tokens"])


class TestCheckpoint:
    def test_roundtrip_and_atomicity(self, tmp_path, tiny_model):
        params = tiny_model.init(jax.random.key(0))
        state = {"params": params, "opt": adamw.init(params),
                 "step": jnp.asarray(5, jnp.int32)}
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(5, state)
        assert mgr.latest_step() == 5
        abstract = jax.eval_shape(lambda: state)
        restored = mgr.restore(5, abstract)
        for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(state)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0],
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # no .tmp dirs left behind
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.ones((3,))}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save_async(7, {"x": jnp.arange(10)})
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_missing_leaf_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.ones((2,))})
        with pytest.raises(KeyError):
            mgr.restore(1, jax.eval_shape(lambda: {"y": jnp.ones((2,))}))


@pytest.mark.slow
class TestLoop:
    def test_loss_decreases_and_restarts(self, tmp_path, tiny_model):
        from repro.models.config import ShapeSpec

        shape = ShapeSpec("tiny", seq_len=32, global_batch=8, kind="train")
        cfg = TrainConfig(steps=30, ckpt_every=10, ckpt_dir=str(tmp_path),
                          log_every=100,
                          opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=5,
                                                total_steps=60))
        out = run(tiny_model, shape, cfg, mesh=None, log=lambda s: None)
        assert out["final_step"] == 30
        first = np.mean(out["losses"][:5])
        last = np.mean(out["losses"][-5:])
        assert last < first, (first, last)  # synthetic stream is learnable

        # restart: resumes from step 30 checkpoint, runs 10 more
        cfg2 = TrainConfig(steps=40, ckpt_every=10, ckpt_dir=str(tmp_path),
                           log_every=100,
                           opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=5,
                                                 total_steps=60))
        out2 = run(tiny_model, shape, cfg2, mesh=None, log=lambda s: None)
        assert out2["final_step"] == 40
        assert len(out2["losses"]) == 10  # only the new steps ran

    def test_grad_accum_equivalence(self, tiny_model):
        """accum=2 must match accum=1 on the same global batch (up to fp)."""
        model = tiny_model
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": adamw.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        batch = make_batch(model.cfg, B=4, S=16)
        opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                                clip_norm=0.0)
        s1, m1 = make_train_step(model, opt, accum=1)(state, batch)
        s2, m2 = make_train_step(model, opt, accum=2)(state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
        a = jax.tree.leaves(s1["params"])[0]
        b = jax.tree.leaves(s2["params"])[0]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestServing:
    def test_batched_generation(self, tiny_model):
        from repro.serving.engine import Request, serve

        model = tiny_model
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, 100, (n,)).astype(np.int32),
                        max_new_tokens=5) for n in (3, 7, 5)]
        results = serve(model, params, reqs, batch_size=2, cache_len=64)
        assert len(results) == 3
        for r in results:
            assert r.tokens.shape[0] == 5
            assert r.tokens.dtype in (np.int32, np.int64)

    def test_greedy_matches_decode_parity(self, tiny_model):
        """Engine greedy decode equals manual argmax rollout."""
        from repro.serving.engine import DecodeEngine

        model = tiny_model
        params = model.init(jax.random.key(1))
        prompts = np.ones((2, 4), np.int32)
        eng = DecodeEngine(model, params, batch_size=2, cache_len=32)
        gen, _ = eng.generate_batch(prompts, max_new=4)

        cache = model.init_cache(2, 32)
        logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompts)}, cache)
        toks = [jnp.argmax(logits, -1)]
        for i in range(3):
            logits, cache = model.decode_step(
                params, toks[-1][:, None].astype(jnp.int32),
                jnp.asarray(4 + i, jnp.int32), cache)
            toks.append(jnp.argmax(logits, -1))
        np.testing.assert_array_equal(gen, np.stack([np.asarray(t) for t in toks], 1))


class TestStraggler:
    def test_monitor_flags_outliers(self):
        from repro.train import StragglerMonitor

        m = StragglerMonitor(factor=1.5)
        for _ in range(20):
            assert m.record(0.1) is None
        assert m.record(0.3) is not None
        assert m.flagged == 1


@pytest.mark.slow
class TestServingAcrossFamilies:
    """The engine must drive every cache family (KV, SSM state, xLSTM state)."""

    @pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-125m", "gemma3-12b"])
    def test_generate_batch(self, arch):
        from repro.serving.engine import DecodeEngine

        cfg = reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = DecodeEngine(model, params, batch_size=2, cache_len=64)
        prompts = np.ones((2, 6), np.int32)
        gen, steps = eng.generate_batch(prompts, max_new=4)
        assert gen.shape == (2, 4)
        assert steps == 4  # every sampled token counts, incl. the prefill one

    def test_temperature_sampling_differs(self):
        from repro.serving.engine import DecodeEngine

        cfg = reduced("stablelm-3b")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        prompts = np.ones((2, 4), np.int32)
        greedy = DecodeEngine(model, params, 2, 64, temperature=0.0)
        hot = DecodeEngine(model, params, 2, 64, temperature=5.0, seed=7)
        g1, _ = greedy.generate_batch(prompts, max_new=8)
        g2, _ = hot.generate_batch(prompts, max_new=8)
        assert not np.array_equal(g1, g2)
