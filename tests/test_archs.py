"""Per-architecture smoke tests: reduced configs of the same family, one forward +
one train step on CPU, asserting shapes and no NaNs; plus prefill/decode parity
checks (decode logits must match teacher-forced logits position by position)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, build_model, get_config
from repro.models.config import MoEConfig, SSMConfig


def reduced(arch_id: str):
    """Family-preserving shrink: few layers, small width, few experts, tiny vocab."""
    cfg = get_config(arch_id)
    kw = dict(d_model=64, vocab=128, remat=False)
    fam = cfg.family
    if fam == "xlstm":
        kw.update(n_layers=2, n_heads=2, n_kv_heads=2, d_ff=0)
    elif fam == "moe":
        kw.update(n_layers=2, n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
                  d_head=16, d_ff=32,
                  moe=MoEConfig(n_experts=4, top_k=2, n_shared=cfg.moe.n_shared))
    elif fam == "hybrid":
        kw.update(n_layers=5, n_heads=4, n_kv_heads=4, d_ff=128,
                  ssm=SSMConfig(state_dim=8, head_dim=16, conv_width=4, expand=2,
                                chunk=8),
                  shared_attn_every=2)  # 2 groups of 2 + 1 trailing
    elif fam == "encdec":
        kw.update(n_layers=2, n_enc_layers=2, n_heads=4, n_kv_heads=4, d_ff=128,
                  enc_len=12)
    elif fam == "vlm":
        kw.update(n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128, n_vis_tokens=4,
                  d_vis=16)
    else:  # dense
        period = max(1, cfg.attn.global_every)
        kw.update(n_layers=2 * period, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128)
    return cfg.replace(**kw)


def make_batch(cfg, B=2, S=16, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(0, 1, (B, cfg.enc_len, cfg.d_model)),
                                      jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(rng.normal(0, 1, (B, cfg.n_vis_tokens, cfg.d_vis)),
                                       jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch_id):
        cfg = reduced(arch_id)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        batch = make_batch(cfg)
        logits, aux = jax.jit(model.train_logits)(params, batch)
        assert logits.shape == (2, 16, cfg.vocab_pad)
        assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
        # one SGD step through the whole model
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        assert np.isfinite(float(loss))
        flat, _ = jax.tree.flatten(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat), "NaN in grads"
        new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
        loss2 = model.loss(new_params, batch)
        assert np.isfinite(float(loss2))

    def test_prefill_decode_shapes(self, arch_id):
        cfg = reduced(arch_id)
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        B, S = 2, 8
        batch = make_batch(cfg, B=B, S=S)
        cache = model.init_cache(B, 32)
        logits, cache = jax.jit(model.prefill)(params, batch, cache)
        assert logits.shape == (B, cfg.vocab_pad)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        step = jax.jit(model.decode_step)
        for i in range(3):
            logits, cache = step(params, tok, jnp.asarray(S + i, jnp.int32), cache)
            assert logits.shape == (B, cfg.vocab_pad)
            assert bool(jnp.isfinite(logits).all())
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ["stablelm-3b", "gemma3-12b", "zamba2-1.2b",
                                     "xlstm-125m", "whisper-small", "yi-34b"])
def test_decode_matches_teacher_forcing(arch_id):
    """Greedy decode against the cache must reproduce the teacher-forced logits."""
    cfg = reduced(arch_id)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    B, S = 2, 12
    batch = make_batch(cfg, B=B, S=S, rng_seed=3)

    full_logits, _ = model.train_logits(params, batch)  # (B, S, V)

    prefix = 6
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :prefix]
    cache = model.init_cache(B, S + 4)
    logits, cache = model.prefill(params, pre_batch, cache)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, prefix - 1]),
                               rtol=2e-2, atol=2e-2)
    step = jax.jit(model.decode_step)
    for i in range(prefix, S):
        tok = batch["tokens"][:, i : i + 1]
        logits, cache = step(params, tok, jnp.asarray(i, jnp.int32), cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, i]),
                                   rtol=2e-2, atol=2e-2,
                                   err_msg=f"pos {i}")


def test_moe_capacity_drops_are_bounded():
    """With cf=1.25 and balanced-ish routing, most tokens must be routed."""
    cfg = reduced("deepseek-moe-16b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, B=4, S=32)
    logits, aux = model.train_logits(params, batch)
    assert float(aux) > 0.5  # aux ~ 1 when perfectly balanced
    assert float(aux) < 4.0


def test_vlm_prefix_changes_logits():
    cfg = reduced("internvl2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b1 = make_batch(cfg, rng_seed=0)
    b2 = dict(b1)
    b2["patches"] = b1["patches"] + 1.0
    l1, _ = model.train_logits(params, b1)
    l2, _ = model.train_logits(params, b2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_sliding_window_restricts_attention():
    """gemma3 local layers: a token far outside every window cannot influence the
    last position through local-only layers (build a 1-group local-only variant)."""
    cfg = reduced("stablelm-3b")
    from repro.models.config import AttnConfig

    cfg = cfg.replace(attn=AttnConfig(window=4))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 16)), jnp.int32)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)  # perturb pos 0
    l1, _ = model.train_logits(params, {"tokens": toks})
    l2, _ = model.train_logits(params, {"tokens": toks2})
    # with window=4 and 2 layers, position 15 sees at most back to pos 15-2*4+... < 8
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-5, atol=1e-5)


def test_param_count_sanity():
    """Full configs: param_count() within the advertised ballpark."""
    qwen = get_config("qwen3-moe-235b-a22b")
    n = qwen.param_count()
    assert 2.0e11 < n < 2.8e11, n  # ~235B
    a = qwen.active_param_count()
    assert 1.5e10 < a < 3.0e10, a  # ~22B
    yi = get_config("yi-34b").param_count()
    assert 2.8e10 < yi < 4.0e10, yi
    ds = get_config("deepseek-moe-16b").param_count()
    assert 1.2e10 < ds < 2.2e10, ds


@pytest.mark.slow
def test_chunked_prefill_matches_full():
    """prefill_chunked (O(chunk) memory) must equal one-shot prefill."""
    cfg = reduced("stablelm-3b")
    model = build_model(cfg)
    params = model.init(jax.random.key(5))
    B, S = 2, 24
    batch = make_batch(cfg, B=B, S=S, rng_seed=6)
    c1 = model.init_cache(B, 32)
    l_full, c_full = model.prefill(params, batch, c1)
    c2 = model.init_cache(B, 32)
    l_chunk, c_chunk = model.prefill_chunked(params, batch, c2, chunk=8)
    np.testing.assert_allclose(np.asarray(l_chunk), np.asarray(l_full),
                               rtol=2e-2, atol=2e-2)
    # decoding from either cache must agree
    tok = jnp.argmax(l_full, -1)[:, None].astype(jnp.int32)
    d1, _ = model.decode_step(params, tok, jnp.asarray(S, jnp.int32), c_full)
    d2, _ = model.decode_step(params, tok, jnp.asarray(S, jnp.int32), c_chunk)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d1), rtol=2e-2,
                               atol=2e-2)
