"""TableFlash: flash attention's running-softmax exponent served from the
pack's exp_neg member, gated by an end-to-end error contract.

Three layers of checks:

1. Flash-level: for EVERY table mode and four attention geometries (dense
   causal, local sliding window, per-slot decode clocks with empty cache
   slots, non-causal cross attention), ``max |table-flash - exact-flash|``
   stays inside the provable row-wise bound from ``repro.core.attn_error``,
   and gradients through the table path are finite everywhere (including the
   clamped tail, whose custom-JVP slope is 0).
2. Kernel parity: the fused Pallas lookup is BITWISE identical to the jnp
   oracle path under jit, including the underflow-to-zero tail (for z < lo
   both return exactly 0.0 — the same weight exact f32 exp gives every
   masked/empty/pad key slot).
3. Serving: at E_a = 1e-6 the per-lookup error sits below the model's bf16
   resolution, so a greedy decode with ``attn_table=True`` must be
   TOKEN-IDENTICAL to the exact engine — through both ``serve_static`` and
   the ContinuousEngine's refill queue, on all four paper configs (stablelm
   fast; gemma3 local:global, zamba2 hybrid, xlstm are nightly ``slow``).

Plus the KV_PAD telemetry regression: chunk-padding key slots added inside
``_flash_inner`` must NOT count as clamp events in ``approx.oob.attn_exp``,
while genuine ``k_pos == -1`` empty cache slots still do.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.approx import TABLE_MODES, ApproxConfig, make_attn_exp_fn
from repro.core.attn_error import (EXP_NEG_LO, flash_abs_bound, lookup_delta,
                                   weight_error)
from repro.models import build_model
from repro.models.attention import KV_PAD, flash_attention
from repro.serving.engine import ContinuousEngine, serve_static
from tests.test_archs import reduced
from tests.test_serving import mixed_requests

EA = 1e-4
# table specs may overshoot e_a by the conformance slop (matches the rope
# parity test's allowance); fold it into the per-lookup delta fed to the bound
EA_EFF = EA * 1.02 + 1e-5

# Flash-level geometries: (causal, window, clocks, empty_slots) — the masking
# regimes the four paper configs exercise, at tiny shapes
GEOMETRIES = {
    "dense_causal": dict(causal=True, window=0, clocks=False, empty=False),
    "local_window": dict(causal=True, window=8, clocks=False, empty=False),
    "decode_clocks": dict(causal=True, window=0, clocks=True, empty=True),
    "cross_attn": dict(causal=False, window=0, clocks=False, empty=False),
}
B, SQ, T, G, QG, D = 2, 6, 24, 2, 2, 8
KV_CHUNK = 8


def _inputs(geom, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (B, SQ, G, QG, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, G, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, G, D)), jnp.float32)
    if geom["clocks"]:
        # per-slot decode clocks: each batch row at its own absolute offset
        q_pos = jnp.asarray([[T - SQ + i for i in range(SQ)],
                             [T - SQ + 3 + i for i in range(SQ)]], jnp.int32)
        k_pos = np.tile(np.arange(T, dtype=np.int32), (B, 1))
        if geom["empty"]:
            k_pos[:, T - 2:] = -1  # genuine empty cache slots
        k_pos = jnp.asarray(k_pos)
    else:
        q_pos = jnp.arange(T - SQ, T, dtype=jnp.int32)
        k_pos = jnp.arange(T, dtype=jnp.int32)
    return q, k, v, q_pos, k_pos


def _run(q, k, v, q_pos, k_pos, geom, exp_fn, kv_chunk=KV_CHUNK):
    return flash_attention(q, k, v, q_pos, k_pos, causal=geom["causal"],
                           window=geom["window"], kv_chunk=kv_chunk,
                           exp_fn=exp_fn)


# --------------------------------------------------------------------------------------
# The bound itself
# --------------------------------------------------------------------------------------

class TestBoundMath:
    def test_lookup_delta_includes_underflow_tail(self):
        # the zero tail drops at most exp(lo) of true weight (z just below
        # lo): the uniform per-lookup error is e_a plus that floor
        import math
        assert lookup_delta(1e-4) == pytest.approx(1e-4 + math.exp(EXP_NEG_LO))

    def test_weight_error_monotone_in_chunks(self):
        d = lookup_delta(1e-4)
        assert weight_error(1, d) < weight_error(3, d) < weight_error(8, d)
        with pytest.raises(ValueError):
            weight_error(0, d)

    def test_bound_scales_and_degenerates(self):
        b1 = flash_abs_bound(1e-6, 32, 8, 1.0)
        assert 0 < b1 < flash_abs_bound(1e-4, 32, 8, 1.0)
        assert flash_abs_bound(1e-6, 32, 8, 2.0) == pytest.approx(2 * b1)
        # kv_chunk > n_keys is clamped, not an error
        assert flash_abs_bound(1e-6, 4, 1024, 1.0) == \
            flash_abs_bound(1e-6, 4, 4, 1.0)
        # outside the validity region (Tp * eps_w >= 1) the bound is inf
        assert flash_abs_bound(0.5, 1 << 20, 1, 1.0) == float("inf")


# --------------------------------------------------------------------------------------
# Flash-level contract: every table mode x every geometry
# --------------------------------------------------------------------------------------

@pytest.mark.parametrize("geom_name", sorted(GEOMETRIES))
@pytest.mark.parametrize("mode", TABLE_MODES)
class TestFlashErrorContract:
    def test_error_within_bound(self, mode, geom_name):
        geom = GEOMETRIES[geom_name]
        q, k, v, q_pos, k_pos = _inputs(geom)
        fn = ApproxConfig(mode=mode, e_a=EA, omega=0.2,
                          attn_table=True).attn_exp()
        assert fn is not None
        exact = np.asarray(_run(q, k, v, q_pos, k_pos, geom, None))
        table = np.asarray(_run(q, k, v, q_pos, k_pos, geom, fn))
        bound = flash_abs_bound(EA_EFF, T, KV_CHUNK,
                                float(jnp.max(jnp.abs(v))))
        err = float(np.max(np.abs(exact - table)))
        assert np.isfinite(bound) and err <= bound, \
            f"{mode}/{geom_name}: err {err:.3e} > bound {bound:.3e}"

    def test_grads_finite(self, mode, geom_name):
        geom = GEOMETRIES[geom_name]
        q, k, v, q_pos, k_pos = _inputs(geom)
        fn = ApproxConfig(mode=mode, e_a=EA, omega=0.2,
                          attn_table=True).attn_exp()

        def loss(qq, kk, vv):
            return jnp.sum(_run(qq, kk, vv, q_pos, k_pos, geom, fn))

        grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        for g in grads:
            assert bool(jnp.isfinite(g).all()), f"{mode}/{geom_name}"


# --------------------------------------------------------------------------------------
# Kernel parity + gating
# --------------------------------------------------------------------------------------

class TestKernelParity:
    def test_pallas_bitwise_equals_oracle(self):
        cfg = ApproxConfig(mode="table_pack", e_a=EA, attn_table=True)
        pack = cfg.pack()
        # span the domain plus a deep below-lo tail (the underflow path) and
        # the pinned x = 0 edge
        x = jnp.asarray(np.concatenate([
            np.linspace(-40.0, 0.0, 2048), [0.0, -16.0, float(KV_PAD)],
        ]).astype(np.float32))
        # bitwise parity holds under jit (the conformance-matrix contract:
        # same XLA fma contraction on both sides)
        y_pal = jax.jit(make_attn_exp_fn(pack, use_pallas=True))(x)
        y_ref = jax.jit(make_attn_exp_fn(pack, use_pallas=False))(x)
        np.testing.assert_array_equal(np.asarray(y_pal), np.asarray(y_ref))
        # below lo the tail is EXACTLY 0 (masked slots keep weight 0);
        # x = lo itself is in-domain and serves exp(-16) > 0
        assert float(y_pal[-1]) == float(y_pal[0]) == 0.0
        assert float(y_pal[-2]) > 0.0
        # and the pinned hi edge is exp(0) within e_a
        assert abs(float(y_pal[-3]) - 1.0) <= EA_EFF

    def test_gating(self):
        assert ApproxConfig(mode="exact", attn_table=True).attn_exp() is None
        assert ApproxConfig(mode="table_pack_ref").attn_exp() is None
        with pytest.raises(ValueError, match="unknown approx mode"):
            ApproxConfig(mode="bogus", attn_table=True).attn_exp()
        with pytest.raises(KeyError, match="exp_neg"):
            ApproxConfig(mode="table_pack_ref", attn_table=True,
                         pack_functions=("gelu", "tanh")).attn_exp()


# --------------------------------------------------------------------------------------
# End-to-end decode identity at E_a = 1e-6 (the rope_table precedent)
# --------------------------------------------------------------------------------------

def _decode_identity(arch_id):
    """attn_table on/off must be token-identical, greedy, through BOTH
    schedulers: the only delta between the engines is _flash_inner's exp
    hook, and at e_a=1e-6 the lookup error is below bf16 resolution."""
    base = reduced(arch_id)
    outs = []
    for attn_table in (False, True):
        cfg = base.replace(approx=ApproxConfig(
            mode="table_pack_ref", e_a=1e-6, omega=0.2,
            attn_table=attn_table))
        model = build_model(cfg)
        assert (model.attn_exp is not None) == attn_table
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(17)
        reqs = mixed_requests(rng, 5, lo_new=2, hi_new=6)
        cont = ContinuousEngine(model, params, batch_size=2,
                                cache_len=64).serve(reqs)
        rng = np.random.default_rng(17)
        reqs = mixed_requests(rng, 5, lo_new=2, hi_new=6)
        stat = serve_static(model, params, reqs, batch_size=2, cache_len=64)
        outs.append((cont, stat))
    (cont_e, stat_e), (cont_t, stat_t) = outs
    for i, (a, b) in enumerate(zip(cont_e, cont_t)):
        np.testing.assert_array_equal(a.tokens, b.tokens,
                                      err_msg=f"continuous req {i}")
        assert a.steps == b.steps
    for i, (a, b) in enumerate(zip(stat_e, stat_t)):
        np.testing.assert_array_equal(a.tokens, b.tokens,
                                      err_msg=f"static req {i}")


class TestDecodeIdentity:
    def test_stablelm(self):
        _decode_identity("stablelm-3b")

    @pytest.mark.slow
    @pytest.mark.parametrize("arch_id", ["gemma3-12b", "zamba2-1.2b",
                                         "xlstm-125m"])
    def test_families(self, arch_id):
        _decode_identity(arch_id)


# --------------------------------------------------------------------------------------
# KV_PAD telemetry regression
# --------------------------------------------------------------------------------------

class TestPadTelemetry:
    def _oob_count(self, k_pos_row, kv_chunk):
        """One decode-style row (B=Sq=G=Qg=1) through instrumented flash;
        returns the approx.oob.attn_exp counter after the run."""
        obs.reset_registry()
        cfg = ApproxConfig(mode="table_pack_ref", e_a=EA, attn_table=True)
        fn = cfg.attn_exp()
        assert getattr(fn, "wants_count_mask", False)
        rng = np.random.default_rng(3)
        t = len(k_pos_row)
        q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 1, D)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (1, t, 1, D)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (1, t, 1, D)), jnp.float32)
        out = flash_attention(q, k, v, jnp.asarray([t - 1], jnp.int32),
                              jnp.asarray(k_pos_row, jnp.int32), causal=True,
                              kv_chunk=kv_chunk, exp_fn=fn)
        jax.block_until_ready(out)
        jax.effects_barrier()
        return obs.get_registry().summary()["counters"].get(
            "approx.oob.attn_exp", 0)

    def test_chunk_pads_excluded_genuine_slots_counted(self):
        try:
            obs.configure(enabled=True, device_telemetry=True)
            # T=4 at kv_chunk=4: no padding.  kv_chunk=3 pads to Tp=6 (two
            # KV_PAD slots) — the count must NOT change: pad rows are a
            # chunking artifact, not approximation events.
            base = self._oob_count([0, 1, 2, 3], kv_chunk=4)
            padded = self._oob_count([0, 1, 2, 3], kv_chunk=3)
            assert padded == base
            # a genuine empty cache slot (k_pos == -1) IS a clamp event:
            # exactly one more masked key for the single query row
            empty = self._oob_count([0, 1, 2, -1], kv_chunk=4)
            assert empty == base + 1
        finally:
            obs.disable()

    def test_masked_slot_count_is_exact(self):
        try:
            obs.configure(enabled=True, device_telemetry=True)
            # 2 genuine empty slots + alpha's first-chunk -inf seed (1 row):
            # the counter is exact, not merely monotone
            n = self._oob_count([0, 1, -1, -1], kv_chunk=4)
            assert n == 2 + 1
        finally:
            obs.disable()
