"""Full-f32-range differential harness for the folded transcendentals.

The conformance matrix samples each member's design interval; this harness
samples the ENTIRE finite f32 line — every decade from the subnormals to
``3.4e38``, both signs, plus the adversarial sets where range reduction
actually breaks (near-multiples of pi/2, exact powers of two, min/max
normals, subnormals, zeros) — and checks the folded table path against
float64 numpy, reporting per-decade max absolute error / max relative
error / max ULP distance.

Error contracts (see docs/range_reduction.md):

* ``sin`` / ``cos``: ABSOLUTE — ``|err| <= Ea'`` everywhere (|f| <= 1, and
  the Cody-Waite/Payne-Hanek fold keeps the reduced argument within ~3e-8
  of exact, so the core-table bound survives reconstruction).
* ``exp``: RELATIVE — ``|err| <= Ea' * max(1, |exp(x)|)``; the ``2^k``
  reconstruction scales the core table's absolute error by ``2^k``.
* ``log``: ABSOLUTE — ``e*ln2`` is applied in exact-ish two-word arithmetic,
  so the core bound survives the shift.

``Ea' = Ea * 1.02 + 1e-5`` matches the conformance-suite slack (f32 lerp
rounding on top of the designed f64 bound).

On XLA CPU (and TPU), f32 subnormal INPUTS flush to zero in arithmetic
(DAZ): sin/exp see ``x = 0`` there, which keeps them inside the absolute
contract trivially; the folded log recovers the true value bitwise (see
``repro.core.range_reduce.log_fold``) and is checked at full strength.

Usage:
    pytest: ``from harness.fullrange import ...`` (tests/test_range_reduce.py)
    CLI:    ``python tests/harness/fullrange.py --out REPORT_fullrange.json
            [--fast] [--ea 1e-4]`` (the nightly CI artifact)
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

import numpy as np

FOLDED_FUNCS = ("sin", "cos", "exp", "log")

# per-function f64 reference and error contract ("abs" | "rel")
_REFS = {"sin": np.sin, "cos": np.cos, "exp": np.exp, "log": np.log}
_CONTRACT = {"sin": "abs", "cos": "abs", "exp": "rel", "log": "abs"}

_MIN_NORMAL = np.float32(1.1754944e-38)
_MAX_FINITE = np.float32(3.4028235e38)


def _near_pi_over_2_multiples(rng, per_k: int) -> np.ndarray:
    """f32 values within a few ULPs of k*(pi/2) — where naive reduction loses
    all its bits.  k spans small octants through the Payne-Hanek regime."""
    ks = np.concatenate([
        np.arange(1, 40),
        rng.integers(40, 1304, 40),            # Cody-Waite regime
        rng.integers(1304, 2**20, 40),         # Payne-Hanek, moderate
        2 ** rng.integers(21, 60, 30),         # Payne-Hanek, huge
    ]).astype(np.float64)
    base = np.float32(ks * (math.pi / 2.0))
    out = [base, -base]
    for step in range(1, per_k + 1):
        up = base.copy()
        dn = base.copy()
        for _ in range(step):
            up = np.nextafter(up, np.float32(np.inf), dtype=np.float32)
            dn = np.nextafter(dn, np.float32(-np.inf), dtype=np.float32)
        out += [up, dn, -up, -dn]
    return np.concatenate(out)


def fullrange_samples(fast: bool = False, seed: int = 0) -> np.ndarray:
    """Finite-f32 sample set: log-spaced decades 10^-45..10^38 (both signs),
    subnormals, near-k*(pi/2), powers of two, extreme normals, zeros.

    ``fast=True`` is the CI fast-tier 10^+-38 subsample (a few hundred points
    per decade block instead of thousands)."""
    rng = np.random.default_rng(seed)
    per_decade = 40 if fast else 400
    decades = np.arange(-45, 39)
    mags = []
    for d in decades:
        # log-uniform within the decade, f32-rounded
        e = rng.uniform(d, d + 1, per_decade)
        mags.append(10.0 ** e)
    mag = np.concatenate(mags)
    with np.errstate(over="ignore"):
        mag = mag[np.isfinite(mag.astype(np.float32))]
    samples = [mag, -mag]
    # subnormals: bit-level uniform over the subnormal payload range
    n_sub = 50 if fast else 500
    sub_bits = rng.integers(1, 1 << 23, n_sub, dtype=np.uint32)
    sub = sub_bits.view(np.uint32).astype(np.uint32)
    sub_f = np.frombuffer(sub.tobytes(), dtype=np.float32)
    samples += [sub_f, -sub_f]
    # the adversarial trig set
    samples.append(_near_pi_over_2_multiples(rng, per_k=2 if fast else 4))
    # exact powers of two across the exponent range (exp/log fold seams)
    p2 = np.float32(2.0) ** np.arange(-126, 128, dtype=np.float32)
    samples += [p2, -p2]
    # extremes and zeros
    samples.append(np.array([
        0.0, -0.0, _MIN_NORMAL, -_MIN_NORMAL, _MAX_FINITE, -_MAX_FINITE,
        np.nextafter(np.float32(0), np.float32(1), dtype=np.float32),
        1.0, -1.0, math.pi / 4, -math.pi / 4, 2048.0, -2048.0,
    ], dtype=np.float32))
    x = np.concatenate([np.asarray(s, np.float32) for s in samples])
    return x[np.isfinite(x)]


def _ulp32(y64: np.ndarray) -> np.ndarray:
    """ULP of the f32 nearest to each f64 reference value (inf-safe)."""
    y32 = np.float64(np.float32(np.clip(y64, -1e38, 1e38)))
    return np.spacing(np.abs(y32).astype(np.float32)).astype(np.float64)


def differential_report(name: str, impl, x: np.ndarray, ea: float) -> dict:
    """Run ``impl`` (f32 in/out, vectorized) over ``x`` against f64 numpy.

    Returns a JSON-ready dict: overall + per-decade ``max_abs`` / ``max_rel``
    / ``max_ulp`` / worst inputs, plus the bound verdict for this function's
    contract.  Overflow lanes (|f64 ref| > f32 max) assert sign-correct inf
    instead of joining the error stats; log's x<=0 lanes assert the IEEE edge
    values."""
    ref64 = _REFS[name]
    if name == "log":
        with np.errstate(divide="ignore", invalid="ignore"):
            t = ref64(x.astype(np.float64))
    else:
        with np.errstate(over="ignore"):
            t = ref64(x.astype(np.float64))
    y = np.asarray(impl(x), np.float64)

    edge_fail = 0
    over = np.abs(t) > np.float64(_MAX_FINITE)
    if over.any():
        edge_fail += int(np.sum(np.sign(y[over]) * np.isinf(y[over]) !=
                                np.sign(t[over])))
    nonedge = ~over & np.isfinite(t)
    if name == "log":
        neg = x < 0
        edge_fail += int(np.sum(~np.isnan(y[neg & (x != 0)])))
        zero = x == 0
        edge_fail += int(np.sum(y[zero] != -np.inf))
        nonedge &= x > 0

    xs, ys, ts = x[nonedge], y[nonedge], t[nonedge]
    abs_err = np.abs(ys - ts)
    rel_err = abs_err / np.maximum(1.0, np.abs(ts))
    ulp_err = abs_err / _ulp32(ts)
    bound = ea * 1.02 + 1e-5
    err = rel_err if _CONTRACT[name] == "rel" else abs_err
    n_over_bound = int(np.sum(err > bound))

    dec = np.full(xs.shape, -99, np.int64)
    nz = xs != 0
    dec[nz] = np.floor(np.log10(np.abs(xs[nz].astype(np.float64)))).astype(np.int64)
    per_decade = {}
    for d in np.unique(dec):
        m = dec == d
        j = int(np.argmax(err[m]))
        per_decade[str(int(d))] = {
            "n": int(m.sum()),
            "max_abs": float(abs_err[m].max()),
            "max_rel": float(rel_err[m].max()),
            "max_ulp": float(ulp_err[m].max()),
            "worst_x": float(xs[m][j]),
        }
    j = int(np.argmax(err)) if err.size else 0
    return {
        "function": name,
        "contract": _CONTRACT[name],
        "ea": ea,
        "bound": bound,
        "n_samples": int(x.size),
        "n_checked": int(xs.size),
        "n_over_bound": n_over_bound,
        "n_edge_fail": edge_fail,
        "max_err": float(err[j]) if err.size else 0.0,
        "worst_x": float(xs[j]) if err.size else 0.0,
        "max_ulp": float(ulp_err.max()) if err.size else 0.0,
        "passed": n_over_bound == 0 and edge_fail == 0,
        "per_decade": per_decade,
    }


def run_harness(mode: str = "folded_pack_ref", ea: float = 1e-4,
                fast: bool = False, seed: int = 0) -> dict:
    """Build the folded config and report every foldable function."""
    import jax.numpy as jnp

    from repro.approx import ApproxConfig

    cfg = ApproxConfig(mode=mode, e_a=ea)
    x = fullrange_samples(fast=fast, seed=seed)
    pad = (-len(x)) % 256
    reports = {}
    for name in FOLDED_FUNCS:
        f = cfg.unary(name)

        def impl(v, _f=f):
            vp = np.pad(v, (0, pad)).reshape(1, -1)
            return np.asarray(_f(jnp.asarray(vp)))[0, : len(v)]

        reports[name] = differential_report(name, impl, x, ea)
    return {"mode": mode, "fast": fast, "seed": seed,
            "passed": all(r["passed"] for r in reports.values()),
            "functions": reports}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="REPORT_fullrange.json")
    ap.add_argument("--mode", default="folded_pack_ref")
    ap.add_argument("--ea", type=float, default=1e-4)
    ap.add_argument("--fast", action="store_true",
                    help="CI fast tier: ~10x fewer samples per decade")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    report = run_harness(mode=args.mode, ea=args.ea, fast=args.fast,
                         seed=args.seed)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    for name, r in report["functions"].items():
        print(f"{name:4s} [{r['contract']}] max_err={r['max_err']:.3e} "
              f"(bound {r['bound']:.3e}) max_ulp={r['max_ulp']:.1f} "
              f"over={r['n_over_bound']} edge_fail={r['n_edge_fail']} "
              f"-> {'PASS' if r['passed'] else 'FAIL'}")
    print(f"wrote {args.out}")
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
    raise SystemExit(main())
