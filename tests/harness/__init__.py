"""Reusable differential test harnesses (imported by tests, runnable as CLIs)."""
