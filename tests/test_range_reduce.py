"""RangeFold: reduction math, folded modes, and the full-range differential
contract.

Layers under test:
  1. ``repro.core.range_reduce`` — the raw folds against f64 numpy: Cody-Waite
     + Payne-Hanek trig reduction (including near-multiples of pi/2 and huge
     |x|), the ``2^k`` exp split, the bitwise (DAZ-immune) log mantissa split,
     and the identity-on-core guarantee that backs the folded-vs-unfolded
     bit-parity property.
  2. ``repro.approx.range_fold`` + the fused kernels — kernel/oracle bit
     parity under jit for the static AND routed folded shapes, fused-grad
     parity, and finite tangents everywhere.
  3. The full-range Ea contract via ``harness.fullrange`` (fast tier here;
     the nightly CI job runs the dense tier and uploads the decade report).
  4. Regression: ``eval_table_ref``/kernel agreement AT the domain edge
     ``x = hi`` for both extrapolate flags (the lerp-parameter-vs-address-
     clamp seam), pinned jit-to-jit.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness.fullrange import (FOLDED_FUNCS, differential_report,
                               fullrange_samples, run_harness)
from repro.approx import ApproxConfig, FOLDED_MODES, eval_folded_ref
from repro.approx.jax_table import eval_table_ref, from_spec
from repro.approx.range_fold import eval_folded_routed, eval_folded_slope
from repro.core.flow import cached_table
from repro.core.range_reduce import (EXP_CORE_INTERVAL, LOG_CORE_INTERVAL,
                                     TRIG_CW_MAX, exp_fold, log_fold, trig_fold)
from repro.kernels.table_lookup import table_lookup_pallas
from repro.kernels.table_pack_lookup import (folded_pack_grad_pallas,
                                             folded_pack_lookup_pallas)

EA = 1e-4
BOUND = EA * 1.02 + 1e-5


def _pack(mode="folded_pack"):
    return ApproxConfig(mode=mode, e_a=EA).pack()


def _probe(seed=0, n=2048):
    rng = np.random.default_rng(seed)
    x = np.concatenate([
        rng.uniform(-3, 3, n // 4),
        rng.uniform(-TRIG_CW_MAX, TRIG_CW_MAX, n // 4),
        np.float32(rng.uniform(-1, 1, n // 4)) * np.float32(1e38),
        np.float32(10.0) ** rng.uniform(-40, 38, n // 4)
        * rng.choice([-1, 1], n // 4),
    ]).astype(np.float32)
    specials = np.array([0.0, -0.0, 1e-38, -1e-38, math.pi / 2, -math.pi / 2,
                         3 * math.pi / 2, TRIG_CW_MAX, -TRIG_CW_MAX, 1e20,
                         -1e20, 1.0, math.pi / 4], np.float32)
    x = np.concatenate([x, specials])
    pad = (-len(x)) % 256
    return np.pad(x, (0, pad))


# ------------------------------------------------------------------------------------
# 1. raw reduction math vs f64
# ------------------------------------------------------------------------------------


def test_trig_fold_reduces_exactly():
    """r + q*(pi/2) (mod 2pi, with the sign flip) reproduces x: check via
    sin/cos reassembled from the EXACT f64 trig of the reduced argument."""
    x = _probe()
    r, q, sflip = jax.jit(trig_fold)(jnp.asarray(x))
    r, q, sflip = np.asarray(r, np.float64), np.asarray(q), np.asarray(sflip)
    assert np.all(np.abs(r) <= math.pi / 4 + 1e-6)
    ys, yc = np.sin(r), np.cos(r)
    sin_rec = np.select([q == 0, q == 1, q == 2, q == 3], [ys, yc, -ys, -yc])
    sin_rec = np.where(sflip, -sin_rec, sin_rec)
    err = np.abs(sin_rec - np.sin(x.astype(np.float64)))
    assert err.max() < 1e-6, err.max()


def test_trig_fold_near_half_pi_multiples():
    """The catastrophic-cancellation set: f32 neighbors of k*(pi/2)."""
    ks = np.concatenate([np.arange(1, 50),
                         2 ** np.arange(6, 58, dtype=np.int64)])
    base = np.float32(ks.astype(np.float64) * (math.pi / 2))
    xs = [base]
    for _ in range(3):
        xs.append(np.nextafter(xs[-1], np.float32(np.inf), dtype=np.float32))
    x = np.concatenate([v for v in xs] + [-v for v in xs])
    pad = (-len(x)) % 256
    x = np.pad(x, (0, pad))
    r, q, sflip = jax.jit(trig_fold)(jnp.asarray(x))
    r, q, sflip = np.asarray(r, np.float64), np.asarray(q), np.asarray(sflip)
    ys, yc = np.sin(r), np.cos(r)
    sin_rec = np.select([q == 0, q == 1, q == 2, q == 3], [ys, yc, -ys, -yc])
    sin_rec = np.where(sflip, -sin_rec, sin_rec)
    err = np.abs(sin_rec - np.sin(x.astype(np.float64)))
    assert err.max() < 1e-6, err.max()


def test_exp_fold_split():
    """exp(x) = 2^k * exp(r) with r in the core interval, to f64 accuracy."""
    x = _probe(seed=1)
    m = np.abs(x) < 88.0  # stay inside f64-comparable range
    r, k = jax.jit(exp_fold)(jnp.asarray(x))
    r, k = np.asarray(r, np.float64)[m], np.asarray(k, np.int64)[m]
    lo, hi = EXP_CORE_INTERVAL
    assert np.all((r >= lo) & (r <= hi))
    rec = np.exp(r) * np.exp2(k.astype(np.float64))
    t = np.exp(x.astype(np.float64)[m])
    rel = np.abs(rec - t) / t
    assert rel.max() < 1e-6, rel.max()


def test_log_fold_split_bitwise_subnormals():
    """x = m * 2^e with m in [~sqrt2/2, sqrt2); exact for subnormals too
    (the mantissa normalization is bitwise, immune to XLA's DAZ flush)."""
    rng = np.random.default_rng(2)
    bits = rng.integers(1, 1 << 23, 300, dtype=np.uint32)
    sub = np.frombuffer(bits.astype(np.uint32).tobytes(), np.float32)
    x = np.concatenate([np.float32(10.0) ** rng.uniform(-38, 38, 700), sub])
    x = np.pad(x.astype(np.float32), (0, (-len(x)) % 256), constant_values=1.0)
    m, e = jax.jit(log_fold)(jnp.asarray(x))
    m, e = np.asarray(m, np.float64), np.asarray(e, np.float64)
    lo, hi = LOG_CORE_INTERVAL
    assert np.all((m >= lo) & (m <= hi))
    rec = np.log(m) + e * math.log(2.0)
    err = np.abs(rec - np.log(x.astype(np.float64)))
    assert err.max() < 1e-5, err.max()


def test_identity_on_core_interval():
    """|x| < pi/4: the fold is a bit-exact identity (k=0, r=x) — the basis of
    the folded-vs-unfolded parity property."""
    rng = np.random.default_rng(3)
    x = np.float32(rng.uniform(-0.78, 0.78, 512))
    r, q, sflip = trig_fold(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(r), x)
    assert not np.asarray(q).any() and not np.asarray(sflip).any()
    xr = np.float32(rng.uniform(-0.34, 0.34, 512))
    r2, k = exp_fold(jnp.asarray(xr))
    np.testing.assert_array_equal(np.asarray(r2), xr)
    assert not np.asarray(k).any()


# ------------------------------------------------------------------------------------
# 2. folded modes: kernel/oracle parity and tangents
# ------------------------------------------------------------------------------------


@pytest.mark.parametrize("name", FOLDED_FUNCS)
def test_folded_kernel_bit_parity(name):
    """Fused fold+lookup kernel == jnp oracle, bitwise under jit, across the
    full range including non-finite lanes."""
    pack = _pack()
    x = jnp.asarray(np.concatenate([
        _probe(seed=4), np.array([np.inf, -np.inf, np.nan], np.float32),
        np.zeros(253, np.float32)]).reshape(1, -1))
    got = np.asarray(folded_pack_lookup_pallas(pack, name, x))
    want = np.asarray(jax.jit(lambda v: eval_folded_ref(pack, name, v))(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("name", FOLDED_FUNCS)
def test_folded_grad_kernel_parity(name):
    """Fused (y, dy) kernel: y bit-matches the value kernel, dy bit-matches
    the jnp chain-rule slope oracle."""
    pack = _pack()
    x = jnp.asarray(_probe(seed=5).reshape(1, -1))
    y, dy = folded_pack_grad_pallas(pack, name, x)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(folded_pack_lookup_pallas(pack, name, x)))
    want = np.asarray(jax.jit(
        lambda v: eval_folded_slope(pack, name, v))(x))
    np.testing.assert_array_equal(np.asarray(dy), want)


@pytest.mark.parametrize("name", FOLDED_FUNCS)
def test_folded_routed_parity(name):
    """Routed folded shape: kernel and oracle share the fold code; parity
    reduces to the routed dispatch contract (jit-to-jit)."""
    pack = _pack("folded_routed_pack")
    x = jnp.asarray(_probe(seed=6).reshape(1, -1))
    got = np.asarray(jax.jit(
        lambda v: eval_folded_routed(pack, name, v, use_pallas=True))(x))
    want = np.asarray(jax.jit(
        lambda v: eval_folded_routed(pack, name, v, use_pallas=False))(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mode", FOLDED_MODES)
def test_folded_unary_grads_finite(mode):
    """Tangents through every folded unary are finite over the full range."""
    cfg = ApproxConfig(mode=mode, e_a=EA)
    x = jnp.asarray(_probe(seed=7).reshape(1, -1))
    for name in FOLDED_FUNCS:
        f = cfg.unary(name)
        g = jax.grad(lambda v, _f=f: jnp.sum(jnp.where(
            jnp.isfinite(_f(v)), _f(v), 0.0)))(x)
        assert np.isfinite(np.asarray(g)).all(), (mode, name)


def test_folded_mode_serves_plain_members_too():
    """folded_* is a superset of the plain pack modes: non-foldable members
    fall through bit-identically to table_pack / routed_pack."""
    x = jnp.asarray(_probe(seed=8).reshape(1, -1))
    for folded, plain in (("folded_pack", "table_pack"),
                          ("folded_routed_pack", "routed_pack")):
        pf = ApproxConfig(mode=folded, e_a=EA)
        pp = ApproxConfig(mode=plain, e_a=EA,
                          pack_functions=pf.pack().names)
        got = np.asarray(jax.jit(pf.unary("gelu"))(x))
        want = np.asarray(jax.jit(pp.unary("gelu"))(x))
        np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------------------------------
# 3. the full-range differential contract (fast tier)
# ------------------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["folded_pack", "folded_pack_ref"])
def test_fullrange_ea_contract_fast(mode):
    """sin/cos/exp/log meet their Ea contracts over the 10^+-38 log-spaced
    subsample (the nightly job runs the dense tier)."""
    report = run_harness(mode=mode, ea=EA, fast=True)
    for name, r in report["functions"].items():
        assert r["passed"], (mode, name, r["max_err"], r["worst_x"],
                             r["n_edge_fail"])


def test_harness_reports_per_decade():
    """The report covers the decade spectrum it claims to sample."""
    x = fullrange_samples(fast=True)
    rep = differential_report("sin", lambda v: np.sin(v.astype(np.float64)),
                              x, EA)
    decades = sorted(int(d) for d in rep["per_decade"])
    assert decades[0] <= -40 and decades[-1] >= 37
    assert rep["passed"]


# ------------------------------------------------------------------------------------
# 4. regression: the x = hi edge seam (ISSUE 8 satellite bugfix)
# ------------------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["gelu", "silu", "softplus", "tanh"])
@pytest.mark.parametrize("extrapolate", [False, True])
def test_table_edge_hi_kernel_ref_agree(name, extrapolate):
    """At exactly ``x = hi`` (and its f32 neighbors) the jnp ref and the
    Pallas kernel agree BITWISE under jit for both extrapolate flags: the
    ref's unclamped last-segment lerp parameter and the kernel's address
    clamp resolve to the same value.  Pinned as a regression — this seam is
    where grid-sampled conformance can't look."""
    spec = cached_table(name, EA, None, None, algorithm="hierarchical",
                        omega=0.3)
    jt = from_spec(spec)
    b = np.asarray(jt.boundaries)
    lo, hi = np.float32(b[0]), np.float32(b[jt.n_intervals])
    probes = np.array([
        lo, np.nextafter(lo, np.float32(-np.inf), dtype=np.float32),
        np.nextafter(lo, np.float32(np.inf), dtype=np.float32),
        hi, np.nextafter(hi, np.float32(-np.inf), dtype=np.float32),
        np.nextafter(hi, np.float32(np.inf), dtype=np.float32),
        hi + np.float32(1.0), lo - np.float32(1.0),
    ], dtype=np.float32)
    x = jnp.asarray(np.pad(probes, (0, 256 - len(probes))).reshape(1, -1))
    ref = np.asarray(jax.jit(
        lambda v: eval_table_ref(jt, v, extrapolate=extrapolate))(x))
    ker = np.asarray(table_lookup_pallas(jt, x, extrapolate=extrapolate))
    np.testing.assert_array_equal(ref, ker)


def test_table_edge_hi_semantics():
    """Value semantics AT the edge: extrapolate=False saturates at the hi
    breakpoint value for all x >= hi; extrapolate=True continues the last
    chord linearly beyond it."""
    spec = cached_table("gelu", EA, None, None, algorithm="hierarchical",
                        omega=0.3)
    jt = from_spec(spec)
    hi = np.float32(np.asarray(jt.boundaries)[jt.n_intervals])
    probes = np.array([hi, hi + 1, hi + 100], np.float32)
    x = jnp.asarray(np.pad(probes, (0, 253)).reshape(1, -1))
    clamped = np.asarray(eval_table_ref(jt, x, extrapolate=False))[0, :3]
    assert clamped[0] == clamped[1] == clamped[2]
    ext = np.asarray(eval_table_ref(jt, x, extrapolate=True))[0, :3]
    slope01 = ext[1] - ext[0]
    assert abs((ext[2] - ext[1]) / 99.0 - slope01) < 1e-3
    assert abs(float(clamped[0]) - float(ext[0])) < 1e-6
