"""RoutedPack validation: dynamic per-row fn_id dispatch must be BIT-IDENTICAL
(under jit) to the corresponding static-fn_id dispatches for every registered
function, in both the f32 and the quantized pack; re-routing must reuse one
compiled executable; and member lookup must fail loudly (KeyError naming the
members) for unknown names AND out-of-range integer ids."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import ApproxConfig, from_quant_layout, make_routed_fn, pack_specs
from repro.approx.table_pack import (
    eval_pack_ref,
    eval_quant_pack_ref,
    eval_routed_quant_ref,
    eval_routed_quant_slope,
    eval_routed_ref,
    eval_routed_slope,
    resolve_fn_ids,
    routed_extr_flags,
)
from repro.core import cached_table, function_names, plan_quant_member, quant_pack_layout
from repro.kernels.routed_pack_lookup import (
    routed_pack_grad_pallas,
    routed_pack_lookup_pallas,
    routed_quant_pack_grad_pallas,
    routed_quant_pack_lookup_pallas,
    tile_routed_rows,
)
from repro.kernels.table_pack_lookup import (
    quant_pack_grad_pallas,
    quant_pack_lookup_pallas,
    table_pack_grad_pallas,
    table_pack_lookup_pallas,
)

RNG = np.random.default_rng(17)

EA = 1e-4

_CACHE = {}


def f32_pack():
    if "f32" not in _CACHE:
        _CACHE["f32"] = pack_specs([cached_table(n, EA)
                                    for n in function_names()])
    return _CACHE["f32"]


def quant_pack():
    if "quant" not in _CACHE:
        _CACHE["quant"] = from_quant_layout(quant_pack_layout(
            [plan_quant_member(n, EA) for n in function_names()]))
    return _CACHE["quant"]


def mixed_width_pack():
    """Forced int8 + int16 members in one pack: the runtime width-group
    select must pick the right codes vector per row."""
    if "mixed" not in _CACHE:
        dtypes = {"gelu": "int8", "tanh": "int16", "log": "int16",
                  "sigmoid": "int8"}
        _CACHE["mixed"] = from_quant_layout(quant_pack_layout(
            [plan_quant_member(n, EA, dtype=d) for n, d in dtypes.items()]))
    return _CACHE["mixed"]


def domain_probe(pack, fid, n=512):
    """One row spanning member fid's table domain plus out-of-range tails."""
    if hasattr(pack, "n_max"):  # TablePack: padded boundary planes
        lo = float(pack.boundaries[fid, 0])
        hi = float(pack.boundaries[fid, pack.n_intervals[fid]])
    else:
        bo = pack.bounds_offset(fid)
        lo = float(pack.boundaries[bo])
        hi = float(pack.boundaries[bo + pack.n_intervals[fid]])
    span = hi - lo
    return RNG.uniform(lo - 0.5 * span, hi + 0.5 * span, n).astype(np.float32)


KERNELS = {
    "f32": (f32_pack, routed_pack_lookup_pallas, table_pack_lookup_pallas,
            routed_pack_grad_pallas, table_pack_grad_pallas, eval_routed_ref,
            eval_routed_slope),
    "quant": (quant_pack, routed_quant_pack_lookup_pallas,
              quant_pack_lookup_pallas, routed_quant_pack_grad_pallas,
              quant_pack_grad_pallas, eval_routed_quant_ref,
              eval_routed_quant_slope),
}


@pytest.mark.parametrize("kind", ["f32", "quant"])
class TestRoutedBitParity:
    """Acceptance: routed == static, bitwise, for EVERY registered function."""

    def test_every_function_matches_static_dispatch(self, kind):
        build, routed, static, *_ = KERNELS[kind]
        pack = build()
        for fid in range(pack.n_functions):
            x = jnp.asarray(np.stack([domain_probe(pack, fid)] * 2))
            ids = np.full((2,), fid, np.int64)
            for ex in (False, True):
                got = routed(pack, ids, x, extrapolate=ex)
                want = static(pack, fid, x, extrapolate=ex)
                np.testing.assert_array_equal(
                    np.asarray(got), np.asarray(want),
                    err_msg=f"{pack.names[fid]} ex={ex}")

    def test_mixed_rows_match_per_row_static(self, kind):
        build, routed, static, _, _, oracle, _ = KERNELS[kind]
        pack = build()
        ids = list(range(pack.n_functions))
        x = jnp.asarray(np.stack([domain_probe(pack, f) for f in ids]))
        got = np.asarray(routed(pack, ids, x))
        for r, fid in enumerate(ids):
            want = np.asarray(static(pack, fid, x[r]))
            np.testing.assert_array_equal(got[r], want,
                                          err_msg=pack.names[fid])
        # and the jnp where-select oracle reproduces the kernel bitwise
        ref = jax.jit(lambda v: oracle(pack, ids, v))(x)
        np.testing.assert_array_equal(got, np.asarray(ref))

    def test_grad_kernel_matches_static_and_oracle(self, kind):
        build, _, _, routed_g, static_g, oracle, oracle_slope = KERNELS[kind]
        pack = build()
        ids = [(3 * r) % pack.n_functions for r in range(5)]
        x = jnp.asarray(np.stack([domain_probe(pack, f, n=256) for f in ids]))
        for ex in (False, True):
            y, dy = routed_g(pack, ids, x, extrapolate=ex)
            for r, fid in enumerate(ids):
                ys, dys = static_g(pack, fid, x[r], extrapolate=ex)
                np.testing.assert_array_equal(np.asarray(y[r]), np.asarray(ys))
                np.testing.assert_array_equal(np.asarray(dy[r]),
                                              np.asarray(dys))
            np.testing.assert_array_equal(
                np.asarray(y),
                np.asarray(jax.jit(
                    lambda v, e=ex: oracle(pack, ids, v, extrapolate=e))(x)))
            np.testing.assert_array_equal(
                np.asarray(dy),
                np.asarray(jax.jit(
                    lambda v, e=ex: oracle_slope(pack, ids, v,
                                                 extrapolate=e))(x)))

    def test_per_member_extrapolate_flags(self, kind):
        """Mixed edge semantics in one call: each row honors ITS member's
        extrapolate flag, matching the per-row static dispatch."""
        build, routed, static, *_ = KERNELS[kind]
        pack = build()
        F = pack.n_functions
        flags = tuple(f % 2 == 0 for f in range(F))
        ids = list(range(F))
        x = jnp.asarray(np.stack([domain_probe(pack, f, n=128) for f in ids]))
        got = np.asarray(routed(pack, ids, x, extrapolate=flags))
        for r, fid in enumerate(ids):
            want = np.asarray(static(pack, fid, x[r],
                                     extrapolate=flags[fid]))
            np.testing.assert_array_equal(got[r], want,
                                          err_msg=pack.names[fid])


class TestRoutedQuantWidthGroups:
    def test_mixed_int8_int16_rows(self):
        pack = mixed_width_pack()
        assert set(pack.entry_bits) == {8, 16}
        ids = [0, 1, 2, 3, 2, 0]
        x = jnp.asarray(np.stack([domain_probe(pack, f, n=200) for f in ids]))
        got = np.asarray(routed_quant_pack_lookup_pallas(pack, ids, x))
        for r, fid in enumerate(ids):
            want = np.asarray(quant_pack_lookup_pallas(pack, fid, x[r]))
            np.testing.assert_array_equal(got[r], want,
                                          err_msg=pack.names[fid])


class TestOneExecutable:
    def test_rerouting_does_not_recompile(self):
        """The whole point: fn_ids is a runtime operand, so a new routing
        reuses the cached executable (vs one specialization per member in the
        static path)."""
        from repro.kernels.routed_pack_lookup import _routed_call
        if not hasattr(_routed_call, "_cache_size"):
            pytest.skip("jit cache introspection unavailable")
        pack = f32_pack()
        x = jnp.asarray(RNG.normal(0, 3, (4, 160)).astype(np.float32))
        routed_pack_lookup_pallas(pack, [0, 1, 2, 3], x)
        size = _routed_call._cache_size()
        routed_pack_lookup_pallas(pack, [3, 2, 1, 0], x)
        routed_pack_lookup_pallas(pack, "tanh", x)
        assert _routed_call._cache_size() == size

    def test_traced_fn_ids(self):
        """Router outputs (traced int vectors) route without retracing per
        assignment, and out-of-range dynamic ids clamp like the kernels."""
        pack = f32_pack()
        x = jnp.asarray(RNG.normal(0, 3, (3, 96)).astype(np.float32))

        @jax.jit
        def serve(ids, v):
            return routed_pack_lookup_pallas(pack, ids, v)

        ids = jnp.asarray([1, 0, 2], jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(serve(ids, x)),
            np.asarray(routed_pack_lookup_pallas(pack, [1, 0, 2], x)))
        big = jnp.asarray([1, 0, 10_000], jnp.int32)  # clamps to last member
        np.testing.assert_array_equal(
            np.asarray(serve(big, x))[2],
            np.asarray(table_pack_lookup_pallas(pack, pack.n_functions - 1,
                                                x[2])))


class TestMemberLookupErrors:
    """Regression: unknown members fail with a KeyError naming the offender
    and listing the pack, never an opaque tuple IndexError."""

    @pytest.mark.parametrize("build", [f32_pack, quant_pack])
    def test_unknown_name_lists_members(self, build):
        pack = build()
        with pytest.raises(KeyError, match="nope.*not in pack"):
            pack.member_id("nope")

    @pytest.mark.parametrize("build", [f32_pack, quant_pack])
    def test_out_of_range_id_lists_members(self, build):
        pack = build()
        for bad in (99, -1):
            with pytest.raises(KeyError, match="out of range.*members"):
                pack.member_id(bad)

    def test_eval_and_kernel_paths_raise_keyerror(self):
        pack, qpack = f32_pack(), quant_pack()
        x = jnp.ones((8,), jnp.float32)
        with pytest.raises(KeyError):
            eval_pack_ref(pack, 99, x)
        with pytest.raises(KeyError):
            eval_quant_pack_ref(qpack, 99, x)
        with pytest.raises(KeyError):
            table_pack_lookup_pallas(pack, 99, x)
        with pytest.raises(KeyError):
            quant_pack_lookup_pallas(qpack, -1, x)

    def test_resolve_fn_ids_validation(self):
        pack = f32_pack()
        with pytest.raises(KeyError, match="nope"):
            resolve_fn_ids(pack, ["gelu", "nope"], 2)
        with pytest.raises(KeyError, match="out of range"):
            resolve_fn_ids(pack, [0, 99], 2)
        with pytest.raises(KeyError, match="out of range"):
            # concrete (non-traced) arrays are validated like sequences
            resolve_fn_ids(pack, jnp.asarray([0, 99], jnp.int32), 2)
        with pytest.raises(ValueError, match="does not match"):
            resolve_fn_ids(pack, [0, 1, 2], 2)
        ids = resolve_fn_ids(pack, "tanh", 3)
        np.testing.assert_array_equal(
            np.asarray(ids), np.full(3, pack.fn_id("tanh"), np.int32))

    def test_extr_flags_validation(self):
        pack = f32_pack()
        with pytest.raises(ValueError, match="one flag per member"):
            routed_extr_flags(pack, (True, False))


class TestRoutingScalars:
    def test_layout_offsets_agree_with_pack(self):
        """QuantPackLayout.bounds_offsets/lane_offsets are the design-layer
        mirror of the runtime's prefetched operands — they must agree."""
        from repro.core import quant_pack_layout

        layout = quant_pack_layout(
            [plan_quant_member(n, EA) for n in ("gelu", "tanh", "log")])
        pack = from_quant_layout(layout)
        n_arr, bo, lo, bits = pack.routing_scalars()
        np.testing.assert_array_equal(bo, layout.bounds_offsets)
        np.testing.assert_array_equal(lo, layout.lane_offsets)
        np.testing.assert_array_equal(n_arr,
                                      np.asarray(layout.n_intervals, np.int32))
        np.testing.assert_array_equal(bits,
                                      np.asarray(layout.entry_bits, np.int32))


class TestTiling:
    @pytest.mark.parametrize("shape", [(1,), (3,), (2, 5), (4, 257),
                                       (3, 2, 130), (2, 1024)])
    def test_shapes_round_trip(self, shape):
        pack = f32_pack()
        x = jnp.asarray(RNG.normal(0, 3, shape).astype(np.float32))
        ids = [r % pack.n_functions for r in range(shape[0])]
        got = routed_pack_lookup_pallas(pack, ids, x)
        assert got.shape == x.shape and got.dtype == x.dtype
        want = jax.jit(lambda v: eval_routed_ref(pack, ids, v))(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_block_cols_sweep(self):
        pack = f32_pack()
        x = jnp.asarray(RNG.normal(0, 3, (3, 1000)).astype(np.float32))
        want = np.asarray(routed_pack_lookup_pallas(pack, [0, 1, 2], x))
        for bc in (128, 256, 1024):
            got = routed_pack_lookup_pallas(pack, [0, 1, 2], x, block_cols=bc)
            np.testing.assert_array_equal(np.asarray(got), want)

    def test_zero_dim_input_rejected(self):
        with pytest.raises(ValueError, match="leading row axis"):
            tile_routed_rows(jnp.float32(1.0), 128)


class TestMakeRoutedFn:
    def test_values_and_grads_match_static(self):
        pack = f32_pack()
        names = ["gelu", "tanh", "silu"]
        f = make_routed_fn(pack, names)
        x = jnp.asarray(RNG.normal(0, 3, (3, 120)).astype(np.float32))
        y = np.asarray(jax.jit(f)(x))
        g = np.asarray(jax.grad(lambda v: f(v).sum())(x))
        for r, n in enumerate(names):
            np.testing.assert_array_equal(
                y[r], np.asarray(table_pack_lookup_pallas(pack, n, x[r])))
            _, dys = table_pack_grad_pallas(pack, n, x[r])
            np.testing.assert_array_equal(g[r], np.asarray(dys))

    def test_ref_variant_matches_kernel(self):
        for pack in (f32_pack(), quant_pack()):
            ids = [2, 0, 1]
            x = jnp.asarray(RNG.normal(0, 3, (3, 64)).astype(np.float32))
            a = jax.jit(make_routed_fn(pack, ids, use_pallas=True))(x)
            b = jax.jit(make_routed_fn(pack, ids, use_pallas=False))(x)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_quant_grads_finite(self):
        f = make_routed_fn(quant_pack(), [0, 5, 9])
        x = jnp.asarray(RNG.normal(0, 2, (3, 80)).astype(np.float32))
        g = np.asarray(jax.grad(lambda v: f(v).sum())(x))
        assert np.isfinite(g).all()


class TestApproxConfigRoutedModes:
    def test_routed_unary_matches_pack_unary(self):
        cfg_r = ApproxConfig(mode="routed_pack", e_a=EA, omega=0.2)
        cfg_p = ApproxConfig(mode="table_pack", e_a=EA, omega=0.2)
        x = jnp.asarray(RNG.normal(0, 4, (300,)).astype(np.float32))
        for name in ("gelu", "silu", "tanh", "sigmoid", "exp", "softplus"):
            np.testing.assert_array_equal(
                np.asarray(jax.jit(cfg_r.unary(name))(x)),
                np.asarray(jax.jit(cfg_p.unary(name))(x)), err_msg=name)
            np.testing.assert_array_equal(
                np.asarray(jax.vmap(jax.grad(cfg_r.unary(name)))(x)),
                np.asarray(jax.vmap(jax.grad(cfg_p.unary(name)))(x)),
                err_msg=f"{name} grad")

    def test_routed_quant_unary_matches_quant_unary(self):
        cfg_r = ApproxConfig(mode="routed_quant_pack", e_a=EA, omega=0.2)
        cfg_q = ApproxConfig(mode="quant_pack", e_a=EA, omega=0.2)
        x = jnp.asarray(RNG.normal(0, 4, (200,)).astype(np.float32))
        for name in ("gelu", "tanh"):
            np.testing.assert_array_equal(
                np.asarray(jax.jit(cfg_r.unary(name))(x)),
                np.asarray(jax.jit(cfg_q.unary(name))(x)), err_msg=name)

    @pytest.mark.parametrize("mode", ["routed_pack", "routed_pack_ref",
                                      "routed_quant_pack", "table_pack",
                                      "exact"])
    def test_routed_fn_matches_per_slot_unary(self, mode):
        """The MoE demo contract: one routed call == per-slot static unaries,
        including the odd-extended tanh rows."""
        cfg = ApproxConfig(mode=mode, e_a=EA, omega=0.2)
        slots = ("gelu", "silu", "tanh", "sigmoid", "softplus", "exp")
        f = cfg.routed_fn(slots)
        x = jnp.asarray(RNG.normal(0, 3, (len(slots), 64)).astype(np.float32))
        y = np.asarray(jax.jit(f)(x))
        for i, n in enumerate(slots):
            np.testing.assert_array_equal(
                y[i], np.asarray(jax.jit(cfg.unary(n))(x[i])),
                err_msg=f"{mode}:{n}")
        g = np.asarray(jax.grad(lambda v: f(v).sum())(x))
        assert np.isfinite(g).all(), mode

    def test_routed_fn_unknown_member_raises(self):
        cfg = ApproxConfig(mode="routed_pack", e_a=EA,
                           pack_functions=("gelu",))
        with pytest.raises(KeyError, match="pack_functions"):
            cfg.routed_fn(("gelu", "tanh"))

    def test_routed_demo_helper(self):
        from repro.models.common import routed_activation
        cfg = ApproxConfig(mode="routed_pack", e_a=EA, omega=0.2)
        f = routed_activation(cfg, ["gelu", "tanh"])
        x = jnp.asarray(RNG.normal(0, 2, (2, 32)).astype(np.float32))
        y = np.asarray(f(x))
        assert y.shape == (2, 32) and np.isfinite(y).all()
