"""Design-space planner (core.design): candidate enumeration, Pareto
filtering, and budgeted selection — the layer every poly_pack artifact rides.

The pinned regressions encode the PR's headline claim at Ea=1e-4: degree-2
chord entries are strictly fewer than degree-1 entries on exp/tanh (the
curvature-heavy members), and the planner's auto pick needs strictly fewer
entries than the linear-f32 pack.  The hypothesis property drives
``plan(budget)`` across random budgets and function subsets: every returned
member meets Ea on a dense grid, and the plan's bytes fit the budget whenever
one was given — the budget trades bytes for runtime cost, never accuracy.

Profiles follow test_properties.py: ``ci`` (default) keeps examples small;
``HYPOTHESIS_PROFILE=nightly`` widens the sweep.
"""

import os

import pytest

from repro.core import design, function_names
from repro.core.design import enumerate_candidates, pareto_front, plan

try:  # the property test widens under hypothesis; pinned cases always run
    from hypothesis import given, settings, strategies as st

    settings.register_profile("ci", max_examples=12, deadline=None)
    settings.register_profile("nightly", max_examples=75, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

EA = 1e-4
# small menu set: members are lru-cached, so only the first build pays
NAMES = ("tanh", "exp_neg", "gelu", "sigmoid_sym")


def _entries(cands, degree, dtype="f32"):
    sel = [c.entries for c in cands if c.degree == degree and c.dtype == dtype]
    assert sel, f"no degree-{degree} {dtype} candidate"
    return min(sel)


class TestCandidates:
    def test_menu_covers_degrees_and_dtypes(self):
        cands = enumerate_candidates("tanh", EA)
        assert {c.degree for c in cands} == set(design.POLY_DEGREES)
        # f32 is always feasible; integer codings may drop out per degree
        assert "f32" in {c.dtype for c in cands}

    def test_every_candidate_meets_ea(self):
        for c in enumerate_candidates("gelu", EA):
            assert c.member.max_error_on_grid(n=4001) <= EA * (1 + 1e-6), \
                (c.degree, c.dtype)

    def test_bad_dtype_rejected(self):
        with pytest.raises(ValueError, match="dtype"):
            enumerate_candidates("tanh", EA, dtypes=("int4",))


class TestParetoFront:
    def test_front_is_nondominated_and_sorted(self):
        for name in ("tanh", "exp"):
            front = pareto_front(enumerate_candidates(name, EA))
            assert front
            for a in front:
                assert not any(
                    o.entries <= a.entries and o.total_bytes <= a.total_bytes
                    and (o.entries < a.entries or o.total_bytes < a.total_bytes)
                    for o in front)
            assert [c.entries for c in front] == sorted(
                c.entries for c in front)

    def test_front_subset_of_menu(self):
        cands = enumerate_candidates("gelu", EA)
        front = pareto_front(cands)
        assert set(id(c) for c in front) <= set(id(c) for c in cands)


class TestPinnedRegressions:
    """Degree-2+ entries beat degree-1 at equal accuracy — the spacing rule's
    h^(d+1) scaling made concrete on the curvature-heavy members."""

    @pytest.mark.parametrize("name", ["exp", "tanh"])
    def test_degree2_beats_degree1_entries(self, name):
        cands = enumerate_candidates(name, EA)
        assert _entries(cands, 2) < _entries(cands, 1), name

    def test_planner_auto_beats_linear_f32_entries(self):
        """The auto plan over the full registry needs strictly fewer entries
        than one linear f32 member per function (the PR 2 pack baseline)."""
        names = tuple(function_names())
        p = plan(names, EA)
        linear = sum(_entries(enumerate_candidates(n, EA), 1) for n in names)
        assert p.total_entries < linear, (p.total_entries, linear)


class TestPlan:
    def test_no_budget_picks_cheapest(self):
        p = plan(NAMES, EA)
        for c in p.chosen:
            menu = enumerate_candidates(c.name, EA)
            assert c.total_bytes == min(m.total_bytes for m in menu)

    def test_budget_respected_and_members_unchanged_accuracy(self):
        p = plan(NAMES, EA, budget_bytes=8192)
        assert p.total_bytes <= 8192
        for m in p.members:
            assert m.max_error_on_grid(n=4001) <= EA * (1 + 1e-6)

    def test_generous_budget_keeps_preferred_quality(self):
        """A budget the preferred plan already fits leaves every function at
        its lowest-degree / widest-dtype candidate (no needless downgrade)."""
        tight = plan(NAMES, EA).total_bytes
        roomy = plan(NAMES, EA, budget_bytes=50 * tight)
        for c in roomy.chosen:
            menu = enumerate_candidates(c.name, EA)
            pref = min(menu, key=design._preferred_key)
            assert (c.degree, c.dtype) == (pref.degree, pref.dtype)

    def test_infeasible_budget_raises(self):
        with pytest.raises(ValueError, match="infeasible"):
            plan(NAMES, EA, budget_bytes=8)

    def test_empty_names_raises(self):
        with pytest.raises(ValueError, match="at least one"):
            plan((), EA)

    def test_interval_override_shrinks_member(self):
        full = plan(("tanh",), EA).total_entries
        narrow = plan(("tanh",), EA,
                      intervals={"tanh": (-2.0, 0.0)}).total_entries
        assert narrow <= full

    def test_vmem_accounting_runs(self):
        v = plan(NAMES, EA).vmem()
        assert v.padded_bytes >= v.table_bytes + v.meta_bytes > 0


def _check_plan_contract(budget, subset):
    """EVERY feasible plan honors both contracts at once: each member meets
    Ea on a dense grid, and total codes+meta bytes fit the byte budget."""
    names = tuple(sorted(subset))
    try:
        p = plan(names, EA, budget_bytes=budget)
    except ValueError:
        # infeasible budget: the cheapest plan itself exceeds it — legitimate
        assert budget is not None
        assert plan(names, EA).total_bytes > budget
        return
    assert p.names == names
    if budget is not None:
        assert p.total_bytes <= budget
    for m in p.members:
        assert m.max_error_on_grid(n=2001) <= EA * (1 + 1e-6)


@pytest.mark.parametrize("budget,subset", [
    (None, NAMES),
    (64, ("tanh",)),           # infeasibly tight
    (600, ("tanh", "gelu")),   # forces downgrades
    (2048, NAMES),
    (8192, NAMES),
    (20_000, ("exp_neg", "sigmoid_sym")),
])
def test_plan_contract_pinned(budget, subset):
    _check_plan_contract(budget, subset)


if HAVE_HYPOTHESIS:
    @given(
        budget=st.one_of(st.none(),
                         st.integers(min_value=64, max_value=20_000)),
        subset=st.sets(st.sampled_from(NAMES), min_size=1,
                       max_size=len(NAMES)),
    )
    @settings(deadline=None)  # examples count from the ci/nightly profile
    def test_plan_property_accuracy_and_budget(budget, subset):
        _check_plan_contract(budget, subset)
