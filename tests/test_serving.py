"""Serving schedulers: per-request EOS/steps accounting, per-slot budgets, and
the ContinuousEngine (admission queue + mid-stream slot refill).

The load-bearing contract is the sequential-oracle parity: a greedy
ContinuousEngine queue must be TOKEN-IDENTICAL, request by request, to serving
each request alone.  The oracle pads every prompt to the engine's prefill
width and replicates it across all batch rows of the PR 1 fixed-batch engine
(same compiled shapes — bf16 results are only bit-stable at equal shapes), so
it goes through the old prefill + scalar-clock decode path: agreement proves
the per-slot clocks, the refill gather/scatter, and slot isolation together.

Fast tier runs small queues on a 2-layer model (exact + one table mode); the
full-size queues across cache families (local:global KV, SSM state, xLSTM
state) are ``slow`` and join the nightly job.
"""

import math

import jax
import numpy as np
import pytest

from repro.models import build_model
from repro.serving.engine import (
    ContinuousEngine,
    DecodeEngine,
    Request,
    _trim_at_eos,
    serve,
    serve_continuous,
    serve_static,
)
from tests.test_archs import reduced


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced("stablelm-3b").replace(n_layers=2)
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


def mixed_requests(rng, n, eos_every=3, lo_len=3, hi_len=9, lo_new=2, hi_new=8):
    """Mixed prompt lengths, budgets, and EOS ids (every ``eos_every``-th
    request gets a plausibly-sampled token id as its EOS)."""
    reqs = []
    for i in range(n):
        reqs.append(Request(
            prompt=rng.integers(0, 100, (int(rng.integers(lo_len, hi_len)),))
            .astype(np.int32),
            max_new_tokens=int(rng.integers(lo_new, hi_new)),
            eos_id=int(rng.integers(0, 128)) if i % eos_every == 1 else -1))
    return reqs


def sequential_oracle(model, params, batch_size, cache_len, prefill_len, req,
                      engine=None):
    """Serve ONE request through the fixed-batch engine, replicated across
    all rows at the continuous engine's prefill width; row 0 is the oracle."""
    if engine is None:
        engine = DecodeEngine(model, params, batch_size, cache_len)
    row = np.zeros((prefill_len,), np.int32)
    row[prefill_len - len(req.prompt):] = req.prompt
    gen, _ = engine.generate_batch(np.tile(row, (batch_size, 1)),
                                   req.max_new_tokens, req.eos_id)
    return _trim_at_eos(gen[0], req.max_new_tokens, req.eos_id)


class TestTrimAtEos:
    def test_cuts_after_first_eos_inclusive(self):
        t = np.asarray([4, 7, 9, 7, 1])
        np.testing.assert_array_equal(_trim_at_eos(t, 5, 7), [4, 7])
        np.testing.assert_array_equal(_trim_at_eos(t, 5, 1), t)
        np.testing.assert_array_equal(_trim_at_eos(t, 3, 1), [4, 7, 9])
        np.testing.assert_array_equal(_trim_at_eos(t, 5, -1), t)


class TestStaticAccounting:
    def test_eos_trims_tokens_and_steps(self, tiny_model):
        """Result.tokens must stop at the request's own first EOS; steps is
        the per-request generated count, not the batch-wide loop count."""
        model, params = tiny_model
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, 100, (n,)).astype(np.int32),
                        max_new_tokens=6) for n in (4, 5)]
        base = serve_static(model, params, reqs, batch_size=2, cache_len=64)
        # rerun with req0's 3rd token as its EOS: same greedy prefix, so the
        # result must now be exactly tokens[:3] (EOS kept) with steps == 3
        eos0 = int(base[0].tokens[2])
        assert base[0].tokens[:2].tolist().count(eos0) == 0
        reqs[0].eos_id = eos0
        res = serve_static(model, params, reqs, batch_size=2, cache_len=64)
        np.testing.assert_array_equal(res[0].tokens, base[0].tokens[:3])
        assert res[0].steps == 3
        # req1 has no EOS: untouched by its neighbour's early stop
        np.testing.assert_array_equal(res[1].tokens, base[1].tokens)
        assert res[1].steps == 6

    def test_per_slot_budgets_stop_the_group_loop(self, tiny_model):
        """A group of [EOS-bearing request, exhausted-budget request] must
        stop decoding when the EOS fires — finished/dummy slots no longer
        drag the loop to the group-wide max budget."""
        model, params = tiny_model
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, 100, (2, 4)).astype(np.int32)
        eng = DecodeEngine(model, params, 2, 64)
        gen, _ = eng.generate_batch(prompts, 8)
        eos0 = int(gen[0, 2])
        assert gen[0, :2].tolist().count(eos0) == 0
        eng.reset_counters()
        _, steps = eng.generate_batch(prompts, np.asarray([8, 1]),
                                      np.asarray([eos0, -1]))
        assert steps == 3  # slot 1 done at its budget, slot 0 at its EOS
        assert eng.batch_steps == 3

    def test_padding_slots_accounted_as_waste(self, tiny_model):
        """3 requests at batch 2: the dummy padding slot must not inflate
        per-request results, and the engine exposes the batch-wide counters
        separately from Result.steps."""
        model, params = tiny_model
        rng = np.random.default_rng(2)
        reqs = [Request(prompt=rng.integers(0, 100, (n,)).astype(np.int32),
                        max_new_tokens=5) for n in (3, 7, 5)]
        eng = DecodeEngine(model, params, 2, 64)
        res = serve_static(model, params, reqs, batch_size=2, cache_len=64,
                           engine=eng)
        assert len(res) == 3
        assert all(r.steps == len(r.tokens) == 5 for r in res)
        assert eng.batch_steps == 10  # two groups x 5 rounds
        # group 2's dummy slot sat done for rounds 2..5
        assert eng.wasted_slot_steps == 4

    def test_legacy_serve_alias(self):
        assert serve is serve_static


class TestContinuousEngine:
    def test_greedy_matches_sequential_oracle(self, tiny_model):
        """Acceptance: >= 8 mixed-length, mixed-EOS requests, token-identical
        to the per-request oracle, zero recompiles after the first refill."""
        model, params = tiny_model
        rng = np.random.default_rng(3)
        reqs = mixed_requests(rng, 8)
        S0 = max(len(r.prompt) for r in reqs)
        eng = ContinuousEngine(model, params, batch_size=2, cache_len=64)
        out = eng.serve(reqs)
        assert eng.refills >= 2
        counts = eng.compile_counts()
        if -1 not in counts.values():
            assert counts == {"prefill": 1, "decode_step": 1}, counts
        oracle = DecodeEngine(model, params, 2, 64)
        for i, r in enumerate(reqs):
            want = sequential_oracle(model, params, 2, 64, S0, r,
                                     engine=oracle)
            np.testing.assert_array_equal(out[i].tokens, want,
                                          err_msg=f"req {i}")
            assert out[i].steps == len(out[i].tokens)
            assert out[i].prompt_len == len(r.prompt)

    def test_greedy_matches_oracle_table_mode(self, tiny_model):
        """Same contract through the fused table-pack kernels (acceptance:
        at least one table mode besides exact)."""
        from repro.approx import ApproxConfig

        base, _ = tiny_model
        cfg = base.cfg.replace(
            approx=ApproxConfig(mode="table_pack", e_a=1e-4, omega=0.2))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(4)
        reqs = mixed_requests(rng, 8, lo_new=2, hi_new=6)
        S0 = max(len(r.prompt) for r in reqs)
        eng = ContinuousEngine(model, params, batch_size=2, cache_len=64)
        out = eng.serve(reqs)
        assert eng.refills >= 2
        counts = eng.compile_counts()
        if -1 not in counts.values():
            assert counts == {"prefill": 1, "decode_step": 1}, counts
        oracle = DecodeEngine(model, params, 2, 64)
        for i, r in enumerate(reqs):
            want = sequential_oracle(model, params, 2, 64, S0, r,
                                     engine=oracle)
            np.testing.assert_array_equal(out[i].tokens, want,
                                          err_msg=f"req {i}")

    def test_refill_keeps_request_identity(self, tiny_model):
        """Results come back in queue order with each request's own prompt
        length and budget, across several refill generations."""
        model, params = tiny_model
        rng = np.random.default_rng(5)
        reqs = [Request(prompt=rng.integers(0, 100, (3 + i,)).astype(np.int32),
                        max_new_tokens=1 + (i % 4)) for i in range(9)]
        out = serve_continuous(model, params, reqs, batch_size=3, cache_len=64)
        for i, (r, res) in enumerate(zip(reqs, out)):
            assert res.prompt_len == len(r.prompt), i
            assert res.steps == len(res.tokens) == r.max_new_tokens, i

    def test_per_slot_rng_reproducible_and_slot_independent(self, tiny_model):
        """temperature > 0: a request's sampled tokens depend only on
        (engine seed, its queue index, its own logits) — identical across
        runs and across different slot assignments/admission times."""
        model, params = tiny_model
        rng = np.random.default_rng(6)
        prompts = [rng.integers(0, 100, (4,)).astype(np.int32)
                   for _ in range(3)]
        mk = lambda order, budgets: [
            Request(prompt=prompts[i], max_new_tokens=b)
            for i, b in zip(order, budgets)]
        a1 = serve_continuous(model, params, mk((0, 1, 2), (6, 2, 4)),
                              batch_size=2, cache_len=64, temperature=1.0,
                              seed=9)
        a2 = serve_continuous(model, params, mk((0, 1, 2), (6, 2, 4)),
                              batch_size=2, cache_len=64, temperature=1.0,
                              seed=9)
        for r1, r2 in zip(a1, a2):
            np.testing.assert_array_equal(r1.tokens, r2.tokens)
        # swap the first two requests: request 2 keeps its queue index but is
        # admitted into a different slot/time; its stream must not change
        b = serve_continuous(model, params, mk((1, 0, 2), (2, 6, 4)),
                             batch_size=2, cache_len=64, temperature=1.0,
                             seed=9)
        np.testing.assert_array_equal(a1[2].tokens, b[2].tokens)
        # and a different seed must actually change something
        c = serve_continuous(model, params, mk((0, 1, 2), (6, 2, 4)),
                             batch_size=2, cache_len=64, temperature=1.0,
                             seed=10)
        assert any(not np.array_equal(x.tokens, y.tokens)
                   for x, y in zip(a1, c))

    def test_wastes_no_more_than_static(self, tiny_model):
        """The serve-bench CI gate's deterministic half: on a staggered
        queue, continuous must strand fewer slot-rounds than static."""
        model, params = tiny_model
        rng = np.random.default_rng(7)
        reqs = [Request(prompt=rng.integers(0, 100, (6,)).astype(np.int32),
                        max_new_tokens=12 if i % 2 == 0 else 2)
                for i in range(8)]
        stat = DecodeEngine(model, params, 2, 64)
        serve_static(model, params, reqs, 2, 64, engine=stat)
        cont = ContinuousEngine(model, params, 2, 64)
        cont.serve(reqs)
        assert cont.wasted_fraction < stat.wasted_fraction
        assert cont.batch_steps < stat.batch_steps

    def test_zero_budget_matches_static(self, tiny_model):
        """max_new_tokens=0 yields an empty result in BOTH schedulers (it
        never occupies a continuous slot), so switching scheduler cannot
        conjure phantom tokens."""
        model, params = tiny_model
        rng = np.random.default_rng(9)
        reqs = [Request(prompt=rng.integers(0, 100, (4,)).astype(np.int32),
                        max_new_tokens=m) for m in (3, 0, 2, 0)]
        for res in (serve_static(model, params, reqs, 2, 64),
                    serve_continuous(model, params, reqs, 2, 64)):
            assert [r.steps for r in res] == [3, 0, 2, 0]
            assert res[1].tokens.size == 0 and res[3].tokens.size == 0

    def test_engine_batch_size_mismatch_rejected(self, tiny_model):
        model, params = tiny_model
        eng = DecodeEngine(model, params, 2, 64)
        with pytest.raises(ValueError, match="batch size"):
            serve_static(model, params, [Request(np.zeros((2,), np.int32))],
                         batch_size=4, cache_len=64, engine=eng)

    def test_prompt_longer_than_prefill_len_rejected(self, tiny_model):
        model, params = tiny_model
        eng = ContinuousEngine(model, params, 2, 64, prefill_len=4)
        with pytest.raises(ValueError, match="exceeds the prefill width"):
            eng.serve([Request(prompt=np.zeros((6,), np.int32))])


@pytest.mark.slow
class TestContinuousAcrossFamilies:
    """Full-size queues through every cache family the engine can refill:
    local:global KV rings (gemma3), Mamba2 state + shared-attention KV
    (zamba2), positionless xLSTM state, and the quantized table mode."""

    @pytest.mark.parametrize("arch,mode", [
        ("gemma3-12b", "exact"),
        ("zamba2-1.2b", "exact"),
        ("xlstm-125m", "exact"),
        ("stablelm-3b", "quant_pack"),
    ])
    def test_oracle_parity_full_size(self, arch, mode):
        from repro.approx import ApproxConfig

        cfg = reduced(arch)
        if mode != "exact":
            cfg = cfg.replace(approx=ApproxConfig(mode=mode, e_a=1e-4,
                                                  omega=0.2))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(8)
        reqs = mixed_requests(rng, 10, lo_len=3, hi_len=12, lo_new=2,
                              hi_new=12)
        S0 = max(len(r.prompt) for r in reqs)
        eng = ContinuousEngine(model, params, batch_size=3, cache_len=64)
        out = eng.serve(reqs)
        assert eng.refills >= 2
        counts = eng.compile_counts()
        if -1 not in counts.values():
            assert counts == {"prefill": 1, "decode_step": 1}, counts
        oracle = DecodeEngine(model, params, 3, 64)
        for i, r in enumerate(reqs):
            want = sequential_oracle(model, params, 3, 64, S0, r,
                                     engine=oracle)
            np.testing.assert_array_equal(out[i].tokens, want,
                                          err_msg=f"{arch}/{mode} req {i}")


class TestCounterLifecycle:
    """The batch/wasted-step counters and the engine metric registry across
    resets — the denominators the serve bench and ScopeKit report from."""

    def test_wasted_fraction_defined_at_zero_rounds(self, tiny_model):
        """A fresh engine (batch_steps == 0) reports wasted_fraction 0.0
        instead of dividing by zero, and serving an empty queue keeps it so."""
        model, params = tiny_model
        eng = ContinuousEngine(model, params, batch_size=2, cache_len=32)
        assert eng.batch_steps == 0
        assert eng.wasted_fraction == 0.0
        assert eng.serve([]) == []
        assert eng.batch_steps == 0 and eng.wasted_fraction == 0.0

    def test_reset_counters_clears_metrics_registry(self, tiny_model):
        """reset_counters() resets the engine's ScopeKit registry along with
        the integers, so warmup latencies never leak into a timed window."""
        from repro import obs

        model, params = tiny_model
        eng = ContinuousEngine(model, params, batch_size=2, cache_len=32)
        try:
            obs.configure(enabled=True)
            eng.serve(mixed_requests(np.random.default_rng(3), 3))
        finally:
            obs.disable()
        assert eng.metrics.summary()["histograms"]  # warmup recorded latencies
        assert eng.batch_steps > 0
        eng.reset_counters()
        assert eng.metrics.summary()["histograms"] == {}
        assert eng.batch_steps == 0 and eng.wasted_slot_steps == 0
        assert eng.compile_time_s == 0.0 and eng.wasted_fraction == 0.0


class TestRopeTableServing:
    """rope_table=True: rotary embeddings served from the folded trig tables
    (PR 8).  The contract is end-to-end — switching ONLY the rotary path from
    exact jnp sin/cos to the table-served fold must leave a greedy decode
    token-identical, and the fold must hold its error bound at the 128k
    positions a long-context cache would feed it."""

    def test_rope_table_token_identical_greedy(self, tiny_model):
        """Same arch, same params, same queue; the only delta between the two
        engines is apply_rope's sin_cos hook.  Greedy streams must match
        token for token through several refills.  At e_a=1e-6 the table trig
        lands within the model's bf16 resolution, so the rotated activations
        are bitwise identical and identity is exact, not probabilistic (at
        1e-4 a ~4e-3 logit wobble can flip a greedy tie some steps in)."""
        from repro.approx import ApproxConfig

        base, _ = tiny_model
        outs = []
        for rope_table in (False, True):
            cfg = base.cfg.replace(approx=ApproxConfig(
                mode="folded_pack_ref", e_a=1e-6, omega=0.2,
                rope_table=rope_table))
            model = build_model(cfg)
            assert (model.rope_sin_cos is not None) == rope_table
            params = model.init(jax.random.key(0))
            rng = np.random.default_rng(11)
            reqs = mixed_requests(rng, 6, lo_new=2, hi_new=6)
            eng = ContinuousEngine(model, params, batch_size=2, cache_len=64)
            outs.append(eng.serve(reqs))
        for i, (exact_r, table_r) in enumerate(zip(*outs)):
            np.testing.assert_array_equal(exact_r.tokens, table_r.tokens,
                                          err_msg=f"req {i}")
            assert exact_r.steps == table_r.steps

    def test_rope_parity_at_128k_positions(self):
        """apply_rope with the table hook vs exact, at positions up to 128k
        (angles deep in the Payne-Hanek regime for the base frequency).
        Rotation error is bounded by |x1|*d_cos + |x2|*d_sin <= 2*Ea'."""
        import jax.numpy as jnp

        from repro.approx import ApproxConfig
        from repro.models.common import apply_rope

        ea = 1e-4
        cfg = ApproxConfig(mode="folded_pack_ref", e_a=ea, rope_table=True)
        sc = cfg.rope_sin_cos()
        assert sc is not None
        positions = jnp.asarray(
            [[0, 1, 63, 4095, 65535, 131071, 131072]], jnp.int32)
        rng = np.random.default_rng(12)
        x = jnp.asarray(rng.uniform(-1, 1, (1, 7, 2, 16)), jnp.float32)
        exact = apply_rope(x, positions, 10_000.0)
        table = apply_rope(x, positions, 10_000.0, sin_cos=sc)
        tol = 2 * (ea * 1.02 + 1e-5)
        err = float(jnp.max(jnp.abs(exact - table)))
        assert err <= tol, f"max rotation err {err:.3e} > {tol:.3e}"
        # and the hook's raw trig is itself within the fold contract against
        # float64 numpy at the largest angles the positions produce
        ang = np.float32(131072.0)
        s, c = sc(jnp.full((1, 256), ang))
        bound = ea * 1.02 + 1e-5
        assert abs(float(s[0, 0]) - math.sin(float(ang))) <= bound
        assert abs(float(c[0, 0]) - math.cos(float(ang))) <= bound

    def test_rope_sin_cos_gating(self):
        """exact mode and rope_table=False both keep the exact path; an
        unknown mode with rope_table on raises instead of silently serving."""
        from repro.approx import ApproxConfig

        assert ApproxConfig(mode="exact", rope_table=True).rope_sin_cos() is None
        assert ApproxConfig(mode="folded_pack_ref").rope_sin_cos() is None
        with pytest.raises(ValueError, match="unknown approx mode"):
            ApproxConfig(mode="bogus", rope_table=True).rope_sin_cos()
