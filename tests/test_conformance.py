"""Cross-mode conformance matrix: EVERY ApproxConfig table mode x EVERY
registered function must honor the paper's |f(x) - approx(x)| <= Ea contract,
every kernel mode must reproduce its jnp oracle bit for bit under jit, and
every mode's differentiable wrapper must have a finite grad path.

This is the one table a reviewer reads to trust a new mode: a mode joins
``repro.approx.TABLE_MODES`` (checked here for completeness) and inherits the
whole matrix.  The fast tier runs a subsampled matrix (FAST_FUNCS x all modes
plus the f64 design-layer row); the full matrix rides the ``slow`` marker and
the nightly CI job.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import TABLE_MODES, ApproxConfig, from_quant_layout, from_spec, pack_specs
from repro.approx.activations import _EXACT, _TABLE_NAME
from repro.approx.jax_table import eval_table_ref, make_table_fn
from repro.approx.range_fold import (
    FOLDABLE,
    FOLDED_MODES,
    eval_folded_ref,
    eval_folded_routed,
    folded_lookup,
    make_folded_fn,
    make_folded_routed_unary_fn,
)
from repro.approx.table_pack import (
    build_poly_pack,
    eval_pack_ref,
    eval_poly_pack_ref,
    eval_quant_pack_ref,
    eval_routed_poly_ref,
    eval_routed_quant_ref,
    eval_routed_ref,
    eval_sharded_ref,
    make_pack_fn,
    make_poly_pack_fn,
    make_quant_pack_fn,
    make_routed_unary_fn,
    make_sharded_pack_fn,
    shard_pack,
)
from repro.core import (
    cached_table,
    function_names,
    get_function,
    pack_layout,
    plan_quant_member,
    poly_member,
    quant_pack_layout,
)
from repro.kernels.routed_pack_lookup import (
    routed_pack_lookup_pallas,
    routed_poly_pack_lookup_pallas,
    routed_quant_pack_lookup_pallas,
)
from repro.kernels.table_lookup import table_lookup_pallas
from repro.kernels.table_pack_lookup import (
    poly_pack_lookup_pallas,
    quant_pack_lookup_pallas,
    sharded_pack_lookup_pallas,
    table_pack_lookup_pallas,
)

EA = 1e-4

MODES = tuple(m for m in TABLE_MODES)
# kernel mode -> the jnp oracle it must reproduce bitwise
KERNEL_ORACLE = {
    "table_pallas": "table_ref",
    "table_pack": "table_pack_ref",
    "quant_pack": "quant_pack_ref",
    "poly_pack": "poly_pack_ref",
    "routed_pack": "routed_pack_ref",
    "routed_quant_pack": "routed_quant_pack_ref",
    "routed_poly_pack": "routed_poly_pack_ref",
    "sharded_pack": "sharded_pack_ref",
    "folded_pack": "folded_pack_ref",
    "folded_routed_pack": "folded_routed_pack_ref",
}
N_SHARDS = 2  # sharded modes: shard count for the conformance pack
FUNCS = tuple(function_names())
# the fast-tier subsample: one easy, one flat-asymptote, one log-domain member
FAST_FUNCS = ("gelu", "tanh", "log")

GRID_N = 8192  # dense-grid points; reshaped (16, 512) for the routed modes
ROWS = 16

_CACHE = {}


def _spec(name):
    return cached_table(name, EA)


def _pack():
    if "pack" not in _CACHE:
        _CACHE["pack"] = pack_specs([_spec(n) for n in FUNCS])
    return _CACHE["pack"]


def _qpack():
    if "qpack" not in _CACHE:
        _CACHE["qpack"] = from_quant_layout(quant_pack_layout(
            [plan_quant_member(n, EA) for n in FUNCS]))
    return _CACHE["qpack"]


def _ppack():
    if "ppack" not in _CACHE:
        # the design-space planner picks each function's Pareto-cheapest
        # (degree, dtype); the returned pack mixes degrees and code widths
        _CACHE["ppack"] = build_poly_pack(FUNCS, EA)
    return _CACHE["ppack"]


def _spack():
    if "spack" not in _CACHE:
        _CACHE["spack"] = shard_pack(
            pack_layout([_spec(n) for n in FUNCS]), N_SHARDS)
    return _CACHE["spack"]


def _rows(x):
    return x.reshape(ROWS, -1)


def approx_eval(mode: str, name: str, x: jnp.ndarray) -> np.ndarray:
    """Evaluate ``name`` through ``mode``'s runtime (f32), any grid size that
    tiles into ROWS rows."""
    if mode == "table_ref":
        out = jax.jit(lambda v: eval_table_ref(from_spec(_spec(name)), v))(x)
    elif mode == "table_pallas":
        out = table_lookup_pallas(from_spec(_spec(name)), x)
    elif mode == "table_pack_ref":
        out = jax.jit(lambda v: eval_pack_ref(_pack(), name, v))(x)
    elif mode == "table_pack":
        out = table_pack_lookup_pallas(_pack(), name, x)
    elif mode == "quant_pack_ref":
        out = jax.jit(lambda v: eval_quant_pack_ref(_qpack(), name, v))(x)
    elif mode == "quant_pack":
        out = quant_pack_lookup_pallas(_qpack(), name, x)
    elif mode == "poly_pack_ref":
        out = jax.jit(lambda v: eval_poly_pack_ref(_ppack(), name, v))(x)
    elif mode == "poly_pack":
        out = poly_pack_lookup_pallas(_ppack(), name, x)
    elif mode == "routed_pack_ref":
        out = jax.jit(lambda v: eval_routed_ref(
            _pack(), name, _rows(v)))(x).reshape(x.shape)
    elif mode == "routed_pack":
        out = routed_pack_lookup_pallas(_pack(), name,
                                        _rows(x)).reshape(x.shape)
    elif mode == "routed_quant_pack_ref":
        out = jax.jit(lambda v: eval_routed_quant_ref(
            _qpack(), name, _rows(v)))(x).reshape(x.shape)
    elif mode == "routed_quant_pack":
        out = routed_quant_pack_lookup_pallas(_qpack(), name,
                                              _rows(x)).reshape(x.shape)
    elif mode == "routed_poly_pack_ref":
        out = jax.jit(lambda v: eval_routed_poly_ref(
            _ppack(), name, _rows(v)))(x).reshape(x.shape)
    elif mode == "routed_poly_pack":
        out = routed_poly_pack_lookup_pallas(_ppack(), name,
                                             _rows(x)).reshape(x.shape)
    elif mode == "sharded_pack_ref":
        out = jax.jit(lambda v: eval_sharded_ref(_spack(), name, v))(x)
    elif mode == "sharded_pack":
        out = sharded_pack_lookup_pallas(_spack(), name, x)
    elif mode == "folded_pack_ref":
        out = jax.jit(lambda v: eval_folded_ref(_pack(), name, v))(x)
    elif mode == "folded_pack":
        out = folded_lookup(_pack(), name, x)
    elif mode == "folded_routed_pack_ref":
        out = jax.jit(lambda v: eval_folded_routed(
            _pack(), name, v, use_pallas=False))(x)
    elif mode == "folded_routed_pack":
        out = jax.jit(lambda v: eval_folded_routed(
            _pack(), name, v, use_pallas=True))(x)
    else:  # pragma: no cover - the completeness test keeps this unreachable
        raise ValueError(mode)
    return np.asarray(out, dtype=np.float64)


def approx_fn(mode: str, name: str):
    """The mode's differentiable unary for ``name`` (table-slope tangent)."""
    if mode in ("table_ref", "table_pallas"):
        return make_table_fn(from_spec(_spec(name)),
                             use_pallas=(mode == "table_pallas"))
    pallas = not mode.endswith("_ref")
    if mode in FOLDED_MODES:
        make = make_folded_routed_unary_fn if "routed" in mode \
            else make_folded_fn
        return make(_pack(), name, use_pallas=pallas)
    if mode.startswith("routed"):
        if "poly" in mode:
            pack = _ppack()
        elif "quant" in mode:
            pack = _qpack()
        else:
            pack = _pack()
        return make_routed_unary_fn(pack, name, use_pallas=pallas)
    if mode.startswith("sharded"):
        return make_sharded_pack_fn(_spack(), name, use_pallas=pallas)
    if mode.startswith("poly"):
        return make_poly_pack_fn(_ppack(), name, use_pallas=pallas)
    if mode.startswith("quant"):
        return make_quant_pack_fn(_qpack(), name, use_pallas=pallas)
    return make_pack_fn(_pack(), name, use_pallas=pallas)


def mode_fn_params():
    for m in MODES:
        for f in FUNCS:
            marks = () if f in FAST_FUNCS else (pytest.mark.slow,)
            yield pytest.param(m, f, marks=marks, id=f"{m}-{f}")


def grid(name, n=GRID_N):
    lo, hi = get_function(name).interval
    return np.linspace(lo, hi, n + 1)[:-1]


def bound_ok(mode, name, got, want):
    """The mode-aware Ea contract, elementwise.

    Default: |err| <= Ea * 1.02 + 1e-5 * scale (f32 gather/FMA rounding on
    top of the f64 design bound).  Folded foldable members promise a
    RELATIVE bound instead — the exp fold's 2^k reconstruction scales the
    core table's absolute error by the function's own magnitude (sin/cos/log
    keep |f| ~ 1 on their grids, so relative == absolute there)."""
    err = np.abs(got - want)
    if mode in FOLDED_MODES and name in FOLDABLE:
        lim = (EA * 1.02 + 1e-5) * np.maximum(1.0, np.abs(want))
        return bool(np.all(err <= lim)), float(np.max(err / np.maximum(
            1.0, np.abs(want))))
    scale = max(1.0, float(np.max(np.abs(want))))
    return bool(np.all(err <= EA * 1.02 + 1e-5 * scale)), float(np.max(err))


def probe(name, n=2048):
    """Domain + deep out-of-range tails (exercises clamp/extrapolation)."""
    lo, hi = get_function(name).interval
    span = hi - lo
    rng = np.random.default_rng(5)
    return rng.uniform(lo - 0.5 * span, hi + 0.5 * span, n).astype(np.float32)


class TestModeMatrixComplete:
    def test_matrix_covers_every_mode(self):
        """A new ApproxConfig mode must join this suite's matrix."""
        assert set(MODES) == set(TABLE_MODES)

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="unknown approx mode"):
            ApproxConfig(mode="bogus").unary("gelu")


@pytest.mark.parametrize("mode,name", mode_fn_params())
def test_error_bound(mode, name):
    """|f(x) - approx(x)| <= Ea on a dense in-domain grid, per mode x fn.

    The table guarantee is proven in f64 (see TestDesignLayerF64); the f32
    runtime adds gather/FMA rounding relative to the function's magnitude
    (the quant-pack convention: Ea * 1.02 + 1e-5 * scale).
    """
    xs = grid(name)
    want = np.asarray(get_function(name).f(xs))
    got = approx_eval(mode, name, jnp.asarray(xs, jnp.float32))
    ok, err = bound_ok(mode, name, got, want)
    assert ok, (mode, name, err)


@pytest.mark.parametrize("mode,name", mode_fn_params())
def test_error_bound_at_domain_edges(mode, name):
    """The Ea contract holds AT the interval edges — x0, x0+a, and their f32
    neighbors — not just on the interior grid.  Dense linspace sampling can
    miss the clamp boundary by construction (the grid's last point is x0+a-h),
    and the edge is exactly where the address clamp, the last-segment lerp,
    and extrapolation semantics meet (the ISSUE 8 edge-seam satellite)."""
    lo, hi = get_function(name).interval
    lo32, hi32 = np.float32(lo), np.float32(hi)
    inward = np.array([
        np.nextafter(lo32, np.float32(np.inf), dtype=np.float32),
        np.nextafter(hi32, np.float32(-np.inf), dtype=np.float32),
    ], dtype=np.float32)
    # keep strictly inside [lo, hi): f32 rounding of the f64 bounds can land
    # either side, and outside the interval the contract is clamp semantics,
    # not Ea
    edges = np.concatenate([[lo32, hi32], inward])
    edges = edges[(edges >= lo) & (edges < hi)]
    xs = np.resize(edges, ROWS * 16).astype(np.float32)
    want = np.asarray(get_function(name).f(xs.astype(np.float64)))
    got = approx_eval(mode, name, jnp.asarray(xs))
    ok, err = bound_ok(mode, name, got, want)
    assert ok, (mode, name, err)


@pytest.mark.parametrize(
    "mode,name",
    [pytest.param(m, f,
                  marks=() if f in FAST_FUNCS else (pytest.mark.slow,),
                  id=f"{m}-{f}")
     for m in KERNEL_ORACLE for f in FUNCS])
def test_kernel_oracle_bit_parity(mode, name):
    """Every kernel mode reproduces its jnp oracle bitwise under jit,
    including out-of-range saturation."""
    x = jnp.asarray(probe(name))
    got = approx_eval(mode, name, x)
    want = approx_eval(KERNEL_ORACLE[mode], name, x)
    np.testing.assert_array_equal(got, want, err_msg=f"{mode} {name}")


@pytest.mark.parametrize("mode,name", mode_fn_params())
def test_grad_path_finite(mode, name):
    """jax.grad through every mode's differentiable wrapper is finite over
    the domain (the custom_jvp table-slope tangent must never NaN)."""
    f = approx_fn(mode, name)
    x = jnp.asarray(grid(name, n=1024), jnp.float32)
    if mode.startswith("routed"):
        x = x.reshape(ROWS, -1)
    y = np.asarray(f(x))
    g = np.asarray(jax.grad(lambda v: f(v).sum())(x))
    assert np.isfinite(y).all(), (mode, name, "value")
    assert np.isfinite(g).all(), (mode, name, "grad")


class TestDesignLayerF64:
    """The f64 rows of the matrix: the design-flow artifacts themselves
    (TableSpec / QuantMember oracles) meet Ea everywhere — the guarantee the
    f32 runtimes inherit."""

    @pytest.mark.parametrize("name", FUNCS)
    def test_table_spec_bound(self, name):
        assert _spec(name).max_error_on_grid(n=20_001) <= EA * (1 + 1e-6)

    @pytest.mark.parametrize("name", FUNCS)
    def test_quant_member_bound(self, name):
        m = plan_quant_member(name, EA)
        assert m.max_error_on_grid(n=20_001) <= EA * (1 + 1e-6)

    @pytest.mark.parametrize("name", FUNCS)
    def test_poly_member_bound(self, name):
        """Degree-2 int16 members (the planner's workhorse point) meet Ea."""
        m = poly_member(name, EA, degree=2, bits=16)
        assert m.max_error_on_grid(n=20_001) <= EA * (1 + 1e-6)


@pytest.mark.parametrize("mode", MODES)
def test_unary_activation_bound(mode):
    """The ApproxConfig.unary layer (name remaps + odd extension) holds the
    bound on each activation's FULL serving domain — notably tanh on both
    signs (the odd extension) and sigmoid via the symmetric table."""
    cfg = ApproxConfig(mode=mode, e_a=EA)
    for act in ("gelu", "tanh", "sigmoid", "exp"):
        reg = _TABLE_NAME.get(act, act)
        lo, hi = get_function(reg).interval
        if act == "tanh":
            lo, hi = lo, -lo  # half-domain table, odd-extended at serve time
        xs = np.linspace(lo, hi, ROWS * 256 + 1)[:-1]
        want = np.asarray(_EXACT[act](jnp.asarray(xs)), dtype=np.float64)
        got = np.asarray(jax.jit(cfg.unary(act))(jnp.asarray(xs, jnp.float32)),
                         dtype=np.float64)
        scale = max(1.0, float(np.max(np.abs(want))))
        err = float(np.max(np.abs(got - want)))
        assert err <= EA * 1.02 + 1e-5 * scale, (mode, act, err)


@pytest.mark.parametrize("mode", ["table_pack", "routed_pack"])
def test_obs_telemetry_value_parity(mode):
    """ScopeKit's device telemetry must be a pure observer: the instrumented
    closure (built with ``device_telemetry`` on) returns bit-identical values
    to the uninstrumented one under jit, for both the unary and the routed
    dispatch paths.  Compared jit-to-jit — eager-vs-jit already differs by
    fp-reassociation noise, which is not what this pins."""
    from repro import obs

    cfg = ApproxConfig(mode=mode, e_a=EA)
    x = jnp.asarray(np.linspace(-12.0, 12.0, ROWS * 64,
                                dtype=np.float32))  # includes out-of-domain
    try:
        obs.disable()
        f_off = jax.jit(cfg.unary("tanh"))
        y_off = np.asarray(f_off(x))
        obs.configure(enabled=True, device_telemetry=True)
        f_on = jax.jit(cfg.unary("tanh"))
        y_on = np.asarray(f_on(x))
        np.testing.assert_array_equal(y_on, y_off, err_msg=f"{mode} unary")
        if mode.startswith("routed"):
            xr = x.reshape(ROWS, -1)
            slots = tuple(("gelu", "tanh", "silu")[i % 3] for i in range(ROWS))
            obs.disable()
            g_off = jax.jit(cfg.routed_fn(slots))
            z_off = np.asarray(g_off(xr))
            obs.configure(enabled=True, device_telemetry=True)
            g_on = jax.jit(cfg.routed_fn(slots))
            z_on = np.asarray(g_on(xr))
            np.testing.assert_array_equal(z_on, z_off,
                                          err_msg=f"{mode} routed")
        jax.effects_barrier()
        counters = obs.get_registry().summary()["counters"]
        assert counters.get("approx.oob.tanh", 0) > 0  # probe left the domain
    finally:
        obs.disable()
        obs.reset_registry()
