"""ScopeKit (repro.obs): trace schema, metrics, report, and the overhead
contract.

The load-bearing guarantees pinned here:

* **Deterministic trace structure** — two identical greedy mixed-EOS queues
  through a warm ContinuousEngine record the SAME ``(name, ph, tid)`` event
  sequence (timestamps differ, structure may not), and every trace passes
  ``tools/check_trace.py``'s validator (balanced/nested B/E per track,
  non-decreasing timestamps, known phases).
* **Zero-cost off, zero-recompile on** — with ObsConfig disabled nothing is
  recorded; flipping host-side recording on between serves of the SAME engine
  adds no compiled executables (``compile_counts`` unchanged) and leaves the
  tokens bit-identical.
* **Device telemetry** — out-of-domain clamp counts, quant-code saturation,
  and routed dispatch land in the global registry when (and only when) the
  activation closures were built with ``device_telemetry`` on.
* ``engine.reset_counters()`` resets the engine's metric registry along with
  the batch/wasted-step integers.
"""

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.approx import ApproxConfig
from repro.models import build_model
from repro.obs.report import diff_summaries, render_summary, span_stats
from repro.serving.engine import ContinuousEngine, DecodeEngine

from tests.test_archs import reduced
from tests.test_serving import mixed_requests

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
from check_trace import validate_trace  # noqa: E402


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with ScopeKit fully off and empty."""
    obs.disable()
    obs.reset_tracer()
    obs.reset_registry()
    yield
    obs.disable()
    obs.reset_tracer()
    obs.reset_registry()


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced("stablelm-3b").replace(n_layers=2)
    model = build_model(cfg)
    return model, model.init(jax.random.key(0))


def fixed_queue():
    """The pinned mixed-length mixed-EOS queue the schema test serves."""
    return mixed_requests(np.random.default_rng(7), 6)


# --------------------------------------------------------------------------------------
# metrics layer
# --------------------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_histogram(self):
        r = obs.Registry()
        r.counter("c").add()
        r.counter("c").add(4)
        r.gauge("g").set(2.5)
        for v in range(100):
            r.histogram("h").observe(float(v))
        s = r.summary()
        assert s["counters"]["c"] == 5
        assert s["gauges"]["g"] == 2.5
        h = s["histograms"]["h"]
        assert h["count"] == 100 and h["min"] == 0.0 and h["max"] == 99.0
        assert h["p50"] == pytest.approx(49.5)
        assert h["p99"] == pytest.approx(98.01)

    def test_registry_reset_and_global(self):
        obs.get_registry().counter("x").add(3)
        assert obs.get_registry().summary()["counters"]["x"] == 3
        obs.reset_registry()
        assert obs.get_registry().summary()["counters"] == {}

    def test_percentiles_empty(self):
        assert obs.percentiles([]) == {}

    def test_histogram_decimation_keeps_percentiles(self):
        from repro.obs import metrics as M
        h = M.Histogram()
        n = M.HIST_CAP + M.HIST_CAP // 2
        for v in range(n):
            h.observe(float(v))
        s = h.summary()
        assert s["count"] == n
        assert len(h.values) < M.HIST_CAP
        # decimated percentiles stay within ~1% of the exact uniform answer
        assert s["p50"] == pytest.approx(0.5 * n, rel=0.02)


# --------------------------------------------------------------------------------------
# tracer invariants
# --------------------------------------------------------------------------------------


class TestTracer:
    def test_span_balanced_and_valid(self, tmp_path):
        tr = obs.Tracer()
        with tr.span("outer", "t") as s:
            with tr.span("inner", "t"):
                tr.instant("tick", "t")
            s["extra"] = 1
        tr.counter("gauge", {"a": 1, "b": 2})
        doc = tr.to_json(metadata={"k": "v"})
        assert validate_trace(doc) == []
        path = tr.save(str(tmp_path / "t.json"))
        with open(path) as f:
            assert validate_trace(json.load(f)) == []
        ends = [e for e in doc["traceEvents"] if e.get("ph") == "E"]
        assert ends[-1]["args"] == {"extra": 1}  # end_args land on the E

    def test_module_helpers_noop_when_disabled(self):
        tr = obs.reset_tracer()
        n0 = len(tr.events)
        with obs.span("nope"):
            obs.instant("nope")
            obs.counter_event("nope", 1)
        assert len(tr.events) == n0
        obs.configure(enabled=True)
        with obs.span("yes"):
            pass
        assert len(tr.events) == n0 + 2

    def test_traced_decorator_fires_on_lru_miss_only(self):
        from functools import lru_cache

        @lru_cache(maxsize=8)
        @obs.traced("phase.x", "design")
        def work(a):
            return a * 2

        obs.configure(enabled=True)
        tr = obs.reset_tracer()
        assert work(3) == 6 and work(3) == 6 and work(4) == 8
        spans = [e for e in tr.events if e["name"] == "phase.x"
                 and e["ph"] == "B"]
        assert len(spans) == 2  # two misses, one hit

    def test_validator_catches_violations(self):
        bad = {"traceEvents": [
            {"name": "a", "ph": "B", "ts": 2.0, "pid": 1, "tid": 0},
            {"name": "b", "ph": "E", "ts": 1.0, "pid": 1, "tid": 0},
            {"name": "c", "ph": "Z", "ts": 3.0, "pid": 1, "tid": 0},
        ]}
        errs = validate_trace(bad)
        assert any("not nested" in e for e in errs)
        assert any("backwards" in e for e in errs)
        assert any("unknown phase" in e for e in errs)


# --------------------------------------------------------------------------------------
# report layer
# --------------------------------------------------------------------------------------


def _mini_doc(scale=1.0):
    evs = []
    t = 0.0
    for _ in range(3):
        evs.append({"name": "work", "ph": "B", "ts": t, "pid": 1, "tid": 0})
        evs.append({"name": "work", "ph": "E", "ts": t + 100.0 * scale,
                    "pid": 1, "tid": 0})
        t += 200.0 * scale
    return {"traceEvents": evs,
            "metadata": {"metrics": {"histograms": {
                "ttft_s": {"count": 3, "p50": 0.01 * scale,
                           "p95": 0.02 * scale, "p99": 0.03 * scale}}}}}


class TestReport:
    def test_span_stats(self):
        s = span_stats(_mini_doc())
        assert s["work"]["count"] == 3
        assert s["work"]["total_us"] == pytest.approx(300.0)
        assert s["work"]["mean_us"] == pytest.approx(100.0)

    def test_render_and_diff(self):
        text = render_summary(_mini_doc(), "run")
        assert "work" in text and "ttft_s" in text
        d = diff_summaries(_mini_doc(1.0), _mini_doc(2.0))
        assert "+100.0%" in d


# --------------------------------------------------------------------------------------
# engine traces: schema, determinism, overhead contract
# --------------------------------------------------------------------------------------


def _serve_traced(engine, reqs):
    obs.configure(enabled=True)
    tr = obs.reset_tracer()
    results = engine.serve(reqs)
    obs.configure(enabled=False)
    return results, tr.to_json(metadata={"metrics": engine.metrics.summary()})


class TestEngineTraces:
    def test_continuous_trace_schema(self, tiny_model):
        """A mixed-EOS continuous serve produces a validator-clean trace with
        the documented span taxonomy and a balanced per-slot request track."""
        model, params = tiny_model
        eng = ContinuousEngine(model, params, batch_size=2, cache_len=32)
        results, doc = _serve_traced(eng, fixed_queue())
        assert all(r is not None for r in results)
        assert validate_trace(doc) == []
        evs = doc["traceEvents"]
        names = {e["name"] for e in evs}
        assert {"request", "first_token", "refill.prefill", "refill.scatter",
                "decode.span", "slots_occupied", "serve.begin"} <= names
        # one balanced request B/E pair per served request, on slot tracks
        req_b = [e for e in evs if e["name"] == "request" and e["ph"] == "B"]
        req_e = [e for e in evs if e["name"] == "request" and e["ph"] == "E"]
        assert len(req_b) == len(results) == len(req_e)
        from repro.obs.trace import SLOT_TID0
        assert all(e["tid"] >= SLOT_TID0 for e in req_b)
        assert {e["args"]["req_idx"] for e in req_b} == set(range(len(results)))
        # E carries the per-request token count
        by_tid = {}
        for e in evs:
            if e["name"] == "request":
                by_tid.setdefault(e["tid"], []).append(e)
        for seq in by_tid.values():
            for b, e in zip(seq[0::2], seq[1::2]):
                assert (b["ph"], e["ph"]) == ("B", "E")
        # metrics made it into the embedded summary
        hists = doc["metadata"]["metrics"]["histograms"]
        assert hists["ttft_s"]["count"] == len(results)
        assert hists["queue_wait_s"]["count"] == len(results)

    def test_trace_structure_deterministic(self, tiny_model):
        """Two identical warm serves record identical (name, ph, tid)
        sequences — the schema test's stability guarantee."""
        model, params = tiny_model
        eng = ContinuousEngine(model, params, batch_size=2, cache_len=32)
        eng.serve(fixed_queue())  # warm: compile outside the compared runs
        _, doc_a = _serve_traced(eng, fixed_queue())
        _, doc_b = _serve_traced(eng, fixed_queue())

        def structure(doc):
            return [(e["name"], e["ph"], e["tid"])
                    for e in doc["traceEvents"]]

        assert structure(doc_a) == structure(doc_b)
        # and timestamps are strictly usable: non-decreasing overall clock
        ts = [e["ts"] for e in doc_a["traceEvents"] if "ts" in e]
        assert all(b >= a for a, b in zip(ts, ts[1:]))

    def test_obs_adds_no_recompiles_and_keeps_tokens(self, tiny_model):
        """Flipping host-side recording on between serves of the same engine
        adds ZERO compiled executables and leaves greedy tokens identical."""
        model, params = tiny_model
        eng = ContinuousEngine(model, params, batch_size=2, cache_len=32)
        base = eng.serve(fixed_queue())
        counts_off = eng.compile_counts()
        obs.configure(enabled=True)
        traced = eng.serve(fixed_queue())
        obs.configure(enabled=False)
        assert eng.compile_counts() == counts_off
        for a, b in zip(base, traced):
            assert np.array_equal(a.tokens, b.tokens)

    def test_static_engine_records_latency(self, tiny_model):
        model, params = tiny_model
        eng = DecodeEngine(model, params, batch_size=2, cache_len=32)
        obs.configure(enabled=True)
        obs.reset_tracer()
        prompts = np.ones((2, 4), np.int32)
        eng.generate_batch(prompts, max_new=5)
        obs.configure(enabled=False)
        hists = eng.metrics.summary()["histograms"]
        assert hists["ttft_s"]["count"] == 1
        assert hists["itl_s"]["count"] == 4  # 5 tokens -> 4 intervals
        names = {e["name"] for e in obs.get_tracer().events}
        assert {"static.prefill", "static.decode"} <= names

    def test_reset_counters_resets_metrics(self, tiny_model):
        model, params = tiny_model
        eng = DecodeEngine(model, params, batch_size=2, cache_len=32)
        obs.configure(enabled=True)
        eng.generate_batch(np.ones((2, 4), np.int32), max_new=3)
        obs.configure(enabled=False)
        assert eng.metrics.summary()["histograms"]
        assert eng.compile_time_s > 0.0
        eng.reset_counters()
        assert eng.metrics.summary()["histograms"] == {}
        assert eng.compile_time_s == 0.0
        assert eng.batch_steps == 0 and eng.wasted_slot_steps == 0


# --------------------------------------------------------------------------------------
# device telemetry
# --------------------------------------------------------------------------------------


class TestDeviceTelemetry:
    def test_oob_and_saturation_counters(self):
        obs.configure(enabled=True, device_telemetry=True)
        cfg = ApproxConfig(mode="quant_pack_ref", e_a=1e-3)
        f = jax.jit(cfg.unary("tanh"))
        # tanh's table spans [lo, 0); the odd extension serves (lo, -lo) —
        # half this probe sits beyond it on each side
        x = jnp.asarray(np.linspace(-16, 16, 64, dtype=np.float32))
        f(x)
        jax.effects_barrier()
        c = obs.get_registry().summary()["counters"]
        assert c["approx.lookups.tanh"] == 64
        assert 0 < c["approx.oob.tanh"] < 64
        assert c["approx.quant_gathers.tanh"] == 128
        assert 0 <= c["approx.quant_sat.tanh"] <= 128

    def test_routed_dispatch_histogram(self):
        obs.configure(enabled=True, device_telemetry=True)
        cfg = ApproxConfig(mode="routed_pack_ref", e_a=1e-3)
        g = jax.jit(cfg.routed_fn(["gelu", "tanh", "gelu"]))
        for _ in range(2):
            g(jnp.ones((3, 8), jnp.float32))
        jax.effects_barrier()
        c = obs.get_registry().summary()["counters"]
        assert c["approx.routed.gelu"] == 4  # 2 rows x 2 executions
        assert c["approx.routed.tanh"] == 2

    def test_off_by_default_records_nothing(self):
        cfg = ApproxConfig(mode="quant_pack_ref", e_a=1e-3)
        f = jax.jit(cfg.unary("tanh"))
        f(jnp.asarray(np.linspace(-4, 4, 32, dtype=np.float32)))
        jax.effects_barrier()
        assert obs.get_registry().summary()["counters"] == {}

    def test_enable_after_build_has_no_effect(self):
        """The build-time contract: closures built before the flag flips stay
        uninstrumented (documented in ObsConfig)."""
        cfg = ApproxConfig(mode="quant_pack_ref", e_a=1e-3)
        f = jax.jit(cfg.unary("tanh"))
        obs.configure(enabled=True, device_telemetry=True)
        f(jnp.asarray(np.linspace(-4, 4, 32, dtype=np.float32)))
        jax.effects_barrier()
        assert obs.get_registry().summary()["counters"] == {}
