"""Hypothesis property tests for the system's invariants.

Invariants from the paper:
  1. Error bound: for any (function, interval, Ea, algorithm, omega), the generated
     table never exceeds Ea anywhere in the interval (Eq. 10 guarantee).
  2. Footprint dominance: any accepted split has footprint <= the Reference footprint
     (splits are only accepted when they reduce).
  3. Partition validity: sorted, spans exactly [lo, hi), no empty sub-intervals.
  4. Monotone Ea: halving Ea never shrinks the Reference footprint.
  5. Fixed-point quantization is idempotent and bounded by half-ULP in range.
  6. QuantPack entry codes: chord-residual affine quantization round-trips
     within the rounding share of the budget, refinement never breaks the
     partition or the stored piecewise-linear function, and the end-to-end
     |f - dequantized table| stays <= Ea for any (function, Ea, rho, width).
"""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    FixedPointFormat,
    build_table,
    chord_residual_ranges,
    delta_for,
    footprint,
    get_function,
    quantize_spec,
    refine_for_quantization,
    reference_spacing,
    split,
)
from repro.core.quantize import quant_rounding_limit

FUNCS = ["log", "exp", "tanh", "sigmoid", "gauss", "gelu", "silu", "softplus"]
ALGS = ["reference", "binary", "hierarchical", "sequential"]


def subinterval(name, frac_lo, frac_len):
    """Map two unit floats to a non-degenerate sub-interval of the registry default."""
    lo0, hi0 = get_function(name).interval
    span = hi0 - lo0
    lo = lo0 + frac_lo * span * 0.8
    length = max(span * 0.05, frac_len * (hi0 - lo) * 0.95)
    hi = min(hi0, lo + length)
    if hi - lo < span * 0.02:
        hi = min(hi0, lo + span * 0.02)
    return float(lo), float(hi)


@settings(max_examples=40, deadline=None)
@given(
    name=st.sampled_from(FUNCS),
    alg=st.sampled_from(ALGS),
    frac_lo=st.floats(0.0, 1.0),
    frac_len=st.floats(0.1, 1.0),
    ea_exp=st.floats(-6.0, -2.0),
    omega=st.floats(0.05, 0.9),
)
def test_error_bound_invariant(name, alg, frac_lo, frac_len, ea_exp, omega):
    lo, hi = subinterval(name, frac_lo, frac_len)
    ea = 10.0 ** ea_exp
    ts = build_table(name, ea, lo, hi, algorithm=alg, omega=omega)
    err = ts.max_error_on_grid(n=20_001)
    assert err <= ea * (1 + 1e-6), (name, alg, lo, hi, ea, err)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(FUNCS),
    alg=st.sampled_from(["binary", "hierarchical", "sequential"]),
    frac_lo=st.floats(0.0, 1.0),
    frac_len=st.floats(0.1, 1.0),
    ea_exp=st.floats(-6.0, -2.0),
    omega=st.floats(0.05, 0.9),
)
def test_split_never_worse_than_reference(name, alg, frac_lo, frac_len, ea_exp, omega):
    lo, hi = subinterval(name, frac_lo, frac_len)
    ea = 10.0 ** ea_exp
    fn = get_function(name)
    ref = reference_spacing(fn, ea, lo, hi)
    sr = split(alg, name, ea, lo, hi, omega)
    # Eq.13 double-counts shared boundary entries; a 1-interval split == reference.
    # Any accepted split strictly reduced, so footprint <= reference always.
    assert sr.footprint <= ref.footprint + 1, (sr.footprint, ref.footprint)
    # partition validity
    p = sr.partition
    assert p[0] == pytest.approx(lo) and p[-1] == pytest.approx(hi)
    assert np.all(np.diff(p) > 0)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(FUNCS),
    frac_lo=st.floats(0.0, 1.0),
    frac_len=st.floats(0.1, 1.0),
    ea_exp=st.floats(-5.0, -2.0),
)
def test_footprint_monotone_in_ea(name, frac_lo, frac_len, ea_exp):
    lo, hi = subinterval(name, frac_lo, frac_len)
    ea = 10.0 ** ea_exp
    fn = get_function(name)
    big = reference_spacing(fn, ea, lo, hi).footprint
    small = reference_spacing(fn, ea / 2.0, lo, hi).footprint
    assert small >= big


@settings(max_examples=50, deadline=None)
@given(
    signed=st.integers(0, 1),
    width=st.integers(4, 32),
    frac=st.integers(0, 30),
    data=st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=16),
)
def test_fixed_point_idempotent_and_bounded(signed, width, frac, data):
    frac = min(frac, width - signed)
    fmt = FixedPointFormat(signed, width, frac)
    x = np.asarray(data)
    q = fmt.quantize(x)
    np.testing.assert_array_equal(fmt.quantize(q), q)
    in_range = (x >= fmt.min_value) & (x <= fmt.max_value)
    if in_range.any():
        err = np.abs(q[in_range] - x[in_range])
        assert np.max(err) <= fmt.quantization_error_bound() * (1 + 1e-9)


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(["tanh", "gelu", "log", "sigmoid"]),
    ea_exp=st.floats(-5.0, -2.5),
    rho=st.floats(0.5, 0.95),
    bits=st.sampled_from([8, 16]),
)
def test_quant_round_trip_within_rounding_budget(name, ea_exp, rho, bits):
    """Affine chord-residual codes reconstruct every stored entry within the
    rounding share (1 - rho) * Ea of the budget, at either storage width."""
    ea = 10.0 ** ea_exp
    tol = (1.0 - rho) * ea
    ts = build_table(name, rho * ea)
    refined = refine_for_quantization(ts, quant_rounding_limit(tol, bits))
    assert chord_residual_ranges(refined).max(initial=0.0) <= \
        quant_rounding_limit(tol, bits) * (1 + 1e-12)
    m = quantize_spec(refined, tol, bits, rho=rho, e_a=ea)
    # round trip: dequantized entries vs the f64 table values
    err = np.max(np.abs(m.dequantize() - refined.values))
    assert err <= tol * (1 + 1e-9), (name, ea, rho, bits, err)
    # codes fit the signed storage width
    assert m.codes.min() >= -(2 ** (bits - 1))
    assert m.codes.max() <= 2 ** (bits - 1) - 1
    # refinement kept a valid partition over the same interval
    p = m.spec.boundaries
    assert p[0] == ts.boundaries[0] and p[-1] == ts.boundaries[-1]
    assert np.all(np.diff(p) > 0)


@settings(max_examples=10, deadline=None)
@given(
    name=st.sampled_from(["tanh", "gelu", "log", "sigmoid"]),
    ea_exp=st.floats(-5.0, -2.5),
    rho=st.floats(0.5, 0.95),
    bits=st.sampled_from([8, 16]),
)
def test_quant_end_to_end_error_bound(name, ea_exp, rho, bits):
    """Eq. 10 (at rho*Ea) + rounding <= (1-rho)*Ea compose: the dequantized
    table never exceeds the full budget Ea anywhere in the interval."""
    ea = 10.0 ** ea_exp
    tol = (1.0 - rho) * ea
    ts = build_table(name, rho * ea)
    refined = refine_for_quantization(ts, quant_rounding_limit(tol, bits))
    m = quantize_spec(refined, tol, bits, rho=rho, e_a=ea)
    assert m.max_error_on_grid(n=20_001) <= ea * (1 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(FUNCS),
    ea_exp=st.floats(-6.0, -2.0),
    n_cuts=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_any_partition_respects_bound(name, ea_exp, n_cuts, seed):
    """Eq. 11 per sub-interval => bound holds for ARBITRARY partitions, not just
    the three algorithms' outputs (the paper's guarantee is partition-independent)."""
    from repro.core.splitting import SplitResult, _finalize
    from repro.core.spacing import SecondDerivMax

    fn = get_function(name)
    lo, hi = fn.interval
    ea = 10.0 ** ea_exp
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.uniform(lo, hi, size=n_cuts))
    cuts = cuts[(cuts > lo + 1e-6) & (cuts < hi - 1e-6)]
    oracle = SecondDerivMax(fn, lo, hi)
    sr = _finalize(fn, oracle, [lo, *cuts.tolist(), hi], ea, 0.3, "manual")
    ts = build_table(name, ea, lo, hi, algorithm="manual", split_result=sr)
    assert ts.max_error_on_grid(n=20_001) <= ea * (1 + 1e-6)
