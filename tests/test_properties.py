"""Hypothesis property tests for the system's invariants.

Invariants from the paper:
  1. Error bound: for any (function, interval, Ea, algorithm, omega), the generated
     table never exceeds Ea anywhere in the interval (Eq. 10 guarantee).
  2. Footprint dominance: any accepted split has footprint <= the Reference footprint
     (splits are only accepted when they reduce).
  3. Partition validity: sorted, spans exactly [lo, hi), no empty sub-intervals.
  4. Monotone Ea: halving Ea never shrinks the Reference footprint.
  5. Fixed-point quantization is idempotent and bounded by half-ULP in range.
  6. QuantPack entry codes: chord-residual affine quantization round-trips
     within the rounding share of the budget, refinement never breaks the
     partition or the stored piecewise-linear function, and the end-to-end
     |f - dequantized table| stays <= Ea for any (function, Ea, rho, width).
  7. Routed dispatch: for ARBITRARY per-row fn_ids assignments, the routed
     kernels/oracles are bit-identical to the corresponding static-fn_id
     dispatches, for both the f32 and the quantized pack.

Profiles: the default ``ci`` profile keeps the unannotated (routing) tests
cheap; ``HYPOTHESIS_PROFILE=nightly`` (the scheduled CI job) runs them with
more examples.  Tests with explicit ``max_examples`` are unaffected.
"""

import math
import os

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

settings.register_profile("ci", max_examples=12, deadline=None)
settings.register_profile("nightly", max_examples=75, deadline=None)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))

from repro.core import (
    FixedPointFormat,
    build_table,
    chord_residual_ranges,
    get_function,
    quantize_spec,
    refine_for_quantization,
    reference_spacing,
    split,
)
from repro.core.quantize import quant_rounding_limit

FUNCS = ["log", "exp", "tanh", "sigmoid", "gauss", "gelu", "silu", "softplus"]
ALGS = ["reference", "binary", "hierarchical", "sequential"]


def subinterval(name, frac_lo, frac_len):
    """Map two unit floats to a non-degenerate sub-interval of the registry default."""
    lo0, hi0 = get_function(name).interval
    span = hi0 - lo0
    lo = lo0 + frac_lo * span * 0.8
    length = max(span * 0.05, frac_len * (hi0 - lo) * 0.95)
    hi = min(hi0, lo + length)
    if hi - lo < span * 0.02:
        hi = min(hi0, lo + span * 0.02)
    return float(lo), float(hi)


@settings(max_examples=40, deadline=None)
@given(
    name=st.sampled_from(FUNCS),
    alg=st.sampled_from(ALGS),
    frac_lo=st.floats(0.0, 1.0),
    frac_len=st.floats(0.1, 1.0),
    ea_exp=st.floats(-6.0, -2.0),
    omega=st.floats(0.05, 0.9),
)
def test_error_bound_invariant(name, alg, frac_lo, frac_len, ea_exp, omega):
    lo, hi = subinterval(name, frac_lo, frac_len)
    ea = 10.0 ** ea_exp
    ts = build_table(name, ea, lo, hi, algorithm=alg, omega=omega)
    err = ts.max_error_on_grid(n=20_001)
    assert err <= ea * (1 + 1e-6), (name, alg, lo, hi, ea, err)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(FUNCS),
    alg=st.sampled_from(["binary", "hierarchical", "sequential"]),
    frac_lo=st.floats(0.0, 1.0),
    frac_len=st.floats(0.1, 1.0),
    ea_exp=st.floats(-6.0, -2.0),
    omega=st.floats(0.05, 0.9),
)
def test_split_never_worse_than_reference(name, alg, frac_lo, frac_len, ea_exp, omega):
    lo, hi = subinterval(name, frac_lo, frac_len)
    ea = 10.0 ** ea_exp
    fn = get_function(name)
    ref = reference_spacing(fn, ea, lo, hi)
    sr = split(alg, name, ea, lo, hi, omega)
    # Eq.13 double-counts shared boundary entries; a 1-interval split == reference.
    # Any accepted split strictly reduced, so footprint <= reference always.
    assert sr.footprint <= ref.footprint + 1, (sr.footprint, ref.footprint)
    # partition validity
    p = sr.partition
    assert p[0] == pytest.approx(lo) and p[-1] == pytest.approx(hi)
    assert np.all(np.diff(p) > 0)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(FUNCS),
    frac_lo=st.floats(0.0, 1.0),
    frac_len=st.floats(0.1, 1.0),
    ea_exp=st.floats(-5.0, -2.0),
)
def test_footprint_monotone_in_ea(name, frac_lo, frac_len, ea_exp):
    lo, hi = subinterval(name, frac_lo, frac_len)
    ea = 10.0 ** ea_exp
    fn = get_function(name)
    big = reference_spacing(fn, ea, lo, hi).footprint
    small = reference_spacing(fn, ea / 2.0, lo, hi).footprint
    assert small >= big


@settings(max_examples=50, deadline=None)
@given(
    signed=st.integers(0, 1),
    width=st.integers(4, 32),
    frac=st.integers(0, 30),
    data=st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=16),
)
def test_fixed_point_idempotent_and_bounded(signed, width, frac, data):
    frac = min(frac, width - signed)
    fmt = FixedPointFormat(signed, width, frac)
    x = np.asarray(data)
    q = fmt.quantize(x)
    np.testing.assert_array_equal(fmt.quantize(q), q)
    in_range = (x >= fmt.min_value) & (x <= fmt.max_value)
    if in_range.any():
        err = np.abs(q[in_range] - x[in_range])
        assert np.max(err) <= fmt.quantization_error_bound() * (1 + 1e-9)


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(["tanh", "gelu", "log", "sigmoid"]),
    ea_exp=st.floats(-5.0, -2.5),
    rho=st.floats(0.5, 0.95),
    bits=st.sampled_from([8, 16]),
)
def test_quant_round_trip_within_rounding_budget(name, ea_exp, rho, bits):
    """Affine chord-residual codes reconstruct every stored entry within the
    rounding share (1 - rho) * Ea of the budget, at either storage width."""
    ea = 10.0 ** ea_exp
    tol = (1.0 - rho) * ea
    ts = build_table(name, rho * ea)
    refined = refine_for_quantization(ts, quant_rounding_limit(tol, bits))
    assert chord_residual_ranges(refined).max(initial=0.0) <= \
        quant_rounding_limit(tol, bits) * (1 + 1e-12)
    m = quantize_spec(refined, tol, bits, rho=rho, e_a=ea)
    # round trip: dequantized entries vs the f64 table values
    err = np.max(np.abs(m.dequantize() - refined.values))
    assert err <= tol * (1 + 1e-9), (name, ea, rho, bits, err)
    # codes fit the signed storage width
    assert m.codes.min() >= -(2 ** (bits - 1))
    assert m.codes.max() <= 2 ** (bits - 1) - 1
    # refinement kept a valid partition over the same interval
    p = m.spec.boundaries
    assert p[0] == ts.boundaries[0] and p[-1] == ts.boundaries[-1]
    assert np.all(np.diff(p) > 0)


@settings(max_examples=10, deadline=None)
@given(
    name=st.sampled_from(["tanh", "gelu", "log", "sigmoid"]),
    ea_exp=st.floats(-5.0, -2.5),
    rho=st.floats(0.5, 0.95),
    bits=st.sampled_from([8, 16]),
)
def test_quant_end_to_end_error_bound(name, ea_exp, rho, bits):
    """Eq. 10 (at rho*Ea) + rounding <= (1-rho)*Ea compose: the dequantized
    table never exceeds the full budget Ea anywhere in the interval."""
    ea = 10.0 ** ea_exp
    tol = (1.0 - rho) * ea
    ts = build_table(name, rho * ea)
    refined = refine_for_quantization(ts, quant_rounding_limit(tol, bits))
    m = quantize_spec(refined, tol, bits, rho=rho, e_a=ea)
    assert m.max_error_on_grid(n=20_001) <= ea * (1 + 1e-6)


# ------------------------------------------------------------------------------
# 7. Routed dispatch == static dispatch, bitwise, for arbitrary routings.
# ------------------------------------------------------------------------------

ROUTED_FUNCS = ("gelu", "tanh", "log", "sigmoid")
ROUTED_EA = 1e-3  # loose budget: tiny tables, fast pack builds
_ROUTED_PACKS = {}


def _routed_pack(kind):
    if kind not in _ROUTED_PACKS:
        import jax.numpy as jnp  # noqa: F401  (jax import deferred to first use)
        from repro.approx import from_quant_layout, pack_specs
        from repro.core import cached_table, plan_quant_member, quant_pack_layout

        if kind == "f32":
            _ROUTED_PACKS[kind] = pack_specs(
                [cached_table(n, ROUTED_EA) for n in ROUTED_FUNCS])
        else:
            _ROUTED_PACKS[kind] = from_quant_layout(quant_pack_layout(
                [plan_quant_member(n, ROUTED_EA) for n in ROUTED_FUNCS]))
    return _ROUTED_PACKS[kind]


def _routed_case_check(kind, ids, seed, extr):
    import jax
    import jax.numpy as jnp
    from repro.approx.table_pack import eval_routed_quant_ref, eval_routed_ref
    from repro.kernels.routed_pack_lookup import (
        routed_pack_lookup_pallas, routed_quant_pack_lookup_pallas)
    from repro.kernels.table_pack_lookup import (
        quant_pack_lookup_pallas, table_pack_lookup_pallas)

    pack = _routed_pack(kind)
    routed = routed_pack_lookup_pallas if kind == "f32" else \
        routed_quant_pack_lookup_pallas
    static = table_pack_lookup_pallas if kind == "f32" else \
        quant_pack_lookup_pallas
    oracle = eval_routed_ref if kind == "f32" else eval_routed_quant_ref

    x = jnp.asarray(np.random.default_rng(seed)
                    .normal(0, 6, (len(ids), 96)).astype(np.float32))
    got = np.asarray(routed(pack, ids, x, extrapolate=extr))
    # bit-identical to the per-row STATIC dispatches...
    for r, fid in enumerate(ids):
        want = np.asarray(static(pack, fid, x[r], extrapolate=extr))
        np.testing.assert_array_equal(got[r], want, err_msg=f"row {r} fid {fid}")
    # ...and to the jnp where-select oracle, under jit
    ref = np.asarray(jax.jit(
        lambda v: oracle(pack, ids, v, extrapolate=extr))(x))
    np.testing.assert_array_equal(got, ref)


@settings(deadline=None)  # examples count comes from the ci/nightly profile
@given(
    ids=st.lists(st.integers(0, len(ROUTED_FUNCS) - 1), min_size=1, max_size=5),
    seed=st.integers(0, 2**31 - 1),
    extr=st.booleans(),
)
def test_routed_f32_bit_identical_to_static(ids, seed, extr):
    """Invariant 7, f32 pack: any routing == the static dispatches, bitwise."""
    _routed_case_check("f32", ids, seed, extr)


@settings(deadline=None)
@given(
    ids=st.lists(st.integers(0, len(ROUTED_FUNCS) - 1), min_size=1, max_size=5),
    seed=st.integers(0, 2**31 - 1),
    extr=st.booleans(),
)
def test_routed_quant_bit_identical_to_static(ids, seed, extr):
    """Invariant 7, quantized pack (dequantize-on-read + width groups)."""
    _routed_case_check("quant", ids, seed, extr)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(FUNCS),
    ea_exp=st.floats(-6.0, -2.0),
    n_cuts=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_any_partition_respects_bound(name, ea_exp, n_cuts, seed):
    """Eq. 11 per sub-interval => bound holds for ARBITRARY partitions, not just
    the three algorithms' outputs (the paper's guarantee is partition-independent)."""
    from repro.core.splitting import _finalize
    from repro.core.spacing import SecondDerivMax

    fn = get_function(name)
    lo, hi = fn.interval
    ea = 10.0 ** ea_exp
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.uniform(lo, hi, size=n_cuts))
    cuts = cuts[(cuts > lo + 1e-6) & (cuts < hi - 1e-6)]
    oracle = SecondDerivMax(fn, lo, hi)
    sr = _finalize(fn, oracle, [lo, *cuts.tolist(), hi], ea, 0.3, "manual")
    ts = build_table(name, ea, lo, hi, algorithm="manual", split_result=sr)
    assert ts.max_error_on_grid(n=20_001) <= ea * (1 + 1e-6)


# ------------------------------------------------------------------------------------
# Invariant 8 (RangeFold): reduction identities through the table path
# ------------------------------------------------------------------------------------
#
# The folded modes promise the MATHEMATICAL identities of the served functions,
# not just pointwise Ea: periodicity for trig, the exp(x)*exp(-x)=1 group law,
# and — the strongest one — bit-exact agreement with the unfolded core lookup
# whenever the argument already lies in the canonical interval (the fold is an
# identity there: k=0, r=x, and the reconstruction multiplies by 2^0 / selects
# quadrant 0).  Subnormals are excluded from the bit-parity properties: XLA
# flushes f32 subnormal ARITHMETIC (DAZ), so the fold's identity guarantee
# starts at the normal range.

_EA_FOLD = 1e-4


def _fold_cfg():
    from repro.approx import ApproxConfig

    return ApproxConfig(mode="folded_pack_ref", e_a=_EA_FOLD)


def _eval_folded(name, xs):
    import jax.numpy as jnp

    from repro.approx.range_fold import eval_folded_ref

    x = np.asarray(xs, np.float32).reshape(1, -1)
    return np.asarray(eval_folded_ref(_fold_cfg().pack(), name, jnp.asarray(x)))[0]


@settings(deadline=None)
@given(x=st.floats(-8.0, 8.0, allow_subnormal=False, width=32))
def test_sin_periodicity_through_table(x):
    """sin(x + 2pi) == sin(x) through the folded table path, within the Ea
    contract on both evaluations plus the f32 rounding of x + 2pi."""
    x2 = np.float32(np.float64(x) + 2.0 * math.pi)
    a, b = _eval_folded("sin", [x, x, x, x]), _eval_folded("sin", [x2] * 4)
    assert abs(float(a[0]) - float(b[0])) <= 2 * (_EA_FOLD * 1.02) + 1e-5


@settings(deadline=None)
@given(x=st.floats(-30.0, 30.0, allow_subnormal=False, width=32))
def test_exp_group_law_through_table(x):
    """exp(x) * exp(-x) == 1 through the folded table: each factor is within
    the RELATIVE contract, so the product is within ~2x of it."""
    e_pos, e_neg = _eval_folded("exp", [x] * 4), _eval_folded("exp", [-x] * 4)
    assert abs(float(e_pos[0]) * float(e_neg[0]) - 1.0) <= 5e-4


@settings(deadline=None)
@given(x=st.floats(-0.78, 0.78, allow_subnormal=False, width=32))
def test_folded_trig_bit_parity_on_core(x):
    """|x| < pi/4: folded sin/cos == the raw core member lookup, BITWISE
    (the fold is an identity and the reconstruction is transparent)."""
    import jax.numpy as jnp

    from repro.approx.range_fold import eval_folded_ref
    from repro.approx.table_pack import eval_pack_ref

    pack = _fold_cfg().pack()
    v = jnp.asarray(np.full((1, 4), x, np.float32))
    for name, core in (("sin", "sin_core"), ("cos", "cos_core")):
        folded = np.asarray(eval_folded_ref(pack, name, v))
        raw = np.asarray(eval_pack_ref(pack, core, v))
        np.testing.assert_array_equal(folded, raw, err_msg=name)


@settings(deadline=None)
@given(x=st.floats(-0.34, 0.34, allow_subnormal=False, width=32))
def test_folded_exp_bit_parity_on_core(x):
    """|x| < ln2/2: folded exp == the raw exp_core lookup bitwise (k = 0)."""
    import jax.numpy as jnp

    from repro.approx.range_fold import eval_folded_ref
    from repro.approx.table_pack import eval_pack_ref

    pack = _fold_cfg().pack()
    v = jnp.asarray(np.full((1, 4), x, np.float32))
    folded = np.asarray(eval_folded_ref(pack, "exp", v))
    raw = np.asarray(eval_pack_ref(pack, "exp_core", v))
    np.testing.assert_array_equal(folded, raw)


# --------------------------------------------------------------------------------------
# Invariant 9 (TableFlash): flash attention is invariant to the kv-chunk split
# --------------------------------------------------------------------------------------


@settings(deadline=None)  # examples count comes from the ci/nightly profile
@given(
    t=st.integers(4, 12),
    sq=st.integers(1, 4),
    window=st.sampled_from([0, 3, 6]),
    causal=st.booleans(),
    clocks=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flash_chunk_invariance(t, sq, window, causal, clocks, seed):
    """``_flash_inner``'s running softmax is a reduction: the kv_chunk split
    must not change the output.  Exact path: invariant to f32 accumulation
    order (tight allclose).  Table path: each chunking carries its own
    provable bound vs exact flash, so two chunkings differ by at most the sum
    of their bounds.  Geometry (window masking, per-slot (B, Sq) clocks with
    genuine empty slots) is drawn by hypothesis.  Rows with NO valid key are
    excluded, per the attn_error contract: the running max never leaves the
    finite NEG_INF floor there, so every slot contributes exp(0) = 1 and the
    renormalized garbage depends on the pad count (callers mask such rows)."""
    import jax.numpy as jnp

    from repro.approx import ApproxConfig
    from repro.core.attn_error import flash_abs_bound
    from repro.models.attention import flash_attention

    rng = np.random.default_rng(seed)
    B, G, QG, D = 2, 2, 2, 4
    q = jnp.asarray(rng.normal(0, 1, (B, sq, G, QG, D)), np.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, t, G, D)), np.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, t, G, D)), np.float32)
    if clocks:
        offs = rng.integers(0, 3, B)
        q_pos = jnp.asarray(np.stack([t - sq + o + np.arange(sq)
                                      for o in offs]).astype(np.int32))
        kp = np.tile(np.arange(t, dtype=np.int32), (B, 1))
        kp[:, -1] = -1  # one genuine empty cache slot per row
        k_pos = jnp.asarray(kp)
    else:
        q_pos = jnp.arange(t - sq, t, dtype=jnp.int32)
        k_pos = jnp.arange(t, dtype=jnp.int32)

    # rows with no valid key are outside the contract — mask them out
    qp2 = np.atleast_2d(np.asarray(q_pos))          # (1|B, Sq)
    kp2 = np.atleast_2d(np.asarray(k_pos))          # (1|B, T)
    ok = kp2[:, None, :] >= 0
    if causal:
        ok = ok & (kp2[:, None, :] <= qp2[:, :, None])
    if window > 0:
        ok = ok & (kp2[:, None, :] > qp2[:, :, None] - window)
    row_ok = np.broadcast_to(ok.any(-1), (B, sq))[:, :, None, None, None]

    table_fn = ApproxConfig(mode="table_pack_ref", e_a=1e-4, omega=0.2,
                            attn_table=True).attn_exp()
    ea_eff = 1e-4 * 1.02 + 1e-5
    v_max = float(jnp.max(jnp.abs(v)))
    for exp_fn, label in ((None, "exact"), (table_fn, "table")):
        outs = {}
        for c in (1, 3, 8, t):
            outs[c] = np.asarray(flash_attention(
                q, k, v, q_pos, k_pos, causal=causal, window=window,
                kv_chunk=c, exp_fn=exp_fn)) * row_ok
        for c in (1, 3, 8):
            if exp_fn is None:
                tol = 1e-5  # f32 accumulation-order slop only
            else:
                tol = (flash_abs_bound(ea_eff, t, c, v_max)
                       + flash_abs_bound(ea_eff, t, t, v_max) + 1e-5)
            np.testing.assert_allclose(
                outs[c], outs[t], atol=tol, rtol=0,
                err_msg=f"{label} kv_chunk={c} vs {t}")
