"""PackLint's failure paths: every rule class must FIRE on a seeded violation.

The green direction (the real registry passes) is covered by the
``tools/check_contracts.py`` CI gate and a slow-marked full run here; these
tests prove the rules have *power* — an injected f64 constant, a
``debug_callback`` on the obs-off path, a weak-type cache-key drift, an
inflated pack operand, and a telemetry-on closure each trip their rule.

Also: direct malformed-input unit tests for ``tools/check_trace.py``.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, jaxpr_lint as jl
from repro.analysis.report import Finding, Report
from repro.kernels.table_pack_lookup import table_pack_lookup_pallas

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import check_trace  # noqa: E402

X = np.linspace(-2.0, 2.0, 512).astype(np.float32)


@pytest.fixture(scope="module")
def ctx():
    # two functions keep every pack build small; log brings a foldable
    # member (and its log_core core) into the pack for the folded modes
    return contracts.LintContext(funcs=("tanh", "log"))


# --------------------------------------------------------------------------------------
# Rule 1 — f64 leakage
# --------------------------------------------------------------------------------------

class TestF64Rule:
    def test_seeded_f64_constant_fires(self):
        # under default config jax silently downcasts f64 consts, which is
        # itself a contract violation but an invisible one; x64 mode makes
        # the leak visible to the lint exactly as it would be on a host
        # where the design layer's np.float64 escaped into a closure
        table = np.linspace(0.0, 1.0, 8)  # np.float64, like a design table

        with jax.experimental.enable_x64():
            traced = jl.trace(lambda v: v + table.sum(), X.astype(np.float64))
            hits = jl.find_wide_dtypes(traced)
        assert hits, "injected f64 constant must be flagged"
        assert any("float64" in h for h in hits)

    def test_seeded_f64_artifact_leaf_fires(self):
        art = {"values": jnp.zeros(4), "raw": np.zeros(4, np.float64)}
        hits = jl.array_leaf_wide_dtypes(art)
        assert len(hits) == 1 and "raw" in hits[0]

    def test_clean_closure_passes(self):
        assert jl.find_wide_dtypes(jl.trace(jnp.tanh, X)) == []


# --------------------------------------------------------------------------------------
# Rule 2 — forbidden primitives / allowlists
# --------------------------------------------------------------------------------------

class TestKernelPrimitivesRule:
    def test_seeded_debug_callback_fires(self):
        def leaky(v):
            jax.debug.callback(lambda a: None, v)
            return jnp.tanh(v)

        cbs = jl.closure_callbacks(jl.trace(leaky, X))
        assert cbs, "debug_callback on the obs-off path must be flagged"
        assert jl.closure_callbacks(jl.trace(jnp.tanh, X)) == []

    def test_callback_forbidden_even_if_allowlisted(self):
        # callbacks are forbidden unconditionally: an allowlist row that
        # names one does not whitelist it
        from collections import Counter
        bad = jl.forbidden_primitives(Counter({"debug_callback": 1}),
                                      allowed=frozenset({"debug_callback"}))
        assert bad == ["debug_callback"]

    def test_unallowlisted_primitive_fires(self, ctx):
        traced = ctx.traced("table_pack", "tanh", "value")
        eqn = jl.pallas_eqns(traced)[0]
        bad = contracts.check_kernel(eqn, allowed=frozenset({"add", "mul"}))
        assert any(b.startswith("unallowlisted:") for b in bad)
        # and against its real allowlist the same kernel is clean
        name = jl.kernel_name(eqn)
        assert contracts.check_kernel(eqn, contracts.KERNEL_ALLOWED[name]) == []

    def test_every_registered_kernel_has_an_allowlist(self, ctx):
        for mode in ("table_pack", "quant_pack", "poly_pack", "routed_pack",
                     "sharded_pack", "folded_pack"):
            for kind in ("value", "grad"):
                for eqn in jl.pallas_eqns(ctx.traced(mode, "tanh", kind)):
                    assert jl.kernel_name(eqn) in contracts.KERNEL_ALLOWED


# --------------------------------------------------------------------------------------
# Rule 3 — recompile hazards
# --------------------------------------------------------------------------------------

class TestRecompileRule:
    def test_seeded_weak_type_drift_fires(self):
        # the same logical call made once with a strongly-typed i32 operand
        # and once with weak python scalars: two jit cache keys == recompile
        strong = jnp.arange(4, dtype=jnp.int32)
        weak = jnp.asarray(2.0) * 1  # weak f32 scalar
        k1 = jl.jit_cache_key((strong, jnp.float32(2.0)))
        k2 = jl.jit_cache_key((strong, weak))
        assert k1 != k2
        assert not jl.keys_stable([k1, k2])
        assert jl.weak_leaves((strong, weak)) != []
        assert jl.weak_leaves((strong, jnp.float32(2.0))) == []

    def test_seeded_dtype_drift_fires(self):
        k1 = jl.jit_cache_key((jnp.arange(4, dtype=jnp.int32),))
        k2 = jl.jit_cache_key((jnp.arange(4, dtype=jnp.int16),))
        assert k1 != k2

    def test_static_kwarg_drift_fires(self):
        a = jnp.zeros(4)
        assert jl.jit_cache_key((a,), static={"grad": False}) != \
            jl.jit_cache_key((a,), static={"grad": True})

    def test_reroute_keys_stable_on_real_entry(self, ctx):
        from repro.kernels.routed_pack_lookup import routed_pack_lookup_pallas

        pack = ctx.pack()
        x2d = ctx.x("tanh").reshape(contracts.ROWS, -1)
        keys, weak = contracts.capture_routed_keys(
            routed_pack_lookup_pallas,
            [(pack, "tanh", x2d), (pack, "log", x2d),
             (pack, ["tanh", "log"] * (contracts.ROWS // 2), x2d)])
        assert len(keys) == 3 and jl.keys_stable(keys)
        assert weak == []

    @pytest.mark.slow
    def test_engine_stationarity(self):
        findings = contracts.engine_stationarity_findings()
        assert findings and all(f.ok for f in findings), \
            [f.detail for f in findings if not f.ok]


# --------------------------------------------------------------------------------------
# Rule 4 — VMEM budgets
# --------------------------------------------------------------------------------------

class TestVmemRule:
    def test_seeded_inflated_pack_fires(self, ctx):
        pack = ctx.pack()
        budget = ctx.layout().vmem().padded_bytes
        fat = pack._replace(values=jnp.concatenate([pack.values] * 4))
        traced = jl.trace(
            lambda v: table_pack_lookup_pallas(fat, "tanh", v), ctx.x("tanh"))
        resident = jl.pack_resident_bytes(jl.pallas_eqns(traced)[0])
        finding = contracts.check_budget(resident, budget, "seeded")
        assert not finding.ok
        assert resident > budget

    def test_real_pack_fits(self, ctx):
        traced = ctx.traced("table_pack", "tanh", "value")
        resident = jl.pack_resident_bytes(jl.pallas_eqns(traced)[0])
        cost = ctx.layout().vmem()
        # the pinned planes the lowered kernel actually carries are exactly
        # the layout's raw table+meta accounting
        assert resident == cost.table_bytes + cost.meta_bytes
        assert contracts.check_budget(resident, cost.padded_bytes, "s").ok

    def test_per_shard_budget(self, ctx):
        traced = ctx.traced("sharded_pack", "tanh", "value")
        eqns = jl.pallas_eqns(traced)
        assert len(eqns) == ctx.n_shards  # one launch per shard
        budget = ctx.slayout().vmem().padded_bytes
        for eqn in eqns:
            assert contracts.check_budget(
                jl.pack_resident_bytes(eqn), budget, "s").ok


# --------------------------------------------------------------------------------------
# Rule 5 — obs-off structural identity
# --------------------------------------------------------------------------------------

class TestObsIdentityRule:
    def test_telemetry_on_closure_differs(self, ctx):
        # the detector must have power: with device_telemetry actually ON the
        # instrumented closure is structurally DIFFERENT from the obs-never
        # closure (that difference is what rule 5 proves absent when off)
        from repro import obs
        from repro.approx import ApproxConfig

        kw = dict(mode="table_pack", e_a=ctx.e_a,
                  pack_functions=ctx.pack_names)
        try:
            obs.disable()
            fp_never = jl.fingerprint(ApproxConfig(**kw).unary("tanh"), X)
            obs.configure(enabled=True, device_telemetry=True)
            fp_on = jl.fingerprint(ApproxConfig(**kw).unary("tanh"), X)
        finally:
            obs.disable()
        assert fp_never != fp_on
        assert "callback" in fp_on and "callback" not in fp_never

    def test_disabled_closure_identical(self, ctx):
        from repro.approx import ApproxConfig

        fp_never, fp_disabled = contracts.obs_identity_fingerprints(
            lambda: ApproxConfig(mode="table_pack", e_a=ctx.e_a,
                                 pack_functions=ctx.pack_names).unary("tanh"),
            X)
        assert fp_never == fp_disabled

    def test_fingerprint_is_deterministic(self, ctx):
        a = jl.fingerprint(ctx.unary_fn("table_pack", "tanh"), X)
        b = jl.fingerprint(ctx.unary_fn("table_pack", "tanh"), X)
        assert a == b


# --------------------------------------------------------------------------------------
# TableFlash enrollment: the attn_exp closure rides rules 2/4/5 whenever the
# lint pack carries exp_neg — with the same seeded-violation power checks
# --------------------------------------------------------------------------------------

class TestTableFlashLint:
    @pytest.fixture(scope="class")
    def actx(self):
        # a pack that actually carries the exp_neg member TableFlash serves
        return contracts.LintContext(funcs=("tanh", "exp_neg"))

    def test_tableflash_kernel_allowlisted(self, actx):
        for kind in ("value", "grad"):
            eqns = jl.pallas_eqns(actx.attn_traced(kind))
            assert eqns, "attn_exp pallas closure must lower a pallas_call"
            for eqn in eqns:
                name = jl.kernel_name(eqn)
                assert name in contracts.KERNEL_ALLOWED
                assert contracts.check_kernel(
                    eqn, contracts.KERNEL_ALLOWED[name]) == []

    def test_seeded_unallowlisted_primitive_fires(self, actx):
        eqn = jl.pallas_eqns(actx.attn_traced("value"))[0]
        bad = contracts.check_kernel(eqn, allowed=frozenset({"add", "mul"}))
        assert any(b.startswith("unallowlisted:") for b in bad)

    def test_vmem_budget_holds_and_seeded_inflation_fires(self, actx):
        from repro.approx import make_attn_exp_fn

        budget = actx.layout().vmem().padded_bytes
        for kind in ("value", "grad"):
            for eqn in jl.pallas_eqns(actx.attn_traced(kind)):
                assert contracts.check_budget(
                    jl.pack_resident_bytes(eqn), budget, "s").ok
        pack = actx.pack()
        fat = pack._replace(values=jnp.concatenate([pack.values] * 4))
        traced = jl.trace(make_attn_exp_fn(fat, use_pallas=True),
                          actx.attn_x())
        resident = jl.pack_resident_bytes(jl.pallas_eqns(traced)[0])
        assert not contracts.check_budget(resident, budget, "seeded").ok

    def test_attn_exp_obs_off_identical(self, actx):
        from repro.approx import ApproxConfig

        fp_never, fp_disabled = contracts.obs_identity_fingerprints(
            lambda: ApproxConfig(mode="table_pack", e_a=actx.e_a,
                                 pack_functions=actx.pack_names,
                                 attn_table=True).attn_exp(), actx.attn_x())
        assert fp_never == fp_disabled

    def test_telemetry_on_attn_exp_differs(self, actx):
        # power check: with device telemetry ON the instrumented attn_exp is
        # structurally different (a callback appears) — the difference rule 5
        # proves absent when telemetry is off
        from repro import obs
        from repro.approx import ApproxConfig

        kw = dict(mode="table_pack", e_a=actx.e_a,
                  pack_functions=actx.pack_names, attn_table=True)
        try:
            obs.disable()
            fp_never = jl.fingerprint(ApproxConfig(**kw).attn_exp(),
                                      actx.attn_x())
            obs.configure(enabled=True, device_telemetry=True)
            fp_on = jl.fingerprint(ApproxConfig(**kw).attn_exp(),
                                   actx.attn_x())
        finally:
            obs.disable()
        assert fp_never != fp_on
        assert "callback" in fp_on and "callback" not in fp_never

    def test_rules_emit_attn_exp_findings(self, actx):
        rep = contracts.run(actx, rules=["kernel_primitives", "vmem_budget"])
        assert rep.ok, rep.summary()
        subjects = {f.subject for f in rep.findings}
        for s in ("closure:attn_exp/value", "closure:attn_exp/grad",
                  "kernel:_tableflash_kernel[attn_exp/value]",
                  "attn_exp/value", "attn_exp/grad"):
            assert s in subjects, s

    def test_no_exp_neg_pack_skips_cleanly(self, ctx):
        # the base fixture's pack has no exp_neg: no attn findings, no error
        rep = contracts.run(ctx, rules=["vmem_budget"])
        assert rep.ok
        assert not any("attn_exp" in f.subject for f in rep.findings)


# --------------------------------------------------------------------------------------
# The registry end-to-end (subsampled fast; the CLI gates the full matrix)
# --------------------------------------------------------------------------------------

class TestRegistry:
    def test_report_shape(self):
        rep = Report(findings=[Finding("r", "s", True),
                               Finding("r", "t", False, "boom")])
        assert not rep.ok and len(rep.failures()) == 1
        doc = rep.to_dict()
        assert doc["schema"] == "packlint-report-v1"
        assert doc["rules"]["r"]["checked"] == 2
        assert "boom" in rep.summary()

    def test_all_five_rules_registered(self):
        assert set(contracts.RULES) == {
            "f64_leak", "kernel_primitives", "recompile_hazard",
            "vmem_budget", "obs_off_identity"}

    def test_fast_rules_green(self, ctx):
        rep = contracts.run(ctx, rules=["f64_leak", "kernel_primitives",
                                        "vmem_budget"])
        assert rep.ok, rep.summary()
        # auto-enrollment: every registered mode was checked
        subjects = {f.subject for f in rep.findings}
        for mode in contracts.ALL_MODES:
            assert any(s.startswith(f"{mode}/") for s in subjects), mode

    @pytest.mark.slow
    def test_full_registry_green(self, ctx):
        rep = contracts.run(ctx)
        assert rep.ok, rep.summary()


# --------------------------------------------------------------------------------------
# tools/check_trace.py malformed-input handling
# --------------------------------------------------------------------------------------

def _ev(**kw):
    base = {"name": "t", "ph": "i", "pid": 1, "tid": 1, "ts": 1.0}
    base.update(kw)
    return base


class TestCheckTrace:
    def test_clean_trace(self):
        doc = {"traceEvents": [_ev(ph="B"), _ev(ph="E", ts=2.0)]}
        assert check_trace.validate_trace(doc) == []

    def test_top_level_garbage(self):
        assert check_trace.validate_trace(42) == [
            "top level is neither an object nor an array"]
        assert check_trace.validate_trace({"foo": []}) == [
            "top level has no traceEvents array"]
        assert check_trace.validate_trace([]) == ["traceEvents is empty"]

    def test_non_dict_event(self):
        errs = check_trace.validate_trace(["nope"])
        assert any("not an object" in e for e in errs)

    def test_missing_name_and_unknown_phase(self):
        errs = check_trace.validate_trace([_ev(name=""), _ev(ph="Q")])
        assert any("missing name" in e for e in errs)
        assert any("unknown phase 'Q'" in e for e in errs)

    def test_missing_pid_tid_and_ts(self):
        ev = {"name": "t", "ph": "i"}
        errs = check_trace.validate_trace([ev])
        assert sum("missing numeric" in e for e in errs) == 3  # pid, tid, ts

    def test_metadata_exempt_from_ts(self):
        ev = {"name": "process_name", "ph": "M", "pid": 1, "tid": 1}
        assert check_trace.validate_trace([ev]) == []

    def test_backwards_ts(self):
        errs = check_trace.validate_trace([_ev(ts=5.0), _ev(ts=1.0)])
        assert any("ts went backwards" in e for e in errs)

    def test_unbalanced_and_crossed_spans(self):
        errs = check_trace.validate_trace([_ev(ph="E")])
        assert any("E without matching B" in e for e in errs)
        errs = check_trace.validate_trace(
            [_ev(ph="B", name="a"), _ev(ph="B", name="b", ts=2.0),
             _ev(ph="E", name="a", ts=3.0)])
        assert any("not nested" in e for e in errs)
        assert any("never ended" in e for e in errs)

    def test_span_ends_before_it_begins(self):
        # E's ts is checked against the B it closes on the same track; a
        # second track resets monotonicity so only the span check fires
        errs = check_trace.validate_trace(
            [_ev(ph="B", ts=5.0), _ev(ph="E", ts=1.0)])
        assert any("backwards" in e for e in errs)

    def test_x_and_c_payloads(self):
        errs = check_trace.validate_trace([_ev(ph="X", dur=-1)])
        assert any("non-negative dur" in e for e in errs)
        errs = check_trace.validate_trace([_ev(ph="C", args={"q": "high"})])
        assert any("dict of numeric series" in e for e in errs)
        assert check_trace.validate_trace(
            [_ev(ph="X", dur=2), _ev(ph="C", args={"q": 1})]) == []

    def test_main_usage_exit(self):
        with pytest.raises(SystemExit) as ei:
            check_trace.main([])
        assert ei.value.code == 2

    def test_main_failing_file(self, tmp_path):
        p = tmp_path / "t.json"
        p.write_text('{"traceEvents": [{"ph": "Q"}]}')
        with pytest.raises(SystemExit) as ei:
            check_trace.main([str(p)])
        assert ei.value.code == 1
