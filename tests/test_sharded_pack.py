"""ShardedPack: planner invariants, bit-parity with the replicated pack, and
the distributed (shard_map + psum) path on a multi-device debug mesh.

The sharding contract (docs/sharding.md): the shard planner partitions the
pack's values vector at sub-interval granularity into contiguous per-shard
slices with rebased base addresses; the shard-local lookup masks elements
whose selected sub-interval the shard does not own; summing the S
contributions (psum over 'model' on a mesh, a stacked-axis sum off-mesh)
reproduces the REPLICATED pack bit for bit — exactly one shard contributes a
real value per element, the rest contribute literal zeros.

Mesh tests run in subprocesses (device count locks at first jax init, same
pattern as tests/test_parallel.py).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.approx import ApproxConfig, pack_specs
from repro.approx.table_pack import (
    eval_pack_ref,
    eval_pack_slope,
    eval_routed_ref,
    eval_routed_sharded_ref,
    eval_sharded_ref,
    eval_sharded_slope,
    from_sharded_layout,
)
from repro.core import cached_table, function_names, get_function, pack_layout, shard_pack_layout
from repro.kernels.routed_pack_lookup import (
    routed_pack_lookup_pallas,
    sharded_routed_pack_grad_pallas,
    sharded_routed_pack_lookup_pallas,
)
from repro.kernels.table_pack_lookup import (
    sharded_pack_grad_pallas,
    sharded_pack_lookup_pallas,
    table_pack_lookup_pallas,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EA = 1e-4
FUNCS = tuple(function_names())
FAST_FUNCS = ("gelu", "tanh", "log")  # same fast-tier subsample as conformance

_CACHE = {}


def _specs():
    if "specs" not in _CACHE:
        _CACHE["specs"] = [cached_table(n, EA) for n in FUNCS]
    return _CACHE["specs"]


def _layout():
    if "layout" not in _CACHE:
        _CACHE["layout"] = pack_layout(_specs())
    return _CACHE["layout"]


def _pack():
    if "pack" not in _CACHE:
        _CACHE["pack"] = pack_specs(_specs())
    return _CACHE["pack"]


def _spack(n_shards=3):
    key = ("spack", n_shards)
    if key not in _CACHE:
        _CACHE[key] = from_sharded_layout(shard_pack_layout(_layout(), n_shards))
    return _CACHE[key]


def probe(name, n=2048):
    lo, hi = get_function(name).interval
    span = hi - lo
    rng = np.random.default_rng(11)
    return jnp.asarray(
        rng.uniform(lo - 0.5 * span, hi + 0.5 * span, n).astype(np.float32))


def fn_params():
    for f in FUNCS:
        marks = () if f in FAST_FUNCS else (pytest.mark.slow,)
        yield pytest.param(f, marks=marks, id=f)


# ---------------------------- planner invariants --------------------------------


class TestPlanner:
    @pytest.mark.parametrize("n_shards", [1, 2, 3, 4, 7])
    def test_slices_partition_the_footprint(self, n_shards):
        lay = _layout()
        sp = shard_pack_layout(lay, n_shards)
        assert int(sp.shard_sizes.sum()) == lay.footprint
        np.testing.assert_array_equal(
            sp.shard_offsets, np.concatenate([[0], np.cumsum(sp.shard_sizes)[:-1]]))
        # every real sub-interval owned by exactly one shard; padding by none
        for f in range(lay.n_functions):
            n = lay.n_intervals[f]
            assert (sp.owner[f, :n] >= 0).all()
            assert (sp.owner[f, :n] < n_shards).all()
            assert (sp.owner[f, n:] == -1).all()

    @pytest.mark.parametrize("n_shards", [2, 3, 4])
    def test_ownership_is_contiguous_in_pack_order(self, n_shards):
        """Slices must be contiguous runs of the values vector (a shard's
        entries are one block, so one device_put slice serves it)."""
        lay = _layout()
        sp = shard_pack_layout(lay, n_shards)
        order = []
        for f in range(lay.n_functions):
            order += list(sp.owner[f, : lay.n_intervals[f]])
        assert order == sorted(order)

    @pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
    def test_rebasing_reproduces_the_global_values(self, n_shards):
        """local_base re-addresses every owned sub-interval into its shard's
        slice without changing a single stored value."""
        lay = _layout()
        sp = shard_pack_layout(lay, n_shards)
        for f in range(lay.n_functions):
            for j in range(lay.n_intervals[f]):
                s = int(sp.owner[f, j])
                k = int(lay.seg_count[f, j]) + 1  # entries incl. both endpoints
                lb, gb = int(sp.local_base[f, j]), int(lay.base[f, j])
                sv = sp.shard_values(s)
                assert 0 <= lb and lb + k <= len(sv)
                np.testing.assert_array_equal(sv[lb : lb + k],
                                              lay.values[gb : gb + k])

    @pytest.mark.parametrize("n_shards", [2, 3, 4, 7])
    def test_per_shard_vmem_beats_replicated(self, n_shards):
        lay = _layout()
        sp = shard_pack_layout(lay, n_shards)
        assert sp.vmem().padded_bytes < lay.vmem().padded_bytes

    def test_single_shard_is_the_identity_plan(self):
        lay = _layout()
        sp = shard_pack_layout(lay, 1)
        np.testing.assert_array_equal(sp.shard_values(0), lay.values)
        for f in range(lay.n_functions):
            n = lay.n_intervals[f]
            np.testing.assert_array_equal(sp.local_base[f, :n], lay.base[f, :n])

    def test_invalid_shard_counts_rejected(self):
        with pytest.raises(ValueError, match="n_shards"):
            shard_pack_layout(_layout(), 0)
        with pytest.raises(ValueError, match="cannot split"):
            shard_pack_layout(_layout(), _layout().footprint + 1)


# ---------------------------- off-mesh bit parity -------------------------------


@pytest.mark.parametrize("name", fn_params())
@pytest.mark.parametrize("extrapolate", [False, True], ids=["clamp", "extrap"])
def test_sharded_ref_matches_replicated_bitwise(name, extrapolate):
    """The stacked-shard-axis oracle == the replicated pack, bit for bit,
    including deep out-of-range tails."""
    x = probe(name)
    pack, spack = _pack(), _spack()  # built OUTSIDE the traces below
    want = jax.jit(
        lambda v: eval_pack_ref(pack, name, v, extrapolate=extrapolate))(x)
    got = jax.jit(
        lambda v: eval_sharded_ref(spack, name, v,
                                   extrapolate=extrapolate))(x)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("name", fn_params())
def test_sharded_kernel_matches_oracle_bitwise(name):
    """Per-shard Pallas launches + sum == the jnp sharded oracle == the
    replicated kernel."""
    x = probe(name)
    pack, spack = _pack(), _spack()
    ref = jax.jit(lambda v: eval_sharded_ref(spack, name, v))(x)
    pal = sharded_pack_lookup_pallas(spack, name, x)
    repl = table_pack_lookup_pallas(pack, name, x)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
    np.testing.assert_array_equal(np.asarray(repl), np.asarray(pal))


@pytest.mark.parametrize("name", fn_params())
def test_sharded_slope_matches_replicated_bitwise(name):
    x = probe(name)
    pack, spack = _pack(), _spack()
    want = jax.jit(lambda v: eval_pack_slope(pack, name, v))(x)
    got = jax.jit(lambda v: eval_sharded_slope(spack, name, v))(x)
    _, pal = sharded_pack_grad_pallas(spack, name, x)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(pal))


def test_routed_sharded_matches_replicated_routed():
    """Dynamic per-row dispatch over the sharded pack == the replicated
    routed kernel for a mixed routing, bit for bit."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 4, (12, 256)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, len(FUNCS), 12), jnp.int32)
    pack, spack = _pack(), _spack()
    want = routed_pack_lookup_pallas(pack, ids, x)
    got = sharded_routed_pack_lookup_pallas(spack, ids, x)
    ref = jax.jit(lambda v: eval_routed_sharded_ref(spack, ids, v))(x)
    oracle = jax.jit(lambda v: eval_routed_ref(pack, ids, v))(x)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(ref))
    y, dy = sharded_routed_pack_grad_pallas(spack, ids, x)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(y))
    assert np.isfinite(np.asarray(dy)).all()


def test_unary_mode_matches_table_pack_bitwise():
    """ApproxConfig(mode='sharded_pack') serves the same bits (value AND
    table-slope gradient) as mode='table_pack' — the user-facing contract."""
    shard_cfg = ApproxConfig(mode="sharded_pack", e_a=EA, pack_shards=3)
    pack_cfg = ApproxConfig(mode="table_pack", e_a=EA)
    x = jnp.asarray(
        np.random.default_rng(4).normal(0, 3, 4096).astype(np.float32))
    for act in ("gelu", "tanh", "sigmoid", "exp"):
        fs, fp = shard_cfg.unary(act), pack_cfg.unary(act)
        np.testing.assert_array_equal(
            np.asarray(jax.jit(fs)(x)), np.asarray(jax.jit(fp)(x)),
            err_msg=act)
        gs = jax.jit(jax.grad(lambda v: fs(v).sum()))(x)
        gp = jax.jit(jax.grad(lambda v: fp(v).sum()))(x)
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(gp),
                                      err_msg=f"{act} grad")


def test_exact_grad_mode_uses_analytic_derivative():
    cfg = ApproxConfig(mode="sharded_pack", e_a=EA, exact_grad=True)
    f = cfg.unary("gelu")
    x = jnp.zeros((8,), jnp.float32)
    g = np.asarray(jax.grad(lambda v: f(v).sum())(x))
    # exact gelu'(0) = 0.5 exactly; the table slope would differ
    np.testing.assert_allclose(g, 0.5, atol=1e-6)


# ---------------------------- mesh (shard_map) parity ---------------------------


def run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + ":" + REPO
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_mesh_parity_and_placement():
    """On a real multi-device mesh: each device holds ONE values slice
    (place_sharded_pack), and the shard_map + psum lookup — jnp body AND
    Pallas body — is bit-identical to the replicated pack."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.approx import pack_specs
from repro.approx.table_pack import eval_pack_ref, eval_sharded_mesh, shard_pack
from repro.core import cached_table, pack_layout
from repro.launch.mesh import make_sharded_pack_mesh
from repro.parallel.sharding import place_sharded_pack, use_sharding

names = ("gelu", "silu", "tanh", "sigmoid_sym", "softplus", "exp_neg")
specs = [cached_table(n, 1e-4) for n in names]
pack = pack_specs(specs)
x = jnp.asarray(np.random.default_rng(0).normal(0, 3, (16, 512)).astype(np.float32))
for S, nd in ((2, 2), (4, 1)):
    spack = shard_pack(pack_layout(specs), S)
    mesh = make_sharded_pack_mesh(S, n_data=nd)
    placed = place_sharded_pack(spack, mesh)
    shards = placed.values.addressable_shards
    assert len(shards) == nd * S
    assert all(s.data.shape[0] == 1 for s in shards), "values not split per device"
    for name in names:
        want = jax.jit(lambda v: eval_pack_ref(pack, name, v))(x)
        with use_sharding(mesh):
            got = jax.jit(lambda v: eval_sharded_mesh(placed, name, v, mesh))(x)
            got_pal = jax.jit(lambda v: eval_sharded_mesh(
                placed, name, v, mesh, use_pallas=True))(x)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got_pal))
print("MESH_SHARDED_OK")
""")
    assert "MESH_SHARDED_OK" in out


@pytest.mark.slow
def test_mesh_unary_auto_dispatch():
    """ApproxConfig(mode='sharded_pack') picks the shard_map path when the
    bound mesh's 'model' axis matches pack_shards — and stays bit-identical
    to the un-meshed call."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.approx import ApproxConfig
from repro.launch.mesh import make_sharded_pack_mesh
from repro.parallel.sharding import use_sharding

cfg = ApproxConfig(mode="sharded_pack", e_a=1e-4, pack_shards=2)
x = jnp.asarray(np.random.default_rng(1).normal(0, 3, 4096).astype(np.float32))
f = cfg.unary("gelu")
plain = np.asarray(jax.jit(f)(x))
mesh = make_sharded_pack_mesh(2, n_data=2)
with use_sharding(mesh):
    meshed = np.asarray(jax.jit(cfg.unary("gelu"))(x))
np.testing.assert_array_equal(plain, meshed)
print("MESH_UNARY_OK")
""")
    assert "MESH_UNARY_OK" in out
