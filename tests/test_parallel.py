"""Multi-device integration tests.

Device count is locked at first jax init, so these run in SUBPROCESSES with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the smoke tests keep the
default 1 device, per the assignment).  Covers: sharded train step on a (2,4)
mesh, WUS layouts, elastic checkpoint restore onto a different mesh, and the
spec builders' divisibility guarantees.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.models import get_config
from repro.models.registry import ARCH_IDS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + ":" + REPO
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


PREAMBLE = """
import jax, jax.numpy as jnp, numpy as np
from tests.test_archs import reduced, make_batch
from repro.models import build_model
from repro.parallel.params import param_pspecs, zero1_pspecs, shardings_from_specs
from repro.parallel.sharding import use_sharding, default_rules
from repro.train.loop import make_train_step, state_pspecs, work_pspecs
from repro.optim import adamw
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = reduced("stablelm-3b").replace(d_model=64, d_ff=128, n_heads=4, n_kv_heads=4)
model = build_model(cfg)
"""


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    out = run_subprocess(PREAMBLE + """
batch = make_batch(cfg, B=8, S=16)
params = model.init(jax.random.key(0))
state = {"params": params, "opt": adamw.init(params),
         "step": jnp.zeros((), jnp.int32)}
opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10, clip_norm=0.0)

# single-device reference
ref_state, ref_metrics = make_train_step(model, opt)(state, batch)

# sharded WUS step
specs = state_pspecs(model, mesh)
sh = shardings_from_specs(mesh, specs)
wsh = shardings_from_specs(mesh, work_pspecs(model, mesh))
msh = sh["params"]
state_sharded = jax.device_put(state, sh)
with use_sharding(mesh):
    step = jax.jit(make_train_step(model, opt, work_shardings=wsh,
                                   master_shardings=msh),
                   in_shardings=(sh, None), out_shardings=(sh, None))
    new_state, metrics = step(state_sharded, batch)
print("LOSS", float(ref_metrics["loss"]), float(metrics["loss"]))
# WUS runs bf16 forward; compare at bf16-appropriate tolerance
assert abs(float(ref_metrics["loss"]) - float(metrics["loss"])) < 0.05
a = np.asarray(jax.device_get(jax.tree.leaves(new_state["params"])[0]))
b = np.asarray(jax.device_get(jax.tree.leaves(ref_state["params"])[0]))
np.testing.assert_allclose(a, b, atol=5e-3)
print("SHARDED_STEP_OK")
""")
    assert "SHARDED_STEP_OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    out = run_subprocess(PREAMBLE + f"""
from repro.train import CheckpointManager
params = model.init(jax.random.key(1))
state = {{"params": params, "opt": adamw.init(params),
         "step": jnp.asarray(3, jnp.int32)}}
specs = state_pspecs(model, mesh)
sh = shardings_from_specs(mesh, specs)
state_sharded = jax.device_put(state, sh)
mgr = CheckpointManager({str(tmp_path)!r}, keep=2)
mgr.save(3, state_sharded)

# restore onto a DIFFERENT mesh shape (4, 2)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
specs2 = state_pspecs(model, mesh2)
sh2 = shardings_from_specs(mesh2, specs2)
abstract = jax.eval_shape(lambda: state)
step, restored = mgr.restore_latest(abstract, sh2)
assert step == 3
for (pa, a), (pb, b) in zip(
    jax.tree_util.tree_flatten_with_path(state)[0],
    jax.tree_util.tree_flatten_with_path(restored)[0]):
    np.testing.assert_array_equal(np.asarray(jax.device_get(a)),
                                  np.asarray(jax.device_get(b)))
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


@pytest.mark.slow
def test_sharded_loss_equals_unsharded_loss():
    """Pure sharding change must not change the math (exact same fwd graph)."""
    out = run_subprocess(PREAMBLE + """
batch = make_batch(cfg, B=8, S=16)
params = model.init(jax.random.key(2))
l_ref = float(model.loss(params, batch))
pspecs = param_pspecs(model.abstract_params(), mesh)
psh = shardings_from_specs(mesh, pspecs)
params_sharded = jax.device_put(params, psh)
with use_sharding(mesh):
    l_sh = float(jax.jit(model.loss)(params_sharded, batch))
print("LOSSES", l_ref, l_sh)
assert abs(l_ref - l_sh) < 1e-3  # sharded reductions reorder float sums
print("LOSS_MATCH_OK")
""")
    assert "LOSS_MATCH_OK" in out


@pytest.mark.slow
def test_decode_sharded_matches_unsharded():
    out = run_subprocess(PREAMBLE + """
from repro.parallel.cache_specs import cache_pspecs
params = model.init(jax.random.key(3))
batch = make_batch(cfg, B=8, S=8)
cache = model.init_cache(8, 32)
logits_ref, cache_ref = model.prefill(params, batch, cache)
tok = jnp.argmax(logits_ref, -1)[:, None].astype(jnp.int32)
step_ref, _ = model.decode_step(params, tok, jnp.asarray(8, jnp.int32), cache_ref)

pspecs = param_pspecs(model.abstract_params(), mesh)
psh = shardings_from_specs(mesh, pspecs)
csh = shardings_from_specs(mesh, cache_pspecs(
    jax.eval_shape(lambda: cache), mesh))
params_s = jax.device_put(params, psh)
cache_s = jax.device_put(cache, csh)
with use_sharding(mesh):
    logits_s, cache_s = jax.jit(model.prefill)(params_s, batch, cache_s)
    step_s, _ = jax.jit(model.decode_step)(params_s, tok,
                                           jnp.asarray(8, jnp.int32), cache_s)
np.testing.assert_allclose(np.asarray(step_ref), np.asarray(jax.device_get(step_s)),
                           atol=2e-2, rtol=2e-2)
print("DECODE_SHARDED_OK")
""")
    assert "DECODE_SHARDED_OK" in out


# ---------------- spec-builder unit tests (no devices needed) -------------------


def test_param_specs_divisibility_all_archs():
    """Every spec must divide its dim — for every assigned arch, on both meshes."""
    from jax.sharding import PartitionSpec
    from repro.models import build_model
    from repro.parallel.params import param_pspecs, zero1_pspecs

    mesh_axes = {"data": 16, "model": 16}

    class FakeMesh:
        axis_names = tuple(mesh_axes)
        devices = np.empty((16, 16))

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        abstract = model.abstract_params()
        for specs in (param_pspecs(abstract, FakeMesh()),
                      zero1_pspecs(abstract, FakeMesh())):
            flat_p = jax.tree_util.tree_flatten_with_path(abstract)[0]
            flat_s = jax.tree_util.tree_flatten(
                specs, is_leaf=lambda x: isinstance(x, PartitionSpec))[0]
            assert len(flat_p) == len(flat_s)
            for (path, leaf), spec in zip(flat_p, flat_s):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    size = int(np.prod([mesh_axes[a] for a in
                                        (ax if isinstance(ax, tuple) else (ax,))]))
                    assert dim % size == 0, (arch, path, leaf.shape, spec)


def test_cache_specs_divisibility_all_archs():
    from jax.sharding import PartitionSpec
    from repro.models import build_model
    from repro.parallel.cache_specs import cache_pspecs

    mesh_axes = {"data": 16, "model": 16}

    class FakeMesh:
        axis_names = tuple(mesh_axes)
        devices = np.empty((16, 16))

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        cache = model.abstract_cache(128, 1024)
        specs = cache_pspecs(cache, FakeMesh())
        flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
        flat_s = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec))[0]
        for (path, leaf), spec in zip(flat_c, flat_s):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                size = int(np.prod([mesh_axes[a] for a in
                                    (ax if isinstance(ax, tuple) else (ax,))]))
                assert dim % size == 0, (arch, path, leaf.shape, spec)
