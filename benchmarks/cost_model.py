"""Analytic per-cell cost model: FLOPs, HBM bytes, collective bytes per device.

WHY ANALYTIC: XLA's ``cost_analysis()`` counts a ``while`` body ONCE, not
times trip-count (verified: a 10-step scanned matmul reports exactly 1/10th the
unrolled FLOPs).  Every model here scans its layer stack and its attention/SSM
chunk loops, so compiled-HLO counts are per-iteration, not per-step.  The
roofline therefore uses THIS itemized model (the standard TPU-perf-model
approach); the HLO numbers stay in dryrun.json as per-iteration cross-checks and
``memory_analysis()`` (which is loop-aware) remains the authoritative fits-check.

Conventions and assumptions (stated once, applied uniformly):
  * matmul flops = 2*m*n*k; backward = 2x forward; per-layer remat adds 1x
    forward recompute => train = 4x forward matmul flops (vs the classic 6*N*D
    = 3x forward; the 4/3 shows up honestly in the useful-FLOPs ratio).
  * attention context: causal global layers average (S-1)/2 keys; local layers
    min(W, (S-1)/2) (+ ring-buffer decode reads min(pos, W) keys).
  * flash-style attention on TPU streams KV from HBM once per layer traversal
    and never spills scores (q-chunked online softmax) — bytes reflect that.
  * params are stored f32 and cast per traversal (3 reads in train: fwd, remat,
    bwd); AdamW state f32 (m, v read+write); grads f32 write+read.
  * padding/capacity waste (attention head padding h_eff/h_log, MoE capacity
    factor, vocab padding) multiplies the relevant flops terms — this is what
    makes the useful-FLOPs ratio informative.
  * collectives (per device):
      - fwd/bwd activation psums: row-parallel output projections (attention out,
        MLP down, MoE combine) all-reduce (B,S,d) bf16 per layer per traversal;
      - gradient all-reduce: ring over the data(xpod) axis of the model-sharded
        grad shard: ~2 * 4B * N / model_shards;
      - MoE dispatch: all-to-all of dispatch+combine slot buffers;
      - decode: per-layer psums only (cache is head-sharded, no comms).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ArchConfig, ShapeSpec

BF16 = 2
F32 = 4


@dataclass
class Costs:
    flops_dev: float
    hbm_bytes_dev: float
    coll_bytes_dev: float
    ideal_flops_dev: float  # useful-work floor (6/2 * N_active * tokens)
    ideal_bytes_dev: float  # decode floor: params + cache read once
    notes: str = ""

    def as_dict(self):
        return {
            "flops_dev": self.flops_dev,
            "hbm_bytes_dev": self.hbm_bytes_dev,
            "coll_bytes_dev": self.coll_bytes_dev,
            "ideal_flops_dev": self.ideal_flops_dev,
            "ideal_bytes_dev": self.ideal_bytes_dev,
        }


def _avg_ctx(S: int, window: int) -> float:
    half = (S - 1) / 2
    return min(window, half) if window > 0 else half


def _attn_flops_fwd(cfg: ArchConfig, tok: float, S: int) -> float:
    """Projections + scores/AV for the whole stack, padding waste included."""
    g = cfg.attn_geom
    d, Dh = cfg.d_model, cfg.head_dim
    period = max(1, cfg.attn.global_every)
    n_glob = cfg.n_layers // period
    n_loc = cfg.n_layers - n_glob
    proj = 2 * tok * d * (g.h_eff * Dh) + 2 * 2 * tok * d * (g.g_log * Dh) \
        + 2 * tok * (g.h_eff * Dh) * d
    heads_eff = g.g_eff * g.q_per_group
    sc_glob = 4 * tok * _avg_ctx(S, 0) * heads_eff * Dh
    sc_loc = 4 * tok * _avg_ctx(S, 1024) * heads_eff * Dh
    return cfg.n_layers * proj + n_glob * sc_glob + n_loc * sc_loc


def _ffn_flops_fwd(cfg: ArchConfig, tok: float) -> float:
    d = cfg.d_model
    if cfg.family == "moe":
        slots = tok * cfg.moe.top_k * cfg.moe.capacity_factor
        routed = 2 * slots * d * cfg.d_ff * 3
        shared = 2 * tok * d * (cfg.moe.n_shared * cfg.d_ff) * 3
        router = 2 * tok * d * cfg.moe.n_experts
        return cfg.n_layers * (routed + shared + router)
    mats = 3 if cfg.mlp_kind == "glu" else 2
    return cfg.n_layers * 2 * tok * d * cfg.d_ff * mats


def _ssm_flops_fwd(cfg: ArchConfig, tok: float) -> float:
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    H, P, N, L = inner // s.head_dim, s.head_dim, s.state_dim, s.chunk
    proj = 2 * tok * d * (2 * inner + 2 * N + H) + 2 * tok * inner * d
    conv = 2 * tok * (inner + 2 * N) * s.conv_width
    core = tok * H * (2 * L * (N + P) + 6 * N * P)
    return proj + conv + core


def _xlstm_flops_fwd(cfg: ArchConfig, tok: float) -> float:
    d = cfg.d_model
    H = cfg.n_heads
    D = d // H
    L = 128
    n_m = n_s = cfg.n_layers // 2
    m_proj = 2 * tok * d * d * 5 + 2 * tok * d * 2 * H
    m_core = tok * H * (4 * L * D + 8 * D * D)
    s_mats = 2 * tok * d * d * 8
    return n_m * (m_proj + m_core) + n_s * s_mats


def _unembed_flops_fwd(cfg: ArchConfig, tok: float) -> float:
    return 2 * tok * cfg.d_model * cfg.vocab_pad


def forward_flops(cfg: ArchConfig, tok: float, S: int) -> float:
    if cfg.family == "xlstm":
        core = _xlstm_flops_fwd(cfg, tok)
    elif cfg.family == "hybrid":
        # n_layers Mamba2 blocks + one shared attn+GLU block applied every k layers
        n_shared = (cfg.n_layers // cfg.shared_attn_every
                    if cfg.shared_attn_every else 0)
        core = cfg.n_layers * _ssm_per_layer(cfg, tok)
        one_attn_layer = cfg.replace(n_layers=1)
        core += n_shared * (_attn_flops_fwd(one_attn_layer, tok, S)
                            + 2 * tok * cfg.d_model * cfg.d_ff * 3)
    elif cfg.family == "encdec":
        enc_tok = tok / S * cfg.enc_len
        enc_cfg = cfg.replace(n_layers=cfg.n_enc_layers)
        core = (_attn_flops_fwd(enc_cfg, enc_tok, cfg.enc_len)
                + _ffn_flops_fwd(enc_cfg, enc_tok))
        dec_self = _attn_flops_fwd(cfg, tok, S)
        # cross attention: q over enc_len keys + kv proj of memory per layer
        g = cfg.attn_geom
        dec_cross = cfg.n_layers * (
            4 * tok * cfg.enc_len * g.g_eff * g.q_per_group * cfg.head_dim
            + 2 * tok * cfg.d_model * (g.h_eff * cfg.head_dim)
            + 2 * 2 * enc_tok * cfg.d_model * (g.g_log * cfg.head_dim))
        core += dec_self + dec_cross + _ffn_flops_fwd(cfg, tok)
    else:  # dense / moe / vlm
        core = _attn_flops_fwd(cfg, tok, S) + _ffn_flops_fwd(cfg, tok)
    return core + _unembed_flops_fwd(cfg, tok)


def _ssm_per_layer(cfg: ArchConfig, tok: float) -> float:
    return _ssm_flops_fwd(cfg, tok)


def decode_attn_read_bytes(cfg: ArchConfig, B_dev: float, pos: int) -> float:
    """KV-cache bytes read for ONE decode step (ring windows cap local layers)."""
    g = cfg.attn_geom
    Dh = cfg.head_dim
    period = max(1, cfg.attn.global_every)
    n_glob = cfg.n_layers // period
    n_loc = cfg.n_layers - n_glob
    glob = n_glob * min(pos, pos) * g.g_eff * Dh * 2 * BF16
    loc = n_loc * min(pos, 1024) * g.g_eff * Dh * 2 * BF16
    if cfg.family == "hybrid":
        n_shared = (cfg.n_layers // cfg.shared_attn_every
                    if cfg.shared_attn_every else 0)
        glob = n_shared * pos * g.g_eff * Dh * 2 * BF16
        loc = 0
        # ssm state read/write
        s = cfg.ssm
        inner = s.expand * cfg.d_model
        glob += cfg.n_layers * (inner // s.head_dim) * s.head_dim * s.state_dim \
            * F32 * 2
    if cfg.family == "xlstm":
        d = cfg.d_model
        D = d // cfg.n_heads
        glob = (cfg.n_layers // 2) * cfg.n_heads * D * D * F32 * 2
        loc = 0
    if cfg.family == "encdec":
        glob = cfg.n_layers * pos * g.g_eff * Dh * 2 * BF16
        glob += cfg.n_layers * cfg.enc_len * g.g_eff * Dh * 2 * BF16  # cross kv
        loc = 0
    return B_dev * (glob + loc)  # per device (batch-sharded)


def cell_costs(cfg: ArchConfig, shape: ShapeSpec, n_chips: int = 256,
               data_shards: int = 16, model_shards: int = 16,
               pods: int = 1, variant: str = "base") -> Costs:
    """Collective accounting is TRANSIT bytes per chip on the bottleneck link:
    all-reduce of result V => 2V;  all-gather receiving V / reduce-scatter of V
    => V;  all-to-all sending V => V.

    Variants:
      base   -- TP=16 (+FSDP second-dim sharding of >32MB/dev leaves, which adds
                the weight all-gather term), ZeRO-1 moments.
      fsdp   -- ZeRO-3 over the flat mesh: no TP psums, params gathered per use
                (3 traversals), grads reduce-scattered; batch over all chips.
      cf10   -- MoE capacity factor 1.0 (vs 1.25).
      accumN -- N gradient-accumulation microbatches (activation memory / N; no
                change to per-step flops; collective bytes unchanged).
    """
    if variant in ("cf10", "limit4") and cfg.family == "moe":
        import dataclasses

        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    N = cfg.param_count()
    N_act = cfg.active_param_count()
    dsize = data_shards * pods
    full = n_chips
    fsdp = variant in ("fsdp", "ddp")
    B_dev = (B / full if fsdp and B % full == 0 else
             B / dsize if B % dsize == 0 else B)
    n_layers_eff = cfg.n_layers + (cfg.n_enc_layers or 0)
    # 'base' leaves bigger than 32MB/dev after TP get a second data-axis shard
    big_model = N * F32 / model_shards > 8e9

    if shape.kind == "train":
        tok = float(B * S)
        fwd = forward_flops(cfg, tok, S)
        flops_global = 4.0 * fwd  # fwd + remat + 2x bwd
        flops_dev = flops_global / n_chips
        ideal_flops_dev = 6.0 * N_act * tok / n_chips

        N_dev = N / (full if (fsdp and variant != "ddp") or big_model
                     else (1 if variant == "ddp" else model_shards))
        param_traffic = 40.0 * (N / full if variant == "ddp" else N_dev)
        if variant == "ddp":
            param_traffic += 3 * N * BF16  # replicated reads (bf16 cast)
        if big_model and not fsdp:
            param_traffic += 3 * N / model_shards * BF16  # gathered copies
        if fsdp and variant != "ddp":
            param_traffic += 3 * N * BF16  # gathered copies traverse HBM
        act_per_layer = B_dev * S * d * BF16
        act_traffic = n_layers_eff * act_per_layer * 10
        if cfg.family == "moe":
            slots = B_dev * S * cfg.moe.top_k * cfg.moe.capacity_factor
            act_traffic += cfg.n_layers * slots * d * BF16 * 6
        logits = B_dev * S * cfg.vocab_pad / (1 if fsdp else model_shards) * F32 * 2
        hbm = param_traffic + act_traffic + logits

        if variant == "ddp":
            # replicated params: one bf16 grad all-reduce (2V transit)
            coll = 2 * N * BF16
        elif fsdp:
            # 3x param all-gather (fwd, remat, bwd) + grad reduce-scatter
            coll = 3 * N * BF16 + N * F32
        else:
            psum = n_layers_eff * 2 * 3 * (B_dev * S * d * BF16) * 2  # AR = 2V
            grad_ar = 2.0 * F32 * N / model_shards
            weight_ag = 3 * N * BF16 / model_shards if big_model else 0.0
            coll = psum + grad_ar + weight_ag
            if cfg.family == "moe":
                a2a_v = (B_dev * S * cfg.moe.top_k * cfg.moe.capacity_factor
                         * d * BF16)
                if variant == "limit4":
                    # device-limited routing (<=4 destination shards) with
                    # dedup transport: one embedding per (token, destination)
                    a2a_v = B_dev * S * 4 * d * BF16
                coll += cfg.n_layers * 2 * 3 * a2a_v  # dispatch+combine x3 trav
        if pods > 1:
            coll *= 1.0 + 1.0 / 8  # hierarchical cross-pod reduction surcharge
        return Costs(flops_dev, hbm, coll, ideal_flops_dev, ideal_bytes_dev=0.0)

    if shape.kind == "prefill":
        tok = float(B * S)
        fwd = forward_flops(cfg, tok, S)
        flops_dev = fwd / n_chips
        ideal_flops_dev = 2.0 * N_act * tok / n_chips
        N_dev = N / (full if (fsdp or big_model) else model_shards)
        act = n_layers_eff * B_dev * S * d * BF16 * 4
        kv_write = decode_attn_read_bytes(cfg, B_dev, S)
        hbm = N_dev * F32 + act + kv_write
        if big_model and not fsdp:
            hbm += N * BF16 / model_shards
        coll = n_layers_eff * 2 * (B_dev * S * d * BF16) * 2
        if big_model and not fsdp:
            coll += N * BF16 / model_shards
        if cfg.family == "moe":
            coll += cfg.n_layers * 2 * (B_dev * S * cfg.moe.top_k
                                        * cfg.moe.capacity_factor) * d * BF16
        return Costs(flops_dev, hbm, coll, ideal_flops_dev, 0.0)

    # decode: one token against a cache of length S
    tok = float(B)
    fwd = forward_flops(cfg, tok, 1)
    attn_read = decode_attn_read_bytes(cfg, B_dev, S)
    flops_attn = attn_read / BF16 * 2
    flops_dev = (fwd / n_chips) + flops_attn
    N_dev = N / model_shards
    hbm = N_dev * F32 + attn_read + B_dev * 1 * d * BF16 * n_layers_eff * 4
    coll = n_layers_eff * 2 * (B_dev * 1 * d * BF16) * 2
    if cfg.family == "moe":
        coll += cfg.n_layers * 2 * (B_dev * cfg.moe.top_k
                                    * cfg.moe.capacity_factor) * d * BF16
    ideal_flops_dev = 2.0 * N_act * tok / n_chips
    ideal_bytes_dev = N_dev * BF16 + attn_read
    return Costs(flops_dev, hbm, coll, ideal_flops_dev, ideal_bytes_dev)
