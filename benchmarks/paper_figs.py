"""Paper-table benchmarks: Fig. 3 (Reference), Figs. 4-5 (worked examples),
Fig. 6 (omega sweep), Table 2 (t-tests), Table 3 (synthesis/resource model).

Each function returns a list of CSV rows (name, value, derived) and prints a
human-readable block.  ``--full`` uses the paper's population sizes (slower).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import (
    SecondDerivMax,
    binary_split,
    bram_count,
    build_table,
    get_function,
    hierarchical_split,
    outperforms,
    reference_spacing,
    sequential_split,
    vmem_cost,
)
from repro.configs.tabla_paper import (
    E_A_FIG3,
    E_A_TABLE2,
    E_A_WORKED,
    OMEGA_SWEEP,
    TABLE2_CELLS,
    TABLE3_CELLS,
)

Rows = List[tuple]


def fig3_reference() -> Rows:
    """Fig. 3: Reference approach on log(x) over [0.625, 15.625), Ea=1.25e-4."""
    fn = get_function("log")
    lo, hi = 0.625, 15.625
    r = reference_spacing(fn, E_A_FIG3, lo, hi)
    ts = build_table("log", E_A_FIG3, lo, hi, algorithm="reference")
    err = ts.max_error_on_grid()
    print(f"[fig3] delta={r.delta:.6f} (paper ~0.019)  M_F={r.footprint} "
          f"(paper 770)  measured_max_err={err:.3e} <= Ea={E_A_FIG3:g}")
    return [("fig3.delta", r.delta, "paper~0.019"),
            ("fig3.M_F", r.footprint, "paper=770"),
            ("fig3.max_err", err, f"Ea={E_A_FIG3:g}")]


def fig45_worked_examples() -> Rows:
    """Sec. 5.1-5.3 worked examples on log(x), Ea=1.22e-4, omega=0.3."""
    lo, hi = 0.625, 15.625
    ref = reference_spacing(get_function("log"), E_A_WORKED, lo, hi).footprint
    rows: Rows = [("fig45.reference.M_F", ref, "paper=770")]
    runs = [
        ("binary", binary_split("log", E_A_WORKED, lo, hi, 0.3), 182),
        ("hierarchical",
         hierarchical_split("log", E_A_WORKED, lo, hi, 0.3, epsilon=0.015), 161),
        ("sequential",
         sequential_split("log", E_A_WORKED, lo, hi, 0.3, epsilon=0.3), 146),
    ]
    for name, sr, paper_mf in runs:
        red = 100.0 * (ref - sr.footprint) / ref
        ts = build_table("log", E_A_WORKED, lo, hi, algorithm=name, omega=0.3,
                         split_result=sr)
        err = ts.max_error_on_grid()
        print(f"[fig4/5] {name:13s} M_F={sr.footprint:4d} (paper {paper_mf})  "
              f"reduction={red:.1f}%  n={sr.n_intervals}  err={err:.3e}")
        rows += [(f"fig45.{name}.M_F", sr.footprint, f"paper={paper_mf}"),
                 (f"fig45.{name}.reduction_pct", round(red, 1), ""),
                 (f"fig45.{name}.max_err", err, f"Ea={E_A_WORKED:g}")]
    return rows


def _random_subintervals(lo, hi, n, rng):
    """Population X: random sub-intervals of [lo, hi) (paper Sec. 5.4)."""
    out = []
    for _ in range(n):
        a, b = np.sort(rng.uniform(lo, hi, 2))
        if b - a < 0.05 * (hi - lo):
            b = min(hi, a + 0.05 * (hi - lo))
            a = max(lo, b - 0.05 * (hi - lo))
        out.append((float(a), float(b)))
    return out


def fig6_omega_sweep(n_intervals: int = 15, omegas=None, eps_frac: float = 1 / 200,
                     seed: int = 0) -> tuple[Rows, Dict]:
    """Fig. 6: mean DeltaM_F over random sub-intervals vs omega, per algorithm."""
    omegas = omegas or OMEGA_SWEEP[1::2]
    rng = np.random.default_rng(seed)
    rows: Rows = []
    samples: Dict[str, Dict[str, list]] = {}  # fn -> alg -> [mean red per omega]
    for fname, (lo, hi) in TABLE2_CELLS.items():
        fn = get_function(fname)
        oracle = SecondDerivMax(fn, lo, hi)
        pop = _random_subintervals(lo, hi, n_intervals, rng)
        per_alg = {"binary": [], "hierarchical": [], "sequential": []}
        for omega in omegas:
            reds = {a: [] for a in per_alg}
            for (a, b) in pop:
                ref = reference_spacing(oracle, E_A_TABLE2, a, b).footprint
                eps = (b - a) * eps_frac
                rs = {
                    "binary": binary_split(fn, E_A_TABLE2, a, b, omega,
                                           oracle=oracle),
                    "hierarchical": hierarchical_split(
                        fn, E_A_TABLE2, a, b, omega, epsilon=eps, oracle=oracle),
                    "sequential": sequential_split(
                        fn, E_A_TABLE2, a, b, omega, epsilon=eps * 4,
                        oracle=oracle),
                }
                for alg, sr in rs.items():
                    reds[alg].append(100.0 * (ref - sr.footprint) / max(ref, 1))
            for alg in per_alg:
                per_alg[alg].append(float(np.mean(reds[alg])))
        samples[fname] = per_alg
        for alg in per_alg:
            m = float(np.max(per_alg[alg]))
            rows.append((f"fig6.{fname}.{alg}.max_mean_reduction_pct",
                         round(m, 1), f"omegas={len(omegas)}"))
        print(f"[fig6] {fname:8s} max mean reduction: "
              + "  ".join(f"{a}={np.max(v):.1f}%" for a, v in per_alg.items()))
    return rows, samples


def table2_ttests(samples: Dict) -> Rows:
    """Table 2: pairwise right/left-tailed two-sample t-tests per function.
    Groups G1/G2/G3 = binary/hierarchical/sequential mean reductions over omega."""
    rows: Rows = []
    pairs = [("binary", "hierarchical"), ("binary", "sequential"),
             ("hierarchical", "sequential")]
    print("[table2] pair-wise t-tests (right_h, left_h); (0,1) => G2 wins")
    for fname, per_alg in samples.items():
        for g1, g2 in pairs:
            r, l = outperforms(per_alg[g1], per_alg[g2])
            rows.append((f"table2.{fname}.{g1}_vs_{g2}", f"{r}{l}",
                         "01=G2 outperforms"))
            print(f"   {fname:8s} ({g1[:4]},{g2[:4]}): right={r} left={l}")
    return rows


def table3_fidelity() -> Rows:
    """Table 3 fixed-point path: quantize inputs per (S,W,F) in-format and stored
    range values per out-format, then verify end-to-end error stays within
    Ea + input-quant*max|f'| + output-quant (the hardware error budget)."""
    import numpy as np

    from repro.core import PAPER_FORMATS, build_table

    rows: Rows = []
    ea = E_A_TABLE2
    for fname, (lo, hi) in TABLE3_CELLS.items():
        if fname not in PAPER_FORMATS:
            continue
        in_fmt, out_fmt = PAPER_FORMATS[fname]
        fn = get_function(fname)
        ts = build_table(fname, ea, lo, hi, algorithm="hierarchical", omega=0.1)
        # quantize the stored table like the BRAM would hold it
        ts_q = ts.__class__(**{**ts.__dict__, "values": out_fmt.quantize(ts.values)})
        xs = np.linspace(lo, hi - 1e-9, 20001)
        xq = in_fmt.quantize(xs)
        y = out_fmt.quantize(ts_q.eval(xq))
        exact = np.asarray(fn.f(xs))
        err = float(np.max(np.abs(y - exact)))
        d1 = float(np.max(np.abs(np.asarray(fn.d1f(xs)))))
        budget = ea + in_fmt.resolution * d1 + 2 * out_fmt.resolution
        ok = err <= budget * 1.01
        rows.append((f"table3_fixedpoint.{fname}.max_err", f"{err:.3e}",
                     f"budget={budget:.3e};ok={ok}"))
        print(f"[table3-fp] {fname:8s} err={err:.3e} <= budget={budget:.3e} "
              f"({'OK' if ok else 'VIOLATION'})")
        assert ok, (fname, err, budget)
    return rows


def table3_packing() -> Rows:
    """Beyond-paper (the paper's stated future work, Sec. 8): mixed-width
    quantized table packing.  Reports bits/entry and total bit reduction vs the
    32-bit Reference at the paper's Ea and at the framework's activation Ea."""
    from repro.core import reference_spacing
    from repro.core.packing import quantize_table

    rows: Rows = []
    cells = [("log", (0.625, 15.625)), ("tanh", (-8.0, 8.0)),
             ("gelu", (-8.0, 8.0)), ("silu", (-10.0, 10.0))]
    for ea, tag in [(E_A_TABLE2, "paperEa"), (1e-4, "mlEa")]:
        for name, (lo, hi) in cells:
            qt = quantize_table(name, ea, lo, hi, omega=0.1)
            err = qt.max_error_on_grid(n=50_001)
            assert err <= ea * 1.001, (name, ea, err)
            ref = reference_spacing(get_function(name), ea, lo, hi)
            bpe = qt.footprint_bits / qt.base.footprint
            total = 100.0 * (1 - qt.footprint_bits / (32.0 * ref.footprint))
            rows.append((f"packing.{tag}.{name}.bits_per_entry", round(bpe, 1),
                         f"total_red_vs_ref32={total:.1f}%"))
            print(f"[packing] {tag:7s} {name:6s} bits/entry={bpe:4.1f} "
                  f"(-{(1 - bpe / 32) * 100:.0f}% vs 32b) "
                  f"total={total:.1f}% vs 32b reference; err={err:.2e}<=Ea")
    return rows


def table3_synthesis() -> Rows:
    """Table 3: memory footprint + BRAM reductions at increasing interval counts,
    plus the TPU-side VMEM packing report (our resource model)."""
    rows: Rows = []
    for fname, (lo, hi) in TABLE3_CELLS.items():
        fn = get_function(fname)
        oracle = SecondDerivMax(fn, lo, hi)
        ref = reference_spacing(oracle, E_A_TABLE2, lo, hi).footprint
        ref_brams = bram_count(ref)
        print(f"[table3] {fname:8s} reference M_F={ref} BRAM={ref_brams}")
        rows.append((f"table3.{fname}.ref.M_F", ref, f"BRAM={ref_brams}"))
        for omega in (0.5, 0.3, 0.1, 0.02):
            sr = hierarchical_split(fn, E_A_TABLE2, lo, hi, omega,
                                    epsilon=(hi - lo) / 500, oracle=oracle)
            mf = sr.footprint
            dm = 100.0 * (ref - mf) / ref
            db = 100.0 * (ref_brams - bram_count(mf)) / ref_brams
            vm = vmem_cost(mf, sr.n_intervals)
            rows.append((f"table3.{fname}.omega{omega}.M_F", mf,
                         f"n={sr.n_intervals};dMF={dm:.0f}%;dBRAM={db:.0f}%;"
                         f"vmem={vm.padded_bytes}B"))
            print(f"    omega={omega:4.2f} n={sr.n_intervals:3d} M_F={mf:6d} "
                  f"dMF={dm:5.1f}% dBRAM={db:5.1f}% "
                  f"VMEM={vm.padded_bytes / 1024:.1f}KiB "
                  f"({vm.fraction * 100:.3f}%)")
    return rows
