"""Benchmark harness: one function per paper table/figure + kernel microbenches +
(if dry-run results exist) the roofline summary.

Prints ``name,value,derived`` CSV rows at the end.

  PYTHONPATH=src python -m benchmarks.run            # default (fast) populations
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale populations
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale populations (slow)")
    ap.add_argument("--skip-fig6", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a ScopeKit Chrome-trace JSON of the bench run "
                         "(design-phase + serve spans; open in Perfetto)")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import kernel_bench, paper_figs

    if args.trace:
        from repro import obs

        obs.configure(enabled=True, trace_path=args.trace)
        obs.reset_tracer()

    t0 = time.time()
    rows = []
    rows += paper_figs.fig3_reference()
    rows += paper_figs.fig45_worked_examples()
    if not args.skip_fig6:
        n = 100 if args.full else 12
        omegas = None if args.full else [0.01, 0.03, 0.05, 0.08, 0.12, 0.16,
                                         0.2, 0.24, 0.27, 0.3]
        fig6_rows, samples = paper_figs.fig6_omega_sweep(
            n_intervals=n, omegas=omegas,
            eps_frac=(1 / 1000 if args.full else 1 / 150))
        rows += fig6_rows
        rows += paper_figs.table2_ttests(samples)
    rows += paper_figs.table3_synthesis()
    rows += paper_figs.table3_fidelity()
    rows += paper_figs.table3_packing()
    rows += kernel_bench.activation_bench(1 << 20 if args.full else 1 << 18)
    rows += kernel_bench.interval_count_flatness()
    rows += kernel_bench.pack_dispatch_bench(1 << 20 if args.full else 1 << 18)
    rows += kernel_bench.quantpack_bench(1 << 20 if args.full else 1 << 18)
    rows += kernel_bench.routed_dispatch_bench(1 << 20)
    rows += kernel_bench.shardedpack_bench(1 << 20 if args.full else 1 << 18)
    rows += kernel_bench.polypack_bench(1 << 20 if args.full else 1 << 18)
    rows += kernel_bench.tableflash_bench()
    rows += kernel_bench.serve_bench(
        n_requests=16 if args.full else 8,
        modes=("exact", "table_pack") if args.full else ("exact",))

    # roofline summary if the dry-run has produced results
    try:
        from benchmarks import roofline

        rrows = roofline.report()
        for r in rrows:
            rows.append((f"roofline.{r['arch']}.{r['shape']}.fraction",
                         round(r["roofline_fraction"], 3), r["dominant"]))
        if rrows:
            with open(roofline.OUT_MD, "w") as f:
                f.write(roofline.to_markdown(rrows))
            print(f"[roofline] {len(rrows)} cells summarised -> {roofline.OUT_MD}")
    except FileNotFoundError:
        print("[roofline] no dry-run results yet (run repro.launch.dryrun)")

    if args.trace:
        from repro import obs

        obs.get_tracer().save(
            args.trace, metadata={"metrics": obs.get_registry().summary()})
        print(f"[trace] written to {args.trace}")

    print(f"\n# total bench time: {time.time() - t0:.1f}s")
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
