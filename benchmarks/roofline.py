"""Roofline analysis (EXPERIMENTS.md §Roofline) — analytic cost model joined with
the dry-run's compiled artifacts.

Terms per (arch x shape x mesh) cell, in SECONDS of one step on one v5e chip:

    compute    = FLOPs_per_device / 197e12      (bf16 peak)
    memory     = HBM_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9   (per-link ICI)

FLOPs/bytes come from ``benchmarks.cost_model`` (itemized analytic model) because
XLA's cost_analysis counts scan bodies exactly once (verified; see cost_model
docstring) — the compiled-HLO numbers are kept in dryrun.json as per-iteration
cross-checks, and ``memory_analysis()`` (loop-aware) remains the fits-check.

Reported per cell:
  * the three terms + dominant bound,
  * MODEL_FLOPS (6*N_active*D train / 2*N_active*D serve) and the useful ratio
    MODEL_FLOPS / analytic FLOPs (remat + padding + capacity waste),
  * roofline fraction = t_ideal / t_bound, where t_ideal is the useful-FLOPs
    time (train/prefill) or the minimal-traffic time (decode: bf16 params +
    cache read once),
  * one-line note on what moves the dominant term.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.models import get_config, shapes_for

from benchmarks.cost_model import cell_costs

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")
OUT_MD = os.path.join(os.path.dirname(__file__), "results", "roofline.md")


def analyze(arch: str, shape_name: str, mesh: str, variant: str = "base") -> Dict:
    cfg = get_config(arch)
    shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
    pods = 2 if mesh == "2x16x16" else 1
    c = cell_costs(cfg, shape, n_chips=256 * pods, data_shards=16,
                   model_shards=16, pods=pods, variant=variant)
    t_compute = c.flops_dev / PEAK_FLOPS
    t_memory = c.hbm_bytes_dev / HBM_BW
    t_coll = c.coll_bytes_dev / LINK_BW
    t_bound = max(t_compute, t_memory, t_coll)
    dominant = ("compute" if t_bound == t_compute
                else "memory" if t_bound == t_memory else "collective")
    if shape.kind == "decode":
        t_ideal = c.ideal_bytes_dev / HBM_BW
    else:
        t_ideal = c.ideal_flops_dev / PEAK_FLOPS
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "useful_ratio": (c.ideal_flops_dev / c.flops_dev
                         if c.flops_dev > 0 else 0.0),
        "roofline_fraction": t_ideal / t_bound if t_bound > 0 else 0.0,
        "model_flops_global": c.ideal_flops_dev * 256 * pods,
        "hbm_gb_per_dev": c.hbm_bytes_dev / 1e9,
        "coll_gb_per_dev": c.coll_bytes_dev / 1e9,
        "kind": shape.kind,
    }


def suggest(a: Dict) -> str:
    if a["dominant"] == "collective":
        return ("collective-bound: overlap grad all-reduce with bwd, bf16-compress "
                "cross-pod, or reshard the psum-heavy projections")
    if a["dominant"] == "memory":
        if a["kind"] == "decode":
            return ("HBM-bound decode: quantize KV/params, raise batch, or "
                    "split cache reads across chips (flash-decoding)")
        if a["useful_ratio"] < 0.5:
            return "HBM-bound, low useful ratio: cut remat traffic / fuse temps"
        return "HBM-bound: bf16 master cast once, fuse elementwise, bigger tiles"
    if a["useful_ratio"] < 0.5:
        return (f"compute-bound, useful={a['useful_ratio']:.2f}: cut remat/"
                "padding/capacity waste")
    return "compute-bound at high useful ratio: near roofline"


def load() -> Dict[str, Dict]:
    with open(RESULTS) as f:
        return json.load(f)


def report(mesh_filter: str = "16x16", variant: str = "base") -> List[Dict]:
    results = load()
    rows = []
    for key, rec in sorted(results.items()):
        arch, shape, mesh, var = key.split("|")
        if rec.get("status") != "ok" or mesh != mesh_filter or var != variant:
            continue
        a = analyze(arch, shape, mesh, var)
        temp_gb = rec["memory"].get("temp_size_in_bytes", 0) / 1e9
        arg_gb = rec["memory"].get("argument_size_in_bytes", 0) / 1e9
        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh, **a,
            "note": suggest(a), "compile_s": rec["compile_s"],
            "temp_gb": temp_gb, "arg_gb": arg_gb,
            "fits_16gb": bool(arg_gb + temp_gb <= 16.0),
            "hlo_flops_per_iter": rec.get("flops_per_device", -1),
        })
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "useful | roofline frac | HBM GB/dev | arg+temp GB | fits 16G | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
                 f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
                 f"{r['dominant']} | {r['useful_ratio']:.2f} | "
                 f"{r['roofline_fraction']:.3f} | {r['hbm_gb_per_dev']:.1f} | "
                 f"{r['arg_gb'] + r['temp_gb']:.1f} | "
                 f"{'Y' if r['fits_16gb'] else 'N'} | {r['note']} |\n")
    return hdr + body


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()
    rows = report(args.mesh, args.variant)
    md = to_markdown(rows)
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write(md)
    print(md)
    print(f"({len(rows)} cells; written to {OUT_MD})")


if __name__ == "__main__":
    main()
