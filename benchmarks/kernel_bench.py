"""Microbenchmarks of the table-approximation runtimes on the host CPU.

CPU wall-times are NOT the TPU performance story (that is the roofline analysis,
benchmarks/roofline.py); these timings validate relative behaviour: the table_ref
path must be within a small factor of the exact transcendental, and costs must be
flat in the number of sub-intervals (the paper's constant-latency claim, Fig. 7,
mapped to SIMD: the comparator plane is O(n) FMAs but n<=32 is noise vs memory
traffic)."""

from __future__ import annotations

import json
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import ApproxConfig
from repro.core import build_table

BENCH_QUANTPACK_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_quantpack.json")


def _time(f, *args, reps=20) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def activation_bench(size: int = 1 << 20) -> List[tuple]:
    rows = []
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, size).astype(np.float32))
    for name in ("gelu", "silu", "tanh"):
        exact = jax.jit(ApproxConfig(mode="exact").unary(name))
        table = jax.jit(ApproxConfig(mode="table_ref", e_a=1e-4,
                                     algorithm="hierarchical", omega=0.2).unary(name))
        te = _time(exact, x)
        tt = _time(table, x)
        rows.append((f"kernel.{name}.exact_us", round(te, 1), f"n={size}"))
        rows.append((f"kernel.{name}.table_ref_us", round(tt, 1),
                     f"ratio={tt / te:.2f}x"))
        print(f"[kernel] {name:6s} exact={te:8.1f}us  table_ref={tt:8.1f}us  "
              f"ratio={tt / te:.2f}x")
    return rows


def interval_count_flatness(size: int = 1 << 18) -> List[tuple]:
    """Constant-latency claim: runtime flat vs #sub-intervals (omega sweep)."""
    rows = []
    x = jnp.asarray(np.random.default_rng(1).normal(0, 3, size).astype(np.float32))
    times = []
    for omega in (0.9, 0.3, 0.1, 0.02):
        cfg = ApproxConfig(mode="table_ref", e_a=1e-5, algorithm="hierarchical",
                           omega=omega)
        jt = cfg.table_for("gelu")
        f = jax.jit(cfg.unary("gelu"))
        t = _time(f, x)
        times.append(t)
        rows.append((f"kernel.flatness.omega{omega}", round(t, 1),
                     f"n_intervals={jt.n_intervals}"))
        print(f"[flatness] omega={omega:4.2f} n={jt.n_intervals:3d} t={t:8.1f}us")
    spread = max(times) / min(times)
    rows.append(("kernel.flatness.spread", round(spread, 2),
                 "CPU serializes the compare chain; flat on the TPU VPU"))
    return rows


def pack_dispatch_bench(size: int = 1 << 18) -> List[tuple]:
    """TablePack vs per-table dispatch: F functions through ONE packed artifact
    and one fused kernel (static fn_id row select) versus F separate tables,
    each with its own VMEM residency and pallas_call.  Also reports the VMEM
    footprint both ways — the BRAM-instantiation win the pack exists for."""
    from repro.approx import pack_specs
    from repro.core import vmem_cost, vmem_cost_pack
    from repro.kernels.ops import table_lookup, table_pack_lookup
    from repro.approx.jax_table import from_spec

    names = ("gelu", "silu", "tanh", "sigmoid_sym", "exp_neg")
    specs = [build_table(n, 1e-4, algorithm="hierarchical", omega=0.2)
             for n in names]
    pack = pack_specs(specs)
    tables = [from_spec(s) for s in specs]
    x = jnp.asarray(np.random.default_rng(2).normal(0, 3, size).astype(np.float32))

    def per_table_all(v):
        return [table_lookup(jt, v) for jt in tables]

    def pack_all(v):
        return [table_pack_lookup(pack, i, v) for i in range(len(names))]

    tp = _time(lambda v: pack_all(v)[-1], x)
    tt = _time(lambda v: per_table_all(v)[-1], x)
    rows = [
        ("kernel.pack.dispatch_us", round(tp, 1),
         f"F={len(names)} fns, one pack, n={size}"),
        ("kernel.pack.per_table_us", round(tt, 1), f"ratio={tt / tp:.2f}x"),
    ]
    vm_pack = vmem_cost_pack([s.footprint for s in specs],
                             [s.n_intervals for s in specs]).padded_bytes
    vm_tabs = sum(vmem_cost(s.footprint, s.n_intervals).padded_bytes
                  for s in specs)
    rows.append(("kernel.pack.vmem_bytes", vm_pack,
                 f"vs {vm_tabs}B across {len(names)} per-table residencies"))
    print(f"[pack] {len(names)} fns: pack={tp:8.1f}us  per-table={tt:8.1f}us  "
          f"({tt / tp:.2f}x)  VMEM {vm_tabs} -> {vm_pack} B")
    return rows


def quantpack_bench(size: int = 1 << 18, e_a: float = 1e-4,
                    out_path: str = BENCH_QUANTPACK_JSON) -> List[tuple]:
    """QuantPack footprint/latency report -> BENCH_quantpack.json.

    Builds the DEFAULT_PACK_FUNCTIONS pack four ways at the same Ea — f32
    entries, forced int16, forced int8, and the budget splitter's auto
    selection — and records for each the entry-storage bytes (the paper's
    M_F footprint axis), the metadata bytes, the total VMEM residency, and
    the fused-kernel dispatch latency on this host.  The acceptance headline
    is ``footprint_reduction_vs_f32``: stored-entry bytes vs the f32 pack at
    equal error budget (the quantized packs keep the end-to-end |f - table|
    <= Ea contract; see docs/quantpack.md for the budget split).
    """
    from repro.approx import DEFAULT_PACK_FUNCTIONS, build_pack, from_quant_layout
    from repro.core import plan_quant_member, quant_pack_layout, vmem_cost_pack
    from repro.core.flow import cached_table
    from repro.kernels.ops import quant_pack_lookup, table_pack_lookup

    names = DEFAULT_PACK_FUNCTIONS
    x = jnp.asarray(np.random.default_rng(3).normal(0, 3, size).astype(np.float32))
    report = {"e_a": e_a, "functions": list(names), "probe_size": size,
              "packs": {}}

    f32_pack = build_pack(names, e_a)
    specs = [cached_table(n, e_a) for n in names]
    c = vmem_cost_pack([s.footprint for s in specs],
                       [s.n_intervals for s in specs])
    t_f32 = _time(lambda v: table_pack_lookup(f32_pack, "silu", v), x)
    report["packs"]["f32"] = {
        "footprint_entries": f32_pack.footprint,
        "footprint_bytes": f32_pack.footprint * 4,
        "meta_bytes": c.meta_bytes,
        "vmem_padded_bytes": c.padded_bytes,
        "dispatch_us": round(t_f32, 1),
    }

    for label, dtype in (("int16", "int16"), ("int8", "int8"),
                         ("auto", "auto")):
        layout = quant_pack_layout(
            [plan_quant_member(n, e_a, dtype=dtype) for n in names])
        qp = from_quant_layout(layout)
        cq = layout.vmem()
        tq = _time(lambda v, q=qp: quant_pack_lookup(q, "silu", v), x)
        report["packs"][label] = {
            "entry_bits": dict(zip(layout.names, layout.entry_bits)),
            "footprint_entries": layout.footprint,
            "footprint_bytes": layout.footprint_bytes,
            "meta_bytes": layout.meta_bytes,
            "vmem_padded_bytes": cq.padded_bytes,
            "dispatch_us": round(tq, 1),
        }

    f32_bytes = report["packs"]["f32"]["footprint_bytes"]
    f32_vmem = report["packs"]["f32"]["vmem_padded_bytes"]
    report["footprint_reduction_vs_f32"] = {
        k: round(f32_bytes / v["footprint_bytes"], 2)
        for k, v in report["packs"].items() if k != "f32"
    }
    # entry storage is the headline (the paper's M_F axis), but refinement buys
    # int8 feasibility with metadata — report the total-residency ratio too so
    # the tradeoff is visible (int16 can win this one at loose Ea)
    report["vmem_reduction_vs_f32"] = {
        k: round(f32_vmem / v["vmem_padded_bytes"], 2)
        for k, v in report["packs"].items() if k != "f32"
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    rows = []
    for k, v in report["packs"].items():
        rows.append((f"kernel.quantpack.{k}.footprint_bytes",
                     v["footprint_bytes"],
                     f"dispatch={v['dispatch_us']}us meta={v['meta_bytes']}B"))
        print(f"[quantpack] {k:5s} footprint={v['footprint_bytes']:6d}B "
              f"meta={v['meta_bytes']:5d}B dispatch={v['dispatch_us']:8.1f}us")
    for k, r in report["footprint_reduction_vs_f32"].items():
        rv = report["vmem_reduction_vs_f32"][k]
        rows.append((f"kernel.quantpack.{k}.reduction_vs_f32", r,
                     f"Ea={e_a:g} vmem_reduction={rv}x"))
        print(f"[quantpack] {k:5s} reduction vs f32: {r:.2f}x entries, "
              f"{rv:.2f}x total VMEM")
    print(f"[quantpack] report -> {out_path}")
    return rows


def main() -> None:
    """CLI for the CI smoke step: ``python -m benchmarks.kernel_bench --quantpack``."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quantpack", action="store_true",
                    help="emit BENCH_quantpack.json (footprint + latency)")
    ap.add_argument("--size", type=int, default=1 << 18,
                    help="probe tensor size (use small values for CI smoke)")
    ap.add_argument("--ea", type=float, default=1e-4)
    ap.add_argument("--out", default=BENCH_QUANTPACK_JSON)
    args = ap.parse_args()
    if args.quantpack:
        rows = quantpack_bench(args.size, args.ea, args.out)
        red = [r for name, r, _ in rows
               if name == "kernel.quantpack.auto.reduction_vs_f32"]
        if red and red[0] < 2.0:
            raise SystemExit(
                f"auto quant pack reduction {red[0]}x < 2x vs f32 at equal Ea")
    else:
        activation_bench(args.size)
        interval_count_flatness()
        pack_dispatch_bench(args.size)


if __name__ == "__main__":
    main()
