"""Microbenchmarks of the table-approximation runtimes on the host CPU.

CPU wall-times are NOT the TPU performance story (that is the roofline analysis,
benchmarks/roofline.py); these timings validate relative behaviour: the table_ref
path must be within a small factor of the exact transcendental, and costs must be
flat in the number of sub-intervals (the paper's constant-latency claim, Fig. 7,
mapped to SIMD: the comparator plane is O(n) FMAs but n<=32 is noise vs memory
traffic)."""

from __future__ import annotations

import json
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import ApproxConfig
from repro.core import build_table

BENCH_QUANTPACK_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_quantpack.json")
BENCH_ROUTEDPACK_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_routedpack.json")
BENCH_SERVE_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json")
BENCH_SHARDEDPACK_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_shardedpack.json")
BENCH_POLYPACK_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_polypack.json")
BENCH_RANGEFOLD_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_rangefold.json")
BENCH_TABLEFLASH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_tableflash.json")


def _time(f, *args, reps=20) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _time_min(f, *args, reps=30) -> float:
    """Best-of-N wall time (us) — robust to CI noisy-neighbor jitter, which
    the mean-of-N above absorbs into ratio guards."""
    jax.block_until_ready(f(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def activation_bench(size: int = 1 << 20) -> List[tuple]:
    rows = []
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, size).astype(np.float32))
    for name in ("gelu", "silu", "tanh"):
        exact = jax.jit(ApproxConfig(mode="exact").unary(name))
        table = jax.jit(ApproxConfig(mode="table_ref", e_a=1e-4,
                                     algorithm="hierarchical", omega=0.2).unary(name))
        te = _time(exact, x)
        tt = _time(table, x)
        rows.append((f"kernel.{name}.exact_us", round(te, 1), f"n={size}"))
        rows.append((f"kernel.{name}.table_ref_us", round(tt, 1),
                     f"ratio={tt / te:.2f}x"))
        print(f"[kernel] {name:6s} exact={te:8.1f}us  table_ref={tt:8.1f}us  "
              f"ratio={tt / te:.2f}x")
    return rows


def interval_count_flatness(size: int = 1 << 18) -> List[tuple]:
    """Constant-latency claim: runtime flat vs #sub-intervals (omega sweep)."""
    rows = []
    x = jnp.asarray(np.random.default_rng(1).normal(0, 3, size).astype(np.float32))
    times = []
    for omega in (0.9, 0.3, 0.1, 0.02):
        cfg = ApproxConfig(mode="table_ref", e_a=1e-5, algorithm="hierarchical",
                           omega=omega)
        jt = cfg.table_for("gelu")
        f = jax.jit(cfg.unary("gelu"))
        t = _time(f, x)
        times.append(t)
        rows.append((f"kernel.flatness.omega{omega}", round(t, 1),
                     f"n_intervals={jt.n_intervals}"))
        print(f"[flatness] omega={omega:4.2f} n={jt.n_intervals:3d} t={t:8.1f}us")
    spread = max(times) / min(times)
    rows.append(("kernel.flatness.spread", round(spread, 2),
                 "CPU serializes the compare chain; flat on the TPU VPU"))
    return rows


def pack_dispatch_bench(size: int = 1 << 18) -> List[tuple]:
    """TablePack vs per-table dispatch: F functions through ONE packed artifact
    and one fused kernel (static fn_id row select) versus F separate tables,
    each with its own VMEM residency and pallas_call.  Also reports the VMEM
    footprint both ways — the BRAM-instantiation win the pack exists for."""
    from repro.approx import pack_specs
    from repro.core import vmem_cost, vmem_cost_pack
    from repro.kernels.ops import table_lookup, table_pack_lookup
    from repro.approx.jax_table import from_spec

    names = ("gelu", "silu", "tanh", "sigmoid_sym", "exp_neg")
    specs = [build_table(n, 1e-4, algorithm="hierarchical", omega=0.2)
             for n in names]
    pack = pack_specs(specs)
    tables = [from_spec(s) for s in specs]
    x = jnp.asarray(np.random.default_rng(2).normal(0, 3, size).astype(np.float32))

    def per_table_all(v):
        return [table_lookup(jt, v) for jt in tables]

    def pack_all(v):
        return [table_pack_lookup(pack, i, v) for i in range(len(names))]

    tp = _time(lambda v: pack_all(v)[-1], x)
    tt = _time(lambda v: per_table_all(v)[-1], x)
    rows = [
        ("kernel.pack.dispatch_us", round(tp, 1),
         f"F={len(names)} fns, one pack, n={size}"),
        ("kernel.pack.per_table_us", round(tt, 1), f"ratio={tt / tp:.2f}x"),
    ]
    vm_pack = vmem_cost_pack([s.footprint for s in specs],
                             [s.n_intervals for s in specs]).padded_bytes
    vm_tabs = sum(vmem_cost(s.footprint, s.n_intervals).padded_bytes
                  for s in specs)
    rows.append(("kernel.pack.vmem_bytes", vm_pack,
                 f"vs {vm_tabs}B across {len(names)} per-table residencies"))
    print(f"[pack] {len(names)} fns: pack={tp:8.1f}us  per-table={tt:8.1f}us  "
          f"({tt / tp:.2f}x)  VMEM {vm_tabs} -> {vm_pack} B")
    return rows


def quantpack_bench(size: int = 1 << 18, e_a: float = 1e-4,
                    out_path: str = BENCH_QUANTPACK_JSON) -> List[tuple]:
    """QuantPack footprint/latency report -> BENCH_quantpack.json.

    Builds the DEFAULT_PACK_FUNCTIONS pack four ways at the same Ea — f32
    entries, forced int16, forced int8, and the budget splitter's auto
    selection — and records for each the entry-storage bytes (the paper's
    M_F footprint axis), the metadata bytes, the total VMEM residency, and
    the fused-kernel dispatch latency on this host.  The acceptance headline
    is ``footprint_reduction_vs_f32``: stored-entry bytes vs the f32 pack at
    equal error budget (the quantized packs keep the end-to-end |f - table|
    <= Ea contract; see docs/quantpack.md for the budget split).
    """
    from repro.approx import DEFAULT_PACK_FUNCTIONS, build_pack, from_quant_layout
    from repro.core import plan_quant_member, quant_pack_layout, vmem_cost_pack
    from repro.core.flow import cached_table
    from repro.kernels.ops import quant_pack_lookup, table_pack_lookup

    names = DEFAULT_PACK_FUNCTIONS
    x = jnp.asarray(np.random.default_rng(3).normal(0, 3, size).astype(np.float32))
    report = {"e_a": e_a, "functions": list(names), "probe_size": size,
              "packs": {}}

    f32_pack = build_pack(names, e_a)
    specs = [cached_table(n, e_a) for n in names]
    c = vmem_cost_pack([s.footprint for s in specs],
                       [s.n_intervals for s in specs])
    t_f32 = _time(lambda v: table_pack_lookup(f32_pack, "silu", v), x)
    report["packs"]["f32"] = {
        "footprint_entries": f32_pack.footprint,
        "footprint_bytes": f32_pack.footprint * 4,
        "meta_bytes": c.meta_bytes,
        "vmem_padded_bytes": c.padded_bytes,
        "dispatch_us": round(t_f32, 1),
    }

    for label, dtype in (("int16", "int16"), ("int8", "int8"),
                         ("auto", "auto")):
        layout = quant_pack_layout(
            [plan_quant_member(n, e_a, dtype=dtype) for n in names])
        qp = from_quant_layout(layout)
        cq = layout.vmem()
        tq = _time(lambda v, q=qp: quant_pack_lookup(q, "silu", v), x)
        report["packs"][label] = {
            "entry_bits": dict(zip(layout.names, layout.entry_bits)),
            "footprint_entries": layout.footprint,
            "footprint_bytes": layout.footprint_bytes,
            "meta_bytes": layout.meta_bytes,
            "vmem_padded_bytes": cq.padded_bytes,
            "dispatch_us": round(tq, 1),
        }

    f32_bytes = report["packs"]["f32"]["footprint_bytes"]
    f32_vmem = report["packs"]["f32"]["vmem_padded_bytes"]
    report["footprint_reduction_vs_f32"] = {
        k: round(f32_bytes / v["footprint_bytes"], 2)
        for k, v in report["packs"].items() if k != "f32"
    }
    # entry storage is the headline (the paper's M_F axis), but refinement buys
    # int8 feasibility with metadata — report the total-residency ratio too so
    # the tradeoff is visible (int16 can win this one at loose Ea)
    report["vmem_reduction_vs_f32"] = {
        k: round(f32_vmem / v["vmem_padded_bytes"], 2)
        for k, v in report["packs"].items() if k != "f32"
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    rows = []
    for k, v in report["packs"].items():
        rows.append((f"kernel.quantpack.{k}.footprint_bytes",
                     v["footprint_bytes"],
                     f"dispatch={v['dispatch_us']}us meta={v['meta_bytes']}B"))
        print(f"[quantpack] {k:5s} footprint={v['footprint_bytes']:6d}B "
              f"meta={v['meta_bytes']:5d}B dispatch={v['dispatch_us']:8.1f}us")
    for k, r in report["footprint_reduction_vs_f32"].items():
        rv = report["vmem_reduction_vs_f32"][k]
        rows.append((f"kernel.quantpack.{k}.reduction_vs_f32", r,
                     f"Ea={e_a:g} vmem_reduction={rv}x"))
        print(f"[quantpack] {k:5s} reduction vs f32: {r:.2f}x entries, "
              f"{rv:.2f}x total VMEM")
    print(f"[quantpack] report -> {out_path}")
    return rows


def polypack_bench(size: int = 1 << 18, e_a: float = 1e-4,
                   out_path: str = BENCH_POLYPACK_JSON) -> List[tuple]:
    """Design-space planner report -> BENCH_polypack.json.

    Prices the planner's (degree, dtype) menu against both hand-tuned
    baselines at the same Ea over DEFAULT_PACK_FUNCTIONS: the linear-f32 pack
    (the PR 2 artifact — entries axis) and the quant splitter's auto pack
    (the PR 3 artifact — VMEM axis).  Per variant it records the plan's total
    entries / stored bytes / padded VMEM residency plus the fused poly-kernel
    dispatch latency on this host.  The acceptance headline is that the auto
    plan SUBSUMES both baselines at once: strictly fewer entries than
    linear-f32 AND no more padded VMEM than the quant auto pack (see
    ``polypack_bench_gate``); the forced-degree rows show where each win
    comes from (degree-2+ buys the entry reduction, narrow codes the bytes).
    """
    from repro.approx import DEFAULT_PACK_FUNCTIONS, build_pack
    from repro.approx.table_pack import from_poly_layout
    from repro.core import (plan_quant_member, poly_pack_layout,
                            quant_pack_layout, vmem_cost_pack)
    from repro.core.design import plan
    from repro.core.flow import cached_table
    from repro.kernels.ops import poly_pack_lookup, table_pack_lookup

    names = DEFAULT_PACK_FUNCTIONS
    x = jnp.asarray(np.random.default_rng(7).normal(0, 3, size).astype(np.float32))
    report = {"e_a": e_a, "functions": list(names), "probe_size": size,
              "packs": {}}

    f32_pack = build_pack(names, e_a)
    specs = [cached_table(n, e_a) for n in names]
    c = vmem_cost_pack([s.footprint for s in specs],
                       [s.n_intervals for s in specs])
    t_f32 = _time(lambda v: table_pack_lookup(f32_pack, "silu", v), x)
    report["packs"]["linear_f32"] = {
        "footprint_entries": f32_pack.footprint,
        "footprint_bytes": f32_pack.footprint * 4,
        "vmem_padded_bytes": c.padded_bytes,
        "dispatch_us": round(t_f32, 1),
    }

    # the quant splitter's auto pack: the hand-tuned VMEM bar the planner
    # must not regress (same Ea, same functions, degree fixed at 1)
    qlayout = quant_pack_layout(
        [plan_quant_member(n, e_a, dtype="auto") for n in names])
    report["packs"]["quant_auto"] = {
        "footprint_entries": qlayout.footprint,
        "footprint_bytes": qlayout.footprint_bytes,
        "vmem_padded_bytes": qlayout.vmem().padded_bytes,
    }

    for label, degrees in (("d1", (1,)), ("d2", (2,)), ("d3", (3,)),
                           ("auto", None)):
        p = (plan(names, e_a) if degrees is None
             else plan(names, e_a, degrees=degrees))
        pack = from_poly_layout(poly_pack_layout(list(p.members)))
        tp = _time(lambda v, pk=pack: poly_pack_lookup(pk, "silu", v), x)
        report["packs"][label] = {
            "choices": {ch.name: [ch.degree, ch.dtype] for ch in p.chosen},
            "footprint_entries": p.total_entries,
            "footprint_bytes": p.total_bytes,
            "vmem_padded_bytes": p.vmem().padded_bytes,
            "dispatch_us": round(tp, 1),
        }

    lin = report["packs"]["linear_f32"]
    auto = report["packs"]["auto"]
    report["entry_reduction_vs_linear_f32"] = round(
        lin["footprint_entries"] / auto["footprint_entries"], 2)
    report["vmem_vs_quant_auto"] = round(
        auto["vmem_padded_bytes"]
        / report["packs"]["quant_auto"]["vmem_padded_bytes"], 3)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    rows = []
    for k, v in report["packs"].items():
        t = v.get("dispatch_us")
        rows.append((f"kernel.polypack.{k}.footprint_entries",
                     v["footprint_entries"],
                     f"bytes={v['footprint_bytes']} "
                     f"vmem={v['vmem_padded_bytes']}B"
                     + (f" dispatch={t}us" if t is not None else "")))
        print(f"[polypack] {k:10s} entries={v['footprint_entries']:6d} "
              f"bytes={v['footprint_bytes']:6d} "
              f"vmem={v['vmem_padded_bytes']:6d}B"
              + (f" dispatch={t:8.1f}us" if t is not None else ""))
    rows.append(("kernel.polypack.entry_reduction_vs_linear_f32",
                 report["entry_reduction_vs_linear_f32"],
                 f"vmem_vs_quant_auto={report['vmem_vs_quant_auto']}x"))
    print(f"[polypack] auto plan: {report['entry_reduction_vs_linear_f32']}x "
          f"fewer entries than linear f32, "
          f"{report['vmem_vs_quant_auto']}x the quant-auto VMEM")
    print(f"[polypack] report -> {out_path}")
    return rows


def polypack_bench_gate(report_path: str = BENCH_POLYPACK_JSON) -> None:
    """CI smoke gate over BENCH_polypack.json: the planner's auto pick must
    subsume BOTH hand-tuned baselines at equal Ea — strictly fewer entries
    than the linear-f32 pack AND no more padded VMEM than the quant splitter's
    auto pack — or the unified design space buys nothing over PR 2/PR 3."""
    with open(report_path) as f:
        report = json.load(f)
    auto = report["packs"]["auto"]
    lin = report["packs"]["linear_f32"]
    quant = report["packs"]["quant_auto"]
    if auto["footprint_entries"] >= lin["footprint_entries"]:
        raise SystemExit(
            f"polypack: auto plan entries {auto['footprint_entries']} >= "
            f"linear f32 {lin['footprint_entries']} — degree-2+ bought nothing")
    if auto["vmem_padded_bytes"] > quant["vmem_padded_bytes"]:
        raise SystemExit(
            f"polypack: auto plan VMEM {auto['vmem_padded_bytes']}B > "
            f"quant auto {quant['vmem_padded_bytes']}B — the planner "
            f"regressed the quantization win")


def routed_dispatch_bench(size: int = 1 << 20, e_a: float = 1e-4,
                          out_path: str = BENCH_ROUTEDPACK_JSON) -> List[tuple]:
    """Routed (dynamic fn_id) vs static dispatch -> BENCH_routedpack.json.

    The routed kernels buy ONE executable for every mixed-function batch
    (scalar-prefetch dispatch) where the static kernels compile one
    specialization per member.  This bench prices that flexibility: the same
    (slots, features) tensor through (a) one static single-function pack
    dispatch, (b) routed dispatch with mixed per-slot functions, for both the
    f32 and the quantized pack.  CI smoke-fails when the f32 routed/static
    ratio exceeds 1.5x on CPU interpret mode (the dispatch must stay
    dispatch-cost-comparable, or the one-executable story is dishonest).

    Geometry note: the routed grid is one step per slot (whole-row column
    blocks), and CPU interpret mode pays a fixed ~0.3 ms per grid step that a
    real TPU overlaps with DMA — so the default ``size`` gives the STATIC
    tiling the same step count (8) as the 8-slot routed grid, making the
    ratio measure dispatch work rather than interpreter loop overhead.
    Timings are best-of-N (``_time_min``): ratio guards on shared CI runners
    must not inherit mean-of-N noise.
    """
    from repro.approx import DEFAULT_PACK_FUNCTIONS, build_pack, build_quant_pack
    from repro.kernels.ops import quant_pack_lookup, table_pack_lookup
    from repro.kernels.routed_pack_lookup import (
        routed_pack_lookup_pallas, routed_quant_pack_lookup_pallas)

    names = DEFAULT_PACK_FUNCTIONS
    F = len(names)
    slots = 8
    feat = max(128, (size // slots // 128) * 128)
    x = jnp.asarray(np.random.default_rng(4).normal(0, 3, (slots, feat))
                    .astype(np.float32))
    ids = jnp.asarray(np.arange(slots) % F, dtype=np.int32)

    pack = build_pack(names, e_a)
    qpack = build_quant_pack(names, e_a)
    t_static = _time_min(lambda v: table_pack_lookup(pack, "silu", v), x)
    t_routed = _time_min(
        lambda v: routed_pack_lookup_pallas(pack, ids, v, block_cols=feat), x)
    t_qstatic = _time_min(lambda v: quant_pack_lookup(qpack, "silu", v), x)
    t_qrouted = _time_min(
        lambda v: routed_quant_pack_lookup_pallas(qpack, ids, v,
                                                  block_cols=feat), x)

    ratio = t_routed / t_static
    qratio = t_qrouted / t_qstatic
    report = {
        "e_a": e_a, "functions": list(names), "slots": slots, "features": feat,
        "f32": {"static_us": round(t_static, 1), "routed_us": round(t_routed, 1),
                "ratio_routed_vs_static": round(ratio, 3)},
        "quant": {"static_us": round(t_qstatic, 1),
                  "routed_us": round(t_qrouted, 1),
                  "ratio_routed_vs_static": round(qratio, 3)},
        # the point of routed dispatch: executables needed for an F-function
        # mixed batch (static specializes per member; routed takes fn_ids as
        # a runtime operand, so any re-routing reuses one executable)
        "executables": {"static": F, "routed": 1},
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    rows = [
        ("kernel.routed.static_us", round(t_static, 1),
         f"one fn, {slots}x{feat}"),
        ("kernel.routed.routed_us", round(t_routed, 1),
         f"{F} fns mixed, ratio={ratio:.2f}x"),
        ("kernel.routed.quant_ratio", round(qratio, 2),
         f"quant routed {t_qrouted:.1f}us vs static {t_qstatic:.1f}us"),
        ("kernel.routed.executables", 1, f"vs {F} static specializations"),
    ]
    print(f"[routed] f32   static={t_static:8.1f}us routed={t_routed:8.1f}us "
          f"({ratio:.2f}x)")
    print(f"[routed] quant static={t_qstatic:8.1f}us routed={t_qrouted:8.1f}us "
          f"({qratio:.2f}x)")
    print(f"[routed] executables for {F}-fn mixed batch: {F} static -> 1 routed")
    print(f"[routed] report -> {out_path}")
    return rows


def rangefold_bench(size: int = 1 << 18, e_a: float = 1e-4,
                    out_path: str = BENCH_RANGEFOLD_JSON) -> List[tuple]:
    """RangeFold fold-overhead report -> BENCH_rangefold.json.

    The folded kernels buy unbounded domains (full-range sin/cos/exp/log,
    table-served RoPE) for the price of a reduction prologue + reconstruction
    epilogue fused around 1-2 core lookups.  This bench prices that fold:
    the same wide-range tensor through (a) the exact jnp transcendental,
    (b) the folded jnp oracle, (c) the fused folded Pallas kernel, plus the
    plain bounded-member pack lookup as the no-fold kernel baseline.  All
    wall-times are host-CPU interpret mode — relative behaviour only (the
    trig fold is ~30 elementwise ops + 2 lookups vs the plain path's 1)."""
    from repro.approx import build_pack
    from repro.approx.range_fold import FOLDED_CORE_MEMBERS, eval_folded_ref
    from repro.kernels.table_pack_lookup import (
        folded_pack_lookup_pallas, table_pack_lookup_pallas)

    names = ("gelu", "silu", "tanh") + FOLDED_CORE_MEMBERS
    pack = build_pack(names, e_a)
    feat = max(256, (size // 8 // 256) * 256)
    # wide range: uniform exponents so Cody-Waite AND Payne-Hanek lanes run
    rng = np.random.default_rng(6)
    x = jnp.asarray((rng.uniform(-1, 1, (8, feat)) *
                     10.0 ** rng.uniform(-2, 6, (8, feat)))
                    .astype(np.float32))
    rows, report_fns = [], {}
    for name in ("sin", "cos", "exp", "log"):
        xs = jnp.abs(x) if name == "log" else x
        t_exact = _time_min(jax.jit(getattr(jnp, name)), xs)
        t_ref = _time_min(
            jax.jit(lambda v, _n=name: eval_folded_ref(pack, _n, v)), xs)
        t_kern = _time_min(
            lambda v, _n=name: folded_pack_lookup_pallas(pack, _n, v), xs)
        report_fns[name] = {
            "exact_us": round(t_exact, 1), "folded_ref_us": round(t_ref, 1),
            "folded_kernel_us": round(t_kern, 1),
            "ratio_folded_vs_exact": round(t_ref / t_exact, 3)}
        rows.append((f"kernel.rangefold.{name}.folded_ref_us", round(t_ref, 1),
                     f"exact={t_exact:.1f}us kernel={t_kern:.1f}us"))
        print(f"[rangefold] {name:4s} exact={t_exact:8.1f}us "
              f"ref={t_ref:8.1f}us kernel={t_kern:8.1f}us "
              f"({t_ref / t_exact:.2f}x vs exact)")
    t_plain = _time_min(lambda v: table_pack_lookup_pallas(pack, "gelu", v), x)
    t_fold = report_fns["exp"]["folded_kernel_us"]
    report = {
        "e_a": e_a, "shape": list(x.shape), "functions": report_fns,
        "plain_member_kernel_us": round(t_plain, 1),
        "fold_overhead_vs_plain_kernel": round(t_fold / t_plain, 3),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    rows.append(("kernel.rangefold.fold_overhead", round(t_fold / t_plain, 2),
                 f"folded exp kernel vs plain gelu kernel {t_plain:.1f}us"))
    print(f"[rangefold] fold overhead: folded exp kernel {t_fold:.1f}us vs "
          f"plain member kernel {t_plain:.1f}us "
          f"({t_fold / t_plain:.2f}x)")
    print(f"[rangefold] report -> {out_path}")
    return rows


def tableflash_bench(e_a: float = 1e-4, out_path: str = BENCH_TABLEFLASH_JSON
                     ) -> List[tuple]:
    """TableFlash error-vs-bound + decode throughput -> BENCH_tableflash.json.

    Two sections.  ``flash_error``: a dense-causal flash attention call with
    the running softmax served from the pack's ``exp_neg`` member (oracle and
    fused Pallas variants) against exact ``jnp.exp`` flash — records the max
    observed |table - exact| next to the derived contract bound
    (``repro.core.attn_error.flash_abs_bound``; docs/table_flash.md) and the
    headroom ratio.  ``decode``: the same reduced model greedily decoding the
    same queue with ``attn_table`` off (exact flash) and on at Ea=1e-6, where
    the end-to-end contract promises token-identical outputs — records
    tokens/sec both ways and the parity bit.  The CI gate
    (``tableflash_bench_gate``) enforces error <= bound per variant and token
    parity; throughput is informational (CPU interpret-mode lookups price the
    dispatch, not the TPU story).
    """
    from repro.approx import ApproxConfig
    from repro.core.attn_error import flash_abs_bound
    from repro.models import build_model, get_config
    from repro.models.attention import flash_attention
    from repro.serving.engine import DecodeEngine, Request, serve_static

    # --- flash error vs the derived bound ---------------------------------
    B, Sq, T, G, Qg, D = 2, 6, 48, 2, 2, 8
    kv_chunk = 8
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(0, 1, (B, Sq, G, Qg, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, G, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, G, D)), jnp.float32)
    q_pos = jnp.arange(T - Sq, T, dtype=jnp.int32)
    k_pos = jnp.arange(T, dtype=jnp.int32)
    run = jax.jit(lambda fn: flash_attention(q, k, v, q_pos, k_pos,
                                             kv_chunk=kv_chunk, exp_fn=fn),
                  static_argnums=0)
    exact = run(None)
    # conformance slop on the synthesis Ea, as in tests/test_table_flash.py
    ea_eff = e_a * 1.02 + 1e-5
    bound = flash_abs_bound(ea_eff, T, kv_chunk, float(jnp.max(jnp.abs(v))))
    report = {"e_a": e_a,
              "geometry": {"B": B, "Sq": Sq, "T": T, "G": G, "Qg": Qg, "D": D,
                           "kv_chunk": kv_chunk},
              "flash_error": {}, "decode": {}}
    rows = []
    for mode in ("table_pack_ref", "table_pack"):
        fn = ApproxConfig(mode=mode, e_a=e_a, omega=0.2,
                          attn_table=True).attn_exp()
        err = float(jnp.max(jnp.abs(run(fn) - exact)))
        t_ex = _time_min(run, None)
        t_tab = _time_min(run, fn)
        report["flash_error"][mode] = {
            "max_abs_err": err, "bound": bound,
            "headroom": round(bound / max(err, 1e-30), 1),
            "exact_us": round(t_ex, 1), "table_us": round(t_tab, 1)}
        rows.append((f"kernel.tableflash.{mode}.max_abs_err", f"{err:.3g}",
                     f"bound={bound:.3g} ({bound / max(err, 1e-30):.0f}x "
                     f"headroom) table={t_tab:.1f}us exact={t_ex:.1f}us"))
        print(f"[tableflash] {mode:14s} max_err={err:.3g} bound={bound:.3g} "
              f"({bound / max(err, 1e-30):.0f}x) table={t_tab:8.1f}us "
              f"exact={t_ex:8.1f}us")

    # --- greedy decode: exact flash vs table-served flash at Ea=1e-6 ------
    rng = np.random.default_rng(9)
    prompt_len, cache_len, vocab, batch = 8, 64, 128, 2
    reqs = [Request(prompt=rng.integers(0, vocab, (prompt_len,))
                    .astype(np.int32), max_new_tokens=16) for _ in range(4)]
    decode = {}
    for label, attn_table in (("exact_flash", False), ("table_flash", True)):
        cfg = get_config("stablelm-3b").replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
            d_ff=128, vocab=vocab, remat=False,
            approx=ApproxConfig(mode="table_pack_ref", e_a=1e-6, omega=0.2,
                                attn_table=attn_table))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        eng = DecodeEngine(model, params, batch, cache_len)
        serve_static(model, params, reqs, batch, cache_len, engine=eng)  # warm
        t_best, res = float("inf"), None
        for _ in range(3):
            t0 = time.perf_counter()
            res = serve_static(model, params, reqs, batch, cache_len,
                               engine=eng)
            t_best = min(t_best, time.perf_counter() - t0)
        useful = sum(r.steps for r in res)
        decode[label] = {"tokens_per_s": round(useful / t_best, 1),
                         "tokens": [np.asarray(r.tokens) for r in res]}
    match = all(np.array_equal(a, b) for a, b in
                zip(decode["exact_flash"]["tokens"],
                    decode["table_flash"]["tokens"]))
    report["decode"] = {
        "e_a": 1e-6, "requests": len(reqs), "batch": batch,
        "exact_flash_tok_s": decode["exact_flash"]["tokens_per_s"],
        "table_flash_tok_s": decode["table_flash"]["tokens_per_s"],
        "tokens_identical": bool(match)}
    rows.append(("kernel.tableflash.decode_tok_s",
                 decode["table_flash"]["tokens_per_s"],
                 f"exact_flash={decode['exact_flash']['tokens_per_s']} "
                 f"tokens_identical={match}"))
    print(f"[tableflash] decode table={decode['table_flash']['tokens_per_s']} "
          f"tok/s exact={decode['exact_flash']['tokens_per_s']} tok/s "
          f"tokens_identical={match}")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"[tableflash] report -> {out_path}")
    return rows


def tableflash_bench_gate(report_path: str = BENCH_TABLEFLASH_JSON) -> None:
    """CI smoke gate over BENCH_tableflash.json: every variant's observed
    flash error must respect the derived contract bound, and the Ea=1e-6
    greedy decode must be token-identical to exact flash."""
    with open(report_path) as f:
        report = json.load(f)
    for mode, m in report["flash_error"].items():
        if m["max_abs_err"] > m["bound"]:
            raise SystemExit(
                f"tableflash[{mode}]: observed error {m['max_abs_err']:.3g} "
                f"> derived bound {m['bound']:.3g} — the attention error "
                f"contract is violated")
    if not report["decode"]["tokens_identical"]:
        raise SystemExit(
            "tableflash: Ea=1e-6 greedy decode diverged from exact flash — "
            "the token-parity contract is violated")


def shardedpack_bench(size: int = 1 << 18, e_a: float = 1e-4,
                      shard_counts=(2, 4),
                      out_path: str = BENCH_SHARDEDPACK_JSON) -> List[tuple]:
    """ShardedPack per-shard VMEM high-water + dispatch -> BENCH_shardedpack.json.

    The sharded pack exists to beat the REPLICATED pack's per-core VMEM
    residency once the pack outgrows a core; this bench records, per shard
    count, the per-shard high-water (padded values slice + replicated selector
    metadata + the local_base/owned planes — what one core actually pins) next
    to the replicated residency, plus the off-mesh dispatch latency (one
    kernel launch PER SHARD on this host; a real mesh runs the S launches on
    S cores concurrently and pays one psum instead).  The CI gate is the
    memory claim: per-shard high-water must be strictly below the replicated
    footprint for every shard count, or the sharding buys nothing.
    """
    from repro.approx import DEFAULT_PACK_FUNCTIONS, build_pack, from_sharded_layout
    from repro.core import cached_table, pack_layout, shard_pack_layout
    from repro.kernels.ops import table_pack_lookup
    from repro.kernels.table_pack_lookup import sharded_pack_lookup_pallas

    names = DEFAULT_PACK_FUNCTIONS
    x = jnp.asarray(np.random.default_rng(6).normal(0, 3, size).astype(np.float32))
    specs = [cached_table(n, e_a) for n in names]
    layout = pack_layout(specs)
    pack = build_pack(names, e_a)
    repl = layout.vmem()  # the canonical replicated residency the tests compare
    t_repl = _time_min(lambda v: table_pack_lookup(pack, "silu", v), x)
    report = {"e_a": e_a, "functions": list(names), "probe_size": size,
              "replicated": {"footprint_entries": layout.footprint,
                             "vmem_padded_bytes": repl.padded_bytes,
                             "dispatch_us": round(t_repl, 1)},
              "shards": {}}
    rows = [("kernel.shardedpack.replicated.vmem_bytes", repl.padded_bytes,
             f"dispatch={t_repl:.1f}us F={len(names)}")]
    print(f"[shardedpack] replicated vmem={repl.padded_bytes}B "
          f"dispatch={t_repl:8.1f}us")
    for S in shard_counts:
        slay = shard_pack_layout(layout, S)
        spack = from_sharded_layout(slay)
        c = slay.vmem()
        t = _time_min(
            lambda v, p=spack: sharded_pack_lookup_pallas(p, "silu", v), x)
        red = repl.padded_bytes / c.padded_bytes
        report["shards"][str(S)] = {
            "shard_sizes": [int(s) for s in slay.shard_sizes],
            "max_shard_entries": slay.max_shard_entries,
            "vmem_padded_bytes_per_shard": c.padded_bytes,
            "vmem_reduction_vs_replicated": round(red, 2),
            "dispatch_us": round(t, 1),
            "kernel_launches": S,
        }
        rows.append((f"kernel.shardedpack.s{S}.vmem_bytes", c.padded_bytes,
                     f"{red:.2f}x smaller/core, dispatch={t:.1f}us "
                     f"({S} launches off-mesh)"))
        print(f"[shardedpack] S={S} per-shard vmem={c.padded_bytes}B "
              f"({red:.2f}x) dispatch={t:8.1f}us")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"[shardedpack] report -> {out_path}")
    return rows


def shardedpack_bench_gate(report_path: str = BENCH_SHARDEDPACK_JSON) -> None:
    """CI smoke gate over BENCH_shardedpack.json: every shard count's
    per-shard VMEM high-water must be strictly below the replicated pack's."""
    with open(report_path) as f:
        report = json.load(f)
    repl = report["replicated"]["vmem_padded_bytes"]
    for S, m in report["shards"].items():
        per = m["vmem_padded_bytes_per_shard"]
        if per >= repl:
            raise SystemExit(
                f"shardedpack[S={S}]: per-shard VMEM {per}B >= replicated "
                f"{repl}B — sharding buys no memory")


def serve_bench(modes=("exact", "table_pack"), n_requests: int = 8,
                batch: int = 2, long_budget: int = 24, short_budget: int = 2,
                out_path: str = BENCH_SERVE_JSON) -> List[tuple]:
    """Continuous vs static serving -> BENCH_serve.json.

    A staggered queue (equal-length prompts, alternating long/short budgets)
    through a tiny dense model, served both ways per table mode.  The static
    scheduler pads each fixed group to its longest budget, so every short
    request strands decode slots; the continuous scheduler refills freed
    slots from the admission queue mid-stream.  Reports tokens/sec over the
    per-request trimmed counts and the wasted-slot-step fraction for each —
    CI smoke-fails if continuous wastes more than static or loses on
    tokens/sec (the refill machinery must pay for itself even on CPU, where
    the refill prefill is NOT overlapped with decode like a TPU host would).

    Equal prompt lengths keep both schedulers at ONE compiled prefill shape
    (static pads per group; a mixed-length queue would recompile its prefill
    per distinct group width) and make their greedy outputs comparable
    token-for-token.  Timings exclude compiles: each engine is warmed on a
    queue long enough to trigger a refill (the refill gather/scatter ops are
    eager and XLA caches them per shape — the first single-slot refill pays
    their compiles), then counters reset before the timed run.

    ScopeKit observability is enabled (host-side only) across the timed reps,
    so each scheduler's dict gains ``latency``: TTFT and inter-token-latency
    p50/p95/p99 in milliseconds, harvested from the engines' metric
    histograms over all reps.  Both schedulers carry the same recording
    overhead, so the continuous-vs-static gate is unaffected.
    """
    from repro import obs
    from repro.approx import ApproxConfig
    from repro.models import build_model, get_config
    from repro.serving.engine import (ContinuousEngine, DecodeEngine, Request,
                                      serve_static)

    def _latency_ms(engine) -> dict:
        hists = engine.metrics.summary()["histograms"]
        out = {}
        for key, label in (("ttft_s", "ttft_ms"), ("itl_s", "itl_ms")):
            s = hists.get(key) or {}
            out[label] = {q: round(s[q] * 1e3, 3)
                          for q in ("p50", "p95", "p99") if q in s}
        return out

    rng = np.random.default_rng(5)
    prompt_len, cache_len, vocab = 8, 64, 128
    report = {"requests": n_requests, "batch": batch,
              "prompt_len": prompt_len,
              "budgets": [long_budget, short_budget], "modes": {}}
    rows = []
    for mode in modes:
        cfg = get_config("stablelm-3b").replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
            d_ff=128, vocab=vocab, remat=False,
            approx=ApproxConfig(mode=mode, e_a=1e-4, omega=0.2))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        mk = lambda n: [Request(
            prompt=rng.integers(0, vocab, (prompt_len,)).astype(np.int32),
            max_new_tokens=long_budget if i % 2 == 0 else short_budget)
            for i in range(n)]
        warm = mk(2 * batch)  # enough requests to exercise mid-stream refill
        reqs = mk(n_requests)

        stat = DecodeEngine(model, params, batch, cache_len)
        serve_static(model, params, warm, batch, cache_len, engine=stat)
        cont = ContinuousEngine(model, params, batch, cache_len,
                                prefill_len=prompt_len)
        cont.serve(warm)
        stat.reset_counters()
        cont.reset_counters()

        # Interleaved best-of-N wall times: shared-runner noise must not flip
        # the gate (same rationale as _time_min), and alternating the two
        # schedulers inside each rep keeps a noisy phase from taxing only one.
        reps = 5
        t_s = t_c = float("inf")
        res_s = res_c = None
        prev_obs = obs.get_config()
        obs.configure(enabled=True)  # host spans + TTFT/ITL histograms
        try:
            for _ in range(reps):
                t0 = time.perf_counter()
                res_s = serve_static(model, params, reqs, batch, cache_len,
                                     engine=stat)
                t_s = min(t_s, time.perf_counter() - t0)
                t0 = time.perf_counter()
                res_c = cont.serve(reqs)
                t_c = min(t_c, time.perf_counter() - t0)
        finally:
            obs.configure(enabled=prev_obs.enabled,
                          device_telemetry=prev_obs.device_telemetry,
                          trace_path=prev_obs.trace_path)
        for eng in (stat, cont):
            eng.batch_steps //= reps
            eng.wasted_slot_steps //= reps
        cont.refills //= reps

        useful_s = sum(r.steps for r in res_s)
        useful_c = sum(r.steps for r in res_c)
        m = {
            "static": {"tokens_per_s": round(useful_s / t_s, 1),
                       "tokens": useful_s, "batch_rounds": stat.batch_steps,
                       "wasted_step_fraction": round(stat.wasted_fraction, 3),
                       "latency": _latency_ms(stat)},
            "continuous": {"tokens_per_s": round(useful_c / t_c, 1),
                           "tokens": useful_c, "batch_rounds": cont.batch_steps,
                           "refills": cont.refills,
                           "wasted_step_fraction": round(cont.wasted_fraction,
                                                         3),
                           "latency": _latency_ms(cont)},
            "speedup_continuous_vs_static": round(t_s / t_c, 2),
        }
        report["modes"][mode] = m
        rows.append((f"serve.{mode}.continuous_tok_s",
                     m["continuous"]["tokens_per_s"],
                     f"static={m['static']['tokens_per_s']} "
                     f"({m['speedup_continuous_vs_static']}x)"))
        rows.append((f"serve.{mode}.wasted_fraction",
                     m["continuous"]["wasted_step_fraction"],
                     f"static={m['static']['wasted_step_fraction']}"))
        print(f"[serve] {mode:10s} continuous="
              f"{m['continuous']['tokens_per_s']:8.1f} tok/s "
              f"(waste {m['continuous']['wasted_step_fraction']:.3f}) "
              f"static={m['static']['tokens_per_s']:8.1f} tok/s "
              f"(waste {m['static']['wasted_step_fraction']:.3f})  "
              f"{m['speedup_continuous_vs_static']}x")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"[serve] report -> {out_path}")
    return rows


def serve_bench_gate(report_path: str = BENCH_SERVE_JSON) -> None:
    """CI smoke gate over BENCH_serve.json: per mode, continuous must not
    waste more slot-steps than static, and must win tokens/sec."""
    with open(report_path) as f:
        report = json.load(f)
    for mode, m in report["modes"].items():
        wc = m["continuous"]["wasted_step_fraction"]
        ws = m["static"]["wasted_step_fraction"]
        if wc > ws:
            raise SystemExit(f"serve[{mode}]: continuous wasted fraction "
                             f"{wc} > static {ws}")
        tc = m["continuous"]["tokens_per_s"]
        ts = m["static"]["tokens_per_s"]
        if tc < ts:
            raise SystemExit(f"serve[{mode}]: continuous {tc} tok/s < "
                             f"static {ts} tok/s")


def main() -> None:
    """CLI for the CI smoke steps: ``python -m benchmarks.kernel_bench
    --quantpack`` / ``--routedpack`` / ``--serve``."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quantpack", action="store_true",
                    help="emit BENCH_quantpack.json (footprint + latency)")
    ap.add_argument("--routedpack", action="store_true",
                    help="emit BENCH_routedpack.json (routed vs static "
                         "dispatch latency)")
    ap.add_argument("--serve", action="store_true",
                    help="emit BENCH_serve.json (continuous vs static "
                         "serving throughput + wasted-step fraction)")
    ap.add_argument("--shardedpack", action="store_true",
                    help="emit BENCH_shardedpack.json (per-shard VMEM "
                         "high-water vs replicated + dispatch latency)")
    ap.add_argument("--polypack", action="store_true",
                    help="emit BENCH_polypack.json (planner auto pick vs "
                         "linear-f32 entries and quant-auto VMEM)")
    ap.add_argument("--rangefold", action="store_true",
                    help="emit BENCH_rangefold.json (folded full-range "
                         "sin/cos/exp/log vs exact and vs the plain pack "
                         "kernel)")
    ap.add_argument("--tableflash", action="store_true",
                    help="emit BENCH_tableflash.json (flash error vs the "
                         "derived bound + decode token parity and tok/s)")
    ap.add_argument("--size", type=int, default=None,
                    help="probe tensor size (default 2^18; 2^20 for "
                         "--routedpack so static and routed tile to the same "
                         "interpret-mode step count)")
    ap.add_argument("--ea", type=float, default=1e-4)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.quantpack:
        rows = quantpack_bench(args.size or (1 << 18), args.ea,
                               args.out or BENCH_QUANTPACK_JSON)
        red = [r for name, r, _ in rows
               if name == "kernel.quantpack.auto.reduction_vs_f32"]
        if red and red[0] < 2.0:
            raise SystemExit(
                f"auto quant pack reduction {red[0]}x < 2x vs f32 at equal Ea")
    elif args.routedpack:
        rows = routed_dispatch_bench(args.size or (1 << 20), args.ea,
                                     args.out or BENCH_ROUTEDPACK_JSON)
        ratio = [r for name, r, _ in rows if name == "kernel.routed.routed_us"]
        static = [r for name, r, _ in rows if name == "kernel.routed.static_us"]
        if ratio and static and ratio[0] > 1.5 * static[0]:
            raise SystemExit(
                f"routed dispatch {ratio[0]}us > 1.5x static {static[0]}us "
                f"on CPU interpret mode")
    elif args.serve:
        serve_bench(out_path=args.out or BENCH_SERVE_JSON)
        serve_bench_gate(args.out or BENCH_SERVE_JSON)
    elif args.shardedpack:
        shardedpack_bench(args.size or (1 << 18), args.ea,
                          out_path=args.out or BENCH_SHARDEDPACK_JSON)
        shardedpack_bench_gate(args.out or BENCH_SHARDEDPACK_JSON)
    elif args.polypack:
        polypack_bench(args.size or (1 << 18), args.ea,
                       args.out or BENCH_POLYPACK_JSON)
        polypack_bench_gate(args.out or BENCH_POLYPACK_JSON)
    elif args.rangefold:
        rangefold_bench(args.size or (1 << 18), args.ea,
                        args.out or BENCH_RANGEFOLD_JSON)
    elif args.tableflash:
        tableflash_bench(args.ea, args.out or BENCH_TABLEFLASH_JSON)
        tableflash_bench_gate(args.out or BENCH_TABLEFLASH_JSON)
    else:
        activation_bench(args.size or (1 << 18))
        interval_count_flatness()
        pack_dispatch_bench(args.size or (1 << 18))
        routed_dispatch_bench(args.size or (1 << 20))
        shardedpack_bench(args.size or (1 << 18))
        polypack_bench(args.size or (1 << 18))
        rangefold_bench(args.size or (1 << 18))


if __name__ == "__main__":
    main()
