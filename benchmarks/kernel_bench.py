"""Microbenchmarks of the table-approximation runtimes on the host CPU.

CPU wall-times are NOT the TPU performance story (that is the roofline analysis,
benchmarks/roofline.py); these timings validate relative behaviour: the table_ref
path must be within a small factor of the exact transcendental, and costs must be
flat in the number of sub-intervals (the paper's constant-latency claim, Fig. 7,
mapped to SIMD: the comparator plane is O(n) FMAs but n<=32 is noise vs memory
traffic)."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import ApproxConfig
from repro.core import build_table


def _time(f, *args, reps=20) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def activation_bench(size: int = 1 << 20) -> List[tuple]:
    rows = []
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, size).astype(np.float32))
    for name in ("gelu", "silu", "tanh"):
        exact = jax.jit(ApproxConfig(mode="exact").unary(name))
        table = jax.jit(ApproxConfig(mode="table_ref", e_a=1e-4,
                                     algorithm="hierarchical", omega=0.2).unary(name))
        te = _time(exact, x)
        tt = _time(table, x)
        rows.append((f"kernel.{name}.exact_us", round(te, 1), f"n={size}"))
        rows.append((f"kernel.{name}.table_ref_us", round(tt, 1),
                     f"ratio={tt / te:.2f}x"))
        print(f"[kernel] {name:6s} exact={te:8.1f}us  table_ref={tt:8.1f}us  "
              f"ratio={tt / te:.2f}x")
    return rows


def interval_count_flatness(size: int = 1 << 18) -> List[tuple]:
    """Constant-latency claim: runtime flat vs #sub-intervals (omega sweep)."""
    rows = []
    x = jnp.asarray(np.random.default_rng(1).normal(0, 3, size).astype(np.float32))
    times = []
    for omega in (0.9, 0.3, 0.1, 0.02):
        cfg = ApproxConfig(mode="table_ref", e_a=1e-5, algorithm="hierarchical",
                           omega=omega)
        jt = cfg.table_for("gelu")
        f = jax.jit(cfg.unary("gelu"))
        t = _time(f, x)
        times.append(t)
        rows.append((f"kernel.flatness.omega{omega}", round(t, 1),
                     f"n_intervals={jt.n_intervals}"))
        print(f"[flatness] omega={omega:4.2f} n={jt.n_intervals:3d} t={t:8.1f}us")
    spread = max(times) / min(times)
    rows.append(("kernel.flatness.spread", round(spread, 2),
                 "CPU serializes the compare chain; flat on the TPU VPU"))
    return rows


def pack_dispatch_bench(size: int = 1 << 18) -> List[tuple]:
    """TablePack vs per-table dispatch: F functions through ONE packed artifact
    and one fused kernel (static fn_id row select) versus F separate tables,
    each with its own VMEM residency and pallas_call.  Also reports the VMEM
    footprint both ways — the BRAM-instantiation win the pack exists for."""
    from repro.approx import pack_specs
    from repro.core import vmem_cost, vmem_cost_pack
    from repro.kernels.ops import table_lookup, table_pack_lookup
    from repro.approx.jax_table import from_spec

    names = ("gelu", "silu", "tanh", "sigmoid_sym", "exp_neg")
    specs = [build_table(n, 1e-4, algorithm="hierarchical", omega=0.2)
             for n in names]
    pack = pack_specs(specs)
    tables = [from_spec(s) for s in specs]
    x = jnp.asarray(np.random.default_rng(2).normal(0, 3, size).astype(np.float32))

    def per_table_all(v):
        return [table_lookup(jt, v) for jt in tables]

    def pack_all(v):
        return [table_pack_lookup(pack, i, v) for i in range(len(names))]

    tp = _time(lambda v: pack_all(v)[-1], x)
    tt = _time(lambda v: per_table_all(v)[-1], x)
    rows = [
        ("kernel.pack.dispatch_us", round(tp, 1),
         f"F={len(names)} fns, one pack, n={size}"),
        ("kernel.pack.per_table_us", round(tt, 1), f"ratio={tt / tp:.2f}x"),
    ]
    vm_pack = vmem_cost_pack([s.footprint for s in specs],
                             [s.n_intervals for s in specs]).padded_bytes
    vm_tabs = sum(vmem_cost(s.footprint, s.n_intervals).padded_bytes
                  for s in specs)
    rows.append(("kernel.pack.vmem_bytes", vm_pack,
                 f"vs {vm_tabs}B across {len(names)} per-table residencies"))
    print(f"[pack] {len(names)} fns: pack={tp:8.1f}us  per-table={tt:8.1f}us  "
          f"({tt / tp:.2f}x)  VMEM {vm_tabs} -> {vm_pack} B")
    return rows
