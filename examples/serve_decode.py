"""Batched serving example: prefill + greedy decode over a request queue with
the KV cache on device, table-backend activations, and a throughput report.

Run:  PYTHONPATH=src python examples/serve_decode.py --requests 6 --max-new 12
"""

import argparse
import time

import jax
import numpy as np

from repro.approx import ApproxConfig
from repro.models import build_model, get_config
from repro.serving.engine import Request, serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--mode", default="table_ref",
                    choices=["exact", "table_ref", "table_pallas", "table_pack",
                             "table_pack_ref", "quant_pack", "quant_pack_ref"])
    args = ap.parse_args()

    cfg = get_config("gemma3-12b").replace(
        n_layers=6, d_model=128, n_heads=4, n_kv_heads=2, d_head=32, d_ff=256,
        vocab=1024, remat=False,
        approx=ApproxConfig(mode=args.mode, e_a=1e-4, omega=0.2),
    )  # a local:global sliding-window model end to end
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, (int(n),)).astype(np.int32),
                    max_new_tokens=args.max_new)
            for n in rng.integers(5, 24, args.requests)]

    t0 = time.time()
    results = serve(model, params, reqs, batch_size=args.batch, cache_len=128)
    dt = time.time() - t0
    total = sum(len(r.tokens) for r in results)
    print(f"mode={args.mode}: served {len(results)} requests / {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s, CPU)")
    for i, r in enumerate(results[:3]):
        print(f"  req{i}: prompt={r.prompt_len} toks -> {r.tokens.tolist()}")
    print("serve_decode OK")


if __name__ == "__main__":
    main()
