"""Batched serving example: prefill + decode over a request queue with the KV
cache on device, table-backend activations, and a throughput report.

Run:  PYTHONPATH=src python examples/serve_decode.py --requests 6 --max-new 12

``--scheduler continuous`` (the default) serves the queue through the
ContinuousEngine: freed slots are refilled mid-stream from the admission
queue, so decode batches stay full; ``--scheduler static`` is the PR 1
fixed-group baseline.  Throughput counts only the tokens each request
actually kept (per-request EOS/budget trimming), and the wasted-slot-step
fraction shows what the scheduler left on the table.

``--routed-demo`` instead demonstrates RoutedPack: a different activation per
expert slot evaluated in ONE call (dynamic fn_id dispatch — the routing is a
runtime operand, so re-routing the slots reuses the same compiled executable).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.approx import TABLE_MODES, ApproxConfig
from repro.models import build_model, get_config
from repro.models.common import routed_activation
from repro.serving.engine import (ContinuousEngine, DecodeEngine, Request,
                                  serve_static)

MODES = ["exact", *TABLE_MODES]


def routed_demo(mode: str, n_slots: int = 6, d: int = 256) -> None:
    """Different activation per expert slot, one dispatch, one executable."""
    cfg = ApproxConfig(mode=mode, e_a=1e-4, omega=0.2)
    slots = tuple(("gelu", "silu", "tanh", "sigmoid", "softplus", "exp")[i % 6]
                  for i in range(n_slots))
    f = jax.jit(routed_activation(cfg, slots))
    x = jnp.asarray(np.random.default_rng(0).normal(0, 2, (n_slots, d))
                    .astype(np.float32))
    y = np.asarray(f(x))
    # parity: each slot must match its own static single-function dispatch
    worst = 0.0
    for i, name in enumerate(slots):
        ys = np.asarray(jax.jit(cfg.unary(name))(x[i]))
        worst = max(worst, float(np.max(np.abs(y[i] - ys))))
    print(f"mode={mode}: routed {n_slots} slots x {d} features "
          f"({','.join(slots)}) in one call; max |routed - static| = {worst:g}")
    assert worst == 0.0, "routed dispatch must match static dispatch bitwise"
    print("routed_demo OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--mode", default="table_ref", choices=MODES)
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "static"],
                    help="continuous = admission queue + mid-stream slot "
                         "refill (full decode batches); static = PR 1 "
                         "fixed-group baseline")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--attn-table", action="store_true",
                    help="TableFlash: serve flash attention's softmax exponent"
                         " from the pack's exp_neg member (table modes only)")
    ap.add_argument("--routed-demo", action="store_true",
                    help="run the per-slot routed-activation demo and exit")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a ScopeKit Chrome-trace JSON of the serve "
                         "(open in Perfetto)")
    ap.add_argument("--obs", action="store_true",
                    help="enable device-side approximation telemetry and "
                         "print the metric summary")
    args = ap.parse_args()

    if args.routed_demo:
        routed_demo(args.mode)
        return

    obs.configure(enabled=True, device_telemetry=args.obs,
                  trace_path=args.trace)
    obs.reset_tracer()

    cfg = get_config("gemma3-12b").replace(
        n_layers=6, d_model=128, n_heads=4, n_kv_heads=2, d_head=32, d_ff=256,
        vocab=1024, remat=False,
        approx=ApproxConfig(mode=args.mode, e_a=1e-4, omega=0.2,
                            attn_table=args.attn_table),
    )  # a local:global sliding-window model end to end
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    # staggered budgets: short and long requests mixed, so the static
    # scheduler visibly wastes decode steps that the continuous one refills
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, (int(n),)).astype(np.int32),
                    max_new_tokens=args.max_new if i % 2 == 0
                    else max(1, args.max_new // 4))
            for i, n in enumerate(rng.integers(5, 24, args.requests))]

    if args.scheduler == "continuous":
        engine = ContinuousEngine(model, params, args.batch, cache_len=128,
                                  temperature=args.temperature)
        t0 = time.time()
        results = engine.serve(reqs)
        dt = time.time() - t0
    else:
        engine = DecodeEngine(model, params, args.batch, cache_len=128,
                              temperature=args.temperature)
        t0 = time.time()
        results = serve_static(model, params, reqs, batch_size=args.batch,
                               cache_len=128, engine=engine)
        dt = time.time() - t0
    # throughput over tokens each request actually generated (Result.steps ==
    # len(tokens), trimmed at that request's own EOS/budget — padded or
    # post-EOS slots don't inflate the number)
    total = sum(r.steps for r in results)
    steady = max(dt - engine.compile_time_s, 1e-9)
    print(f"mode={args.mode}/{args.scheduler}: served {len(results)} requests "
          f"/ {total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s wall, "
          f"{total / steady:.1f} tok/s steady after "
          f"{engine.compile_time_s:.2f}s compile, CPU); "
          f"{engine.batch_steps} batch rounds, "
          f"wasted slot-step fraction {engine.wasted_fraction:.2f}")
    for i, r in enumerate(results[:3]):
        print(f"  req{i}: prompt={r.prompt_len} toks -> {r.tokens.tolist()}")
    if args.obs:
        import json

        print(json.dumps({"metrics": obs.get_registry().summary(),
                          "engine_metrics": engine.metrics.summary()},
                         indent=1, default=str))
    if args.trace:
        obs.get_tracer().save(args.trace, metadata={
            "metrics": {
                "histograms": engine.metrics.summary()["histograms"],
                "counters": obs.get_registry().summary()["counters"],
            }})
        print(f"trace written to {args.trace}")
    print("serve_decode OK")


if __name__ == "__main__":
    main()
