"""Quickstart: the paper's design flow end-to-end, on its own worked example.

1. Reference (even-spacing) table for log(x) on [0.625, 15.625)  (paper Fig. 3)
2. The three interval-splitting algorithms                       (paper Sec. 5)
3. Resource models: BRAM18 packing + TPU VMEM packing            (paper Sec. 7)
4. The runtime: pure-jnp oracle, the Pallas kernel (interpret mode on CPU),
   the differentiable activation wrapper, and the error-bound check.
5. QuantPack: the error budget split between interpolation and int8/int16
   code rounding, with the dequantize-on-read kernel still inside Ea.
6. Beyond one core: RoutedPack (per-row dynamic fn_id dispatch — one
   executable serves mixed-function batches, docs/routedpack.md) and
   ShardedPack (the pack's values split over the mesh 'model' axis with
   per-shard base rebasing, bit-identical to the replicated pack,
   docs/sharding.md).
7. The design-space planner: degree-1..3 Horner cells x f32/int16/int8 codes
   searched as ONE space, with byte-budgeted plans (docs/planner.md).

Run:  PYTHONPATH=src python examples/quickstart.py
(The full mode matrix — every ApproxConfig mode with its kernel, oracle, and
tests — is in docs/architecture.md.)
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.approx import ApproxConfig, from_spec
from repro.core import (
    binary_split,
    bram_count,
    build_table,
    hierarchical_split,
    reference_spacing,
    run_flow,
    sequential_split,
    get_function,
    vmem_cost,
)
from repro.kernels.ops import table_lookup
from repro.kernels.ref import table_lookup_ref

EA = 1.22e-4
LO, HI = 0.625, 15.625

print("=== 1. Reference approach (paper Fig. 3) ===")
fn = get_function("log")
ref = reference_spacing(fn, EA, LO, HI)
print(f"delta = {ref.delta:.5f}, M_F = {ref.footprint} entries "
      f"(paper: delta~0.019, M_F~770)")

print("\n=== 2. Interval splitting (paper Sec. 5, omega = 0.3) ===")
for name, sr in [
    ("binary      ", binary_split("log", EA, LO, HI, 0.3)),
    ("hierarchical", hierarchical_split("log", EA, LO, HI, 0.3, epsilon=0.015)),
    ("sequential  ", sequential_split("log", EA, LO, HI, 0.3, epsilon=0.3)),
]:
    red = 100 * (ref.footprint - sr.footprint) / ref.footprint
    print(f"{name}: P = {np.round(sr.partition, 3).tolist()}")
    print(f"              M_F = {sr.footprint} (-{red:.0f}%), "
          f"{sr.n_intervals} sub-intervals")

print("\n=== 3. Resource models (paper Sec. 7) ===")
report = run_flow("log", EA, LO, HI, algorithm="hierarchical", omega=0.3,
                  verify_error=True)
print(report.summary())
print(f"BRAM18s: reference {bram_count(ref.footprint)} -> "
      f"{bram_count(report.footprint)}")
vm = vmem_cost(report.footprint, report.n_intervals)
print(f"VMEM residency of the kernel table: {vm.padded_bytes} bytes "
      f"({vm.fraction * 100:.4f}% of a v5e core's 16 MiB)")
print(f"measured max |table - f| = {report.measured_max_error:.3e} <= Ea = {EA}")

print("\n=== 4. Runtime: oracle, Pallas kernel, differentiable activation ===")
spec = build_table("log", EA, LO, HI, algorithm="hierarchical", omega=0.3)
jt = from_spec(spec)
x = jnp.asarray(np.random.default_rng(0).uniform(LO, HI, 8192).astype(np.float32))
y_ref = table_lookup_ref(jt, x)
y_pal = table_lookup(jt, x)  # pl.pallas_call, interpret=True on CPU
print(f"pallas vs oracle max diff: {float(jnp.max(jnp.abs(y_pal - y_ref))):.2e}")
print(f"vs exact log(x) max err:   "
      f"{float(jnp.max(jnp.abs(y_ref - jnp.log(x)))):.2e} (Ea = {EA})")

cfg = ApproxConfig(mode="table_ref", e_a=1e-4)
gelu = cfg.unary("gelu")
g = jax.grad(lambda v: gelu(v).sum())(jnp.linspace(-3, 3, 16))
print(f"table-GELU gradient via custom_jvp (slope rule): "
      f"{np.round(np.asarray(g[:4]), 3).tolist()} ...")

print("\n=== 5. QuantPack: error-budgeted int8/int16 entries ===")
from repro.approx import build_quant_pack, eval_quant_pack_ref
from repro.core import build_table, get_function

QNAMES = ("gelu", "tanh", "sigmoid_sym")
QEA = 1e-4
qpack = build_quant_pack(QNAMES, QEA)  # interp gets 0.9*Ea, rounding 0.1*Ea
f32_bytes = 4 * sum(build_table(n, QEA, algorithm="hierarchical",
                                omega=0.3).footprint for n in QNAMES)
print(f"per-function width from the budget split: "
      f"{dict(zip(qpack.names, qpack.entry_bits))}")
print(f"entry storage: {qpack.footprint_bytes} B quantized vs {f32_bytes} B "
      f"f32 ({f32_bytes / qpack.footprint_bytes:.1f}x smaller)")
for name in QNAMES:
    fn = get_function(name)
    xs = jnp.asarray(np.linspace(*fn.interval, 4001)[:-1].astype(np.float32))
    err = float(jnp.max(jnp.abs(
        eval_quant_pack_ref(qpack, name, xs)
        - jnp.asarray(fn.f(np.asarray(xs, np.float64))))))
    print(f"  {name:12s} dequantize-on-read max err = {err:.2e} <= Ea = {QEA}")

print("\n=== 6. Routed + sharded dispatch: past one executable, past one core ===")
# Routed: fn_ids are a RUNTIME operand (scalar prefetch) — one executable
# serves any per-row mix of members; re-routing never recompiles.
from repro.approx import ApproxConfig as AC

cfg = AC(mode="routed_pack", e_a=QEA)
routed = cfg.routed_fn(("gelu", "tanh", "sigmoid"))  # row i -> function i
xr = jnp.asarray(np.random.default_rng(1).normal(0, 2, (3, 256)).astype(np.float32))
static = jnp.stack([cfg.unary(n)(xr[i]) for i, n in
                    enumerate(("gelu", "tanh", "sigmoid"))])
print(f"routed vs per-row static dispatch max diff: "
      f"{float(jnp.max(jnp.abs(routed(xr) - static))):.1e} (bit-identical)")

# Sharded: the pack's values vector split pack_shards ways (sub-interval
# granularity, per-shard base rebasing).  Off-mesh it sums a stacked shard
# axis; under a use_sharding mesh whose 'model' axis is pack_shards wide it
# runs shard_map + psum with ONE slice per core — same bits either way.
scfg = AC(mode="sharded_pack", e_a=QEA, pack_shards=2)
spack = scfg.sharded_pack()
repl = scfg.pack()
y_sh = jax.jit(scfg.unary("gelu"))(xr)
y_re = jax.jit(AC(mode="table_pack", e_a=QEA).unary("gelu"))(xr)
print(f"sharded vs replicated pack max diff:        "
      f"{float(jnp.max(jnp.abs(y_sh - y_re))):.1e} (bit-identical)")
print(f"per-core values entries: {repl.footprint} replicated -> "
      f"{spack.footprint_per_shard} per shard ({spack.n_shards} shards)")

print("\n=== 7. The design-space planner: degree x width under a byte budget ===")
# plan() picks one (degree, dtype) candidate per function from its verified
# Pareto menu: degree-2+ cells shrink ENTRIES (the remainder bound scales as
# h^(d+1)), narrow codes shrink BYTES — one search subsumes both passes.
from repro.core import get_function, plan

PNAMES = ("gelu", "tanh", "exp_neg", "sigmoid_sym")
free = plan(PNAMES, QEA)                      # cheapest per function
tight = plan(PNAMES, QEA, budget_bytes=2048)  # greedy downgrade until it fits
for label, p in (("auto  ", free), ("2048 B", tight)):
    picks = ", ".join(f"{c.name}=d{c.degree}/{c.dtype}" for c in p.chosen)
    print(f"plan[{label}]: {p.total_entries} entries, {p.total_bytes} B "
          f"(vmem {p.vmem().padded_bytes} B) -- {picks}")
for m in free.members:  # every member still meets the paper's Ea contract
    err = m.max_error_on_grid(n=4001)
    assert err <= QEA * (1 + 1e-6)
name = free.chosen[0].name
print(f"measured max |{name} - member| = "
      f"{free.members[0].max_error_on_grid(n=4001):.2e} <= Ea = {QEA}")

# The runtime artifact: one pack mixing degrees/widths, served by the fused
# Horner kernel through the same one-knob config (budget included; the
# default pack carries 6 functions, so its floor is higher than PNAMES')
pcfg = AC(mode="poly_pack", e_a=QEA, pack_budget=4096)
ppack = pcfg.poly_pack()
xs = jnp.linspace(-4, 4, 2049, dtype=jnp.float32)[:-1]
perr = float(jnp.max(jnp.abs(
    pcfg.unary("gelu")(xs)
    - jnp.asarray(get_function("gelu").f(np.asarray(xs, np.float64))))))
print(f"poly_pack(budget=4096 B): {ppack.footprint_bytes} B stored, "
      f"gelu kernel max err = {perr:.2e} <= ~Ea")
print("\nquickstart OK")
