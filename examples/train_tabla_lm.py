"""End-to-end driver: train a reduced LM whose every nonlinearity runs through
the paper's interval-split function tables, for a few hundred steps, with
checkpointing — then show the exact-vs-table ablation.

This is the 100M-class training example scaled to the CPU container (a ~9M-param
stablelm-family model; pass --steps/--dim to scale up on real hardware).

Run:  PYTHONPATH=src python examples/train_tabla_lm.py --steps 120
"""

import argparse
import time

from repro.approx import ApproxConfig
from repro.models import ShapeSpec, build_model, get_config
from repro.optim import adamw
from repro.train.loop import TrainConfig, run


def small_cfg(arch="stablelm-3b", dim=192, layers=4, mode="table_ref"):
    cfg = get_config(arch)
    return cfg.replace(
        n_layers=layers, d_model=dim, n_heads=4, n_kv_heads=4, d_ff=dim * 3,
        vocab=2048, remat=False,
        approx=ApproxConfig(mode=mode, e_a=1e-4, algorithm="hierarchical",
                            omega=0.2),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--dim", type=int, default=192)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ablate-exact", action="store_true",
                    help="also train the exact-activation twin for comparison")
    ap.add_argument("--ckpt-dir", default="/tmp/tabla_lm_ckpt")
    args = ap.parse_args()

    shape = ShapeSpec("example", seq_len=args.seq, global_batch=args.batch,
                      kind="train")

    results = {}
    modes = ["table_ref"] + (["exact"] if args.ablate_exact else [])
    for mode in modes:
        cfg = small_cfg(dim=args.dim, layers=args.layers, mode=mode)
        model = build_model(cfg)
        n_params = cfg.param_count()
        tc = TrainConfig(
            steps=args.steps, ckpt_every=max(20, args.steps // 3),
            ckpt_dir=f"{args.ckpt_dir}_{mode}", log_every=20,
            opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=10,
                                  total_steps=args.steps),
        )
        print(f"--- mode={mode}: {n_params / 1e6:.1f}M params, "
              f"{args.steps} steps ---")
        t0 = time.time()
        out = run(model, shape, tc, mesh=None)
        dt = time.time() - t0
        first = sum(out["losses"][:10]) / 10
        last = sum(out["losses"][-10:]) / 10
        results[mode] = (first, last)
        print(f"mode={mode}: loss {first:.4f} -> {last:.4f} "
              f"({dt / args.steps * 1e3:.0f} ms/step, "
              f"ckpt at {tc.ckpt_dir})")

    if "exact" in results:
        t = results["table_ref"][1]
        e = results["exact"][1]
        print(f"\nfinal loss — table backend: {t:.4f} vs exact: {e:.4f} "
              f"(delta {t - e:+.4f}; the paper's Ea bound keeps them close)")
    print("train_tabla_lm OK")


if __name__ == "__main__":
    main()
