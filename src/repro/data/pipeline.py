"""Deterministic, counter-addressed synthetic data pipeline.

Every batch is a pure function of (seed, step) via Philox counter streams, so:
  * restart-after-failure resumes the exact token stream with NO replay state,
  * every data-parallel host slices its shard deterministically,
  * elastic re-sharding (different host count after restore) re-slices the same
    global batch.

This is the substrate the paper's technique trains over; a real deployment swaps
``SyntheticLM`` for a tokenized corpus reader with the same ``batch_at(step)``
contract (the checkpoint stores only ``step``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # modality stubs
    enc_len: int = 0
    d_frames: int = 0
    n_vis_tokens: int = 0
    d_vis: int = 0


class SyntheticLM:
    """Markov-ish synthetic token stream (learnable structure, not uniform noise):
    tokens follow t_{i+1} = (a * t_i + b_i) mod V with per-sequence a and Philox
    noise b — next-token prediction has non-trivial but learnable statistics."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=self.cfg.seed, counter=np.uint64(step)))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = self._rng(step)
        B, S, V = c.global_batch, c.seq_len, c.vocab
        a = rng.integers(1, 8, size=(B, 1), dtype=np.int64)
        noise = rng.integers(0, 3, size=(B, S), dtype=np.int64)
        t0 = rng.integers(0, V, size=(B, 1), dtype=np.int64)
        toks = np.empty((B, S + 1), dtype=np.int64)
        toks[:, :1] = t0
        for i in range(S):
            toks[:, i + 1] = (a[:, 0] * toks[:, i] + noise[:, i]) % V
        batch = {
            "tokens": toks[:, :S].astype(np.int32),
            "targets": toks[:, 1 : S + 1].astype(np.int32),
        }
        if c.enc_len:
            batch["frames"] = rng.normal(
                0, 1, size=(B, c.enc_len, c.d_frames)).astype(np.float32)
        if c.n_vis_tokens:
            batch["patches"] = rng.normal(
                0, 1, size=(B, c.n_vis_tokens, c.d_vis)).astype(np.float32)
        return batch

    def host_shard(self, batch: Dict[str, np.ndarray], host_id: int,
                   n_hosts: int) -> Dict[str, np.ndarray]:
        B = batch["tokens"].shape[0]
        per = B // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in batch.items()}


def data_config_for(arch_cfg, shape) -> DataConfig:
    return DataConfig(
        vocab=arch_cfg.vocab,
        global_batch=shape.global_batch,
        seq_len=shape.seq_len,
        enc_len=arch_cfg.enc_len,
        d_frames=arch_cfg.d_model if arch_cfg.family == "encdec" else 0,
        n_vis_tokens=arch_cfg.n_vis_tokens,
        d_vis=arch_cfg.d_vis,
    )
