"""repro.data subpackage."""
