"""repro — interval-split table-based function approximation (Pradhan et al. 2022),
built out as a multi-pod JAX training/serving framework for TPU."""

__version__ = "1.0.0"
