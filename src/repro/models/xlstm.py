"""xLSTM blocks: mLSTM (matrix memory, exp input gating) and sLSTM (scalar memory,
recurrent gates) — arXiv:2405.04517, adapted for chunk-parallel TPU execution.

mLSTM recurrence per (batch, head), state C in R^{DxD}, normalizer n in R^D,
stabilizer m (scalar):

    m_t = max(logsig(f~_t) + m_{t-1}, i~_t)
    C_t = exp(logsig(f~)+m_{t-1}-m_t) C_{t-1} + exp(i~_t - m_t) v_t k_t^T
    n_t = (same decays) n + exp(i~ - m) k
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

The chunkwise closed form tracks per-position running maxima inside each chunk and
rescales the carry — all exp() arguments are <= 0 so the paper's ``exp_neg`` table
backend applies directly (the exp-gating IS the xLSTM hot spot; see DESIGN.md §5).

sLSTM keeps true recurrent gates (R h_{t-1}) and is inherently sequential: a
lax.scan over time with block-diagonal-per-head recurrent weights.  Decode is the
same scan with S=1.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .common import Params, init_linear, linear, rmsnorm


class MLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, D, D) stabilized matrix memory
    n: jax.Array  # (B, H, D) stabilized normalizer
    m: jax.Array  # (B, H) stabilizer (log scale)


class SLSTMCache(NamedTuple):
    h: jax.Array  # (B, d)
    c: jax.Array  # (B, d)
    n: jax.Array  # (B, d)
    m: jax.Array  # (B, d)


def init_mlstm(key, d_model: int, n_heads: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    return {
        "wq": init_linear(ks[0], d_model, d_model, dtype=dtype),
        "wk": init_linear(ks[1], d_model, d_model, dtype=dtype),
        "wv": init_linear(ks[2], d_model, d_model, dtype=dtype),
        "wi": init_linear(ks[3], d_model, n_heads, dtype=dtype),  # input gate (exp)
        "wf": init_linear(ks[4], d_model, n_heads, dtype=dtype),  # forget gate
        "wog": init_linear(ks[5], d_model, d_model, dtype=dtype),  # output gate
        "norm": {"g": jnp.ones((d_model,), dtype)},
        "wo": init_linear(ks[6], d_model, d_model, dtype=dtype),
        "f_bias": 3.0 * jnp.ones((n_heads,), jnp.float32),  # forget-open init
    }


def _logsigmoid(x, act_sigmoid):
    # log sigmoid(x) = -softplus(-x); keep it in terms of the table backend's sigmoid
    return -jax.nn.softplus(-x)


def mlstm_block(
    p: Params,
    x: jax.Array,  # (B, S, d)
    *,
    n_heads: int,
    act_sigmoid: Callable,
    act_exp: Callable,  # exp over (-inf, 0] — the exp_neg table
    cache: MLSTMCache | None = None,
    chunk: int = 128,
):
    B, S, d = x.shape
    H = n_heads
    D = d // H

    def split_heads(t):  # (B,S,d) -> (B,H,S,D)
        return jnp.moveaxis(t.reshape(B, S, H, D), 2, 1)

    q = split_heads(linear(p["wq"], x)).astype(jnp.float32) * (D ** -0.5)
    k = split_heads(linear(p["wk"], x)).astype(jnp.float32) * (D ** -0.5)
    v = split_heads(linear(p["wv"], x)).astype(jnp.float32)
    it = jnp.moveaxis(linear(p["wi"], x), 2, 1).astype(jnp.float32)  # (B,H,S) i~
    ft = jnp.moveaxis(linear(p["wf"], x), 2, 1).astype(jnp.float32) + p["f_bias"][None, :, None]
    logf = _logsigmoid(ft, act_sigmoid)  # (B,H,S) <= 0

    if cache is None:
        c0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        c0, n0, m0 = cache.c.astype(jnp.float32), cache.n.astype(jnp.float32), cache.m

    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        it = jnp.pad(it, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, 0), (0, pad)))
    Sp = S + pad
    nch = Sp // L

    def resh(t, feat):  # (B,H,Sp,*) -> chunk-major (nch, B, H, L, *)
        t = t.reshape(B, H, nch, L, *((feat,) if feat else ()))
        return jnp.moveaxis(t, 2, 0)

    def step(carry, xs):
        c, n, m = carry
        qc, kc, vc, ic, fc = xs
        cl = jnp.cumsum(fc, axis=-1)  # (B,H,L) cumulative log forget
        # log-weight of source j at target i: cl_i - cl_j + i~_j  (j <= i)
        src = ic - cl  # (B,H,L) at j
        # per-position running stabilizer: m_i = max(m_prev + cl_i, max_{j<=i} cl_i + src_j)
        run_src = jax.lax.cummax(src, axis=2)
        m_i = jnp.maximum(m[..., None] + cl, cl + run_src)  # (B,H,L)
        # carry term
        carry_w = act_exp(jnp.minimum(m[..., None] + cl - m_i, 0.0))
        y_carry = carry_w[..., None] * jnp.einsum("bhde,bhle->bhld", c, qc)
        nq_carry = carry_w * jnp.einsum("bhd,bhld->bhl", n, qc)
        # intra term: W_ij = cl_i - cl_j + i~_j - m_i
        gap = cl[..., :, None] - cl[..., None, :] + ic[..., None, :]
        w_ij = gap - m_i[..., None]
        mask = jnp.tril(jnp.ones((L, L), bool))
        pw = jnp.where(mask, act_exp(jnp.minimum(w_ij, 0.0)), 0.0)
        g = jnp.einsum("bhld,bhmd->bhlm", qc, kc)  # q_i . k_j
        y_intra = jnp.einsum("bhlm,bhmd->bhld", pw * g, vc)
        nq_intra = jnp.einsum("bhlm,bhlm->bhl", pw, g)
        h_num = y_carry + y_intra
        nq = nq_carry + nq_intra
        denom = jnp.maximum(jnp.abs(nq), act_exp(jnp.minimum(-m_i, 0.0)))
        h = h_num / jnp.maximum(denom, 1e-30)[..., None]
        # new carry at chunk end
        m_new = jnp.maximum(m + cl[..., -1], cl[..., -1] + run_src[..., -1])
        cw = act_exp(jnp.minimum(m + cl[..., -1] - m_new, 0.0))
        dj = act_exp(jnp.minimum(cl[..., -1:] - cl + ic - m_new[..., None], 0.0))
        c_new = cw[..., None, None] * c + jnp.einsum("bhm,bhmd,bhme->bhde", dj, vc, kc)
        n_new = cw[..., None] * n + jnp.einsum("bhm,bhmd->bhd", dj, kc)
        return (c_new, n_new, m_new), h

    (cF, nF, mF), hs = jax.lax.scan(
        step, (c0, n0, m0),
        (resh(q, D), resh(k, D), resh(v, D), resh(it, 0), resh(logf, 0)),
    )
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, Sp, D)[:, :, :S]
    h = jnp.moveaxis(h, 1, 2).reshape(B, S, d).astype(x.dtype)
    og = act_sigmoid(linear(p["wog"], x))
    h = rmsnorm(p["norm"], h) * og
    return linear(p["wo"], h), MLSTMCache(cF, nF, mF)


def init_slstm(key, d_model: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 9)
    mk = lambda i: init_linear(ks[i], d_model, d_model, dtype=dtype)
    return {
        "wz": mk(0), "wi": mk(1), "wf": mk(2), "wo": mk(3),
        "rz": mk(4), "ri": mk(5), "rf": mk(6), "ro": mk(7),
        "f_bias": 3.0 * jnp.ones((d_model,), jnp.float32),
        "norm": {"g": jnp.ones((d_model,), dtype)},
        "wd": init_linear(ks[8], d_model, d_model, dtype=dtype),
    }


def slstm_block(
    p: Params,
    x: jax.Array,  # (B, S, d)
    *,
    act_sigmoid: Callable,
    act_tanh: Callable,
    act_exp: Callable,
    cache: SLSTMCache | None = None,
):
    B, S, d = x.shape
    if cache is None:
        cache = init_slstm_cache(B, d)
    zx = linear(p["wz"], x).astype(jnp.float32)
    ix = linear(p["wi"], x).astype(jnp.float32)
    fx = linear(p["wf"], x).astype(jnp.float32) + p["f_bias"]
    ox = linear(p["wo"], x).astype(jnp.float32)

    rz, ri, rf, ro = (p[k]["w"].astype(jnp.float32) for k in ("rz", "ri", "rf", "ro"))

    def step(carry, xs):
        h, c, n, m = carry
        zx_, ix_, fx_, ox_ = xs  # (B, d)
        zt = act_tanh(zx_ + h @ rz)
        i_t = ix_ + h @ ri
        f_t = fx_ + h @ rf
        logf = -jax.nn.softplus(-f_t)  # log sigmoid
        m_new = jnp.maximum(logf + m, i_t)
        ip = act_exp(jnp.minimum(i_t - m_new, 0.0))
        fp = act_exp(jnp.minimum(logf + m - m_new, 0.0))
        c_new = fp * c + ip * zt
        n_new = fp * n + ip
        h_tilde = c_new / jnp.maximum(n_new, 1e-6)
        o = act_sigmoid(ox_ + h @ ro)
        h_new = o * h_tilde
        return (h_new, c_new, n_new, m_new), h_new

    (hF, cF, nF, mF), hs = jax.lax.scan(
        step, (cache.h.astype(jnp.float32), cache.c.astype(jnp.float32),
               cache.n.astype(jnp.float32), cache.m.astype(jnp.float32)),
        (jnp.moveaxis(zx, 1, 0), jnp.moveaxis(ix, 1, 0),
         jnp.moveaxis(fx, 1, 0), jnp.moveaxis(ox, 1, 0)),
    )
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B, S, d)
    out = linear(p["wd"], rmsnorm(p["norm"], h))
    return out, SLSTMCache(hF, cF, nF, mF)


def init_mlstm_cache(batch: int, d_model: int, n_heads: int) -> MLSTMCache:
    D = d_model // n_heads
    return MLSTMCache(
        c=jnp.zeros((batch, n_heads, D, D), jnp.float32),
        n=jnp.zeros((batch, n_heads, D), jnp.float32),
        m=jnp.full((batch, n_heads), -1e30, jnp.float32),
    )


def init_slstm_cache(batch: int, d_model: int) -> SLSTMCache:
    z = jnp.zeros((batch, d_model), jnp.float32)
    return SLSTMCache(h=z, c=z, n=z, m=jnp.full((batch, d_model), -1e30, jnp.float32))
