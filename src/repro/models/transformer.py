"""Model assembly: every assigned architecture family behind one API.

    model = build_model(cfg)                      # repro.models.registry
    params = model.init(key)
    logits, aux = model.train_logits(params, batch)
    loss = model.loss(params, batch)
    cache = model.init_cache(batch_size, cache_len)
    logits, cache = model.prefill(params, batch, cache)
    logits, cache = model.decode_step(params, tok, pos, cache)

Families:
  DecoderLM   — dense / MoE / local:global-pattern GQA transformers
  HybridLM    — Mamba2 stack with a shared attention+MLP block every k layers
  XLSTMLM     — alternating mLSTM / sLSTM blocks
  EncDecLM    — Whisper-style encoder-decoder (conv frontend stubbed to embeddings)
  VLM         — vision-prefix (stub patch embeddings) + DecoderLM backbone

Layer stacks are scanned (stacked params, jax.lax.scan) so HLO size is O(1) in
depth; per-layer heterogeneity (gemma3 5:1 local:global, zamba2 shared block) is a
scan over *groups* with the intra-group pattern unrolled.  All nonlinearities route
through ``cfg.approx`` (the paper's table backend).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard_activation as shard

from .attention import (
    attention_out,
    cache_insert,
    flash_attention,
    init_attention,
    project_qkv,
)
from .common import (
    embed,
    init_embedding,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
    sinusoidal_positions,
    softcap,
    unembed,
)
from .config import MOE, ArchConfig
from .mlp import glu, init_glu, init_mlp, init_moe, mlp, moe
from .ssm import init_mamba2, init_ssm_cache, mamba2_block
from .xlstm import (
    init_mlstm,
    init_mlstm_cache,
    init_slstm,
    init_slstm_cache,
    mlstm_block,
    slstm_block,
)

Params = Dict[str, Any]
Cache = Dict[str, Any]

AUX_WEIGHT = 0.01  # MoE load-balance loss weight
LOCAL_WINDOW = 1024  # sliding window of 'local' layers in a local:global pattern


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _decode_positions(pos, pos_buf, W: int):
    """Normalize a decode position operand against a per-slot (B, W) buffer.

    ``pos`` is either a () scalar (shared clock: every slot writes the same
    ring index) or a (B,) vector (continuous batching: each slot runs its own
    absolute clock).  Returns ``(positions, pos_buf)`` where positions is
    (1,) or (B, 1) — both broadcast through RoPE/flash — and pos_buf has this
    step's entries marked valid."""
    pos32 = pos.astype(jnp.int32)
    if pos.ndim == 0:
        return pos32[None], pos_buf.at[:, pos32 % W].set(pos32)
    b = jnp.arange(pos.shape[0])
    return pos32[:, None], pos_buf.at[b, pos32 % W].set(pos32)


def _slice_layer(stacked, i):
    return jax.tree.map(lambda t: t[i], stacked)


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean CE over targets >= 0 (-1 = ignore).  logits f32 (B, S, V).

    The gold logit is extracted with a fused one-hot reduction instead of
    take_along_axis: a vocab-dim gather over 'model'-sharded logits lowers to a
    full logits all-gather (measured 13.6 GB/device on whisper train_4k), while
    the masked reduction keeps the vocab dim sharded end-to-end."""
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
              == tgt[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


class BaseLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.compute_dtype)
        self.act = cfg.approx.unary(cfg.act)
        # Route the final-logit softcap tanh through the approx backend too.
        # The backend odd-extends every table-mode tanh to the full symmetric
        # domain (the table spans the paper's [-8, 0) only), and returns
        # jnp.tanh in exact mode — one uniform path for gates and softcap.
        self._cap_tanh = None
        if cfg.attn.logit_softcap > 0:
            self._cap_tanh = cfg.approx.unary("tanh")
        # Rotary trig through the pack's folded sin/cos when rope_table is on
        # (None = exact jnp rotations); every layer shares the cached pair.
        self.rope_sin_cos = cfg.approx.rope_sin_cos()
        # TableFlash: flash attention's softmax exponent through the pack's
        # exp_neg member when attn_table is on (None = exact jnp.exp); every
        # attention layer shares the cached closure.
        self.attn_exp = cfg.approx.attn_exp()

    def loss(self, params, batch):
        logits, aux = self.train_logits(params, batch)
        return cross_entropy(logits, batch["targets"]) + AUX_WEIGHT * aux

    def _logits(self, params, x):
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params.get("unembed", params["embed"]), x)
        logits = softcap(logits, self.cfg.attn.logit_softcap, self._cap_tanh)
        if self.cfg.vocab_pad != self.cfg.vocab:  # mask padded vocab rows
            pad_mask = (jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, logits.ndim - 1) < self.cfg.vocab)
            logits = jnp.where(pad_mask, logits, -1e30)
        return shard(logits, "batch", None, "vocab")

    def abstract_params(self):
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(0))

    def abstract_cache(self, batch: int, cache_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, cache_len))


# ======================================================================================
# DecoderLM — dense / MoE / local:global GQA transformer
# ======================================================================================


class DecoderLM(BaseLM):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        self.period = max(1, cfg.attn.global_every)
        if cfg.n_layers % self.period:
            raise ValueError("n_layers must be divisible by the local:global period")
        self.n_groups = cfg.n_layers // self.period

    # ------------------------------- init ----------------------------------------

    def _init_layer(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": init_rmsnorm(cfg.d_model),
            "attn": init_attention(k1, cfg.d_model, cfg.attn_geom,
                                   qk_norm=cfg.attn.qk_norm),
            "ln2": init_rmsnorm(cfg.d_model),
        }
        if cfg.family == MOE:
            p["moe"] = init_moe(k2, cfg.d_model, cfg.d_ff, cfg.moe.n_experts,
                                cfg.moe.n_shared)
        elif cfg.mlp_kind == "glu":
            p["mlp"] = init_glu(k2, cfg.d_model, cfg.d_ff)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
        return p

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, kl, kg, ku = jax.random.split(key, 4)
        params: Params = {
            "embed": init_embedding(ke, cfg.vocab_pad, cfg.d_model),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if self.period == 1:
            params["layers"] = _stack_init(self._init_layer, kl, cfg.n_layers)
        else:
            loc = _stack_init(self._init_layer, kl, self.n_groups * (self.period - 1))
            params["layers_loc"] = jax.tree.map(
                lambda t: t.reshape(self.n_groups, self.period - 1, *t.shape[1:]), loc)
            params["layers_glob"] = _stack_init(self._init_layer, kg, self.n_groups)
        if not cfg.tie_embeddings:
            params["unembed"] = init_embedding(ku, cfg.vocab_pad, cfg.d_model)
        return params

    # ------------------------------ block ------------------------------------------

    def _ffn(self, lp, x):
        cfg = self.cfg
        hin = rmsnorm(lp["ln2"], x)
        if cfg.family == MOE:
            ff, aux = moe(lp["moe"], hin, self.act, top_k=cfg.moe.top_k,
                          capacity_factor=cfg.moe.capacity_factor,
                          device_groups=cfg.moe.device_groups,
                          max_groups=cfg.moe.max_groups)
        elif cfg.mlp_kind == "glu":
            ff, aux = glu(lp["mlp"], hin, self.act), jnp.zeros((), jnp.float32)
        else:
            ff, aux = mlp(lp["mlp"], hin, self.act), jnp.zeros((), jnp.float32)
        return x + shard(ff, "batch", None, None), aux

    def _self_block(self, lp, x, positions, window):
        """Train/prefill block: attend within x.  Returns (x, (k, v), aux)."""
        cfg = self.cfg
        q, k, v = project_qkv(lp["attn"], rmsnorm(lp["ln1"], x), positions,
                              geom=cfg.attn_geom, rope_theta=cfg.attn.rope_theta,
                              rope_sin_cos=self.rope_sin_cos)
        o = flash_attention(q, k, v, positions, positions, causal=True, window=window,
                            exp_fn=self.attn_exp)
        x = x + shard(attention_out(lp["attn"], o, cfg.attn_geom), "batch", None, None)
        x, aux = self._ffn(lp, x)
        return x, (k, v), aux

    def _decode_block(self, lp, x, positions, window, kb, vb, pb_new):
        """Decode block: project 1 token, insert, attend over buffer."""
        cfg = self.cfg
        q, k, v = project_qkv(lp["attn"], rmsnorm(lp["ln1"], x), positions,
                              geom=cfg.attn_geom, rope_theta=cfg.attn.rope_theta,
                              rope_sin_cos=self.rope_sin_cos)
        kb, vb, _ = cache_insert(kb, vb, pb_new, k, v, positions)
        o = flash_attention(q, kb, vb, positions, pb_new, causal=True, window=window,
                            exp_fn=self.attn_exp)
        x = x + shard(attention_out(lp["attn"], o, cfg.attn_geom), "batch", None, None)
        x, _ = self._ffn(lp, x)
        return x, kb, vb

    def _window_of(self, idx_in_period):
        if self.period == 1:
            return self.cfg.attn.window
        return LOCAL_WINDOW if idx_in_period < self.period - 1 else 0

    # ------------------------------- train -----------------------------------------

    def train_logits(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = shard(embed(params["embed"], tokens, self.dtype), "batch", None, None)
        positions = jnp.arange(S)
        aux0 = jnp.zeros((), jnp.float32)

        if self.period == 1:
            def body(carry, lp):
                x, aux = carry
                x, _, a = self._self_block(lp, x, positions, cfg.attn.window)
                return (x, aux + a), None
            body = jax.checkpoint(body) if cfg.remat else body
            (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
        else:
            def gbody(carry, lps):
                x, aux = carry
                loc, glob = lps
                for i in range(self.period - 1):
                    x, _, a = self._self_block(_slice_layer(loc, i), x, positions,
                                               LOCAL_WINDOW)
                    aux = aux + a
                x, _, a = self._self_block(glob, x, positions, 0)
                return (x, aux + a), None
            gbody = jax.checkpoint(gbody) if cfg.remat else gbody
            (x, aux), _ = jax.lax.scan(
                gbody, (x, aux0), (params["layers_loc"], params["layers_glob"]))

        return self._logits(params, x), aux / cfg.n_layers

    # ------------------------------- cache ------------------------------------------

    def init_cache(self, batch: int, cache_len: int) -> Cache:
        # Position buffers are per-slot (B, W): every batch slot carries its
        # own validity/clock row, so a freed slot can be refilled mid-stream
        # (ContinuousEngine) without corrupting its neighbours' masks.
        cfg = self.cfg
        G, D = cfg.attn_geom.g_eff, cfg.head_dim
        mk = lambda *s: jnp.zeros(s, jnp.bfloat16)
        if self.period == 1:
            W = cache_len if cfg.attn.window == 0 else min(cfg.attn.window, cache_len)
            return {"k": mk(cfg.n_layers, batch, W, G, D),
                    "v": mk(cfg.n_layers, batch, W, G, D),
                    "pos": jnp.full((batch, W), -1, jnp.int32)}
        Wl = min(LOCAL_WINDOW, cache_len)
        return {
            "loc_k": mk(self.n_groups, self.period - 1, batch, Wl, G, D),
            "loc_v": mk(self.n_groups, self.period - 1, batch, Wl, G, D),
            "loc_pos": jnp.full((batch, Wl), -1, jnp.int32),
            "glob_k": mk(self.n_groups, batch, cache_len, G, D),
            "glob_v": mk(self.n_groups, batch, cache_len, G, D),
            "glob_pos": jnp.full((batch, cache_len), -1, jnp.int32),
        }

    @staticmethod
    def _ring_window(k_new, v_new, positions, W):
        S = k_new.shape[1]
        if S >= W:  # only the last W tokens can survive a ring overwrite
            return k_new[:, -W:], v_new[:, -W:], positions[-W:]
        return k_new, v_new, positions

    # --------------------------- prefill / decode ------------------------------------

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = shard(embed(params["embed"], tokens, self.dtype), "batch", None, None)
        positions = jnp.arange(S)

        if self.period == 1:
            W = cache["k"].shape[2]

            def body(x, xs):
                lp, kb, vb = xs
                x, (k, v), _ = self._self_block(lp, x, positions, cfg.attn.window)
                kn, vn, pn = self._ring_window(k, v, positions, W)
                kb, vb, pb = cache_insert(kb, vb, cache["pos"], kn, vn, pn)
                return x, (kb, vb, pb)

            x, (ks, vs, pbs) = jax.lax.scan(body, x,
                                            (params["layers"], cache["k"], cache["v"]))
            new_cache = {"k": ks, "v": vs, "pos": pbs[0]}
        else:
            Wl = cache["loc_k"].shape[3]
            Wg = cache["glob_k"].shape[2]

            def gbody(x, xs):
                (loc, glob), lkb, lvb, gkb, gvb = xs
                lks, lvs = [], []
                lpb = cache["loc_pos"]
                for i in range(self.period - 1):
                    x, (k, v), _ = self._self_block(_slice_layer(loc, i), x,
                                                    positions, LOCAL_WINDOW)
                    kn, vn, pn = self._ring_window(k, v, positions, Wl)
                    kb, vb, lpb = cache_insert(lkb[i], lvb[i], cache["loc_pos"],
                                               kn, vn, pn)
                    lks.append(kb)
                    lvs.append(vb)
                x, (k, v), _ = self._self_block(glob, x, positions, 0)
                kn, vn, pn = self._ring_window(k, v, positions, Wg)
                gkb, gvb, gpb = cache_insert(gkb, gvb, cache["glob_pos"], kn, vn, pn)
                return x, (jnp.stack(lks), jnp.stack(lvs), lpb, gkb, gvb, gpb)

            x, (lks, lvs, lpb, gks, gvs, gpb) = jax.lax.scan(
                gbody, x,
                ((params["layers_loc"], params["layers_glob"]),
                 cache["loc_k"], cache["loc_v"], cache["glob_k"], cache["glob_v"]))
            new_cache = {"loc_k": lks, "loc_v": lvs, "loc_pos": lpb[0],
                         "glob_k": gks, "glob_v": gvs, "glob_pos": gpb[0]}

        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, new_cache

    def prefill_chunked(self, params, batch, cache, chunk: int = 4096):
        """Deployment prefill for long prompts: feed ``chunk`` tokens at a time
        through the decode path (insert the chunk's k/v, attend to cache+self),
        so peak activation memory is O(chunk) instead of O(S).  Equivalent to
        ``prefill`` (tests/test_archs.py); the per-chunk step is one compiled
        program reused across chunks."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        if self.period != 1:
            raise NotImplementedError("chunked prefill: single-period stacks only")
        logits = None
        step = jax.jit(self._prefill_chunk_step)
        for start in range(0, S, chunk):
            tok_c = tokens[:, start : start + chunk]
            pos_c = jnp.arange(start, start + tok_c.shape[1])
            logits, cache = step(params, tok_c, pos_c, cache)
        return logits, cache

    def _prefill_chunk_step(self, params, tok_c, positions, cache):
        cfg = self.cfg
        x = shard(embed(params["embed"], tok_c, self.dtype), "batch", None, None)
        W = cache["k"].shape[2]
        pb = cache["pos"].at[:, positions % W].set(positions.astype(jnp.int32))

        def body(x, xs):
            lp, kb, vb = xs
            x, kb, vb = self._decode_block(lp, x, positions, cfg.attn.window,
                                           kb, vb, pb)
            return x, (kb, vb)

        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["layers"], cache["k"], cache["v"]))
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, {"k": ks, "v": vs, "pos": pb}

    def decode_step(self, params, tok, pos, cache):
        """tok: (B, 1) int32; pos: () int32 shared absolute position, or (B,)
        int32 per-slot positions (continuous batching)."""
        cfg = self.cfg
        x = shard(embed(params["embed"], tok, self.dtype), "batch", None, None)

        if self.period == 1:
            W = cache["k"].shape[2]
            positions, pb = _decode_positions(pos, cache["pos"], W)

            def body(x, xs):
                lp, kb, vb = xs
                x, kb, vb = self._decode_block(lp, x, positions, cfg.attn.window,
                                               kb, vb, pb)
                return x, (kb, vb)

            x, (ks, vs) = jax.lax.scan(body, x,
                                       (params["layers"], cache["k"], cache["v"]))
            new_cache = {"k": ks, "v": vs, "pos": pb}
        else:
            Wl = cache["loc_k"].shape[3]
            Wg = cache["glob_k"].shape[2]
            positions, lpb = _decode_positions(pos, cache["loc_pos"], Wl)
            _, gpb = _decode_positions(pos, cache["glob_pos"], Wg)

            def gbody(x, xs):
                (loc, glob), lkb, lvb, gkb, gvb = xs
                lks, lvs = [], []
                for i in range(self.period - 1):
                    x, kb, vb = self._decode_block(_slice_layer(loc, i), x, positions,
                                                   LOCAL_WINDOW, lkb[i], lvb[i], lpb)
                    lks.append(kb)
                    lvs.append(vb)
                x, gkb, gvb = self._decode_block(glob, x, positions, 0, gkb, gvb, gpb)
                return x, (jnp.stack(lks), jnp.stack(lvs), gkb, gvb)

            x, (lks, lvs, gks, gvs) = jax.lax.scan(
                gbody, x,
                ((params["layers_loc"], params["layers_glob"]),
                 cache["loc_k"], cache["loc_v"], cache["glob_k"], cache["glob_v"]))
            new_cache = {"loc_k": lks, "loc_v": lvs, "loc_pos": lpb,
                         "glob_k": gks, "glob_v": gvs, "glob_pos": gpb}

        logits = self._logits(params, x)[:, 0]
        return logits, new_cache


# ======================================================================================
# HybridLM — Mamba2 + shared attention block (zamba2)
# ======================================================================================


class HybridLM(BaseLM):
    """`shared_attn_every` Mamba2 layers per group, then ONE shared (weight-tied)
    attention+MLP block; trailing Mamba2 layers absorb the remainder."""

    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        self.act_softplus = cfg.approx.unary("softplus")
        k = cfg.shared_attn_every or cfg.n_layers
        self.n_groups = cfg.n_layers // k
        self.per_group = k
        self.trailing = cfg.n_layers - self.n_groups * k
        s = cfg.ssm
        self.inner = s.expand * cfg.d_model

    def _init_mamba(self, key):
        s = self.cfg.ssm
        return {"ln": init_rmsnorm(self.cfg.d_model),
                "m": init_mamba2(key, self.cfg.d_model, expand=s.expand,
                                 head_dim=s.head_dim, state_dim=s.state_dim,
                                 conv_width=s.conv_width)}

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, km, kt, ks, ku = jax.random.split(key, 5)
        grouped = _stack_init(self._init_mamba, km, self.n_groups * self.per_group)
        params = {
            "embed": init_embedding(ke, cfg.vocab_pad, cfg.d_model),
            "mamba": jax.tree.map(
                lambda t: t.reshape(self.n_groups, self.per_group, *t.shape[1:]),
                grouped),
            "shared": {
                "ln1": init_rmsnorm(cfg.d_model),
                "attn": init_attention(jax.random.fold_in(ks, 0), cfg.d_model,
                                       cfg.attn_geom),
                "ln2": init_rmsnorm(cfg.d_model),
                "mlp": init_glu(jax.random.fold_in(ks, 1), cfg.d_model, cfg.d_ff),
            },
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if self.trailing:
            params["mamba_tail"] = _stack_init(self._init_mamba, kt, self.trailing)
        if not cfg.tie_embeddings:
            params["unembed"] = init_embedding(ku, cfg.vocab_pad, cfg.d_model)
        return params

    def _mamba(self, lp, x, cache=None):
        s = self.cfg.ssm
        y, new_cache = mamba2_block(
            lp["m"], rmsnorm(lp["ln"], x), expand=s.expand, head_dim=s.head_dim,
            state_dim=s.state_dim, conv_width=s.conv_width, chunk=s.chunk,
            act_silu=self.act, act_softplus=self.act_softplus, cache=cache)
        return x + shard(y, "batch", None, None), new_cache

    def _shared(self, sp, x, positions, kb=None, vb=None, pb=None):
        cfg = self.cfg
        q, k, v = project_qkv(sp["attn"], rmsnorm(sp["ln1"], x), positions,
                              geom=cfg.attn_geom, rope_theta=cfg.attn.rope_theta,
                              rope_sin_cos=self.rope_sin_cos)
        if kb is None:  # train/prefill: attend within x
            o = flash_attention(q, k, v, positions, positions, causal=True,
                                window=cfg.attn.window, exp_fn=self.attn_exp)
            new = (k, v)
        else:  # decode: insert then attend over buffer
            kb, vb, _ = cache_insert(kb, vb, pb, k, v, positions)
            o = flash_attention(q, kb, vb, positions, pb, causal=True,
                                window=cfg.attn.window, exp_fn=self.attn_exp)
            new = (kb, vb)
        x = x + shard(attention_out(sp["attn"], o, cfg.attn_geom), "batch", None, None)
        x = x + shard(glu(sp["mlp"], rmsnorm(sp["ln2"], x), self.act),
                      "batch", None, None)
        return x, new

    def _forward(self, params, x, positions, caches, mode):
        """mode: 'train' | 'prefill' | 'decode'. caches None in train."""
        cfg = self.cfg
        remat = cfg.remat and mode == "train"

        def gbody(x, xs):
            mp = xs[0]
            mc = xs[1] if mode != "train" else None
            akv = xs[2] if mode != "train" else None
            new_mc = []
            for i in range(self.per_group):
                lp = _slice_layer(mp, i)
                c = _slice_layer(mc, i) if mc is not None else None
                x, nc = self._mamba(lp, x, c)
                new_mc.append(nc)
            if mode == "decode":
                kb, vb = akv
                x, (kb, vb) = self._shared(params["shared"], x, positions, kb, vb,
                                           caches["attn_pos"])
                new_akv = (kb, vb)
            else:
                x, (k, v) = self._shared(params["shared"], x, positions)
                if mode == "prefill":
                    kb, vb = akv
                    W = kb.shape[1]
                    kn, vn, pn = DecoderLM._ring_window(k, v, positions, W)
                    kb, vb, pb = cache_insert(kb, vb, caches["attn_pos"], kn, vn, pn)
                    new_akv = (kb, vb)
                else:
                    new_akv = None
            if mode == "train":
                return x, None
            return x, (jax.tree.map(lambda *t: jnp.stack(t), *new_mc), new_akv)

        if remat:
            gbody = jax.checkpoint(gbody)

        if mode == "train":
            xs = (params["mamba"],)
            x, _ = jax.lax.scan(lambda c, s: gbody(c, s + (None, None)), x,
                                xs)
        else:
            xs = (params["mamba"], caches["mamba"], (caches["attn_k"], caches["attn_v"]))
            x, ys = jax.lax.scan(gbody, x, xs)
            caches = dict(caches)
            caches["mamba"] = ys[0]
            caches["attn_k"], caches["attn_v"] = ys[1]

        # trailing mamba layers
        if self.trailing:
            if mode == "train":
                def tbody(x, lp):
                    x, _ = self._mamba(lp, x, None)
                    return x, None
                tbody = jax.checkpoint(tbody) if remat else tbody
                x, _ = jax.lax.scan(tbody, x, params["mamba_tail"])
            else:
                def tbody(x, xs):
                    lp, c = xs
                    x, nc = self._mamba(lp, x, c)
                    return x, nc
                x, tail_c = jax.lax.scan(tbody, x,
                                         (params["mamba_tail"], caches["mamba_tail"]))
                caches["mamba_tail"] = tail_c
        return x, caches

    def train_logits(self, params, batch):
        tokens = batch["tokens"]
        x = shard(embed(params["embed"], tokens, self.dtype), "batch", None, None)
        positions = jnp.arange(tokens.shape[1])
        x, _ = self._forward(params, x, positions, None, "train")
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def init_cache(self, batch: int, cache_len: int) -> Cache:
        cfg = self.cfg
        s = cfg.ssm
        W = cache_len if cfg.attn.window == 0 else min(cfg.attn.window, cache_len)
        c = {
            "mamba": jax.tree.map(
                lambda t: jnp.broadcast_to(
                    t, (self.n_groups, self.per_group) + t.shape).copy(),
                init_ssm_cache(batch, self.inner, s.state_dim, s.head_dim,
                               s.conv_width)),
            "attn_k": jnp.zeros((self.n_groups, batch, W, cfg.attn_geom.g_eff,
                                 cfg.head_dim), jnp.bfloat16),
            "attn_v": jnp.zeros((self.n_groups, batch, W, cfg.attn_geom.g_eff,
                                 cfg.head_dim), jnp.bfloat16),
            "attn_pos": jnp.full((batch, W), -1, jnp.int32),
        }
        if self.trailing:
            c["mamba_tail"] = jax.tree.map(
                lambda t: jnp.broadcast_to(t, (self.trailing,) + t.shape).copy(),
                init_ssm_cache(batch, self.inner, s.state_dim, s.head_dim,
                               s.conv_width))
        return c

    def prefill(self, params, batch, cache):
        tokens = batch["tokens"]
        x = shard(embed(params["embed"], tokens, self.dtype), "batch", None, None)
        positions = jnp.arange(tokens.shape[1])
        cache = dict(cache)
        x, cache = self._forward(params, x, positions, cache, "prefill")
        W = cache["attn_k"].shape[2]
        pn = positions[-W:] if tokens.shape[1] >= W else positions
        cache["attn_pos"] = cache["attn_pos"].at[:, pn % W].set(
            pn.astype(jnp.int32))
        return self._logits(params, x[:, -1:])[:, 0], cache

    def decode_step(self, params, tok, pos, cache):
        x = shard(embed(params["embed"], tok, self.dtype), "batch", None, None)
        cache = dict(cache)
        W = cache["attn_k"].shape[2]
        positions, pb = _decode_positions(pos, cache["attn_pos"], W)
        cache["attn_pos"] = pb
        x, cache = self._forward(params, x, positions, cache, "decode")
        return self._logits(params, x)[:, 0], cache


# ======================================================================================
# XLSTMLM — alternating mLSTM / sLSTM
# ======================================================================================


class XLSTMLM(BaseLM):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        if cfg.n_layers % 2:
            raise ValueError("xLSTM stack alternates mLSTM/sLSTM: need even layers")
        self.n_pairs = cfg.n_layers // 2
        self.act_sigmoid = cfg.approx.unary("sigmoid")
        self.act_tanh = cfg.approx.unary("tanh")
        self.act_exp = cfg.approx.unary("exp")  # exp_neg table domain

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, km, ks, ku = jax.random.split(key, 4)
        params = {
            "embed": init_embedding(ke, cfg.vocab_pad, cfg.d_model),
            "mlstm": _stack_init(
                lambda k: {"ln": init_rmsnorm(cfg.d_model),
                           "b": init_mlstm(k, cfg.d_model, cfg.n_heads)},
                km, self.n_pairs),
            "slstm": _stack_init(
                lambda k: {"ln": init_rmsnorm(cfg.d_model),
                           "b": init_slstm(k, cfg.d_model)},
                ks, self.n_pairs),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = init_embedding(ku, cfg.vocab_pad, cfg.d_model)
        return params

    def _pair(self, mp, sp, x, mcache, scache):
        y, new_m = mlstm_block(mp["b"], rmsnorm(mp["ln"], x),
                               n_heads=self.cfg.n_heads,
                               act_sigmoid=self.act_sigmoid, act_exp=self.act_exp,
                               cache=mcache)
        x = x + shard(y, "batch", None, None)
        y, new_s = slstm_block(sp["b"], rmsnorm(sp["ln"], x),
                               act_sigmoid=self.act_sigmoid, act_tanh=self.act_tanh,
                               act_exp=self.act_exp, cache=scache)
        x = x + shard(y, "batch", None, None)
        return x, new_m, new_s

    def _forward(self, params, x, caches, mode):
        remat = self.cfg.remat and mode == "train"

        def body(x, xs):
            mp, sp = xs[0], xs[1]
            mc = xs[2] if mode != "train" else None
            sc = xs[3] if mode != "train" else None
            x, nm, ns = self._pair(mp, sp, x, mc, sc)
            return x, (None if mode == "train" else (nm, ns))

        if remat:
            body = jax.checkpoint(body)
        if mode == "train":
            x, _ = jax.lax.scan(lambda c, s: body(c, s + (None, None)), x,
                                (params["mlstm"], params["slstm"]))
            return x, caches
        x, (nm, ns) = jax.lax.scan(
            body, x, (params["mlstm"], params["slstm"], caches["m"], caches["s"]))
        return x, {"m": nm, "s": ns}

    def train_logits(self, params, batch):
        x = shard(embed(params["embed"], batch["tokens"], self.dtype),
                  "batch", None, None)
        x, _ = self._forward(params, x, None, "train")
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def init_cache(self, batch: int, cache_len: int) -> Cache:
        cfg = self.cfg
        stack = lambda c: jax.tree.map(
            lambda t: jnp.broadcast_to(t, (self.n_pairs,) + t.shape).copy(), c)
        return {"m": stack(init_mlstm_cache(batch, cfg.d_model, cfg.n_heads)),
                "s": stack(init_slstm_cache(batch, cfg.d_model))}

    def prefill(self, params, batch, cache):
        x = shard(embed(params["embed"], batch["tokens"], self.dtype),
                  "batch", None, None)
        x, cache = self._forward(params, x, cache, "prefill")
        return self._logits(params, x[:, -1:])[:, 0], cache

    def decode_step(self, params, tok, pos, cache):
        x = shard(embed(params["embed"], tok, self.dtype), "batch", None, None)
        x, cache = self._forward(params, x, cache, "decode")
        return self._logits(params, x)[:, 0], cache


# ======================================================================================
# EncDecLM — whisper-small (stub conv frontend)
# ======================================================================================


class EncDecLM(BaseLM):
    """Encoder: bidirectional transformer over stub frame embeddings (B, T_enc, d).
    Decoder: causal self-attn (cached) + cross-attn into encoder memory."""

    def _init_enc_layer(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"ln1": init_rmsnorm(cfg.d_model),
                "attn": init_attention(k1, cfg.d_model, cfg.attn_geom),
                "ln2": init_rmsnorm(cfg.d_model),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff)}

    def _init_dec_layer(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {"ln1": init_rmsnorm(cfg.d_model),
                "self": init_attention(k1, cfg.d_model, cfg.attn_geom),
                "lnx": init_rmsnorm(cfg.d_model),
                "cross": init_attention(k2, cfg.d_model, cfg.attn_geom),
                "ln2": init_rmsnorm(cfg.d_model),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff)}

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, k1, k2, ku = jax.random.split(key, 4)
        return {
            "embed": init_embedding(ke, cfg.vocab_pad, cfg.d_model),
            "enc_layers": _stack_init(self._init_enc_layer, k1, cfg.n_enc_layers),
            "enc_norm": init_rmsnorm(cfg.d_model),
            "dec_layers": _stack_init(self._init_dec_layer, k2, cfg.n_layers),
            "final_norm": init_rmsnorm(cfg.d_model),
            "unembed": init_embedding(ku, cfg.vocab_pad, cfg.d_model),
        }

    def encode(self, params, frames):
        cfg = self.cfg
        B, T, _ = frames.shape
        x = frames.astype(self.dtype) + sinusoidal_positions(T, cfg.d_model).astype(
            self.dtype)[None]
        x = shard(x, "batch", None, None)
        positions = jnp.arange(T)

        def body(x, lp):
            q, k, v = project_qkv(lp["attn"], rmsnorm(lp["ln1"], x), None,
                                  geom=cfg.attn_geom, rope_theta=0.0)
            o = flash_attention(q, k, v, positions, positions, causal=False,
                                exp_fn=self.attn_exp)
            x = x + shard(attention_out(lp["attn"], o, cfg.attn_geom), "batch", None, None)
            x = x + shard(mlp(lp["mlp"], rmsnorm(lp["ln2"], x), self.act),
                          "batch", None, None)
            return x, None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rmsnorm(params["enc_norm"], x)

    def _dec_block(self, lp, x, positions, memory, mem_pos, self_kv=None, pb=None):
        cfg = self.cfg
        q, k, v = project_qkv(lp["self"], rmsnorm(lp["ln1"], x), positions,
                              geom=cfg.attn_geom, rope_theta=cfg.attn.rope_theta,
                              rope_sin_cos=self.rope_sin_cos)
        if self_kv is None:
            o = flash_attention(q, k, v, positions, positions, causal=True,
                                exp_fn=self.attn_exp)
            new_kv = (k, v)
        else:
            kb, vb = self_kv
            kb, vb, _ = cache_insert(kb, vb, pb, k, v, positions)
            o = flash_attention(q, kb, vb, positions, pb, causal=True,
                                exp_fn=self.attn_exp)
            new_kv = (kb, vb)
        x = x + shard(attention_out(lp["self"], o, cfg.attn_geom), "batch", None, None)
        # cross attention into encoder memory (no rope, bidirectional over memory)
        qx, kx, vx = project_qkv(lp["cross"], rmsnorm(lp["lnx"], x), None,
                                 geom=cfg.attn_geom, rope_theta=0.0)
        _, km, vm = project_qkv(lp["cross"], memory, None,
                                geom=cfg.attn_geom, rope_theta=0.0)
        ox = flash_attention(qx, km, vm, positions, mem_pos, causal=False,
                             exp_fn=self.attn_exp)
        x = x + shard(attention_out(lp["cross"], ox, cfg.attn_geom), "batch", None, None)
        x = x + shard(mlp(lp["mlp"], rmsnorm(lp["ln2"], x), self.act),
                      "batch", None, None)
        return x, new_kv

    def train_logits(self, params, batch):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        mem_pos = jnp.arange(memory.shape[1])
        tokens = batch["tokens"]
        x = shard(embed(params["embed"], tokens, self.dtype), "batch", None, None)
        positions = jnp.arange(tokens.shape[1])

        def body(x, lp):
            x, _ = self._dec_block(lp, x, positions, memory, mem_pos)
            return x, None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def init_cache(self, batch: int, cache_len: int) -> Cache:
        cfg = self.cfg
        G, D = cfg.attn_geom.g_eff, cfg.head_dim
        return {
            "k": jnp.zeros((cfg.n_layers, batch, cache_len, G, D), jnp.bfloat16),
            "v": jnp.zeros((cfg.n_layers, batch, cache_len, G, D), jnp.bfloat16),
            "pos": jnp.full((batch, cache_len), -1, jnp.int32),
            "memory": jnp.zeros((batch, cfg.enc_len, cfg.d_model), jnp.bfloat16),
        }

    def prefill(self, params, batch, cache):
        memory = self.encode(params, batch["frames"])
        mem_pos = jnp.arange(memory.shape[1])
        tokens = batch["tokens"]
        x = shard(embed(params["embed"], tokens, self.dtype), "batch", None, None)
        positions = jnp.arange(tokens.shape[1])
        W = cache["k"].shape[2]

        def body(x, xs):
            lp, kb, vb = xs
            x, (k, v) = self._dec_block(lp, x, positions, memory, mem_pos)
            kn, vn, pn = DecoderLM._ring_window(k, v, positions, W)
            kb, vb, pb = cache_insert(kb, vb, cache["pos"], kn, vn, pn)
            return x, (kb, vb, pb)

        x, (ks, vs, pbs) = jax.lax.scan(body, x,
                                        (params["dec_layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": pbs[0],
                     "memory": memory.astype(jnp.bfloat16)}
        return self._logits(params, x[:, -1:])[:, 0], new_cache

    def decode_step(self, params, tok, pos, cache):
        x = shard(embed(params["embed"], tok, self.dtype), "batch", None, None)
        memory = cache["memory"].astype(self.dtype)
        mem_pos = jnp.arange(memory.shape[1])
        W = cache["k"].shape[2]
        positions, pb = _decode_positions(pos, cache["pos"], W)

        def body(x, xs):
            lp, kb, vb = xs
            x, (kb, vb) = self._dec_block(lp, x, positions, memory, mem_pos,
                                          self_kv=(kb, vb), pb=pb)
            return x, (kb, vb)

        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["dec_layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": pb, "memory": cache["memory"]}
        return self._logits(params, x)[:, 0], new_cache


# ======================================================================================
# VLM — vision prefix (stub) + decoder backbone
# ======================================================================================


class VLM(BaseLM):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        self.backbone = DecoderLM(cfg)

    def init(self, key) -> Params:
        k1, k2 = jax.random.split(key)
        params = self.backbone.init(k1)
        params["vis_proj"] = init_linear(k2, self.cfg.d_vis, self.cfg.d_model)
        return params

    def _prefix(self, params, batch):
        """Concatenate projected patch embeddings with token embeddings."""
        vis = linear(params["vis_proj"], batch["patches"].astype(self.dtype))
        tok = embed(params["embed"], batch["tokens"], self.dtype)
        return shard(jnp.concatenate([vis, tok], axis=1), "batch", None, None)

    def train_logits(self, params, batch):
        cfg = self.cfg
        x = self._prefix(params, batch)
        positions = jnp.arange(x.shape[1])
        aux0 = jnp.zeros((), jnp.float32)

        def body(carry, lp):
            x, aux = carry
            x, _, a = self.backbone._self_block(lp, x, positions, cfg.attn.window)
            return (x, aux + a), None

        body = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
        # logits over the text positions only
        x = x[:, batch["patches"].shape[1]:]
        return self.backbone._logits(params, x), aux / cfg.n_layers

    def loss(self, params, batch):
        logits, aux = self.train_logits(params, batch)
        return cross_entropy(logits, batch["targets"]) + AUX_WEIGHT * aux

    def init_cache(self, batch: int, cache_len: int) -> Cache:
        return self.backbone.init_cache(batch, cache_len + self.cfg.n_vis_tokens)

    def prefill(self, params, batch, cache):
        x = self._prefix(params, batch)
        positions = jnp.arange(x.shape[1])
        cfg = self.cfg
        W = cache["k"].shape[2]

        def body(x, xs):
            lp, kb, vb = xs
            x, (k, v), _ = self.backbone._self_block(lp, x, positions,
                                                     cfg.attn.window)
            kn, vn, pn = DecoderLM._ring_window(k, v, positions, W)
            kb, vb, pb = cache_insert(kb, vb, cache["pos"], kn, vn, pn)
            return x, (kb, vb, pb)

        x, (ks, vs, pbs) = jax.lax.scan(body, x,
                                        (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": pbs[0]}
        return self.backbone._logits(params, x[:, -1:])[:, 0], new_cache

    def decode_step(self, params, tok, pos, cache):
        return self.backbone.decode_step(params, tok, pos, cache)
