"""Mamba2 (SSD) blocks on a chunkwise gated outer-product scan.

Recurrence (per batch, head):   S_t = a_t * S_{t-1} + u_t w_t^T,   y_t = S_t r_t
with S in R^{P x N}, a_t in (0, 1].  The chunkwise closed form (chunk length L):

    y_i = exp(lA_i) * (S_0 r_i) + sum_{j<=i} exp(lA_i - lA_j) (w_j . r_i) u_j
    S_L = exp(lA_L) * S_0 + sum_j exp(lA_L - lA_j) u_j w_j^T

where lA is the within-chunk cumulative log-decay.  Peak memory is O(B H L^2) per
chunk (L = 256 default), so prefill_32k and the 500k decode shapes stay bounded.
All transcendentals (softplus for dt, exp for the decay) route through the paper's
table backend.

Projections are kept UNFUSED (separate z/x/B/C/dt weights): the fused layout's
split points do not align with 'model'-axis shard boundaries and would force
resharding collectives (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .common import Params, init_linear, linear, rmsnorm


def gated_outer_scan(log_a, u, w, r, s0, chunk: int = 256):
    """Chunk-parallel scan of S_t = a_t S_{t-1} + u_t w_t^T ; y_t = S_t r_t.

    log_a: (B, H, S); u: (B, H, S, P); w, r: (B, H, S, N); s0: (B, H, P, N).
    S must be a multiple of ``chunk`` (callers pad).  Returns (y, s_final).
    """
    B, H, S, P = u.shape
    N = w.shape[-1]
    L = min(chunk, S)
    n_chunks = S // L
    la = jnp.moveaxis(log_a.reshape(B, H, n_chunks, L), 2, 0)
    uc = jnp.moveaxis(u.reshape(B, H, n_chunks, L, P), 2, 0)
    wc = jnp.moveaxis(w.reshape(B, H, n_chunks, L, N), 2, 0)
    rc = jnp.moveaxis(r.reshape(B, H, n_chunks, L, N), 2, 0)

    mask = jnp.tril(jnp.ones((L, L), bool))

    def step(s, xs):
        la_, u_, w_, r_ = xs
        cl = jnp.cumsum(la_, axis=-1)  # within-chunk cumulative log decay
        y_carry = jnp.exp(cl)[..., None] * jnp.einsum("bhpn,bhln->bhlp", s, r_)
        gap = cl[..., :, None] - cl[..., None, :]  # (B,H,L,L) i x j
        t = jnp.where(mask, jnp.exp(jnp.minimum(gap, 0.0)), 0.0)
        g = jnp.einsum("bhln,bhmn->bhlm", r_, w_)
        y_intra = jnp.einsum("bhlm,bhmp->bhlp", t * g, u_)
        decay_to_end = jnp.exp(cl[..., -1:] - cl)
        s_new = jnp.exp(cl[..., -1])[..., None, None] * s + jnp.einsum(
            "bhm,bhmp,bhmn->bhpn", decay_to_end, u_, w_)
        return s_new, y_carry + y_intra

    s_final, y = jax.lax.scan(step, s0, (la, uc, wc, rc))
    return jnp.moveaxis(y, 0, 2).reshape(B, H, S, P), s_final


class SSMCache(NamedTuple):
    state: jax.Array  # (B, H, P, N) f32
    conv_x: jax.Array  # (B, K-1, inner)
    conv_b: jax.Array  # (B, K-1, N)
    conv_c: jax.Array  # (B, K-1, N)


def init_mamba2(key, d_model: int, *, expand: int, head_dim: int, state_dim: int,
                conv_width: int, dtype=jnp.float32) -> Params:
    inner = expand * d_model
    n_heads = inner // head_dim
    ks = jax.random.split(key, 9)
    return {
        "in_z": init_linear(ks[0], d_model, inner, dtype=dtype),
        "in_x": init_linear(ks[1], d_model, inner, dtype=dtype),
        "in_b": init_linear(ks[2], d_model, state_dim, dtype=dtype),
        "in_c": init_linear(ks[3], d_model, state_dim, dtype=dtype),
        "in_dt": init_linear(ks[4], d_model, n_heads, dtype=dtype),
        "conv_x": {"w": jax.random.normal(ks[5], (conv_width, inner), dtype) * 0.2},
        "conv_b": {"w": jax.random.normal(ks[6], (conv_width, state_dim), dtype) * 0.2},
        "conv_c": {"w": jax.random.normal(ks[7], (conv_width, state_dim), dtype) * 0.2},
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": {"g": jnp.ones((inner,), dtype)},
        "out": init_linear(ks[8], inner, d_model, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, carry: jax.Array | None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C); carry: (B, K-1, C) or None.
    Returns (out, new_carry)."""
    K = w.shape[0]
    if carry is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
              for i in range(K))
    return out, xp[:, -(K - 1):]


def mamba2_block(
    p: Params,
    x: jax.Array,  # (B, S, d)
    *,
    expand: int,
    head_dim: int,
    state_dim: int,
    conv_width: int,
    chunk: int,
    act_silu: Callable,
    act_softplus: Callable,
    cache: SSMCache | None = None,
):
    """Returns (y, new_cache)."""
    B, S, d = x.shape
    inner = expand * d
    H = inner // head_dim
    N = state_dim

    z = linear(p["in_z"], x)
    xin = linear(p["in_x"], x)
    b = linear(p["in_b"], x)
    c = linear(p["in_c"], x)
    dt_raw = linear(p["in_dt"], x)

    cx = cache.conv_x if cache is not None else None
    cb = cache.conv_b if cache is not None else None
    cc = cache.conv_c if cache is not None else None
    xin, ncx = _causal_conv(xin, p["conv_x"]["w"], cx)
    b, ncb = _causal_conv(b, p["conv_b"]["w"], cb)
    c, ncc = _causal_conv(c, p["conv_c"]["w"], cc)
    xin = act_silu(xin)
    b = act_silu(b)
    c = act_silu(c)

    dt = act_softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,) negative
    log_decay = jnp.moveaxis(dt * a, 2, 1)  # (B,H,S) <= 0

    u = jnp.moveaxis(
        (xin.reshape(B, S, H, head_dim) * dt[..., None]).astype(jnp.float32), 2, 1)
    w_ = jnp.broadcast_to(b[:, None].astype(jnp.float32), (B, H, S, N))
    r_ = jnp.broadcast_to(c[:, None].astype(jnp.float32), (B, H, S, N))

    s0 = (cache.state.astype(jnp.float32) if cache is not None
          else jnp.zeros((B, H, head_dim, N), jnp.float32))

    if S == 1:  # decode fast path: one recurrence step
        a1 = jnp.exp(log_decay[..., 0])
        s_final = a1[..., None, None] * s0 + jnp.einsum(
            "bhp,bhn->bhpn", u[..., 0, :], w_[..., 0, :])
        y = jnp.einsum("bhpn,bhn->bhp", s_final, r_[..., 0, :])[:, None]  # (B,1,H,P)
    else:
        pad = (-S) % chunk
        if pad:
            f = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 3))
            log_decay, u, w_, r_ = f(log_decay), f(u), f(w_), f(r_)
        y, s_final = gated_outer_scan(log_decay, u, w_, r_, s0, chunk)
        y = jnp.moveaxis(y[:, :, :S], 1, 2)  # (B,S,H,P)

    y = y + (xin.reshape(B, S, H, head_dim).astype(jnp.float32)
             * p["d_skip"][None, None, :, None])
    y = y.reshape(B, S, inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * act_silu(z))
    out = linear(p["out"], y)
    new_cache = SSMCache(
        state=s_final.astype(jnp.float32),
        conv_x=ncx.astype(jnp.float32), conv_b=ncb.astype(jnp.float32),
        conv_c=ncc.astype(jnp.float32),
    )
    return out, new_cache


def init_ssm_cache(batch: int, inner: int, state_dim: int, head_dim: int,
                   conv_width: int) -> SSMCache:
    H = inner // head_dim
    return SSMCache(
        state=jnp.zeros((batch, H, head_dim, state_dim), jnp.float32),
        conv_x=jnp.zeros((batch, conv_width - 1, inner), jnp.float32),
        conv_b=jnp.zeros((batch, conv_width - 1, state_dim), jnp.float32),
        conv_c=jnp.zeros((batch, conv_width - 1, state_dim), jnp.float32),
    )
