"""Shared building blocks: initializers, norms, rotary embeddings, projections.

Models are plain pytrees of arrays + pure functions (no flax dependency): ``init_*``
builds parameter subtrees from a PRNG key, ``apply``-style functions consume them.
Stacked (scan-over-layers) parameters are produced by vmapping the initializers.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def normal_init(key, shape, scale: float, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 1 else 1
    return (scale / np.sqrt(max(1, fan_in))) * jax.random.normal(key, shape, dtype)


def init_linear(key, d_in: int, d_out, *, scale: float = 1.0, dtype=jnp.float32):
    """Weight of shape (d_in, *d_out) — d_out may be a tuple for fused heads."""
    shape = (d_in,) + (d_out if isinstance(d_out, tuple) else (d_out,))
    return {"w": normal_init(key, shape, scale, dtype)}


def linear(p: Params, x: jax.Array, dims: str = "...d,df->...f") -> jax.Array:
    return jnp.einsum(dims, x, p["w"].astype(x.dtype))


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Project to vocab logits in f32 (loss stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["table"].astype(jnp.float32))


# ------------------------------- rotary ---------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               sin_cos=None) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: broadcastable to (..., S).

    ``sin_cos`` optionally replaces the exact jnp trig with a table-served
    ``f(ang) -> (sin, cos)`` — models pass ``ApproxConfig.rope_sin_cos()``,
    which folds the unbounded position*freq angles onto the pack's trig core
    members (``rope_table=True``); ``None`` keeps exact rotations."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if sin_cos is None:
        cos, sin = jnp.cos(ang), jnp.sin(ang)
    else:
        sin, cos = sin_cos(ang)
    if x.ndim == ang.ndim + 1:  # head axis present between S and D
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype=jnp.float32)


# Canonical home is the approx backend, which applies it to every table-mode
# tanh automatically; re-exported here for the model-side callers.


def softcap(x: jax.Array, cap: float, tanh_fn=None) -> jax.Array:
    """Soft logit cap ``cap * tanh(x / cap)``.

    ``tanh_fn`` lets the caller route the tanh through the approx backend
    instead of the exact transcendental — models pass
    ``cfg.approx.unary("tanh")``, which is already odd-extended to the full
    symmetric domain in table modes.
    """
    if cap <= 0:
        return x
    t = jnp.tanh if tanh_fn is None else tanh_fn
    return cap * t(x / cap)


def routed_activation(approx, names) -> Any:
    """MoE-style slot-routed activations: ``f(x)`` applies ``names[i]`` to
    row i of a slot-major tensor ``(n_slots, ...)`` in ONE call.

    ``approx`` is the model's :class:`repro.approx.ApproxConfig`.  In table
    modes the dispatch runs through the scalar-prefetch routed kernels — the
    slot->function assignment is a runtime operand, so one compiled executable
    serves every routing (vs one specialization per member with the static
    pack path); exact mode falls back to a row-select over the exact
    activations.  See examples/serve_decode.py ``--routed-demo``.
    """
    return approx.routed_fn(tuple(names))
