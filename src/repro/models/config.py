"""Architecture + shape configuration.

``ArchConfig`` is the single source of truth consumed by the model builders, the
launcher, the dry-run, and the roofline extractor.  One instance per assigned
architecture lives in ``repro/configs/<id>.py``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple

from repro.approx.activations import ApproxConfig

# Families
DENSE = "dense"
MOE = "moe"
SSM_HYBRID = "hybrid"  # mamba2 blocks + shared attention (zamba2)
XLSTM = "xlstm"
ENCDEC = "encdec"  # whisper
VLM = "vlm"  # vision stub + decoder LM


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0  # routed experts
    top_k: int = 0
    n_shared: int = 0  # always-on shared experts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # device-limited routing (DeepSeek-V3): tokens route into at most
    # ``max_groups`` of ``device_groups`` EP shards (0 = unrestricted)
    device_groups: int = 0
    max_groups: int = 0


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64  # N
    head_dim: int = 64  # P (per-head channels)
    conv_width: int = 4
    expand: int = 2  # inner dim = expand * d_model
    chunk: int = 256  # chunkwise-scan length


# Width of the 'model' mesh axis in the production mesh.  Attention geometry is
# normalized so KV groups shard exactly TARGET_GROUPS ways: KV heads are
# activation-replicated (never parameter-replicated — GQA ties stay faithful) and
# Q heads are zero-padded + masked (function-preserving; the pad waste is visible
# in the roofline useful-FLOPs ratio).  See DESIGN.md §6.
TARGET_GROUPS = 16


@dataclass(frozen=True)
class AttnGeom:
    """Normalized attention geometry: logical (h, g) -> effective (h_eff, g_eff)."""

    h_log: int  # architecture's q heads
    g_log: int  # architecture's kv heads
    h_eff: int  # padded q heads (multiple of g_eff * ... )
    g_eff: int  # effective kv groups (shards exactly over 'model')
    repeat: int  # kv activation-replication factor
    g_zero_pad: int  # zero kv groups appended (only when TARGET_GROUPS % g != 0)
    d_head: int

    @property
    def q_per_group(self) -> int:
        return self.h_eff // self.g_eff

    @property
    def is_padded(self) -> bool:
        return self.h_eff != self.h_log or self.g_zero_pad > 0


def make_attn_geom(n_heads: int, n_kv: int, d_head: int,
                   target: int = TARGET_GROUPS) -> AttnGeom:
    if n_kv % target == 0:
        g_eff, repeat, zero = n_kv, 1, 0
        h_eff = n_kv * -(-n_heads // n_kv)  # pad to a multiple of the group count
    elif target % n_kv == 0:
        g_eff, repeat, zero = target, target // n_kv, 0
        # per-logical-group q count must divide evenly across the kv replicas
        unit = n_kv * repeat
        h_eff = unit * -(-n_heads // unit)
    else:  # e.g. whisper's 12 MHA heads: zero-pad kv groups up to target
        g_eff, repeat, zero = target, 1, target - n_kv
        h_eff = g_eff * -(-n_heads // g_eff)
    return AttnGeom(h_log=n_heads, g_log=n_kv, h_eff=h_eff, g_eff=g_eff,
                    repeat=repeat, g_zero_pad=zero, d_head=d_head)


@dataclass(frozen=True)
class AttnConfig:
    # sliding-window pattern: every `global_every`-th layer is global, others use
    # `window`; window=0 => all layers global (standard causal attention).
    window: int = 0
    global_every: int = 1
    logit_softcap: float = 0.0  # final-logit softcap (gemma), 0 = off
    qk_norm: bool = False
    rope_theta: float = 10_000.0


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 => d_model // n_heads
    act: str = "silu"  # MLP activation routed through the approx backend
    mlp_kind: str = "glu"  # "glu" (llama-style) | "mlp" (2-matrix, starcoder/whisper)
    tie_embeddings: bool = False
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    attn: AttnConfig = field(default_factory=AttnConfig)
    approx: ApproxConfig = field(default_factory=ApproxConfig)
    # enc-dec (whisper): encoder stack depth and source length; frontends are stubs
    n_enc_layers: int = 0
    enc_len: int = 0
    # vlm: number of vision-prefix patch embeddings (precomputed, stub frontend)
    n_vis_tokens: int = 0
    d_vis: int = 0
    # hybrid: one shared attention block applied every k ssm layers
    shared_attn_every: int = 0
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def attn_geom(self) -> AttnGeom:
        return make_attn_geom(self.n_heads, self.n_kv_heads, self.head_dim)

    @property
    def vocab_pad(self) -> int:
        """Embedding rows padded to a multiple of 16*128 so the vocab dim shards
        evenly over 'model' with lane-aligned per-shard tiles (Megatron-style).
        Pad logits are masked to -inf in the head; pad rows never train."""
        return -(-self.vocab // 2048) * 2048

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for MODEL_FLOPS = 6*N*D and memory budgeting) -----

    def param_count(self) -> int:
        d, v = self.d_model, self.vocab
        hd = self.head_dim
        n_emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params():
            return d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
                self.n_heads * hd
            ) * d

        def glu_params(ff):
            return 3 * d * ff

        if self.family in (DENSE, VLM):
            per_layer = attn_params() + glu_params(self.d_ff) + 2 * d
            n = self.n_layers * per_layer + n_emb
            if self.family == VLM:
                n += self.d_vis * d  # vision projector
            return n
        if self.family == MOE:
            ex = (self.moe.n_experts + self.moe.n_shared) * glu_params(self.d_ff)
            router = d * self.moe.n_experts
            per_layer = attn_params() + ex + router + 2 * d
            return self.n_layers * per_layer + n_emb
        if self.family == SSM_HYBRID:
            inner = self.ssm.expand * d
            n_h = inner // self.ssm.head_dim
            per_ssm = (
                d * (2 * inner + 2 * self.ssm.state_dim + n_h)  # in_proj(zx,B,C,dt)
                + inner * self.ssm.conv_width
                + inner * d  # out proj
                + n_h  # A_log
                + 2 * d
            )
            shared = attn_params() + glu_params(self.d_ff) + 2 * d
            return self.n_layers * per_ssm + shared + n_emb
        if self.family == XLSTM:
            per_m = 4 * d * d + d * 3 * self.n_heads + 2 * d + 2 * d * self.d_ff_x()
            per_s = 4 * d * 2 + 4 * d * d // 1 + 2 * d  # gates z,i,f,o as d->d
            n_m = (self.n_layers + 1) // 2
            n_s = self.n_layers // 2
            return n_m * per_m + n_s * per_s + n_emb
        if self.family == ENCDEC:
            enc_per = attn_params() + 2 * d * self.d_ff + 2 * d
            dec_per = 2 * attn_params() + 2 * d * self.d_ff + 3 * d
            return (
                self.n_enc_layers * enc_per + self.n_layers * dec_per + n_emb
            )
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (= total for non-MoE)."""
        if self.family != MOE:
            return self.param_count()
        d = self.d_model
        ex_all = (self.moe.n_experts + self.moe.n_shared) * 3 * d * self.d_ff
        ex_act = (self.moe.top_k + self.moe.n_shared) * 3 * d * self.d_ff
        return self.param_count() - self.n_layers * (ex_all - ex_act)

    def d_ff_x(self) -> int:
        # xLSTM mLSTM up-projection factor 2 when d_ff is unset in the assignment
        return self.d_ff if self.d_ff > 0 else 2 * self.d_model


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

# long_500k runs only for sub-quadratic archs (DESIGN.md §5): linear-state archs and
# gemma3 (5:1 local:global => only 8/48 layers hold a full 500k KV).
LONG_CONTEXT_ARCHS = {"xlstm-125m", "zamba2-1.2b", "gemma3-12b"}


def shapes_for(arch: ArchConfig) -> Tuple[ShapeSpec, ...]:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch.name in LONG_CONTEXT_ARCHS:
        out.append(LONG_500K)
    return tuple(out)
