"""Attention: GQA with RoPE, pure-JAX flash (two-level chunked softmax), sliding
windows, and KV caches (full-length or ring-buffer for local layers).

Why pure-JAX flash and not a Pallas kernel: the multi-pod dry-run must
``.lower().compile()`` on a CPU host for a TPU-sized mesh; a Mosaic custom-call
cannot compile there, while this lax.scan formulation fuses well under XLA:TPU and
keeps peak memory at O(q_chunk * kv_chunk) per head — required for the 32k prefill
shapes.  The paper's kernels (table lookup) remain Pallas; attention is substrate.

GQA never materializes repeated KV: einsums carry a (groups, q_per_kv) axis.
Shapes: q (B, S, G, Qg, D); k,v (B, T, G, D).

API split for the three execution modes:
  project_qkv()   — fused projections + qk-norm + RoPE
  attention_out() — flash + output projection
  train/prefill: attend within the projected sequence; prefill also inserts into
  the cache. decode: insert this step's k/v into the ring buffer FIRST, then attend
  against the buffer with its per-slot absolute positions.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .common import Params, apply_rope, init_linear, linear

NEG_INF = -2.0e38
# Sentinel for CHUNK-PADDING key slots added inside _flash_inner (Tp > T).
# Distinct from the genuine "empty cache slot" marker (k_pos == -1, written by
# the cache init) so TableFlash underflow telemetry can exclude rows that exist
# only because of the chunked scan's padding while still counting real empty
# slots.  Any negative value masks identically (`valid = k_pos >= 0`); the
# sentinel only matters to the obs `approx.oob.attn_exp` counter.
KV_PAD = -(1 << 31)


def init_attention(key, d_model: int, geom, qk_norm: bool = False,
                   dtype=jnp.float32) -> Params:
    """Weights use the *normalized* geometry (DESIGN.md §6): q/o projections carry
    ``h_eff`` padded heads (masked in the forward — function-preserving); k/v stay
    at the architecture's logical ``g_log`` heads (GQA ties are parameter-exact,
    replication happens on activations)."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_linear(kq, d_model, (geom.h_eff, geom.d_head), dtype=dtype),
        "wk": init_linear(kk, d_model, (geom.g_log, geom.d_head), dtype=dtype),
        "wv": init_linear(kv, d_model, (geom.g_log, geom.d_head), dtype=dtype),
        "wo": {"w": jax.random.normal(
            ko, (geom.h_eff, geom.d_head, d_model), dtype) * 0.02},
    }
    if qk_norm:
        p["qn"] = {"g": jnp.ones((geom.d_head,), dtype)}
        p["kn"] = {"g": jnp.ones((geom.d_head,), dtype)}
    return p


def _headnorm(g, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)).astype(x.dtype)


def head_mask(geom) -> jax.Array:
    """(g_eff, q_per_group) 1/0 mask of REAL heads in the normalized layout."""
    import numpy as np

    if geom.g_zero_pad:
        m = np.zeros((geom.g_eff, geom.q_per_group), np.float32)
        m[: geom.g_log] = 1.0
        return jnp.asarray(m)
    per_group = geom.h_eff // geom.g_log
    qg_real = geom.h_log // geom.g_log
    per_rep = per_group // geom.repeat
    mg = np.concatenate([np.ones(qg_real, np.float32),
                         np.zeros(per_group - qg_real, np.float32)])
    m = np.tile(mg.reshape(1, geom.repeat, per_rep), (geom.g_log, 1, 1))
    return jnp.asarray(m.reshape(geom.g_eff, per_rep))


def project_qkv(p: Params, x: jax.Array, positions: Optional[jax.Array], *,
                geom, rope_theta: float, rope_sin_cos=None):
    """x: (B,S,d) -> q (B,S,g_eff,Qg,D), k/v (B,S,g_eff,D) in normalized layout.
    positions=None or rope_theta==0 skips RoPE (whisper-style absolute pos).
    ``rope_sin_cos`` optionally serves the rotary trig from the approx pack
    (``ApproxConfig.rope_sin_cos()``); None keeps exact jnp sin/cos."""
    B, S, _ = x.shape
    D = geom.d_head
    q = linear(p["wq"], x, "bsd,dhe->bshe")  # (B,S,h_eff,D)
    k = linear(p["wk"], x, "bsd,dge->bsge")  # (B,S,g_log,D)
    v = linear(p["wv"], x, "bsd,dge->bsge")
    if "qn" in p:
        q = _headnorm(p["qn"]["g"], q)
        k = _headnorm(p["kn"]["g"], k)
    if positions is not None and rope_theta > 0:
        # positions: (S,) shared across the batch, or (B, S) per-slot clocks
        # (continuous batching: each slot decodes at its own absolute position)
        pos_b = positions if positions.ndim == 2 else positions[None, :]
        q = apply_rope(q, pos_b, rope_theta, sin_cos=rope_sin_cos)
        k = apply_rope(k, pos_b, rope_theta, sin_cos=rope_sin_cos)
    # normalize kv to g_eff groups on the ACTIVATION (params stay logical)
    if geom.repeat > 1:
        k = jnp.repeat(k, geom.repeat, axis=2)
        v = jnp.repeat(v, geom.repeat, axis=2)
    elif geom.g_zero_pad:
        zpad = ((0, 0), (0, 0), (0, geom.g_zero_pad), (0, 0))
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
    q = q.reshape(B, S, geom.g_eff, geom.q_per_group, D)
    return q, k, v


def _flash_inner(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                 kv_chunk: int, scale: float, exp_fn=None):
    """Running-softmax attention for one q block over all kv chunks.

    q: (B, Sq, G, Qg, D); k/v: (B, T, G, D); positions: (Sq,) / (T,) shared
    across the batch, or (B, Sq) / (B, T) per-slot (continuous batching lets
    every batch slot run its own absolute clock and cache validity).
    Returns (B, Sq, G, Qg, D).

    ``exp_fn`` optionally serves the two running-softmax exponents (whose
    arguments are <= 0 by construction) from the pack's ``exp_neg`` member
    (``ApproxConfig.attn_exp()``); None keeps exact ``jnp.exp``.  An
    instrumented closure advertising ``wants_count_mask`` also receives a
    ``count_mask`` excluding the KV_PAD chunk-padding slots from its
    underflow telemetry — only on that telemetry path, so the obs-off jaxpr
    stays identical to a build without ScopeKit.
    """
    B, Sq, G, Qg, D = q.shape
    T = k.shape[1]
    kv_chunk = min(kv_chunk, T)
    n_chunks = -(-T // kv_chunk)
    Tp = n_chunks * kv_chunk
    if Tp != T:
        pad = Tp - T
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_pos.ndim == 1:
            k_pos = jnp.pad(k_pos, (0, pad), constant_values=KV_PAD)
        else:
            k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=KV_PAD)
    k = jnp.moveaxis(k.reshape(B, n_chunks, kv_chunk, G, D), 1, 0)
    v = jnp.moveaxis(v.reshape(B, n_chunks, kv_chunk, G, D), 1, 0)
    if k_pos.ndim == 1:
        k_pos = k_pos.reshape(n_chunks, kv_chunk)
    else:
        k_pos = jnp.moveaxis(k_pos.reshape(B, n_chunks, kv_chunk), 1, 0)
    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]  # (1|B, Sq)

    qf = (q * scale).astype(jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, kp = xs
        s = jnp.einsum("bsgqd,btgd->bsgqt", qf, kc.astype(jnp.float32))
        kpb = kp if kp.ndim == 2 else kp[None, :]  # (1|B, Tc)
        valid = kpb[:, None, :] >= 0  # empty slots masked
        if causal:
            valid = valid & (kpb[:, None, :] <= qp[:, :, None])
        if window > 0:
            valid = valid & (kpb[:, None, :] > qp[:, :, None] - window)
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        if exp_fn is None:
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
        elif getattr(exp_fn, "wants_count_mask", False):
            # pad rows are a chunking artifact, not approximation events
            countable = (kpb != KV_PAD)[:, None, None, None, :]
            p = exp_fn(s - m_new[..., None],
                       count_mask=jnp.broadcast_to(countable, s.shape))
            alpha = exp_fn(m - m_new)
        else:
            p = exp_fn(s - m_new[..., None])
            alpha = exp_fn(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bsgqt,btgd->bsgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, G, Qg), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, G, Qg), jnp.float32)
    a0 = jnp.zeros((B, Sq, G, Qg, D), jnp.float32)
    if n_chunks == 1:
        (m, l, acc), _ = step((m0, l0, a0), (k[0], v[0], k_pos[0]))
    else:
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (k, v, k_pos))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def flash_attention(q, k, v, q_pos, k_pos, *, causal: bool = True, window: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    exp_fn=None) -> jax.Array:
    """q: (B, S, G, Qg, D); k/v: (B, T, G, D). Positions are absolute token
    indices; negative k_pos marks empty cache slots.  Either positions operand
    may carry a leading batch axis ((B, S) / (B, T)) for per-slot clocks.
    ``exp_fn`` routes the softmax exponent through the exp_neg table
    (TableFlash; see ``_flash_inner``)."""
    B, S, G, Qg, D = q.shape
    scale = D ** -0.5
    q_chunk = min(q_chunk, S)
    pad = q_chunk * (-(-S // q_chunk)) - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        if q_pos.ndim == 1:
            q_pos = jnp.pad(q_pos, (0, pad), constant_values=2_000_000_000)
        else:
            q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)),
                            constant_values=2_000_000_000)
    n_q = q.shape[1] // q_chunk
    qs = q.reshape(B, n_q, q_chunk, G, Qg, D)
    if q_pos.ndim == 1:
        qp = q_pos.reshape(n_q, q_chunk)
    else:
        qp = jnp.moveaxis(q_pos.reshape(B, n_q, q_chunk), 1, 0)

    inner = functools.partial(
        _flash_inner, k=k, v=v, k_pos=k_pos, causal=causal, window=window,
        kv_chunk=kv_chunk, scale=scale, exp_fn=exp_fn)
    if n_q == 1:
        out = inner(qs[:, 0], q_pos=qp[0])[:, None]
    else:
        out = jax.lax.map(lambda xs: inner(xs[0], q_pos=xs[1]),
                          (jnp.moveaxis(qs, 1, 0), qp))
        out = jnp.moveaxis(out, 0, 1)
    return out.reshape(B, n_q * q_chunk, G, Qg, D)[:, :S]


def attention_out(p: Params, attended: jax.Array, geom=None) -> jax.Array:
    """(B, S, G, Qg, D) -> (B, S, d_model) via the output projection.  Padded
    heads are masked here, which also kills their gradients (pad params never
    train — the normalized model is exactly the logical one)."""
    B, S, G, Qg, D = attended.shape
    if geom is not None and geom.is_padded:
        attended = attended * head_mask(geom)[None, None, :, :, None].astype(
            attended.dtype)
    wo = p["wo"]["w"].astype(attended.dtype).reshape(G, Qg, D, -1)
    return jnp.einsum("bsgqd,gqdm->bsm", attended, wo)


def cache_insert(k_buf, v_buf, pos_buf, k_new, v_new, positions):
    """Insert S new rope'd entries into a ring/linear buffer.

    k_buf/v_buf: (B, W, G, D); pos_buf: (B, W) int32 per-slot validity rows
    (-1 = empty slot).  positions: (S,) absolute shared across the batch
    (broadcast to every row), or (B, S) per-slot; slot = position % W.
    Callers must pass S <= W (prefill truncates to the last W tokens first).
    """
    W = k_buf.shape[1]
    B = k_buf.shape[0]
    pos2 = jnp.broadcast_to(jnp.atleast_2d(positions),
                            (B, positions.shape[-1]))
    slots = (pos2 % W).astype(jnp.int32)
    b = jnp.arange(B)[:, None]
    k_buf = k_buf.at[b, slots].set(k_new.astype(k_buf.dtype))
    v_buf = v_buf.at[b, slots].set(v_new.astype(v_buf.dtype))
    pos_buf = pos_buf.at[b, slots].set(pos2.astype(jnp.int32))
    return k_buf, v_buf, pos_buf
