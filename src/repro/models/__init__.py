"""repro.models — the model zoo: configs, families, factory."""

from .config import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ArchConfig,
    AttnConfig,
    MoEConfig,
    SSMConfig,
    ShapeSpec,
    shapes_for,
)
from .registry import ARCH_IDS, build_model, get_config, input_specs

__all__ = [
    "ALL_SHAPES",
    "ARCH_IDS",
    "ArchConfig",
    "AttnConfig",
    "DECODE_32K",
    "LONG_500K",
    "MoEConfig",
    "PREFILL_32K",
    "SHAPES_BY_NAME",
    "SSMConfig",
    "ShapeSpec",
    "TRAIN_4K",
    "build_model",
    "get_config",
    "input_specs",
    "shapes_for",
]
