"""Feed-forward blocks: gated-linear-unit MLP and fine-grained MoE.

The MoE uses sort-based capacity dispatch (MegaBlocks-style, no custom kernel):
top-k routing -> stable sort of (token, expert) slots by expert -> scatter into a
static (E, C, d) buffer -> grouped einsum -> weighted scatter-add back.  Under pjit
the (E, ...) dims shard over the 'model' mesh axis (expert parallelism) and XLA
inserts the dispatch collectives; the shard_map all-to-all variant is a §Perf
iteration (see EXPERIMENTS.md).

All nonlinearities route through the paper's table backend via ``act_fn``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .common import Params, init_linear, linear


def init_glu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": init_linear(k1, d_model, d_ff, dtype=dtype),  # gate branch
        "wu": init_linear(k2, d_model, d_ff, dtype=dtype),  # linear branch
        "wd": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def glu(p: Params, x: jax.Array, act: Callable) -> jax.Array:
    return linear(p["wd"], act(linear(p["wi"], x)) * linear(p["wu"], x))


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    """Plain 2-matrix MLP (whisper/starcoder style)."""
    k1, k2 = jax.random.split(key)
    return {
        "wi": init_linear(k1, d_model, d_ff, dtype=dtype),
        "wd": init_linear(k2, d_ff, d_model, dtype=dtype),
    }


def mlp(p: Params, x: jax.Array, act: Callable) -> jax.Array:
    return linear(p["wd"], act(linear(p["wi"], x)))


# ----------------------------------- MoE --------------------------------------


def init_moe(key, d_model: int, d_ff: int, n_experts: int, n_shared: int,
             dtype=jnp.float32) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    p = {
        "router": {"w": jax.random.normal(kr, (d_model, n_experts), jnp.float32) * 0.02},
        "experts": {
            "wi": jax.random.normal(ke, (n_experts, d_model, d_ff), dtype) * 0.02,
            "wu": jax.random.normal(
                jax.random.fold_in(ke, 1), (n_experts, d_model, d_ff), dtype) * 0.02,
            "wd": jax.random.normal(
                jax.random.fold_in(ke, 2), (n_experts, d_ff, d_model), dtype) * 0.02,
        },
    }
    if n_shared:
        p["shared"] = init_glu(ks, d_model, n_shared * d_ff, dtype)
    return p


def moe(
    p: Params,
    x: jax.Array,  # (B, S, d)
    act: Callable,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    softmax_fn=None,
    device_groups: int = 0,
    max_groups: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  Dropped tokens (over capacity) fall back to the
    shared-expert path (their routed contribution is zero).

    ``device_groups``/``max_groups`` enable DeepSeek-V3-style device-limited
    routing: experts are grouped into ``device_groups`` contiguous EP shards and
    each token may only route into its ``max_groups`` best shards (by max expert
    affinity) — bounding the all-to-all fan-out to max_groups destinations.
    Semantics change (a routing restriction) but this is standard practice for
    exactly the collective bound it attacks (EXPERIMENTS.md §Perf)."""
    B, S, d = x.shape
    E = p["experts"]["wi"].shape[0]
    T = B * S
    xt = x.reshape(T, d)

    # --- routing (f32) ---------------------------------------------------------
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1) if softmax_fn is None else softmax_fn(logits)
    if device_groups and max_groups and max_groups < device_groups:
        per = E // device_groups
        group_score = probs.reshape(T, device_groups, per).max(-1)  # (T, G)
        _, top_g = jax.lax.top_k(group_score, max_groups)
        allowed = jnp.zeros((T, device_groups), bool).at[
            jnp.arange(T)[:, None], top_g].set(True)
        probs = jnp.where(
            jnp.repeat(allowed, per, axis=1), probs, 0.0)
    gate, eidx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch ----------------------------------------------------
    C = int(capacity_factor * T * top_k / E) + 1
    flat_e = eidx.reshape(-1)  # (T*k,) expert of each slot
    slot_token = jnp.repeat(jnp.arange(T), top_k)  # token of each slot
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank of each sorted slot within its expert group
    ranks = jnp.arange(T * top_k) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = ranks < C
    dest = sorted_e * C + ranks  # (T*k,) position in the (E*C) buffer
    dest = jnp.where(keep, dest, E * C)  # overflow -> scratch row

    buf = jnp.zeros((E * C + 1, d), x.dtype)
    xe = buf.at[dest].set(xt[slot_token[order]].astype(x.dtype))[:-1]
    xe = xe.reshape(E, C, d)

    # --- grouped expert GLU ------------------------------------------------------
    we = p["experts"]
    h = act(jnp.einsum("ecd,edf->ecf", xe, we["wi"].astype(x.dtype))) * jnp.einsum(
        "ecd,edf->ecf", xe, we["wu"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, we["wd"].astype(x.dtype))  # (E, C, d)

    # --- combine: gather each kept slot's output, weight by gate, sum per token --
    ye_flat = jnp.concatenate([ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)], 0)
    slot_out = ye_flat[dest]  # (T*k, d) — overflow slots read zeros
    slot_gate = gate.reshape(-1)[order].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[slot_token[order]].add(
        slot_out * slot_gate[:, None])

    if "shared" in p:
        y = y + glu(p["shared"], xt, act)
    return y.reshape(B, S, d), aux
