"""Model factory + abstract input specs for every (arch, shape) cell."""

from __future__ import annotations

import importlib
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ENCDEC, MOE, SSM_HYBRID, VLM as VLM_FAM, XLSTM, ArchConfig, ShapeSpec
from .transformer import DecoderLM, EncDecLM, HybridLM, VLM, XLSTMLM

ARCH_IDS = (
    "xlstm-125m",
    "deepseek-moe-16b",
    "qwen3-moe-235b-a22b",
    "stablelm-3b",
    "yi-34b",
    "gemma3-12b",
    "starcoder2-3b",
    "whisper-small",
    "zamba2-1.2b",
    "internvl2-1b",
)


def get_config(arch_id: str) -> ArchConfig:
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def build_model(cfg: ArchConfig, mesh=None):
    """Construct the family's model for ``cfg``.

    ``mesh`` (default: the active ``use_sharding`` mesh, if any) pre-places
    sharded-mode approx packs over the mesh BEFORE the constructors build
    their activation closures, so each 'model' core captures its one values
    slice and step 0 pays no pack reshard (see ``ApproxConfig.place_packs``).
    """
    if mesh is None:
        from repro.parallel.sharding import current_mesh
        mesh = current_mesh()
    cfg.approx.place_packs(mesh)
    family = cfg.family
    if family in ("dense", MOE):
        return DecoderLM(cfg)
    if family == SSM_HYBRID:
        return HybridLM(cfg)
    if family == XLSTM:
        return XLSTMLM(cfg)
    if family == ENCDEC:
        return EncDecLM(cfg)
    if family == VLM_FAM:
        return VLM(cfg)
    raise ValueError(f"unknown family {family!r}")


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for a step's data inputs (no allocation).

    train/prefill: the token batch (+ stub modality inputs).
    decode: one new token + position (the KV cache is part of the carried state and
    produced by ``abstract_cache``)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": sd((B, S), i32)}
        if shape.kind == "train":
            batch["targets"] = sd((B, S), i32)
        if cfg.family == ENCDEC:
            batch["frames"] = sd((B, cfg.enc_len, cfg.d_model), jnp.float32)
        if cfg.family == VLM_FAM:
            batch["patches"] = sd((B, cfg.n_vis_tokens, cfg.d_vis), jnp.float32)
        return batch
    if shape.kind == "decode":
        return {"tok": sd((B, 1), i32), "pos": sd((), i32)}
    raise ValueError(shape.kind)
