"""Batched serving driver: fixed-batch prefill + greedy/temperature decode over a
request queue, with the KV cache living on-device across steps.

The continuous-batching extension point is ``DecodeEngine.step`` — requests that
finish (EOS/max_tokens) free their batch slot; ``serve`` refills slots between
steps.  On TPU the same jitted decode_step serves every step; slot refill is a
host-side gather/scatter into the cache (cheap relative to a decode step at the
assigned shapes).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1  # -1: never stops early


@dataclass
class Result:
    tokens: np.ndarray
    prompt_len: int
    steps: int


class DecodeEngine:
    def __init__(self, model, params, batch_size: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1)

    def generate_batch(self, prompts: np.ndarray, max_new: int,
                       eos_id=-1, extra_inputs: Optional[dict] = None):
        """prompts: (B, S) int32, right-aligned equal length (caller pads).

        ``eos_id`` is a scalar applied to the whole batch or a (B,) vector of
        per-slot EOS ids (-1: that slot never stops early).  Returns
        ``(tokens, steps)`` where ``steps`` counts every sampled token,
        including the one sampled from the prefill logits.
        """
        B, S = prompts.shape
        assert B == self.B
        eos = np.broadcast_to(np.asarray(eos_id, np.int64), (B,))
        cache = self.model.init_cache(B, self.cache_len)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        logits, cache = self._prefill(self.params, batch, cache)
        out = [self._sample(logits)]
        # only force a device->host sync per step when some slot can stop early
        has_eos = bool((eos >= 0).any())
        done = np.zeros((B,), bool)
        if has_eos:
            done = (eos >= 0) & (np.asarray(out[0]) == eos)
        steps = 1  # the prefill logits already yielded one token
        for i in range(max_new - 1):
            if has_eos and done.all():
                break
            tok = out[-1][:, None].astype(jnp.int32)
            logits, cache = self._step(self.params, tok,
                                       jnp.asarray(S + i, jnp.int32), cache)
            nxt = self._sample(logits)
            out.append(nxt)
            steps += 1
            if has_eos:
                done |= (eos >= 0) & (np.asarray(nxt) == eos)
        return np.stack([np.asarray(t) for t in out], axis=1), steps


def pad_and_batch(requests: List[Request], batch_size: int, pad_id: int = 0):
    """Left-pad prompts to a common length; group into fixed-size batches."""
    groups = [requests[i : i + batch_size]
              for i in range(0, len(requests), batch_size)]
    out = []
    for g in groups:
        while len(g) < batch_size:
            g = g + [Request(prompt=np.zeros((1,), np.int32), max_new_tokens=1)]
        maxlen = max(len(r.prompt) for r in g)
        toks = np.full((batch_size, maxlen), pad_id, np.int32)
        for i, r in enumerate(g):
            toks[i, maxlen - len(r.prompt):] = r.prompt
        out.append((g, toks))
    return out


def serve(model, params, requests: List[Request], batch_size: int,
          cache_len: int, temperature: float = 0.0) -> List[Result]:
    engine = DecodeEngine(model, params, batch_size, cache_len, temperature)
    results: List[Result] = []
    for group, toks in pad_and_batch(requests, batch_size):
        max_new = max(r.max_new_tokens for r in group)
        eos = np.asarray([r.eos_id for r in group], np.int64)
        gen, steps = engine.generate_batch(toks, max_new, eos)
        for i, r in enumerate(group):
            results.append(Result(tokens=gen[i, : r.max_new_tokens],
                                  prompt_len=len(r.prompt), steps=steps))
    return results[: len(requests)]
