"""Serving drivers: fixed-batch (static) and continuous-batching decode over a
request queue, with the KV cache living on-device across steps.

Two schedulers share the model's prefill/decode executables:

``serve_static`` — PR 1's fixed-group path, kept as the regression baseline:
requests are grouped into fixed-size batches, each group prefills together and
decodes until every slot has hit its own EOS or budget (per-slot ``done``
tracking stops a group early; finished and padding slots no longer drag the
loop to the group-wide max).

``ContinuousEngine`` / ``serve_continuous`` — an admission queue with
mid-stream slot refill: every batch slot carries its own request state
(budget, EOS id, RNG stream, absolute position clock).  When a slot finishes,
the host prefills the next queued request (one fixed-shape prefill whose rows
serve every slot freed that round) and scatters the freed slots' rows of the
fresh cache into the live cache — the same two jitted executables
(``prefill``, ``decode_step``) serve the whole queue, with zero recompiles
across refills (per-slot positions keep every decode tick at one shape).

Result accounting is per-request: ``Result.tokens`` is truncated at the
request's own first EOS (inclusive) and ``Result.steps`` counts the tokens
actually generated for that request; the batch-wide round count lives on the
engine (``engine.batch_steps``) together with the wasted-slot-step counters
the serve benchmark gates on.
"""

from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager, nullcontext
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs.trace import MAIN_TID, SLOT_TID0


@dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1  # -1: never stops early


@dataclass
class Result:
    tokens: np.ndarray  # truncated at this request's first EOS (inclusive)
    prompt_len: int
    steps: int  # tokens generated for THIS request (== len(tokens))


def _trim_at_eos(tokens: np.ndarray, budget: int, eos_id: int) -> np.ndarray:
    """This request's tokens: at most ``budget``, cut at the first EOS
    (keeping the EOS token itself)."""
    tokens = tokens[:budget]
    if eos_id >= 0:
        hits = np.flatnonzero(tokens == eos_id)
        if hits.size:
            tokens = tokens[: hits[0] + 1]
    return tokens


def _jit_cache_size(fn) -> int:
    """Number of compiled specializations behind a jax.jit wrapper (-1 if the
    runtime doesn't expose it)."""
    try:
        return fn._cache_size()
    except Exception:
        return -1


class _EngineBase:
    """Shared engine plumbing: compiled-executable bookkeeping and the
    batch-round / wasted-slot-step counters both schedulers report.

    A slot-round is one slot position in one sampling round (prefill round or
    decode step); it counts as wasted when it yields no token for a live
    request.  Caveat the serve bench documents: the static prefill round
    counts every slot as useful (a padding dummy's first token is kept by its
    1-token budget even though the caller never sees it), while a continuous
    refill round charges every non-admitted row — both distortions make the
    static number look BETTER, so the continuous-vs-static gate is
    conservative."""

    def reset_counters(self) -> None:
        self.batch_steps = 0  # sampling rounds (prefill rounds + decode steps)
        self.wasted_slot_steps = 0
        self.compile_time_s = 0.0  # wall time inside compile-flagged spans
        metrics = getattr(self, "metrics", None)
        if metrics is not None:
            metrics.reset()

    @property
    def wasted_fraction(self) -> float:
        total = self.B * self.batch_steps
        return self.wasted_slot_steps / total if total else 0.0

    def compile_counts(self) -> dict:
        return {name: _jit_cache_size(fn)
                for name, fn in self._executables.items()}


@contextmanager
def _phase_span(engine, tracer, name: str, cat: str = "serve", fn=None,
                **args):
    """B/E span around one engine phase, recorded only when the caller already
    checked ``obs.enabled()``.  The body may set two keys on the yielded
    state dict: ``sync`` (a jax value to ``block_until_ready`` before the E
    event, so durations measure work rather than dispatch) and ``end_args``
    (extra fields for the E event).  If ``fn``'s jit cache grew during the
    span, the span is flagged ``compiled=True``, a ``jit.compile`` instant is
    emitted, and the duration feeds ``engine.compile_time_s`` — the number
    the CLIs subtract to report steady-state throughput.  After the span,
    ``st["dur_s"]`` holds the measured duration."""
    before = _jit_cache_size(fn) if fn is not None else -1
    tracer.begin(name, cat, **args)
    t0 = time.perf_counter()
    st: dict = {}
    try:
        yield st
        if st.get("sync") is not None:
            jax.block_until_ready(st["sync"])
    finally:
        st["dur_s"] = time.perf_counter() - t0
        end_args = dict(st.get("end_args") or {})
        if fn is not None and _jit_cache_size(fn) > before:
            end_args["compiled"] = True
            engine.compile_time_s += st["dur_s"]
            tracer.instant("jit.compile", "jit", phase=name)
        tracer.end(name, cat, **end_args)


def _place_engine_packs(model, mesh) -> None:
    """Pre-place the model's sharded approx pack before jitting the engine
    executables (``ApproxConfig.place_packs``): idempotent when
    ``build_model(cfg, mesh=...)`` already placed it, and covers engines whose
    mesh only exists at serve time — packs requested after this call capture
    per-core slices instead of paying a first-dispatch reshard."""
    if mesh is None:
        from repro.parallel.sharding import current_mesh
        mesh = current_mesh()
    approx = getattr(getattr(model, "cfg", None), "approx", None)
    if approx is not None:
        approx.place_packs(mesh)


def _check_engine_batch(engine, batch_size: int) -> None:
    if engine.B != batch_size:
        raise ValueError(f"engine batch size {engine.B} != requested "
                         f"{batch_size} (a passed engine overrides cache_len/"
                         "temperature/seed; batch_size must agree)")


class DecodeEngine(_EngineBase):
    """Fixed-batch prefill + decode (the static scheduler's inner engine)."""

    def __init__(self, model, params, batch_size: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0, mesh=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.metrics = obs.Registry()  # ttft_s / itl_s histograms
        _place_engine_packs(model, mesh)
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step)
        self._executables = {"prefill": self._prefill,
                             "decode_step": self._step}
        self.reset_counters()

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1)

    def generate_batch(self, prompts: np.ndarray, max_new,
                       eos_id=-1, extra_inputs: Optional[dict] = None):
        """prompts: (B, S) int32, right-aligned equal length (caller pads).

        ``max_new`` and ``eos_id`` are scalars applied to the whole batch or
        (B,) vectors of per-slot budgets / EOS ids (-1: that slot never stops
        early).  Returns ``(tokens, steps)`` where ``steps`` is the
        batch-wide sampling-round count (every round samples one token per
        slot, including the round fed by the prefill logits); the loop stops
        as soon as EVERY slot has hit its own EOS or its own budget, so
        finished and padding slots never drag the group to the max budget.
        """
        B, S = prompts.shape
        assert B == self.B
        rec = obs.enabled()
        tracer = obs.get_tracer() if rec else None
        t0 = time.perf_counter()
        eos = np.broadcast_to(np.asarray(eos_id, np.int64), (B,))
        budget = np.broadcast_to(np.asarray(max_new, np.int64), (B,))
        horizon = int(budget.max())
        cache = self.model.init_cache(B, self.cache_len)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        cm = (_phase_span(self, tracer, "static.prefill", fn=self._prefill,
                          batch=B, prompt_len=S) if rec else nullcontext({}))
        with cm as st:
            logits, cache = self._prefill(self.params, batch, cache)
            st["sync"] = logits
        out = [self._sample(logits)]
        self.batch_steps += 1
        if rec:
            np.asarray(out[0])  # settle the first tokens for an honest TTFT
            self.metrics.histogram("ttft_s").observe(time.perf_counter() - t0)
        # only force a device->host sync per step when some slot can stop early
        has_eos = bool((eos >= 0).any())
        done = budget <= 1
        if has_eos:
            done = done | ((eos >= 0) & (np.asarray(out[0]) == eos))
        steps = 1  # the prefill logits already yielded one token
        cm = (_phase_span(self, tracer, "static.decode", fn=self._step)
              if rec else nullcontext({}))
        with cm as st:
            for i in range(horizon - 1):
                if done.all():
                    break
                self.wasted_slot_steps += int(done.sum())
                tok = out[-1][:, None].astype(jnp.int32)
                logits, cache = self._step(self.params, tok,
                                           jnp.asarray(S + i, jnp.int32),
                                           cache)
                nxt = self._sample(logits)
                out.append(nxt)
                steps += 1
                self.batch_steps += 1
                done = done | (budget <= steps)
                if has_eos:
                    done = done | ((eos >= 0) & (np.asarray(nxt) == eos))
            st["sync"] = out[-1]
            st["end_args"] = {"steps": steps - 1}
        if rec and steps > 1:
            # decode ticks are uniform in the static loop, so the amortized
            # per-step interval stands in for each inter-token latency
            itl = st["dur_s"] / (steps - 1)
            hist = self.metrics.histogram("itl_s")
            for _ in range(steps - 1):
                hist.observe(itl)
        return np.stack([np.asarray(t) for t in out], axis=1), steps


def pad_and_batch(requests: List[Request], batch_size: int, pad_id: int = 0):
    """Left-pad prompts to a common length; group into fixed-size batches."""
    groups = [requests[i : i + batch_size]
              for i in range(0, len(requests), batch_size)]
    out = []
    for g in groups:
        while len(g) < batch_size:
            g = g + [Request(prompt=np.zeros((1,), np.int32), max_new_tokens=1)]
        maxlen = max(len(r.prompt) for r in g)
        toks = np.full((batch_size, maxlen), pad_id, np.int32)
        for i, r in enumerate(g):
            toks[i, maxlen - len(r.prompt):] = r.prompt
        out.append((g, toks))
    return out


def serve_static(model, params, requests: List[Request], batch_size: int,
                 cache_len: int, temperature: float = 0.0, seed: int = 0,
                 engine: Optional[DecodeEngine] = None) -> List[Result]:
    """Fixed-group scheduler: one prefill + decode loop per group of
    ``batch_size`` requests (short groups padded with 1-token dummies).
    Pass ``engine`` to reuse compiled executables across calls and to read
    the round/wasted-step counters afterwards — the engine's own cache_len/
    temperature/seed then apply and those arguments are ignored."""
    if engine is None:
        engine = DecodeEngine(model, params, batch_size, cache_len, temperature,
                              seed)
    else:
        _check_engine_batch(engine, batch_size)
    results: List[Result] = []
    for group, toks in pad_and_batch(requests, batch_size):
        budgets = np.asarray([r.max_new_tokens for r in group], np.int64)
        eos = np.asarray([r.eos_id for r in group], np.int64)
        gen, _ = engine.generate_batch(toks, budgets, eos)
        for i, r in enumerate(group):
            kept = _trim_at_eos(gen[i], r.max_new_tokens, r.eos_id)
            results.append(Result(tokens=kept, prompt_len=len(r.prompt),
                                  steps=len(kept)))
    return results[: len(requests)]


# Legacy name: PR 1..3 callers imported ``serve`` for the fixed-batch path.
serve = serve_static


# ======================================================================================
# Continuous batching: admission queue + mid-stream slot refill
# ======================================================================================


def cache_batch_axes(model, cache_len: int):
    """Per-leaf batch axis of the model's decode cache, inferred by comparing
    abstract caches at two batch sizes.  Every leaf must carry exactly one
    batch axis — per-slot position buffers included — or slot refill cannot
    gather/scatter that leaf."""
    a = jax.eval_shape(lambda: model.init_cache(1, cache_len))
    b = jax.eval_shape(lambda: model.init_cache(2, cache_len))

    def one(x, y):
        diffs = [i for i, (p, q) in enumerate(zip(x.shape, y.shape)) if p != q]
        if len(diffs) != 1:
            raise ValueError(
                "cache leaf without a unique batch axis: "
                f"{x.shape} vs {y.shape} — ContinuousEngine needs per-slot "
                "cache rows (shared-clock caches cannot be refilled)")
        return diffs[0]

    return jax.tree.map(one, a, b)


def scatter_cache_slots(dst, src, slot_ids: Sequence[int], axes):
    """dst[..., slot, ...] = src[..., slot, ...] for each refilled slot, per
    leaf along its batch axis.  Host-orchestrated (eager ops, outside jit), so
    refill never touches the decode executable."""
    sl = jnp.asarray(list(slot_ids), jnp.int32)

    def one(d, s, ax):
        idx = (slice(None),) * ax + (sl,)
        return d.at[idx].set(jnp.take(s, sl, axis=ax))

    return jax.tree.map(one, dst, src, axes)


@dataclass
class _Slot:
    req_idx: int
    prompt_len: int
    budget: int
    eos_id: int
    emitted: list = field(default_factory=list)


class ContinuousEngine(_EngineBase):
    """Admission queue + per-slot lifecycle + mid-stream slot refill.

    Every prompt is left-padded to one fixed prefill width (``prefill_len``,
    default: the queue's longest prompt), so admission — initial fill and
    every refill — reuses ONE compiled prefill; per-slot position clocks keep
    every decode tick at one shape, so the whole queue is served by exactly
    two executables (assert via ``compile_counts()``).  Greedy output is
    token-identical to serving each request alone (per-request oracle): slot
    rows never interact, and a refilled slot's scattered cache rows are
    exactly the rows a solo prefill would have produced.

    Token-only prompts (models whose prefill needs extra inputs — encoder
    frames, vision patches — are served by ``serve_static`` only).
    """

    def __init__(self, model, params, batch_size: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 prefill_len: Optional[int] = None, pad_id: int = 0,
                 mesh=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.prefill_len = prefill_len
        self.pad_id = pad_id
        self.metrics = obs.Registry()  # ttft_s / itl_s / queue_wait_s
        _place_engine_packs(model, mesh)
        self._prefill = jax.jit(model.prefill)

        # One fused executable per decode tick: step + greedy argmax + clock
        # advance, with the fed-back token and the per-slot positions staying
        # device-resident (host pushes them only at refill rounds — per-tick
        # host->device transfers would otherwise rival the step itself).
        def tick(params, tok, pos, cache):
            logits, cache = model.decode_step(params, tok, pos, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt[:, None], logits, pos + 1, cache

        self._tick = jax.jit(tick)
        self._executables = {"prefill": self._prefill,
                             "decode_step": self._tick}
        self._axes = None
        self._fresh = None
        self.reset_counters()

    def reset_counters(self) -> None:
        super().reset_counters()
        self.prefills = 0
        self.refills = 0  # admissions into a previously-used slot

    # ------------------------------ sampling ---------------------------------

    def _sample_row(self, row: np.ndarray, req_idx: int, tok_step: int) -> int:
        """Per-request RNG stream: token ``tok_step`` of request ``req_idx``
        depends only on (engine seed, req_idx, tok_step, that row's logits) —
        reproducible regardless of which slot the request landed in."""
        if self.temperature <= 0.0:
            return int(np.argmax(row))
        k = jax.random.fold_in(jax.random.fold_in(self.key, req_idx), tok_step)
        return int(jax.random.categorical(
            k, jnp.asarray(row) / self.temperature))

    # ------------------------------- serve -----------------------------------

    def serve(self, requests: List[Request],
              on_result: Optional[Callable[[int, Result], None]] = None
              ) -> List[Result]:
        if not requests:
            return []
        B = self.B
        S0 = self.prefill_len or max(len(r.prompt) for r in requests)
        longest = max(len(r.prompt) for r in requests)
        if longest > S0:
            raise ValueError(f"prompt of length {longest} exceeds the "
                             f"prefill width {S0}")
        if S0 > self.cache_len:
            raise ValueError(f"prefill width {S0} exceeds cache_len "
                             f"{self.cache_len}")
        if self._axes is None:
            self._axes = cache_batch_axes(self.model, self.cache_len)
        if self._fresh is None:
            self._fresh = self.model.init_cache(B, self.cache_len)

        # Per-request lifecycle spans live on one trace track per slot
        # (tid = SLOT_TID0 + slot): a slot serves one request at a time, so
        # every track's B/E events are balanced and non-overlapping.  All
        # requests enqueue at serve() entry, so queue_wait_s is admission
        # time minus t0 and ttft_s additionally includes the prefill.
        rec = obs.enabled()
        tracer = obs.get_tracer() if rec else None
        t0 = time.perf_counter()
        if rec:
            tracer.set_thread_name(MAIN_TID, "engine")
            tracer.instant("serve.begin", "serve", requests=len(requests),
                           batch=B, prefill_len=S0)

        results: List[Optional[Result]] = [None] * len(requests)
        pending = deque(enumerate(requests))
        live: List[Optional[_Slot]] = [None] * B
        used = [False] * B  # slots occupied before (this call): refill marker
        cache = self._fresh
        pos = np.zeros((B,), np.int64)  # host mirror of the per-slot clocks
        last = np.zeros((B,), np.int64)  # host mirror of last sampled tokens
        tok_dev = None  # (B, 1) int32 device-resident fed-back token
        pos_dev = None  # (B,) int32 device-resident clocks

        def emit(j: int, tok: int) -> None:
            s = live[j]
            s.emitted.append(tok)
            if (s.eos_id >= 0 and tok == s.eos_id) or \
                    len(s.emitted) >= s.budget:
                res = Result(tokens=np.asarray(s.emitted, np.int64),
                             prompt_len=s.prompt_len, steps=len(s.emitted))
                results[s.req_idx] = res
                if on_result is not None:
                    on_result(s.req_idx, res)
                if rec:
                    tracer.end("request", "request", SLOT_TID0 + j,
                               tokens=len(s.emitted))
                live[j] = None

        while True:
            # admission: one fixed-shape prefill serves every free slot
            # (budget-1 / instant-EOS admissions free their slot immediately,
            # so keep refilling until slots or queue run dry)
            admitted = False
            while pending and any(s is None for s in live):
                free = [j for j in range(B) if live[j] is None]
                rows = np.full((B, S0), self.pad_id, np.int32)
                take = []
                for j in free:
                    i, r = None, None
                    while pending:  # zero-budget requests never take a slot
                        i, r = pending.popleft()
                        if r.max_new_tokens >= 1:
                            break
                        res = Result(tokens=np.zeros((0,), np.int64),
                                     prompt_len=len(r.prompt), steps=0)
                        results[i] = res
                        if on_result is not None:
                            on_result(i, res)
                        i, r = None, None
                    if r is None:
                        break
                    rows[j, S0 - len(r.prompt):] = r.prompt
                    take.append((j, i, r))
                if not take:
                    break
                t_admit = time.perf_counter()
                cm = (_phase_span(self, tracer, "refill.prefill",
                                  fn=self._prefill, admitted=len(take))
                      if rec else nullcontext({}))
                with cm as st:
                    logits, rcache = self._prefill(
                        self.params, {"tokens": jnp.asarray(rows)},
                        self._fresh)
                    st["sync"] = logits
                self.prefills += 1
                self.batch_steps += 1
                self.wasted_slot_steps += B - len(take)
                self.refills += sum(used[j] for j, _, _ in take)
                for j, _, _ in take:
                    used[j] = True
                cm = (_phase_span(self, tracer, "refill.scatter",
                                  slots=len(take)) if rec else nullcontext({}))
                with cm as st:
                    cache = scatter_cache_slots(cache, rcache,
                                                [j for j, _, _ in take],
                                                self._axes)
                    st["sync"] = cache
                lg = np.asarray(logits)
                for j, i, r in take:
                    live[j] = _Slot(req_idx=i, prompt_len=len(r.prompt),
                                    budget=r.max_new_tokens, eos_id=r.eos_id)
                    pos[j] = S0
                    if rec:
                        tracer.set_thread_name(SLOT_TID0 + j, f"slot {j}")
                        tracer.begin("request", "request", SLOT_TID0 + j,
                                     req_idx=i, prompt_len=len(r.prompt),
                                     budget=r.max_new_tokens)
                        self.metrics.histogram("queue_wait_s").observe(
                            t_admit - t0)
                    tok = self._sample_row(lg[j], i, 0)
                    last[j] = tok
                    if rec:
                        tracer.instant("first_token", "request",
                                       SLOT_TID0 + j, req_idx=i)
                        self.metrics.histogram("ttft_s").observe(
                            time.perf_counter() - t0)
                    emit(j, tok)
                admitted = True
                if rec:
                    tracer.counter("slots_occupied",
                                   sum(s is not None for s in live))

            if all(s is None for s in live):
                break

            if admitted or tok_dev is None:
                # push the host mirrors once per refill round, not per tick
                tok_dev = jnp.asarray(last[:, None], jnp.int32)
                pos_dev = jnp.asarray(pos, jnp.int32)

            # Greedy slots with no live EOS can only leave the batch at a
            # known budget boundary: run the fused tick (step + argmax +
            # clock advance) up to that boundary with no host feedback, so
            # dispatches pipeline like the static engine's inner loop; one
            # sync then settles the whole span.  EOS-bearing or sampled
            # slots need per-tick feedback (k = 1).
            alive = [s for s in live if s is not None]
            if self.temperature <= 0.0 and all(s.eos_id < 0 for s in alive):
                k = min(s.budget - len(s.emitted) for s in alive)
            else:
                k = 1
            n_free = sum(s is None for s in live)
            cm = (_phase_span(self, tracer, "decode.span", fn=self._tick,
                              k=k, slots=B - n_free)
                  if rec else nullcontext({}))
            with cm as st:
                pend = []
                for _ in range(k):
                    tok_dev, logits, pos_dev, cache = self._tick(
                        self.params, tok_dev, pos_dev, cache)
                    pend.append(tok_dev)
                    self.batch_steps += 1
                    self.wasted_slot_steps += n_free
                # the settle belongs to the span: span duration then covers
                # device work, not just dispatch
                if self.temperature <= 0.0:
                    span = [np.asarray(t)[:, 0] for t in pend]
                else:  # k == 1: per-slot RNG sampling overrides argmax token
                    lg = np.asarray(logits)
                    toks = last.copy()
                    for j in range(B):
                        if live[j] is not None:
                            toks[j] = self._sample_row(lg[j],
                                                       live[j].req_idx,
                                                       len(live[j].emitted))
                    tok_dev = jnp.asarray(toks[:, None], jnp.int32)
                    span = [toks]
            if rec and B > n_free:
                # every live slot got one token per tick, k ticks per span
                itl = st["dur_s"] / k
                hist = self.metrics.histogram("itl_s")
                for _ in range(k * (B - n_free)):
                    hist.observe(itl)
            for toks in span:
                for j in range(B):
                    s = live[j]
                    if s is None:
                        continue  # drained queue: slot decodes garbage
                    last[j] = toks[j]
                    emit(j, int(toks[j]))
            pos += k
            if rec:
                tracer.counter("slots_occupied",
                               sum(s is not None for s in live))

        return results


def serve_continuous(model, params, requests: List[Request], batch_size: int,
                     cache_len: int, temperature: float = 0.0, seed: int = 0,
                     prefill_len: Optional[int] = None,
                     engine: Optional[ContinuousEngine] = None) -> List[Result]:
    """Continuous-batching scheduler (admission queue + mid-stream refill).
    Pass ``engine`` to reuse compiled executables across calls and to read
    the round/wasted-step counters afterwards — the engine's own cache_len/
    temperature/seed/prefill_len then apply and those arguments are
    ignored."""
    if engine is None:
        engine = ContinuousEngine(model, params, batch_size, cache_len,
                                  temperature, seed, prefill_len=prefill_len)
    else:
        _check_engine_batch(engine, batch_size)
    return engine.serve(requests)
