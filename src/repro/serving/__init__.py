"""repro.serving subpackage: static and continuous-batching decode drivers."""

from .engine import (  # noqa: F401
    ContinuousEngine,
    DecodeEngine,
    Request,
    Result,
    cache_batch_axes,
    pad_and_batch,
    scatter_cache_slots,
    serve,
    serve_continuous,
    serve_static,
)
