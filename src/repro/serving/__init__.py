"""repro.serving subpackage."""
