"""Routed Pallas kernels: per-row DYNAMIC fn_id dispatch via scalar prefetch.

The static pack kernels (``table_pack_lookup``) bake ``fn_id`` into the trace,
so a batch mixing functions — MoE-style routed activations, heterogeneous
serve traffic — needs one compiled executable per member.  Here the per-row
``fn_ids`` vector is a RUNTIME operand instead: ``pltpu.PrefetchScalarGridSpec``
prefetches it (plus the per-member interval counts / ragged offsets) into SMEM
before the grid runs, and

  * for the f32 :class:`TablePack`, the metadata BlockSpec *index maps* read
    ``fn_ids[i]`` to choose which (F, n_max) plane row is DMA'd into VMEM for
    grid row i — the scalar prefetch literally steers the DMA, the kernel body
    is the static body with a dynamic interval count;
  * for the :class:`QuantTablePack`, the ragged flat lanes stay whole-pinned
    in VMEM and the prefetched ``bounds_offsets`` / ``lane_offsets`` /
    ``entry_bits`` scalars (``pack.routing_scalars()``) index a member's lane
    segment and width group at runtime — gathers at ``offset + j`` replace the
    python-slice-at-trace-time of the static kernel, and both code vectors are
    gathered with the live one selected per row.

Grid geometry: one grid row per input row (the routing granularity), columns
blocked at ``block_cols``.  Because ``fn_ids`` (and the per-member flag
vectors) are runtime operands, RE-ROUTING NEVER RECOMPILES: one executable
serves every assignment of functions to rows, collapsing F specializations
into one.

Bit-parity contract (tests/test_routed_pack.py, tests/test_properties.py):
row i of every routed output is bit-identical under jit to the static-fn_id
dispatch of member ``fn_ids[i]`` — the kernel bodies run the same f32
compare/gather/FMA sequence as the static kernels, with the static python
branches (interval count, extrapolate, codes width) replaced by value-equal
dynamic selects.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.approx.table_pack import (PolyTablePack, QuantTablePack,
                                     ShardedTablePack, TablePack, poly_horner,
                                     poly_horner_d1, resolve_fn_ids,
                                     routed_extr_flags)

DEFAULT_BLOCK_COLS = 65536  # (1, 65536) f32 tile = 256 KiB in + 256 KiB out


def tile_routed_rows(x: jax.Array, block_cols: int):
    """Flatten trailing dims and zero-pad columns for the routed grid.

    Rows are the routing granularity and stay unpadded (the grid is exactly
    (R, C_pad/block)); only columns pad to a lane multiple.  Returns
    ``(x2d, block, C)`` with ``block`` the largest 128-multiple column block
    <= ``block_cols`` that tiles ``C_pad``.
    """
    if x.ndim < 1:
        raise ValueError("routed dispatch needs a leading row axis (one "
                         "function id per row); got a 0-d input")
    flat = x.reshape(x.shape[0], -1)
    c = flat.shape[1]
    cpad = -(-c // 128) * 128
    block = min(-(-block_cols // 128) * 128, cpad)
    cpad = -(-cpad // block) * block
    if cpad != c:
        flat = jnp.pad(flat, ((0, 0), (0, cpad - c)))
    return flat, block, c


def _untile_rows(out2d: jax.Array, c: int, shape) -> jax.Array:
    return out2d[:, :c].reshape(shape)


# --------------------------------------------------------------------------------------
# f32 TablePack: prefetched fn_ids steer the metadata-row DMA.
# --------------------------------------------------------------------------------------


def _routed_select(x, brow, invd_row, base_row, segs_row, nf):
    """The static comparator plane + gathers with a DYNAMIC interval count.

    Same ops as ``table_lookup.select_params`` on the fn_ids-selected padded
    row: +inf padding never compares true, so the unclipped count ``ju`` only
    needs the dynamic ``min(ju, nf - 1)`` clip.  Returns ``ju`` too — the
    grad kernel derives the domain test ``x < b_nf`` from it (``ju < nf``)
    without a dynamic VMEM read.
    """
    ju = jnp.sum((x[..., None] >= brow[1:]).astype(jnp.int32), axis=-1)
    j = jnp.minimum(ju, nf - 1)
    p = jnp.take(brow, j, axis=0, mode="clip")
    invd = jnp.take(invd_row, j, axis=0, mode="clip")
    base = jnp.take(base_row, j, axis=0, mode="clip")
    segs = jnp.take(segs_row, j, axis=0, mode="clip")
    return ju, p, invd, base, segs


def _routed_kernel(ids_ref, n_ref, extr_ref, x_ref, bounds_ref, invd_ref,
                   base_ref, segs_ref, values_ref, o_ref):
    r = pl.program_id(0)
    fid = ids_ref[r]
    nf = n_ref[fid]
    extr = extr_ref[fid]
    x = x_ref[...].astype(jnp.float32)

    # the BlockSpec index map already DMA'd member fid's metadata row here
    _, p, invd, base, segs = _routed_select(
        x, bounds_ref[0, :], invd_ref[0, :], base_ref[0, :], segs_ref[0, :], nf)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)

    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = u - i
    t = jnp.where(extr > 0, t, jnp.clip(t, 0.0, 1.0))
    o_ref[...] = (y0 + t * (y1 - y0)).astype(o_ref.dtype)


def _routed_grad_kernel(ids_ref, n_ref, extr_ref, x_ref, bounds_ref, invd_ref,
                        base_ref, segs_ref, values_ref, y_ref, dy_ref):
    r = pl.program_id(0)
    fid = ids_ref[r]
    nf = n_ref[fid]
    extr = extr_ref[fid]
    x = x_ref[...].astype(jnp.float32)

    brow = bounds_ref[0, :]
    ju, p, invd, base, segs = _routed_select(
        x, brow, invd_ref[0, :], base_ref[0, :], segs_ref[0, :], nf)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = u - i
    slope = (y1 - y0) * invd
    inside = ((x >= brow[0]) & (ju < nf)).astype(jnp.float32)
    t = jnp.where(extr > 0, t, jnp.clip(t, 0.0, 1.0))
    slope = jnp.where(extr > 0, slope, slope * inside)
    y_ref[...] = (y0 + t * (y1 - y0)).astype(y_ref.dtype)
    dy_ref[...] = slope.astype(dy_ref.dtype)


def _routed_grid_spec(x2d, n_max: int, values_shape, block_cols: int,
                      n_outs: int, num_scalars: int, pinned_meta: bool,
                      extra_pinned=(), n_meta_rows: int = 3):
    """PrefetchScalarGridSpec shared by the routed entry points.

    ``pinned_meta=False`` (f32 pack): the metadata planes — the boundary row
    plus ``n_meta_rows`` (F, n_max) planes (3 for the replicated pack, 4 for
    the sharded pack, which adds the ownership plane) — are streamed per grid
    row with ``fn_ids[i]`` as the DMA row index.  ``pinned_meta=True`` (quant
    pack): the ragged flat lanes stay whole-resident and the kernel indexes
    them with prefetched offsets.
    """
    rows, cpad = x2d.shape

    def row_map(i, j, *_):
        return (i, j)

    def fid_map(i, j, ids, *_):
        return (ids[i], 0)

    def pin_map(i, j, *_):
        return (0, 0)

    x_spec = pl.BlockSpec((1, block_cols), row_map)
    if pinned_meta:
        in_specs = [x_spec] + [pl.BlockSpec(s, pin_map) for s in extra_pinned]
    else:
        in_specs = ([x_spec, pl.BlockSpec((1, n_max + 1), fid_map)] +
                    [pl.BlockSpec((1, n_max), fid_map)] * n_meta_rows +
                    [pl.BlockSpec(values_shape, pin_map)])
    out_spec = pl.BlockSpec((1, block_cols), row_map)
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalars,
        grid=(rows, cpad // block_cols),
        in_specs=in_specs,
        out_specs=out_spec if n_outs == 1 else [out_spec] * n_outs,
    )


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret",
                                             "n_max", "grad"))
def _routed_call(ids, n_arr, extr_arr, x2d, bounds, invd, base, segs, values,
                 *, block_cols, interpret, n_max, grad):
    n_outs = 2 if grad else 1
    grid_spec = _routed_grid_spec(x2d, n_max, values.shape, block_cols,
                                  n_outs, num_scalars=3, pinned_meta=False)
    kernel = _routed_grad_kernel if grad else _routed_kernel
    out_shape = jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape if not grad else [out_shape] * 2,
        interpret=interpret,
    )(ids, n_arr, extr_arr, x2d, bounds, invd, base, segs, values)


def _routed_prep(pack, fn_ids, x, extrapolate, block_cols, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x2d, block, c = tile_routed_rows(x, block_cols)
    ids = resolve_fn_ids(pack, fn_ids, x2d.shape[0])
    extr = jnp.asarray(routed_extr_flags(pack, extrapolate))
    return x2d, block, c, ids, extr, interpret


def routed_pack_lookup_pallas(
    pack: TablePack,
    fn_ids,
    x: jax.Array,
    *,
    extrapolate=False,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool | None = None,
) -> jax.Array:
    """Row i of ``x`` through member ``fn_ids[i]`` — one executable for every
    routing.  ``fn_ids``: names/ints (validated) or a traced int vector."""
    x2d, block, c, ids, extr, interpret = _routed_prep(
        pack, fn_ids, x, extrapolate, block_cols, interpret)
    (n_arr,) = pack.routing_scalars()
    out = _routed_call(
        ids, jnp.asarray(n_arr), extr, x2d, pack.boundaries, pack.inv_delta,
        pack.base, pack.seg_count, pack.values.reshape(1, -1),
        block_cols=block, interpret=interpret, n_max=pack.n_max, grad=False)
    return _untile_rows(out, c, x.shape)


def routed_pack_grad_pallas(
    pack: TablePack,
    fn_ids,
    x: jax.Array,
    *,
    extrapolate=False,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool | None = None,
):
    """Routed (y, dy/dx) in one fused selector pass per row."""
    x2d, block, c, ids, extr, interpret = _routed_prep(
        pack, fn_ids, x, extrapolate, block_cols, interpret)
    (n_arr,) = pack.routing_scalars()
    y2d, dy2d = _routed_call(
        ids, jnp.asarray(n_arr), extr, x2d, pack.boundaries, pack.inv_delta,
        pack.base, pack.seg_count, pack.values.reshape(1, -1),
        block_cols=block, interpret=interpret, n_max=pack.n_max, grad=True)
    return _untile_rows(y2d, c, x.shape), _untile_rows(dy2d, c, x.shape)


# --------------------------------------------------------------------------------------
# QuantTablePack: prefetched ragged offsets + runtime width-group select.
# --------------------------------------------------------------------------------------


def _routed_quant_select(x, bounds, invd, base, segs, scale, zero, ramp,
                         bo, lo, nf, n_max: int):
    """Masked comparator over the fid's ragged lane segment + seven gathers.

    The static kernel slices ``[bo : bo + n]`` at trace time; here ``bo``/
    ``lo`` are runtime scalars, so the comparator gathers the boundary row at
    ``bo + m`` and masks lanes past the member's real count (they belong to
    the NEXT member and would otherwise compare true).  All parameter gathers
    hit exactly the static kernel's elements: ``lane[lo + j]``.
    """
    m = jax.lax.broadcasted_iota(jnp.int32, (1, n_max), 1) + 1  # (1, n_max)
    bvals = jnp.take(bounds, bo + m[0], axis=0, mode="clip")  # (n_max,)
    cmp = (x[..., None] >= bvals) & (m[0] <= nf)
    ju = jnp.sum(cmp.astype(jnp.int32), axis=-1)
    j = jnp.minimum(ju, nf - 1)
    p = jnp.take(bounds, bo + j, axis=0, mode="clip")
    gl = lo + j
    return (ju, p,
            jnp.take(invd, gl, axis=0, mode="clip"),
            jnp.take(base, gl, axis=0, mode="clip"),
            jnp.take(segs, gl, axis=0, mode="clip"),
            jnp.take(scale, gl, axis=0, mode="clip"),
            jnp.take(zero, gl, axis=0, mode="clip"),
            jnp.take(ramp, gl, axis=0, mode="clip"))


def _gather_codes(codes8_ref, codes16_ref, a, bits):
    """Adjacent-pair gather from BOTH width groups, live one selected per row
    (the static kernel's python-time ``codes_for(fid)`` made dynamic)."""
    c8 = jnp.take(codes8_ref[0, :], a, axis=0, mode="clip").astype(jnp.float32)
    c16 = jnp.take(codes16_ref[0, :], a, axis=0,
                   mode="clip").astype(jnp.float32)
    return jnp.where(bits == 8, c8, c16)


def _routed_quant_kernel(ids_ref, n_ref, extr_ref, bo_ref, lo_ref, bits_ref,
                         x_ref, bounds_ref, invd_ref, base_ref, segs_ref,
                         scale_ref, zero_ref, ramp_ref, codes8_ref,
                         codes16_ref, o_ref, *, n_max: int):
    r = pl.program_id(0)
    fid = ids_ref[r]
    nf, extr = n_ref[fid], extr_ref[fid]
    bo, lo, bits = bo_ref[fid], lo_ref[fid], bits_ref[fid]
    x = x_ref[...].astype(jnp.float32)

    _, p, invd, base, segs, scale, zero, ramp = _routed_quant_select(
        x, bounds_ref[0, :], invd_ref[0, :], base_ref[0, :], segs_ref[0, :],
        scale_ref[0, :], zero_ref[0, :], ramp_ref[0, :], bo, lo, nf, n_max)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    c0 = _gather_codes(codes8_ref, codes16_ref, a, bits)
    c1 = _gather_codes(codes8_ref, codes16_ref, a + 1, bits)

    r_ = zero + ramp * i  # dequantize-on-read: chord ramp + scaled code
    y0 = r_ + scale * c0
    y1 = (r_ + ramp) + scale * c1

    t = u - i
    t = jnp.where(extr > 0, t, jnp.clip(t, 0.0, 1.0))
    o_ref[...] = (y0 + t * (y1 - y0)).astype(o_ref.dtype)


def _routed_quant_grad_kernel(ids_ref, n_ref, extr_ref, bo_ref, lo_ref,
                              bits_ref, x_ref, bounds_ref, invd_ref, base_ref,
                              segs_ref, scale_ref, zero_ref, ramp_ref,
                              codes8_ref, codes16_ref, y_ref, dy_ref, *,
                              n_max: int):
    r = pl.program_id(0)
    fid = ids_ref[r]
    nf, extr = n_ref[fid], extr_ref[fid]
    bo, lo, bits = bo_ref[fid], lo_ref[fid], bits_ref[fid]
    x = x_ref[...].astype(jnp.float32)

    bounds = bounds_ref[0, :]
    ju, p, invd, base, segs, scale, zero, ramp = _routed_quant_select(
        x, bounds, invd_ref[0, :], base_ref[0, :], segs_ref[0, :],
        scale_ref[0, :], zero_ref[0, :], ramp_ref[0, :], bo, lo, nf, n_max)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    c0 = _gather_codes(codes8_ref, codes16_ref, a, bits)
    c1 = _gather_codes(codes8_ref, codes16_ref, a + 1, bits)

    r_ = zero + ramp * i
    y0 = r_ + scale * c0
    y1 = (r_ + ramp) + scale * c1

    t = u - i
    slope = (ramp + scale * (c1 - c0)) * invd
    p0 = jnp.take(bounds, bo, axis=0, mode="clip")
    inside = ((x >= p0) & (ju < nf)).astype(jnp.float32)
    t = jnp.where(extr > 0, t, jnp.clip(t, 0.0, 1.0))
    slope = jnp.where(extr > 0, slope, slope * inside)
    y_ref[...] = (y0 + t * (y1 - y0)).astype(y_ref.dtype)
    dy_ref[...] = slope.astype(dy_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret",
                                             "n_max", "grad"))
def _routed_quant_call(ids, n_arr, extr_arr, bo_arr, lo_arr, bits_arr, x2d,
                       bounds, invd, base, segs, scale, zero, ramp, codes8,
                       codes16, *, block_cols, interpret, n_max, grad):
    operands = (bounds, invd, base, segs, scale, zero, ramp, codes8, codes16)
    n_outs = 2 if grad else 1
    grid_spec = _routed_grid_spec(
        x2d, n_max, None, block_cols, n_outs, num_scalars=6, pinned_meta=True,
        extra_pinned=[a.shape for a in operands])
    kernel = functools.partial(
        _routed_quant_grad_kernel if grad else _routed_quant_kernel,
        n_max=n_max)
    out_shape = jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape if not grad else [out_shape] * 2,
        interpret=interpret,
    )(ids, n_arr, extr_arr, bo_arr, lo_arr, bits_arr, x2d, *operands)


def _quant_routed_args(pack: QuantTablePack):
    scalars = tuple(jnp.asarray(s) for s in pack.routing_scalars())
    operands = (pack.boundaries.reshape(1, -1), pack.inv_delta.reshape(1, -1),
                pack.base.reshape(1, -1), pack.seg_count.reshape(1, -1),
                pack.scale.reshape(1, -1), pack.zero.reshape(1, -1),
                pack.ramp.reshape(1, -1), pack.codes8.reshape(1, -1),
                pack.codes16.reshape(1, -1))
    n_max = int(np.max(pack.n_intervals))
    return scalars, operands, n_max


def routed_quant_pack_lookup_pallas(
    pack: QuantTablePack,
    fn_ids,
    x: jax.Array,
    *,
    extrapolate=False,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool | None = None,
) -> jax.Array:
    """Routed dequantize-on-read: row i through quantized member fn_ids[i]."""
    x2d, block, c, ids, extr, interpret = _routed_prep(
        pack, fn_ids, x, extrapolate, block_cols, interpret)
    (n_arr, bo_arr, lo_arr, bits_arr), operands, n_max = \
        _quant_routed_args(pack)
    out = _routed_quant_call(
        ids, n_arr, extr, bo_arr, lo_arr, bits_arr, x2d, *operands,
        block_cols=block, interpret=interpret, n_max=n_max, grad=False)
    return _untile_rows(out, c, x.shape)


def routed_quant_pack_grad_pallas(
    pack: QuantTablePack,
    fn_ids,
    x: jax.Array,
    *,
    extrapolate=False,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool | None = None,
):
    """Routed quantized (y, dy/dx) in one fused selector pass per row."""
    x2d, block, c, ids, extr, interpret = _routed_prep(
        pack, fn_ids, x, extrapolate, block_cols, interpret)
    (n_arr, bo_arr, lo_arr, bits_arr), operands, n_max = \
        _quant_routed_args(pack)
    y2d, dy2d = _routed_quant_call(
        ids, n_arr, extr, bo_arr, lo_arr, bits_arr, x2d, *operands,
        block_cols=block, interpret=interpret, n_max=n_max, grad=True)
    return _untile_rows(y2d, c, x.shape), _untile_rows(dy2d, c, x.shape)


# --------------------------------------------------------------------------------------
# ShardedTablePack: routed dispatch over ONE shard's values slice, unowned masked.
# --------------------------------------------------------------------------------------
#
# Same scalar-prefetch dispatch as the f32 routed kernels — fn_ids steer the
# metadata-row DMA — but the values operand is one SHARD's padded slice, the
# base plane holds shard-local rebased addresses, and a fourth streamed plane
# (the ownership mask, gathered at the selected sub-interval like the other
# parameters) zeroes rows of elements the shard does not own.  Per-shard
# outputs sum to the replicated routed result bit for bit (one owner + zeros),
# so ONE executable still serves every routing — per shard.


def _sharded_routed_kernel(ids_ref, n_ref, extr_ref, x_ref, bounds_ref,
                           invd_ref, lbase_ref, segs_ref, own_ref, values_ref,
                           o_ref):
    r = pl.program_id(0)
    fid = ids_ref[r]
    nf = n_ref[fid]
    extr = extr_ref[fid]
    x = x_ref[...].astype(jnp.float32)

    ju, p, invd, base, segs = _routed_select(
        x, bounds_ref[0, :], invd_ref[0, :], lbase_ref[0, :], segs_ref[0, :],
        nf)
    j = jnp.minimum(ju, nf - 1)
    own = jnp.take(own_ref[0, :], j, axis=0, mode="clip")

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)  # SHARD-LOCAL (rebased at plan time)

    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = u - i
    t = jnp.where(extr > 0, t, jnp.clip(t, 0.0, 1.0))
    y = y0 + t * (y1 - y0)
    o_ref[...] = jnp.where(own > 0, y, 0.0).astype(o_ref.dtype)


def _sharded_routed_grad_kernel(ids_ref, n_ref, extr_ref, x_ref, bounds_ref,
                                invd_ref, lbase_ref, segs_ref, own_ref,
                                values_ref, y_ref, dy_ref):
    r = pl.program_id(0)
    fid = ids_ref[r]
    nf = n_ref[fid]
    extr = extr_ref[fid]
    x = x_ref[...].astype(jnp.float32)

    brow = bounds_ref[0, :]
    ju, p, invd, base, segs = _routed_select(
        x, brow, invd_ref[0, :], lbase_ref[0, :], segs_ref[0, :], nf)
    j = jnp.minimum(ju, nf - 1)
    own = jnp.take(own_ref[0, :], j, axis=0, mode="clip")

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = u - i
    slope = (y1 - y0) * invd
    inside = ((x >= brow[0]) & (ju < nf)).astype(jnp.float32)
    t = jnp.where(extr > 0, t, jnp.clip(t, 0.0, 1.0))
    slope = jnp.where(extr > 0, slope, slope * inside)
    y_ref[...] = jnp.where(own > 0, y0 + t * (y1 - y0), 0.0).astype(y_ref.dtype)
    dy_ref[...] = jnp.where(own > 0, slope, 0.0).astype(dy_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret",
                                             "n_max", "grad"))
def _sharded_routed_call(ids, n_arr, extr_arr, x2d, bounds, invd, lbase, segs,
                         own, values, *, block_cols, interpret, n_max, grad):
    n_outs = 2 if grad else 1
    grid_spec = _routed_grid_spec(x2d, n_max, values.shape, block_cols,
                                  n_outs, num_scalars=3, pinned_meta=False,
                                  n_meta_rows=4)
    kernel = _sharded_routed_grad_kernel if grad else _sharded_routed_kernel
    out_shape = jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape if not grad else [out_shape] * 2,
        interpret=interpret,
    )(ids, n_arr, extr_arr, x2d, bounds, invd, lbase, segs, own, values)


def _sharded_routed_sum(pack: ShardedTablePack, fn_ids, x, extrapolate,
                        block_cols, interpret, grad: bool):
    x2d, block, c, ids, extr, interpret = _routed_prep(
        pack, fn_ids, x, extrapolate, block_cols, interpret)
    (n_arr,) = pack.routing_scalars()
    n_arr = jnp.asarray(n_arr)
    outs = None
    for s in range(pack.n_shards):
        o = _sharded_routed_call(
            ids, n_arr, extr, x2d, pack.boundaries, pack.inv_delta,
            pack.local_base[s], pack.seg_count, pack.owned[s],
            pack.values[s].reshape(1, -1),
            block_cols=block, interpret=interpret, n_max=pack.n_max, grad=grad)
        if not grad:
            o = (o,)
        outs = o if outs is None else tuple(a + b for a, b in zip(outs, o))
    return tuple(_untile_rows(o, c, x.shape) for o in outs)


def sharded_routed_pack_lookup_pallas(
    pack: ShardedTablePack,
    fn_ids,
    x: jax.Array,
    *,
    extrapolate=False,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool | None = None,
) -> jax.Array:
    """Row i of ``x`` through member ``fn_ids[i]`` of the SHARDED pack — one
    routed executable per shard, contributions summed (off-mesh path)."""
    (y,) = _sharded_routed_sum(pack, fn_ids, x, extrapolate, block_cols,
                               interpret, grad=False)
    return y


def sharded_routed_pack_grad_pallas(
    pack: ShardedTablePack,
    fn_ids,
    x: jax.Array,
    *,
    extrapolate=False,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool | None = None,
):
    """Routed sharded (y, dy/dx) — per-shard fused passes, summed."""
    return _sharded_routed_sum(pack, fn_ids, x, extrapolate, block_cols,
                               interpret, grad=True)


# --------------------------------------------------------------------------------------
# PolyTablePack: routed Horner over lane-padded mixed-degree / mixed-width cells.
# --------------------------------------------------------------------------------------
#
# Same prefetch dispatch as the quant routed kernels, with two extra runtime
# scalars per member: ``stride`` (= degree+1, the cell width in the code
# vectors) and a THREE-way width-group select (int8 / int16 / raw f32).  Every
# row runs a uniform ``lmax``-lane Horner: padded metadata lanes dequantize to
# exactly 0.0, and leading zero coefficients pass through Horner as
# ``0*t + c = c``, so the uniform loop is bit-identical to each member's own
# degree-L evaluation.


def _routed_poly_select(x, bounds, invd, base, segs, bo, lo, nf, n_max: int):
    """Masked comparator over the fid's lane segment + four selector gathers
    (the quant select minus the single-lane dequant params — poly dequant is
    per LANE and happens in the coefficient loop)."""
    m = jax.lax.broadcasted_iota(jnp.int32, (1, n_max), 1) + 1  # (1, n_max)
    bvals = jnp.take(bounds, bo + m[0], axis=0, mode="clip")  # (n_max,)
    cmp = (x[..., None] >= bvals) & (m[0] <= nf)
    ju = jnp.sum(cmp.astype(jnp.int32), axis=-1)
    j = jnp.minimum(ju, nf - 1)
    p = jnp.take(bounds, bo + j, axis=0, mode="clip")
    gl = lo + j
    return (ju, gl, p,
            jnp.take(invd, gl, axis=0, mode="clip"),
            jnp.take(base, gl, axis=0, mode="clip"),
            jnp.take(segs, gl, axis=0, mode="clip"))


def _gather_poly_codes(codes8_ref, codes16_ref, codes32_ref, a, bits):
    """Gather from all THREE width groups, live one selected per row (the
    static kernel's python-time ``codes_for(fid)`` made dynamic; f32 members
    store raw coefficients, so the 32-bit group needs no cast)."""
    c8 = jnp.take(codes8_ref[0, :], a, axis=0, mode="clip").astype(jnp.float32)
    c16 = jnp.take(codes16_ref[0, :], a, axis=0,
                   mode="clip").astype(jnp.float32)
    c32 = jnp.take(codes32_ref[0, :], a, axis=0, mode="clip")
    return jnp.where(bits == 8, c8, jnp.where(bits == 16, c16, c32))


def _routed_poly_coeffs(gl, i, base, bits, stride_f, zero_ref, ramp_ref,
                        scale_ref, codes8_ref, codes16_ref, codes32_ref, *,
                        lmax: int):
    """Uniform ``lmax`` lane-padded coefficient gather + dequant.

    Metadata lane l of global member cell ``gl`` lives at flat
    ``gl*lmax + l``; code lane l of sub-interval i at ``base + i*stride + l``
    (addresses past a member's real cell may alias neighbours, but the padded
    lane's (zero, ramp, scale) = (0, 0, 0) dequantizes them to exactly 0.0).
    """
    cs = []
    for l in range(lmax):
        gm = gl * lmax + l
        zl = jnp.take(zero_ref[0, :], gm, axis=0, mode="clip")
        rl = jnp.take(ramp_ref[0, :], gm, axis=0, mode="clip")
        sl = jnp.take(scale_ref[0, :], gm, axis=0, mode="clip")
        a = (base + i * stride_f + float(l)).astype(jnp.int32)
        q = _gather_poly_codes(codes8_ref, codes16_ref, codes32_ref, a, bits)
        cs.append((zl + rl * i) + sl * q)
    return cs


def _routed_poly_kernel(ids_ref, n_ref, extr_ref, bo_ref, lo_ref, bits_ref,
                        stride_ref, x_ref, bounds_ref, invd_ref, base_ref,
                        segs_ref, zero_ref, ramp_ref, scale_ref, codes8_ref,
                        codes16_ref, codes32_ref, o_ref, *, n_max: int,
                        lmax: int):
    r = pl.program_id(0)
    fid = ids_ref[r]
    nf, extr = n_ref[fid], extr_ref[fid]
    bo, lo, bits = bo_ref[fid], lo_ref[fid], bits_ref[fid]
    stride_f = stride_ref[fid].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)

    _, gl, p, invd, base, segs = _routed_poly_select(
        x, bounds_ref[0, :], invd_ref[0, :], base_ref[0, :], segs_ref[0, :],
        bo, lo, nf, n_max)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    cs = _routed_poly_coeffs(gl, i, base, bits, stride_f, zero_ref, ramp_ref,
                             scale_ref, codes8_ref, codes16_ref, codes32_ref,
                             lmax=lmax)

    t = u - i
    tc = jnp.clip(t, 0.0, 1.0)
    y = poly_horner(cs, tc)
    ye = y + poly_horner_d1(cs, tc) * (t - tc)
    o_ref[...] = jnp.where(extr > 0, ye, y).astype(o_ref.dtype)


def _routed_poly_grad_kernel(ids_ref, n_ref, extr_ref, bo_ref, lo_ref,
                             bits_ref, stride_ref, x_ref, bounds_ref, invd_ref,
                             base_ref, segs_ref, zero_ref, ramp_ref, scale_ref,
                             codes8_ref, codes16_ref, codes32_ref, y_ref,
                             dy_ref, *, n_max: int, lmax: int):
    r = pl.program_id(0)
    fid = ids_ref[r]
    nf, extr = n_ref[fid], extr_ref[fid]
    bo, lo, bits = bo_ref[fid], lo_ref[fid], bits_ref[fid]
    stride_f = stride_ref[fid].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)

    bounds = bounds_ref[0, :]
    ju, gl, p, invd, base, segs = _routed_poly_select(
        x, bounds, invd_ref[0, :], base_ref[0, :], segs_ref[0, :],
        bo, lo, nf, n_max)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    cs = _routed_poly_coeffs(gl, i, base, bits, stride_f, zero_ref, ramp_ref,
                             scale_ref, codes8_ref, codes16_ref, codes32_ref,
                             lmax=lmax)

    t = u - i
    tc = jnp.clip(t, 0.0, 1.0)
    y = poly_horner(cs, tc)
    g = poly_horner_d1(cs, tc)
    slope = g * invd
    p0 = jnp.take(bounds, bo, axis=0, mode="clip")
    inside = ((x >= p0) & (ju < nf)).astype(jnp.float32)
    y_ref[...] = jnp.where(extr > 0, y + g * (t - tc), y).astype(y_ref.dtype)
    dy_ref[...] = jnp.where(extr > 0, slope, slope * inside).astype(dy_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret",
                                             "n_max", "lmax", "grad"))
def _routed_poly_call(ids, n_arr, extr_arr, bo_arr, lo_arr, bits_arr,
                      stride_arr, x2d, bounds, invd, base, segs, zero, ramp,
                      scale, codes8, codes16, codes32, *, block_cols,
                      interpret, n_max, lmax, grad):
    operands = (bounds, invd, base, segs, zero, ramp, scale, codes8, codes16,
                codes32)
    n_outs = 2 if grad else 1
    grid_spec = _routed_grid_spec(
        x2d, n_max, None, block_cols, n_outs, num_scalars=7, pinned_meta=True,
        extra_pinned=[a.shape for a in operands])
    kernel = functools.partial(
        _routed_poly_grad_kernel if grad else _routed_poly_kernel,
        n_max=n_max, lmax=lmax)
    out_shape = jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape if not grad else [out_shape] * 2,
        interpret=interpret,
    )(ids, n_arr, extr_arr, bo_arr, lo_arr, bits_arr, stride_arr, x2d,
      *operands)


def _poly_routed_args(pack: PolyTablePack):
    scalars = tuple(jnp.asarray(s) for s in pack.routing_scalars())
    operands = (pack.boundaries.reshape(1, -1), pack.inv_delta.reshape(1, -1),
                pack.base.reshape(1, -1), pack.seg_count.reshape(1, -1),
                pack.zero.reshape(1, -1), pack.ramp.reshape(1, -1),
                pack.scale.reshape(1, -1), pack.codes8.reshape(1, -1),
                pack.codes16.reshape(1, -1), pack.codes32.reshape(1, -1))
    n_max = int(np.max(pack.n_intervals))
    return scalars, operands, n_max


def routed_poly_pack_lookup_pallas(
    pack: PolyTablePack,
    fn_ids,
    x: jax.Array,
    *,
    extrapolate=False,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool | None = None,
) -> jax.Array:
    """Routed Horner-on-read: row i through planner-chosen member fn_ids[i]."""
    x2d, block, c, ids, extr, interpret = _routed_prep(
        pack, fn_ids, x, extrapolate, block_cols, interpret)
    (n_arr, bo_arr, lo_arr, bits_arr, stride_arr), operands, n_max = \
        _poly_routed_args(pack)
    out = _routed_poly_call(
        ids, n_arr, extr, bo_arr, lo_arr, bits_arr, stride_arr, x2d, *operands,
        block_cols=block, interpret=interpret, n_max=n_max,
        lmax=pack.max_lanes, grad=False)
    return _untile_rows(out, c, x.shape)


def routed_poly_pack_grad_pallas(
    pack: PolyTablePack,
    fn_ids,
    x: jax.Array,
    *,
    extrapolate=False,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool | None = None,
):
    """Routed poly (y, dy/dx) in one fused selector + Horner pass per row."""
    x2d, block, c, ids, extr, interpret = _routed_prep(
        pack, fn_ids, x, extrapolate, block_cols, interpret)
    (n_arr, bo_arr, lo_arr, bits_arr, stride_arr), operands, n_max = \
        _poly_routed_args(pack)
    y2d, dy2d = _routed_poly_call(
        ids, n_arr, extr, bo_arr, lo_arr, bits_arr, stride_arr, x2d, *operands,
        block_cols=block, interpret=interpret, n_max=n_max,
        lmax=pack.max_lanes, grad=True)
    return _untile_rows(y2d, c, x.shape), _untile_rows(dy2d, c, x.shape)
