"""Pure-jnp oracles for every kernel in this package."""

from __future__ import annotations

import jax

from repro.approx.jax_table import JaxTable, eval_table_ref
from repro.approx.table_pack import TablePack, eval_pack_ref


def table_lookup_ref(jt: JaxTable, x: jax.Array, *, extrapolate: bool = False) -> jax.Array:
    """Oracle for ``table_lookup``: identical math, plain jnp ops."""
    return eval_table_ref(jt, x, extrapolate=extrapolate)


def table_pack_lookup_ref(pack: TablePack, fn, x: jax.Array, *,
                          extrapolate: bool = False) -> jax.Array:
    """Oracle for ``table_pack_lookup``: identical math, plain jnp ops."""
    return eval_pack_ref(pack, fn, x, extrapolate=extrapolate)
