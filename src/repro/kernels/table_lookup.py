"""Pallas TPU kernel: fused interval-select + table-lookup + linear interpolation.

This is the paper's Fig. 7 pipeline re-thought for the TPU memory hierarchy
(DESIGN.md §2):

  * the packed table (``values``) and selector metadata are **VMEM-resident for the
    whole kernel** — the BRAM analogue.  BlockSpecs pin them with a constant index
    map so every grid step reuses the same VMEM copy; only activation tiles stream
    HBM→VMEM.
  * the interval selector is a *comparator plane*: ONE broadcast ``x >= bounds``
    compare against the whole boundary row plus a sum-reduction yields the
    sub-interval index j per element; the per-element parameters are then four
    gathers from the VMEM metadata rows.  The paper's binary comparator tree
    (and its LUT-count versus #intervals tradeoff, Fig. 8b) has no TPU meaning —
    a VPU evaluates all comparators at once, and the gather replaces the old
    n-1-deep unrolled FMA select chain (serial latency AND accumulated-rounding
    drift) with O(1)-depth exact reads.
  * address generation uses precomputed reciprocals ``inv_delta`` (no divide on the
    VPU hot path) and float accumulators (exact for indices < 2^24).
  * the dual-port BRAM read of (y_i, y_{i+1}) becomes one adjacent-pair gather from
    the VMEM table; the 5-cycle fixed-point lerp becomes a single FMA.

Tile geometry: activations are flattened to (rows, LANE) with LANE a multiple of 128
(the VREG lane width) and rows blocked at ``block_rows`` (a multiple of 8 sublanes),
so each tile is MXU/VPU aligned.  VMEM working set per grid step:
``block_rows*LANE*4 (in) + same (out) + table bytes`` — checked against the VMEM
budget by ``repro.core.bram.vmem_cost``.

Validated against ``ref.table_lookup_ref`` in interpret mode (CPU container); the
``pl.pallas_call`` + BlockSpec lowering is the TPU target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.approx.jax_table import JaxTable, select_interval

LANE = 512  # 4 VREG lanes worth of f32; amortizes control per vector op
DEFAULT_BLOCK_ROWS = 256  # 256x512 f32 tile = 512 KiB in + 512 KiB out


def select_params(x, bounds_row, invd_row, base_row, segs_row, n_intervals: int):
    """Comparator plane + parameter fetch, shared by every table kernel.

    The subtle part — broadcast compare + sum-reduction + clip — is the ONE
    ``select_interval`` implementation shared with the jnp oracles, so the
    kernel/oracle bit-identity holds by construction; this helper only adds
    the four gathers from the VMEM-resident metadata rows.  ``bounds_row`` may
    be right-padded (+inf in the multi-function pack plane): padding never
    compares true and the clip pins out-of-range x into the last real
    sub-interval.
    """
    j = select_interval(bounds_row, n_intervals, x)
    p = jnp.take(bounds_row, j, axis=0, mode="clip")
    invd = jnp.take(invd_row, j, axis=0, mode="clip")
    base = jnp.take(base_row, j, axis=0, mode="clip")
    segs = jnp.take(segs_row, j, axis=0, mode="clip")
    return p, invd, base, segs


def _table_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref, values_ref, o_ref,
                  *, n_intervals: int, extrapolate: bool):
    x = x_ref[...].astype(jnp.float32)

    # --- interval selector + parameter fetch (comparator plane + gathers) -------
    p, invd, base, segs = select_params(
        x, bounds_ref[0, :], invd_ref[0, :], base_ref[0, :], segs_ref[0, :],
        n_intervals)

    # --- address generation (reciprocal multiply + floor + clamp) ---------------
    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)

    # --- BRAM read: adjacent-pair gather from the VMEM-resident table -----------
    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    # --- linear interpolation (one FMA); edge handling: saturate (hardware clamp)
    # or extend the edge segments linearly (asymptote-correct for gelu/silu) -----
    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    o_ref[...] = (y0 + t * (y1 - y0)).astype(o_ref.dtype)


def _pinned(shape):
    """BlockSpec that keeps a whole operand resident in VMEM across grid steps."""
    return pl.BlockSpec(shape, lambda i: (0,) * len(shape))


def tile_activations(x: jax.Array, lane: int, block_rows: int):
    """Flatten + zero-pad an arbitrary tensor into an MXU/VPU-aligned 2D tiling.

    Shared by every table kernel wrapper (per-table and pack) so the whole
    subsystem pads exactly one way.  Returns ``(x2d, block, n)`` with
    ``x2d: (rows_pad, lane)``, ``block`` the largest grid-dividing row block
    <= ``block_rows``, and ``n`` the true element count for untiling.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // lane)
    block = min(block_rows, rows)
    rows_pad = -(-rows // block) * block
    pad = rows_pad * lane - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows_pad, lane), block, n


def untile_activations(out2d: jax.Array, n: int, shape) -> jax.Array:
    """Inverse of :func:`tile_activations` for one kernel output."""
    return out2d.reshape(-1)[:n].reshape(shape)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "n_intervals", "extrapolate")
)
def _call(x2d, bounds, invd, base, segs, values, *, block_rows, interpret, n_intervals,
          extrapolate):
    rows, lane = x2d.shape
    grid = (rows // block_rows,)
    kernel = functools.partial(
        _table_kernel, n_intervals=n_intervals, extrapolate=extrapolate
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, lane), lambda i: (i, 0)),
            _pinned(bounds.shape),
            _pinned(invd.shape),
            _pinned(base.shape),
            _pinned(segs.shape),
            _pinned(values.shape),
        ],
        out_specs=pl.BlockSpec((block_rows, lane), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, bounds, invd, base, segs, values)


def table_lookup_pallas(
    jt: JaxTable,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate the table approximator over an arbitrarily-shaped tensor."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    x2d, block, n = tile_activations(x, lane, block_rows)
    out = _call(
        x2d,
        jt.boundaries.reshape(1, -1),
        jt.inv_delta.reshape(1, -1),
        jt.base.reshape(1, -1),
        jt.seg_count.reshape(1, -1),
        jt.values.reshape(1, -1),
        block_rows=block,
        interpret=interpret,
        n_intervals=jt.n_intervals,
        extrapolate=extrapolate,
    )
    return untile_activations(out, n, shape)
