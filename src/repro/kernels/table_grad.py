"""Fused forward+slope Pallas kernel: one interval-selection pass yields BOTH the
table value y(x) and the piecewise-linear derivative dy/dx.

The backward pass of a table activation needs the segment slope at x.  Running
the selector twice (forward kernel + slope kernel) doubles the comparator-plane
and gather work; this kernel shares them: after the (p, invd, base, segs) mux and
the adjacent-pair gather, the slope is one extra multiply
``(y1 - y0) * invd`` — the FPGA pipeline's subtract/multiply stage reused.

Used by ``repro.approx.make_table_fn`` when ``use_pallas=True``: the custom_jvp
calls this once instead of forward + slope separately.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.approx.jax_table import JaxTable

from .table_lookup import (DEFAULT_BLOCK_ROWS, LANE, _pinned, select_params,
                           tile_activations, untile_activations)


def _table_grad_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref,
                       values_ref, y_ref, dy_ref, *, n_intervals: int,
                       extrapolate: bool):
    x = x_ref[...].astype(jnp.float32)

    p, invd, base, segs = select_params(
        x, bounds_ref[0, :], invd_ref[0, :], base_ref[0, :], segs_ref[0, :],
        n_intervals)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = u - i
    slope = (y1 - y0) * invd
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
        inside = ((x >= bounds_ref[0, 0]) &
                  (x < bounds_ref[0, n_intervals])).astype(jnp.float32)
        slope = slope * inside
    y_ref[...] = (y0 + t * (y1 - y0)).astype(y_ref.dtype)
    dy_ref[...] = slope.astype(dy_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "n_intervals",
                              "extrapolate"))
def _call(x2d, bounds, invd, base, segs, values, *, block_rows, interpret,
          n_intervals, extrapolate):
    rows, lane = x2d.shape
    kernel = functools.partial(_table_grad_kernel, n_intervals=n_intervals,
                               extrapolate=extrapolate)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, lane), lambda i: (i, 0)),
            _pinned(bounds.shape),
            _pinned(invd.shape),
            _pinned(base.shape),
            _pinned(segs.shape),
            _pinned(values.shape),
        ],
        out_specs=[pl.BlockSpec((block_rows, lane), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)] * 2,
        interpret=interpret,
    )(x2d, bounds, invd, base, segs, values)


def table_lookup_grad_pallas(
    jt: JaxTable,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
):
    """Returns (y, dy/dx) with one fused selector pass."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    x2d, block, n = tile_activations(x, lane, block_rows)
    y2d, dy2d = _call(
        x2d,
        jt.boundaries.reshape(1, -1),
        jt.inv_delta.reshape(1, -1),
        jt.base.reshape(1, -1),
        jt.seg_count.reshape(1, -1),
        jt.values.reshape(1, -1),
        block_rows=block, interpret=interpret,
        n_intervals=jt.n_intervals, extrapolate=extrapolate,
    )
    return (untile_activations(y2d, n, shape),
            untile_activations(dy2d, n, shape))
