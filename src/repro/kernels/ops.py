"""jit'd public wrappers around the Pallas kernels."""

from __future__ import annotations

import jax

from repro.approx.jax_table import JaxTable
from repro.approx.table_pack import PolyTablePack, QuantTablePack, TablePack

from .routed_pack_lookup import (routed_pack_lookup_pallas,
                                 routed_poly_pack_lookup_pallas,
                                 routed_quant_pack_lookup_pallas)
from .table_lookup import table_lookup_pallas
from .table_pack_lookup import (poly_pack_lookup_pallas,
                                quant_pack_lookup_pallas,
                                table_pack_lookup_pallas)


def table_lookup(jt: JaxTable, x: jax.Array, *, extrapolate: bool = False) -> jax.Array:
    """Fused interval-select + lookup + lerp (Fig. 7) over a tensor.

    Dispatches to the Pallas kernel (interpret mode off-TPU).  Differentiability is
    provided one level up by ``repro.approx.make_table_fn`` (custom_jvp with the
    table slope), matching the hardware's piecewise-linear semantics.
    """
    return table_lookup_pallas(jt, x, extrapolate=extrapolate)


def table_pack_lookup(pack: TablePack, fn, x: jax.Array, *,
                      extrapolate: bool = False) -> jax.Array:
    """Fused lookup of member ``fn`` (name or fn_id) from the shared pack.

    One VMEM-resident pack + one kernel body serve every member function; the
    static ``fn_id`` only picks a metadata row.  Differentiability lives in
    ``repro.approx.make_pack_fn``.
    """
    return table_pack_lookup_pallas(pack, fn, x, extrapolate=extrapolate)


def quant_pack_lookup(pack: QuantTablePack, fn, x: jax.Array, *,
                      extrapolate: bool = False) -> jax.Array:
    """Fused dequantize-on-read lookup of member ``fn`` from the quantized pack.

    The int8/int16 codes stay VMEM-resident (2-4x smaller than the f32 pack);
    the kernel reconstructs values with one extra FMA per gathered endpoint.
    Differentiability lives in ``repro.approx.make_quant_pack_fn``.
    """
    return quant_pack_lookup_pallas(pack, fn, x, extrapolate=extrapolate)


def poly_pack_lookup(pack: PolyTablePack, fn, x: jax.Array, *,
                     extrapolate: bool = False) -> jax.Array:
    """Fused Horner lookup of member ``fn`` from the planner-built pack.

    Members may mix degrees (1..3) and code widths (f32/int16/int8) in one
    artifact; the kernel evaluates a uniform max-lanes Horner whose padded
    lanes dequantize to exactly 0.  Differentiability lives in
    ``repro.approx.make_poly_pack_fn``.
    """
    return poly_pack_lookup_pallas(pack, fn, x, extrapolate=extrapolate)


def routed_pack_lookup(pack: TablePack, fn_ids, x: jax.Array, *,
                       extrapolate=False) -> jax.Array:
    """DYNAMIC per-row dispatch: row i of ``x`` through member ``fn_ids[i]``.

    ``fn_ids`` is a runtime operand (scalar-prefetched), so one compiled
    executable serves every mixed-function batch — no per-member
    specialization.  Differentiability lives in ``repro.approx.make_routed_fn``.
    """
    return routed_pack_lookup_pallas(pack, fn_ids, x, extrapolate=extrapolate)


def routed_quant_pack_lookup(pack: QuantTablePack, fn_ids, x: jax.Array, *,
                             extrapolate=False) -> jax.Array:
    """Routed dispatch over the quantized pack (dequantize-on-read, dynamic
    width-group select per row)."""
    return routed_quant_pack_lookup_pallas(pack, fn_ids, x,
                                           extrapolate=extrapolate)


def routed_poly_pack_lookup(pack: PolyTablePack, fn_ids, x: jax.Array, *,
                            extrapolate=False) -> jax.Array:
    """Routed dispatch over the planner-built pack (dynamic per-row degree,
    code-width group, AND stride select)."""
    return routed_poly_pack_lookup_pallas(pack, fn_ids, x,
                                          extrapolate=extrapolate)
