"""jit'd public wrappers around the Pallas kernels."""

from __future__ import annotations

import jax

from repro.approx.jax_table import JaxTable
from repro.approx.table_pack import TablePack

from .table_lookup import table_lookup_pallas
from .table_pack_lookup import table_pack_lookup_pallas


def table_lookup(jt: JaxTable, x: jax.Array, *, extrapolate: bool = False) -> jax.Array:
    """Fused interval-select + lookup + lerp (Fig. 7) over a tensor.

    Dispatches to the Pallas kernel (interpret mode off-TPU).  Differentiability is
    provided one level up by ``repro.approx.make_table_fn`` (custom_jvp with the
    table slope), matching the hardware's piecewise-linear semantics.
    """
    return table_lookup_pallas(jt, x, extrapolate=extrapolate)


def table_pack_lookup(pack: TablePack, fn, x: jax.Array, *,
                      extrapolate: bool = False) -> jax.Array:
    """Fused lookup of member ``fn`` (name or fn_id) from the shared pack.

    One VMEM-resident pack + one kernel body serve every member function; the
    static ``fn_id`` only picks a metadata row.  Differentiability lives in
    ``repro.approx.make_pack_fn``.
    """
    return table_pack_lookup_pallas(pack, fn, x, extrapolate=extrapolate)
