"""Fused multi-function Pallas kernels over a :class:`repro.approx.TablePack`.

One packed values vector + (F, n_max) metadata planes stay VMEM-resident —
BRAM instantiation at the function-set level — and a single kernel body serves
ANY member function: the static ``fn_id`` picks the metadata row at trace time
(zero runtime cost; the row read lowers to a constant offset), then the shared
comparator-plane selector (``table_lookup.select_params``) and adjacent-pair
gather run exactly as in the per-table kernel.  Because every specialization
shares the same operand shapes and the same pack arrays, switching functions
costs one cached-executable lookup instead of a new table upload, and the VMEM
working set is ONE pack instead of F separate tables.

Two entry points mirror the per-table pair:

  * ``table_pack_lookup_pallas``  — value only (serving path);
  * ``table_pack_grad_pallas``    — fused value + slope in one selector pass
    (training path; used by ``make_pack_fn``'s custom_jvp).

Both validated bit-identical against ``repro.approx.table_pack.eval_pack_ref``
/ ``eval_pack_slope`` in interpret mode (tests/test_table_pack.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.approx.table_pack import (PolyTablePack, QuantTablePack,
                                     ShardedTablePack, TablePack, poly_horner,
                                     poly_horner_d1)
from repro.core.range_reduce import (exp_edges, exp_fold, exp_reconstruct,
                                     log_edges, log_fold, log_reconstruct,
                                     trig_edges, trig_fold, trig_reconstruct,
                                     trig_slope_reconstruct)

from .table_lookup import (DEFAULT_BLOCK_ROWS, LANE, _pinned, select_interval,
                           select_params, tile_activations, untile_activations)


def _pack_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref, values_ref,
                 o_ref, *, fn_id: int, n_intervals: int, extrapolate: bool):
    x = x_ref[...].astype(jnp.float32)

    # static row pick: the ONE pack serves any function; fn_id costs nothing
    p, invd, base, segs = select_params(
        x, bounds_ref[fn_id, :], invd_ref[fn_id, :], base_ref[fn_id, :],
        segs_ref[fn_id, :], n_intervals)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)  # base is GLOBAL: offset baked in at pack time

    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    o_ref[...] = (y0 + t * (y1 - y0)).astype(o_ref.dtype)


def _pack_grad_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref,
                      values_ref, y_ref, dy_ref, *, fn_id: int,
                      n_intervals: int, extrapolate: bool):
    x = x_ref[...].astype(jnp.float32)

    p, invd, base, segs = select_params(
        x, bounds_ref[fn_id, :], invd_ref[fn_id, :], base_ref[fn_id, :],
        segs_ref[fn_id, :], n_intervals)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = u - i
    slope = (y1 - y0) * invd
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
        inside = ((x >= bounds_ref[fn_id, 0]) &
                  (x < bounds_ref[fn_id, n_intervals])).astype(jnp.float32)
        slope = slope * inside
    y_ref[...] = (y0 + t * (y1 - y0)).astype(y_ref.dtype)
    dy_ref[...] = slope.astype(dy_ref.dtype)


def _pack_specs(x2d, pack_arrays, block_rows):
    rows, lane = x2d.shape
    in_specs = [pl.BlockSpec((block_rows, lane), lambda i: (i, 0))]
    in_specs += [_pinned(a.shape) for a in pack_arrays]
    return (rows // block_rows,), in_specs


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "fn_id", "n_intervals",
                              "extrapolate"))
def _call(x2d, bounds, invd, base, segs, values, *, block_rows, interpret,
          fn_id, n_intervals, extrapolate):
    grid, in_specs = _pack_specs(x2d, (bounds, invd, base, segs, values),
                                 block_rows)
    kernel = functools.partial(_pack_kernel, fn_id=fn_id,
                               n_intervals=n_intervals, extrapolate=extrapolate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, bounds, invd, base, segs, values)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "fn_id", "n_intervals",
                              "extrapolate"))
def _call_grad(x2d, bounds, invd, base, segs, values, *, block_rows, interpret,
               fn_id, n_intervals, extrapolate):
    grid, in_specs = _pack_specs(x2d, (bounds, invd, base, segs, values),
                                 block_rows)
    kernel = functools.partial(_pack_grad_kernel, fn_id=fn_id,
                               n_intervals=n_intervals, extrapolate=extrapolate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)] * 2,
        interpret=interpret,
    )(x2d, bounds, invd, base, segs, values)


def _prep(pack: TablePack, fn, x, lane, block_rows, interpret):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # member_id validates ints too: an out-of-range fn_id raises a KeyError
    # naming the pack members instead of an opaque tuple IndexError below
    fid = pack.member_id(fn)
    x2d, block, n = tile_activations(x, lane, block_rows)
    return fid, x2d, block, n, interpret


def table_pack_lookup_pallas(
    pack: TablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate member ``fn`` (name or fn_id) of the pack over a tensor."""
    fid, x2d, block, n, interpret = _prep(pack, fn, x, lane, block_rows,
                                          interpret)
    out = _call(
        x2d, pack.boundaries, pack.inv_delta, pack.base, pack.seg_count,
        pack.values.reshape(1, -1),
        block_rows=block, interpret=interpret, fn_id=fid,
        n_intervals=pack.n_intervals[fid], extrapolate=extrapolate,
    )
    return untile_activations(out, n, x.shape)


# --------------------------------------------------------------------------------------
# TableFlash kernel — flash attention's softmax exponent from the exp_neg member.
# --------------------------------------------------------------------------------------
#
# The running-softmax arguments (s - m_new, m - m_new) are <= 0 by construction
# but can sit at -2e38 for masked/pad key slots (NEG_INF - m).  The kernel fuses
# an UNDERFLOW-TO-ZERO tail in front of the standard selector: below the
# member's lo edge the result is exactly 0.0, matching f32 ``jnp.exp``'s own
# underflow for the hugely-negative masked-slot arguments — so masked, empty,
# and pad key slots carry weight 0 in BOTH the exact and the table path (a
# clamp-at-lo tail would instead give every masked slot a spurious exp(lo)
# ~ 1.1e-7 weight, which at decode's ring-buffer occupancy dominates E_a).
# The address math still clamps (``max(x, lo)``) so the ``(x - p) * inv_delta``
# product never sees a 1e38-magnitude operand; the zero-tail select happens on
# the RAW x afterwards.  Bit-identical to the jnp oracle under jit, asserted
# in tests/test_table_flash.py.


def _tableflash_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref,
                       values_ref, o_ref, *, fn_id: int, n_intervals: int):
    x_raw = x_ref[...].astype(jnp.float32)
    lo = bounds_ref[fn_id, 0]
    x = jnp.maximum(x_raw, lo)  # address saturation only

    p, invd, base, segs = select_params(
        x, bounds_ref[fn_id, :], invd_ref[fn_id, :], base_ref[fn_id, :],
        segs_ref[fn_id, :], n_intervals)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)

    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = jnp.clip(u - i, 0.0, 1.0)  # saturate: exp_neg never extrapolates
    y = y0 + t * (y1 - y0)
    # underflow-to-zero tail: exp(z) < exp(lo) ~ 1.1e-7 rounds to 0, exactly
    # like the masked-slot exact path
    o_ref[...] = jnp.where(x_raw < lo, 0.0, y).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "fn_id", "n_intervals"))
def _tableflash_call(x2d, bounds, invd, base, segs, values, *, block_rows,
                     interpret, fn_id, n_intervals):
    grid, in_specs = _pack_specs(x2d, (bounds, invd, base, segs, values),
                                 block_rows)
    kernel = functools.partial(_tableflash_kernel, fn_id=fn_id,
                               n_intervals=n_intervals)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, bounds, invd, base, segs, values)


def tableflash_exp_pallas(
    pack: TablePack,
    x: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused clamp + exp_neg lookup over flash attention's exponent tensor."""
    fid, x2d, block, n, interpret = _prep(pack, "exp_neg", x, lane, block_rows,
                                          interpret)
    out = _tableflash_call(
        x2d, pack.boundaries, pack.inv_delta, pack.base, pack.seg_count,
        pack.values.reshape(1, -1),
        block_rows=block, interpret=interpret, fn_id=fid,
        n_intervals=pack.n_intervals[fid],
    )
    return untile_activations(out, n, x.shape)


# --------------------------------------------------------------------------------------
# QuantPack kernels — int8/int16 codes VMEM-resident, dequantized on read.
# --------------------------------------------------------------------------------------
#
# The quantized pack stores RAGGED metadata lanes (member fid's segment starts
# at a static offset — see QuantTablePack), so the kernels slice the lane refs
# with python-int bounds (free at trace time) instead of indexing an
# (F, n_max) plane row.  Dequantization adds three gathers (scale, zero, ramp
# — same selector index j) and one FMA per endpoint after the codes gather:
#
#     v = (zero + ramp * i) + scale * c
#
# The codes operand is int8 or int16 — chosen per member at pack-build time by
# the error-budget splitter — so the VMEM working set shrinks 2-4x vs the f32
# pack while the end-to-end |f - table| <= Ea contract still holds.


def _quant_select(x, bounds_ref, invd_ref, base_ref, segs_ref, scale_ref,
                  zero_ref, ramp_ref, *, bo: int, lo: int, n: int):
    """Comparator plane + seven gathers from member (bo, lo, n)'s ragged lanes."""
    brow = bounds_ref[0, bo : bo + n + 1]
    j = select_interval(brow, n, x)
    p = jnp.take(brow, j, axis=0, mode="clip")
    invd = jnp.take(invd_ref[0, lo : lo + n], j, axis=0, mode="clip")
    base = jnp.take(base_ref[0, lo : lo + n], j, axis=0, mode="clip")
    segs = jnp.take(segs_ref[0, lo : lo + n], j, axis=0, mode="clip")
    scale = jnp.take(scale_ref[0, lo : lo + n], j, axis=0, mode="clip")
    zero = jnp.take(zero_ref[0, lo : lo + n], j, axis=0, mode="clip")
    ramp = jnp.take(ramp_ref[0, lo : lo + n], j, axis=0, mode="clip")
    return p, invd, base, segs, scale, zero, ramp


def _quant_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref, scale_ref,
                  zero_ref, ramp_ref, codes_ref, o_ref, *, bo: int, lo: int,
                  n_intervals: int, extrapolate: bool):
    x = x_ref[...].astype(jnp.float32)
    p, invd, base, segs, scale, zero, ramp = _quant_select(
        x, bounds_ref, invd_ref, base_ref, segs_ref, scale_ref, zero_ref,
        ramp_ref, bo=bo, lo=lo, n=n_intervals)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)

    codes = codes_ref[0, :]
    c0 = jnp.take(codes, a, axis=0, mode="clip").astype(jnp.float32)
    c1 = jnp.take(codes, a + 1, axis=0, mode="clip").astype(jnp.float32)

    r = zero + ramp * i  # dequantize-on-read: chord ramp + scaled code
    y0 = r + scale * c0
    y1 = (r + ramp) + scale * c1

    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    o_ref[...] = (y0 + t * (y1 - y0)).astype(o_ref.dtype)


def _quant_grad_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref,
                       scale_ref, zero_ref, ramp_ref, codes_ref, y_ref, dy_ref,
                       *, bo: int, lo: int, n_intervals: int,
                       extrapolate: bool):
    x = x_ref[...].astype(jnp.float32)
    p, invd, base, segs, scale, zero, ramp = _quant_select(
        x, bounds_ref, invd_ref, base_ref, segs_ref, scale_ref, zero_ref,
        ramp_ref, bo=bo, lo=lo, n=n_intervals)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    codes = codes_ref[0, :]
    c0 = jnp.take(codes, a, axis=0, mode="clip").astype(jnp.float32)
    c1 = jnp.take(codes, a + 1, axis=0, mode="clip").astype(jnp.float32)

    r = zero + ramp * i
    y0 = r + scale * c0
    y1 = (r + ramp) + scale * c1

    t = u - i
    slope = (ramp + scale * (c1 - c0)) * invd
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
        inside = ((x >= bounds_ref[0, bo]) &
                  (x < bounds_ref[0, bo + n_intervals])).astype(jnp.float32)
        slope = slope * inside
    y_ref[...] = (y0 + t * (y1 - y0)).astype(y_ref.dtype)
    dy_ref[...] = slope.astype(dy_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "bo", "lo",
                              "n_intervals", "extrapolate"))
def _quant_call(x2d, bounds, invd, base, segs, scale, zero, ramp, codes, *,
                block_rows, interpret, bo, lo, n_intervals, extrapolate):
    operands = (bounds, invd, base, segs, scale, zero, ramp, codes)
    grid, in_specs = _pack_specs(x2d, operands, block_rows)
    kernel = functools.partial(_quant_kernel, bo=bo, lo=lo,
                               n_intervals=n_intervals, extrapolate=extrapolate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, *operands)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "bo", "lo",
                              "n_intervals", "extrapolate"))
def _quant_call_grad(x2d, bounds, invd, base, segs, scale, zero, ramp, codes,
                     *, block_rows, interpret, bo, lo, n_intervals,
                     extrapolate):
    operands = (bounds, invd, base, segs, scale, zero, ramp, codes)
    grid, in_specs = _pack_specs(x2d, operands, block_rows)
    kernel = functools.partial(_quant_grad_kernel, bo=bo, lo=lo,
                               n_intervals=n_intervals, extrapolate=extrapolate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)] * 2,
        interpret=interpret,
    )(x2d, *operands)


def _quant_operands(pack: QuantTablePack, fid: int):
    return (pack.boundaries.reshape(1, -1), pack.inv_delta.reshape(1, -1),
            pack.base.reshape(1, -1), pack.seg_count.reshape(1, -1),
            pack.scale.reshape(1, -1), pack.zero.reshape(1, -1),
            pack.ramp.reshape(1, -1), pack.codes_for(fid).reshape(1, -1))


def quant_pack_lookup_pallas(
    pack: QuantTablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate member ``fn`` from the quantized pack (dequantize-on-read)."""
    fid, x2d, block, n, interpret = _prep(pack, fn, x, lane, block_rows,
                                          interpret)
    out = _quant_call(
        x2d, *_quant_operands(pack, fid),
        block_rows=block, interpret=interpret, bo=pack.bounds_offset(fid),
        lo=pack.lane_offset(fid), n_intervals=pack.n_intervals[fid],
        extrapolate=extrapolate,
    )
    return untile_activations(out, n, x.shape)


def quant_pack_grad_pallas(
    pack: QuantTablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
):
    """Returns (y, dy/dx) from the quantized pack in one fused selector pass."""
    fid, x2d, block, n, interpret = _prep(pack, fn, x, lane, block_rows,
                                          interpret)
    y2d, dy2d = _quant_call_grad(
        x2d, *_quant_operands(pack, fid),
        block_rows=block, interpret=interpret, bo=pack.bounds_offset(fid),
        lo=pack.lane_offset(fid), n_intervals=pack.n_intervals[fid],
        extrapolate=extrapolate,
    )
    return (untile_activations(y2d, n, x.shape),
            untile_activations(dy2d, n, x.shape))


def table_pack_grad_pallas(
    pack: TablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
):
    """Returns (y, dy/dx) for member ``fn`` with one fused selector pass."""
    fid, x2d, block, n, interpret = _prep(pack, fn, x, lane, block_rows,
                                          interpret)
    y2d, dy2d = _call_grad(
        x2d, pack.boundaries, pack.inv_delta, pack.base, pack.seg_count,
        pack.values.reshape(1, -1),
        block_rows=block, interpret=interpret, fn_id=fid,
        n_intervals=pack.n_intervals[fid], extrapolate=extrapolate,
    )
    return (untile_activations(y2d, n, x.shape),
            untile_activations(dy2d, n, x.shape))


# --------------------------------------------------------------------------------------
# PolyPack kernels — degree-d coefficient codes VMEM-resident, dequant + Horner on read.
# --------------------------------------------------------------------------------------
#
# The polynomial pack generalizes the quant kernel from 2 chord endpoints to
# ``degree + 1`` monomial coefficients per cell: each lane is gathered from the
# member's width group (int8 / int16 codes or raw f32 coefficients — the f32
# members ride the SAME dequant FMA with zero = ramp = 0, scale = 1, a bit-exact
# identity) at ``base + i*(degree+1) + l``, dequantized per lane, and combined
# by Horner at the clamped cell coordinate.  ``extrapolate=True`` continues past
# the grid along the tangent: ``y = p(tc) + p'(tc) * (t - tc)``.  The dequant
# planes are lane-padded flat lanes (stride ``lmax = max_degree + 1``); the
# static fid bakes the member's degree, so only its real lanes are touched here
# (the routed kernel runs all lmax lanes — identical bits, see
# ``repro.core.packing.PolyPackLayout``).


def _poly_select(x, bounds_ref, invd_ref, base_ref, segs_ref, *, bo: int,
                 lo: int, n: int):
    """Comparator plane + four selector gathers from member (bo, lo, n)."""
    brow = bounds_ref[0, bo : bo + n + 1]
    j = select_interval(brow, n, x)
    p = jnp.take(brow, j, axis=0, mode="clip")
    invd = jnp.take(invd_ref[0, lo : lo + n], j, axis=0, mode="clip")
    base = jnp.take(base_ref[0, lo : lo + n], j, axis=0, mode="clip")
    segs = jnp.take(segs_ref[0, lo : lo + n], j, axis=0, mode="clip")
    return j, p, invd, base, segs


def _poly_coeffs_kernel(j, i, base, zero_ref, ramp_ref, scale_ref, codes_ref,
                        *, lo: int, n: int, lmax: int, degree: int):
    """Gather + dequantize the cell's ``degree + 1`` coefficient lanes."""
    codes = codes_ref[0, :]
    stride = float(degree + 1)
    cs = []
    for l in range(degree + 1):
        m = j * lmax + l  # flat (sub-interval, lane) metadata index
        zl = jnp.take(zero_ref[0, lo * lmax : (lo + n) * lmax], m, axis=0,
                      mode="clip")
        rl = jnp.take(ramp_ref[0, lo * lmax : (lo + n) * lmax], m, axis=0,
                      mode="clip")
        sl = jnp.take(scale_ref[0, lo * lmax : (lo + n) * lmax], m, axis=0,
                      mode="clip")
        a = (base + i * stride + float(l)).astype(jnp.int32)
        q = jnp.take(codes, a, axis=0, mode="clip").astype(jnp.float32)
        cs.append((zl + rl * i) + sl * q)
    return cs


def _poly_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref, zero_ref,
                 ramp_ref, scale_ref, codes_ref, o_ref, *, bo: int, lo: int,
                 n_intervals: int, lmax: int, degree: int, extrapolate: bool):
    x = x_ref[...].astype(jnp.float32)
    j, p, invd, base, segs = _poly_select(
        x, bounds_ref, invd_ref, base_ref, segs_ref, bo=bo, lo=lo,
        n=n_intervals)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    cs = _poly_coeffs_kernel(j, i, base, zero_ref, ramp_ref, scale_ref,
                             codes_ref, lo=lo, n=n_intervals, lmax=lmax,
                             degree=degree)
    t = u - i
    tc = jnp.clip(t, 0.0, 1.0)
    y = poly_horner(cs, tc)
    if extrapolate:
        y = y + poly_horner_d1(cs, tc) * (t - tc)
    o_ref[...] = y.astype(o_ref.dtype)


def _poly_grad_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref,
                      zero_ref, ramp_ref, scale_ref, codes_ref, y_ref, dy_ref,
                      *, bo: int, lo: int, n_intervals: int, lmax: int,
                      degree: int, extrapolate: bool):
    x = x_ref[...].astype(jnp.float32)
    j, p, invd, base, segs = _poly_select(
        x, bounds_ref, invd_ref, base_ref, segs_ref, bo=bo, lo=lo,
        n=n_intervals)

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    cs = _poly_coeffs_kernel(j, i, base, zero_ref, ramp_ref, scale_ref,
                             codes_ref, lo=lo, n=n_intervals, lmax=lmax,
                             degree=degree)
    t = u - i
    tc = jnp.clip(t, 0.0, 1.0)
    y = poly_horner(cs, tc)
    g = poly_horner_d1(cs, tc)
    slope = g * invd
    if extrapolate:
        y = y + g * (t - tc)
    else:
        inside = ((x >= bounds_ref[0, bo]) &
                  (x < bounds_ref[0, bo + n_intervals])).astype(jnp.float32)
        slope = slope * inside
    y_ref[...] = y.astype(y_ref.dtype)
    dy_ref[...] = slope.astype(dy_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "bo", "lo",
                              "n_intervals", "lmax", "degree", "extrapolate"))
def _poly_call(x2d, bounds, invd, base, segs, zero, ramp, scale, codes, *,
               block_rows, interpret, bo, lo, n_intervals, lmax, degree,
               extrapolate):
    operands = (bounds, invd, base, segs, zero, ramp, scale, codes)
    grid, in_specs = _pack_specs(x2d, operands, block_rows)
    kernel = functools.partial(_poly_kernel, bo=bo, lo=lo,
                               n_intervals=n_intervals, lmax=lmax,
                               degree=degree, extrapolate=extrapolate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, *operands)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "bo", "lo",
                              "n_intervals", "lmax", "degree", "extrapolate"))
def _poly_call_grad(x2d, bounds, invd, base, segs, zero, ramp, scale, codes,
                    *, block_rows, interpret, bo, lo, n_intervals, lmax,
                    degree, extrapolate):
    operands = (bounds, invd, base, segs, zero, ramp, scale, codes)
    grid, in_specs = _pack_specs(x2d, operands, block_rows)
    kernel = functools.partial(_poly_grad_kernel, bo=bo, lo=lo,
                               n_intervals=n_intervals, lmax=lmax,
                               degree=degree, extrapolate=extrapolate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)] * 2,
        interpret=interpret,
    )(x2d, *operands)


def _poly_operands(pack: PolyTablePack, fid: int):
    return (pack.boundaries.reshape(1, -1), pack.inv_delta.reshape(1, -1),
            pack.base.reshape(1, -1), pack.seg_count.reshape(1, -1),
            pack.zero.reshape(1, -1), pack.ramp.reshape(1, -1),
            pack.scale.reshape(1, -1), pack.codes_for(fid).reshape(1, -1))


def poly_pack_lookup_pallas(
    pack: PolyTablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate member ``fn`` from the polynomial pack (dequant + Horner)."""
    fid, x2d, block, n, interpret = _prep(pack, fn, x, lane, block_rows,
                                          interpret)
    out = _poly_call(
        x2d, *_poly_operands(pack, fid),
        block_rows=block, interpret=interpret, bo=pack.bounds_offset(fid),
        lo=pack.lane_offset(fid), n_intervals=pack.n_intervals[fid],
        lmax=pack.max_lanes, degree=pack.degrees[fid], extrapolate=extrapolate,
    )
    return untile_activations(out, n, x.shape)


def poly_pack_grad_pallas(
    pack: PolyTablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
):
    """Returns (y, dy/dx) from the polynomial pack in one fused selector pass."""
    fid, x2d, block, n, interpret = _prep(pack, fn, x, lane, block_rows,
                                          interpret)
    y2d, dy2d = _poly_call_grad(
        x2d, *_poly_operands(pack, fid),
        block_rows=block, interpret=interpret, bo=pack.bounds_offset(fid),
        lo=pack.lane_offset(fid), n_intervals=pack.n_intervals[fid],
        lmax=pack.max_lanes, degree=pack.degrees[fid], extrapolate=extrapolate,
    )
    return (untile_activations(y2d, n, x.shape),
            untile_activations(dy2d, n, x.shape))


# --------------------------------------------------------------------------------------
# ShardedPack kernels — one shard's values slice VMEM-resident, unowned rows masked.
# --------------------------------------------------------------------------------------
#
# The replicated kernels above pin the WHOLE values vector; the sharded kernel
# pins one shard's padded slice plus the (small, replicated) selector metadata
# and the shard's (local_base, owned) planes.  The body is the static pack
# body with two changes: the base gather reads the SHARD-LOCAL rebased
# address, and the output is masked to the sub-intervals this shard owns.
# Contributions combine OUTSIDE the kernel — a psum over the mesh 'model'
# axis under shard_map, or a stacked-axis sum off-mesh — adding one owner
# value and S-1 zeros, so the summed result is bit-identical to the
# replicated kernel (asserted in tests/test_sharded_pack.py and the
# conformance matrix).


def _spack_kernel(x_ref, bounds_ref, invd_ref, segs_ref, lbase_ref, own_ref,
                  values_ref, o_ref, *, fn_id: int, n_intervals: int,
                  extrapolate: bool, slope: bool):
    x = x_ref[...].astype(jnp.float32)

    brow = bounds_ref[fn_id, :]
    j = select_interval(brow, n_intervals, x)
    p = jnp.take(brow, j, axis=0, mode="clip")
    invd = jnp.take(invd_ref[fn_id, :], j, axis=0, mode="clip")
    segs = jnp.take(segs_ref[fn_id, :], j, axis=0, mode="clip")
    base = jnp.take(lbase_ref[fn_id, :], j, axis=0, mode="clip")
    own = jnp.take(own_ref[fn_id, :], j, axis=0, mode="clip")

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)  # SHARD-LOCAL address (rebased at plan time)

    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    if slope:
        out = (y1 - y0) * invd
        if not extrapolate:
            inside = ((x >= brow[0]) & (x < brow[n_intervals]))
            out = out * inside.astype(jnp.float32)
    else:
        t = u - i
        if not extrapolate:
            t = jnp.clip(t, 0.0, 1.0)
        out = y0 + t * (y1 - y0)
    o_ref[...] = jnp.where(own > 0, out, 0.0).astype(o_ref.dtype)


def _spack_grad_kernel(x_ref, bounds_ref, invd_ref, segs_ref, lbase_ref,
                       own_ref, values_ref, y_ref, dy_ref, *, fn_id: int,
                       n_intervals: int, extrapolate: bool):
    """Fused (value, slope) shard contribution in ONE selector pass — the
    sharded twin of ``_pack_grad_kernel`` (same ops, masked outputs)."""
    x = x_ref[...].astype(jnp.float32)

    brow = bounds_ref[fn_id, :]
    j = select_interval(brow, n_intervals, x)
    p = jnp.take(brow, j, axis=0, mode="clip")
    invd = jnp.take(invd_ref[fn_id, :], j, axis=0, mode="clip")
    segs = jnp.take(segs_ref[fn_id, :], j, axis=0, mode="clip")
    base = jnp.take(lbase_ref[fn_id, :], j, axis=0, mode="clip")
    own = jnp.take(own_ref[fn_id, :], j, axis=0, mode="clip")

    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    values = values_ref[0, :]
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")

    t = u - i
    slope = (y1 - y0) * invd
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
        inside = ((x >= brow[0]) &
                  (x < brow[n_intervals])).astype(jnp.float32)
        slope = slope * inside
    y_ref[...] = jnp.where(own > 0, y0 + t * (y1 - y0), 0.0).astype(y_ref.dtype)
    dy_ref[...] = jnp.where(own > 0, slope, 0.0).astype(dy_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "fn_id", "n_intervals",
                              "extrapolate", "slope"))
def _sharded_call(x2d, bounds, invd, segs, lbase, own, values, *, block_rows,
                  interpret, fn_id, n_intervals, extrapolate, slope):
    grid, in_specs = _pack_specs(x2d, (bounds, invd, segs, lbase, own, values),
                                 block_rows)
    kernel = functools.partial(_spack_kernel, fn_id=fn_id,
                               n_intervals=n_intervals, extrapolate=extrapolate,
                               slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, bounds, invd, segs, lbase, own, values)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "fn_id", "n_intervals",
                              "extrapolate"))
def _sharded_call_grad(x2d, bounds, invd, segs, lbase, own, values, *,
                       block_rows, interpret, fn_id, n_intervals, extrapolate):
    grid, in_specs = _pack_specs(x2d, (bounds, invd, segs, lbase, own, values),
                                 block_rows)
    kernel = functools.partial(_spack_grad_kernel, fn_id=fn_id,
                               n_intervals=n_intervals, extrapolate=extrapolate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)] * 2,
        interpret=interpret,
    )(x2d, bounds, invd, segs, lbase, own, values)


def sharded_shard_contrib_pallas(
    boundaries: jax.Array,
    inv_delta: jax.Array,
    seg_count: jax.Array,
    local_base: jax.Array,
    owned: jax.Array,
    values_s: jax.Array,
    x: jax.Array,
    *,
    fn_id: int,
    n_intervals: int,
    extrapolate: bool = False,
    slope: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """ONE shard's masked contribution from explicit (mesh-local) arrays.

    This is the entry the shard_map body calls: ``local_base``/``owned`` are
    the (F, n_max) planes of the CALLING shard and ``values_s`` its (m_max,)
    slice.  The caller combines contributions (psum on mesh, sum off-mesh).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x2d, block, n = tile_activations(x, lane, block_rows)
    out = _sharded_call(
        x2d, boundaries, inv_delta, seg_count, local_base, owned,
        values_s.reshape(1, -1),
        block_rows=block, interpret=interpret, fn_id=fn_id,
        n_intervals=n_intervals, extrapolate=extrapolate, slope=slope)
    return untile_activations(out, n, x.shape)


def _sharded_sum_pallas(pack: ShardedTablePack, fn, x, extrapolate, slope,
                        block_rows, lane, interpret):
    fid = pack.member_id(fn)
    out = None
    for s in range(pack.n_shards):
        c = sharded_shard_contrib_pallas(
            pack.boundaries, pack.inv_delta, pack.seg_count,
            pack.local_base[s], pack.owned[s], pack.values[s], x,
            fn_id=fid, n_intervals=pack.n_intervals[fid],
            extrapolate=extrapolate, slope=slope, block_rows=block_rows,
            lane=lane, interpret=interpret)
        out = c if out is None else out + c
    return out


def sharded_pack_lookup_pallas(
    pack: ShardedTablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """Evaluate member ``fn`` of the sharded pack (stacked shard axis: one
    kernel launch per shard, contributions summed — the off-mesh path)."""
    return _sharded_sum_pallas(pack, fn, x, extrapolate, False, block_rows,
                               lane, interpret)


def sharded_pack_slope_pallas(
    pack: ShardedTablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """Slope only (no value pass) — bit-identical to ``eval_sharded_slope``."""
    return _sharded_sum_pallas(pack, fn, x, extrapolate, True, block_rows,
                               lane, interpret)


def sharded_pack_grad_pallas(
    pack: ShardedTablePack,
    fn,
    x: jax.Array,
    *,
    extrapolate: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
):
    """Returns (y, dy/dx) from the sharded pack — one FUSED selector pass per
    shard (S launches total, like the replicated ``table_pack_grad_pallas``'s
    single fused launch)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fid = pack.member_id(fn)
    x2d, block, n = tile_activations(x, lane, block_rows)
    y2d = dy2d = None
    for s in range(pack.n_shards):
        cy, cdy = _sharded_call_grad(
            x2d, pack.boundaries, pack.inv_delta, pack.seg_count,
            pack.local_base[s], pack.owned[s], pack.values[s].reshape(1, -1),
            block_rows=block, interpret=interpret, fn_id=fid,
            n_intervals=pack.n_intervals[fid], extrapolate=extrapolate)
        y2d = cy if y2d is None else y2d + cy
        dy2d = cdy if dy2d is None else dy2d + cdy
    return (untile_activations(y2d, n, x.shape),
            untile_activations(dy2d, n, x.shape))


# --------------------------------------------------------------------------------------
# RangeFold kernels — fold prologue + core lookup(s) + reconstruction epilogue,
# all fused in ONE kernel body (mode="folded_pack").
# --------------------------------------------------------------------------------------
#
# The reduction (repro.core.range_reduce) folds the unbounded argument onto the
# canonical core interval INSIDE the kernel — Cody-Waite / Payne-Hanek for trig,
# exponent-field splits for exp/log — then the standard comparator-plane lookup
# reads the core member(s) and the epilogue reapplies the exact bookkeeping
# (octant sign/swap, 2^k scaling, e*ln2 shift).  Trig needs TWO static-fn_id
# core reads per element (sin_core and cos_core feed the quadrant select); exp
# and log need one.  Because the fold helpers are the same jnp functions the
# oracle (repro.approx.range_fold.eval_folded_ref) calls, the kernel/oracle pair
# is bit-identical by construction, like select_interval before it.


def _folded_core_lookup(x, bounds_ref, invd_ref, base_ref, segs_ref, values,
                        fid: int, n_intervals: int):
    """One core-member read: identical op sequence to ``eval_pack_ref`` with
    ``extrapolate=False`` (the cores never extrapolate — the fold guarantees
    in-domain arguments up to the guard band, which clamps)."""
    p, invd, base, segs = select_params(
        x, bounds_ref[fid, :], invd_ref[fid, :], base_ref[fid, :],
        segs_ref[fid, :], n_intervals)
    u = (x - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")
    t = jnp.clip(u - i, 0.0, 1.0)
    return y0 + t * (y1 - y0)


def _folded_core_slope(x, bounds_ref, invd_ref, base_ref, segs_ref, values,
                       fid: int, n_intervals: int):
    """Chord slope of one core member — mirrors ``eval_pack_slope``."""
    p, invd, base, segs = select_params(
        x, bounds_ref[fid, :], invd_ref[fid, :], base_ref[fid, :],
        segs_ref[fid, :], n_intervals)
    i = jnp.clip(jnp.floor((x - p) * invd), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(values, a, axis=0, mode="clip")
    y1 = jnp.take(values, a + 1, axis=0, mode="clip")
    slope = (y1 - y0) * invd
    inside = (x >= bounds_ref[fid, 0]) & (x < bounds_ref[fid, n_intervals])
    return slope * inside.astype(jnp.float32)


def _folded_value(x, bounds_ref, invd_ref, base_ref, segs_ref, values, *,
                  kind: str, fid_a: int, fid_b: int, n_a: int, n_b: int):
    look = lambda v, fid, n: _folded_core_lookup(
        v, bounds_ref, invd_ref, base_ref, segs_ref, values, fid, n)
    if kind in ("sin", "cos"):
        r, q, sflip = trig_fold(x)
        y = trig_reconstruct(kind, look(r, fid_a, n_a), look(r, fid_b, n_b),
                             q, sflip)
        return trig_edges(x, y)
    if kind == "exp":
        r, k = exp_fold(x)
        return exp_edges(x, exp_reconstruct(look(r, fid_a, n_a), k))
    m, e = log_fold(x)
    return log_edges(x, log_reconstruct(look(m, fid_a, n_a), e))


def _folded_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref, values_ref,
                   o_ref, *, kind: str, fid_a: int, fid_b: int, n_a: int,
                   n_b: int):
    x = x_ref[...].astype(jnp.float32)
    y = _folded_value(x, bounds_ref, invd_ref, base_ref, segs_ref,
                      values_ref[0, :], kind=kind, fid_a=fid_a, fid_b=fid_b,
                      n_a=n_a, n_b=n_b)
    o_ref[...] = y.astype(o_ref.dtype)


def _folded_grad_kernel(x_ref, bounds_ref, invd_ref, base_ref, segs_ref,
                        values_ref, y_ref, dy_ref, *, kind: str, fid_a: int,
                        fid_b: int, n_a: int, n_b: int):
    from repro.approx.range_fold import _log_slope_mask, _log_slope_safe_x

    x = x_ref[...].astype(jnp.float32)
    values = values_ref[0, :]
    y = _folded_value(x, bounds_ref, invd_ref, base_ref, segs_ref, values,
                      kind=kind, fid_a=fid_a, fid_b=fid_b, n_a=n_a, n_b=n_b)
    sl = lambda v, fid, n: _folded_core_slope(
        v, bounds_ref, invd_ref, base_ref, segs_ref, values, fid, n)
    if kind in ("sin", "cos"):
        r, q, sflip = trig_fold(x)
        slope = trig_slope_reconstruct(kind, sl(r, fid_a, n_a),
                                       sl(r, fid_b, n_b), q, sflip)
        slope = jnp.where(jnp.isfinite(x), slope, 0.0)
    elif kind == "exp":
        r, k = exp_fold(x)
        slope = exp_reconstruct(sl(r, fid_a, n_a), k)
        # zero overflowed-2^k lanes too (matches eval_folded_slope)
        slope = jnp.where(jnp.isfinite(x) & jnp.isfinite(slope), slope, 0.0)
    else:
        m, e = log_fold(x)
        slope = _log_slope_mask(x) * sl(m, fid_a, n_a) \
            * (m / _log_slope_safe_x(x))
    y_ref[...] = y.astype(y_ref.dtype)
    dy_ref[...] = slope.astype(dy_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "kind", "fid_a",
                              "fid_b", "n_a", "n_b"))
def _folded_call(x2d, bounds, invd, base, segs, values, *, block_rows,
                 interpret, kind, fid_a, fid_b, n_a, n_b):
    grid, in_specs = _pack_specs(x2d, (bounds, invd, base, segs, values),
                                 block_rows)
    kernel = functools.partial(_folded_kernel, kind=kind, fid_a=fid_a,
                               fid_b=fid_b, n_a=n_a, n_b=n_b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, bounds, invd, base, segs, values)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "kind", "fid_a",
                              "fid_b", "n_a", "n_b"))
def _folded_call_grad(x2d, bounds, invd, base, segs, values, *, block_rows,
                      interpret, kind, fid_a, fid_b, n_a, n_b):
    grid, in_specs = _pack_specs(x2d, (bounds, invd, base, segs, values),
                                 block_rows)
    kernel = functools.partial(_folded_grad_kernel, kind=kind, fid_a=fid_a,
                               fid_b=fid_b, n_a=n_a, n_b=n_b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_rows, x2d.shape[1]), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(x2d.shape, x2d.dtype)] * 2,
        interpret=interpret,
    )(x2d, bounds, invd, base, segs, values)


def _folded_prep(pack: TablePack, name: str, x, lane, block_rows, interpret):
    from repro.approx.range_fold import FOLDABLE

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if name not in FOLDABLE:
        raise KeyError(f"folded kernel serves {sorted(FOLDABLE)}, got {name!r}; "
                       f"use table_pack_lookup_pallas for plain members")
    cores = FOLDABLE[name]
    fid_a = pack.member_id(cores[0])
    fid_b = pack.member_id(cores[1]) if len(cores) > 1 else fid_a
    x2d, block, n = tile_activations(x, lane, block_rows)
    return fid_a, fid_b, x2d, block, n, interpret


def folded_pack_lookup_pallas(
    pack: TablePack,
    name: str,
    x: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
) -> jax.Array:
    """Full-f32-range ``sin``/``cos``/``exp``/``log`` over a tensor: fold +
    core lookup(s) + reconstruction fused in one kernel launch."""
    fid_a, fid_b, x2d, block, n, interpret = _folded_prep(
        pack, name, x, lane, block_rows, interpret)
    out = _folded_call(
        x2d, pack.boundaries, pack.inv_delta, pack.base, pack.seg_count,
        pack.values.reshape(1, -1),
        block_rows=block, interpret=interpret, kind=name, fid_a=fid_a,
        fid_b=fid_b, n_a=pack.n_intervals[fid_a], n_b=pack.n_intervals[fid_b])
    return untile_activations(out, n, x.shape)


def folded_pack_grad_pallas(
    pack: TablePack,
    name: str,
    x: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    lane: int = LANE,
    interpret: bool | None = None,
):
    """Fused (y, dy/dx) of the folded surrogate in one selector pass."""
    fid_a, fid_b, x2d, block, n, interpret = _folded_prep(
        pack, name, x, lane, block_rows, interpret)
    y2d, dy2d = _folded_call_grad(
        x2d, pack.boundaries, pack.inv_delta, pack.base, pack.seg_count,
        pack.values.reshape(1, -1),
        block_rows=block, interpret=interpret, kind=name, fid_a=fid_a,
        fid_b=fid_b, n_a=pack.n_intervals[fid_a], n_b=pack.n_intervals[fid_b])
    return untile_activations(y2d, n, x.shape), untile_activations(dy2d, n, x.shape)
