"""Jaxpr-level lint primitives for PackLint (see ``repro.analysis.contracts``).

Everything here works on *traces* — ``jax.make_jaxpr`` / ``jax.eval_shape``
artifacts — and never executes a kernel.  The helpers are deliberately small
and composable: the contract rules in ``contracts.py`` decide *what* must
hold; this module only answers structural questions about a jaxpr:

- which primitives appear (recursively, through ``pjit``/``custom_jvp``/
  ``scan``/``pallas_call`` sub-jaxprs);
- which dtypes appear (avals, literals, and closed-over consts) — the
  f64-leakage lint;
- where the Pallas kernels are, what their kernel bodies contain, and what
  their grid/BlockSpec footprints are — the forbidden-primitive and static
  VMEM lints;
- what a ``jax.jit`` cache key looks like for a concrete call — the
  recompile-hazard lint (weak types and dtype drift show up here).
"""

from __future__ import annotations

import re
from collections import Counter
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import core as jax_core

try:  # the raw Jaxpr type moved around across jax versions
    from jax._src.core import Jaxpr as _Jaxpr
    from jax._src.core import ClosedJaxpr as _ClosedJaxpr
except ImportError:  # pragma: no cover - version drift guard
    _Jaxpr = type(None)
    _ClosedJaxpr = type(None)

# Dtypes that must never appear in a runtime trace: the design layer
# (core/design.py, core/quantize.py) works in f64 on purpose, and a single
# leaked f64 constant silently doubles VMEM traffic (or, with x64 disabled,
# silently *downcasts* the design guarantee).
WIDE_DTYPES = frozenset({"float64", "complex128"})


# --------------------------------------------------------------------------------------
# Recursive jaxpr walking
# --------------------------------------------------------------------------------------

def _as_jaxpr(obj) -> Optional[Any]:
    """Return the raw ``Jaxpr`` carried by ``obj`` (Jaxpr/ClosedJaxpr), else None."""
    if isinstance(obj, _ClosedJaxpr):
        return obj.jaxpr
    if isinstance(obj, _Jaxpr):
        return obj
    if hasattr(obj, "jaxpr") and hasattr(obj, "eqns"):  # pragma: no cover
        return obj
    return None


def sub_jaxprs(params: Dict[str, Any]) -> Iterator[Any]:
    """Yield every raw Jaxpr nested in an eqn's params (pjit's ``jaxpr``,
    pallas_call's kernel body, scan/cond branches, custom_jvp closures...)."""
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            j = _as_jaxpr(item)
            if j is not None:
                yield j
            elif hasattr(item, "call_jaxpr"):  # custom_jvp_call wrappers
                j2 = _as_jaxpr(item.call_jaxpr)
                if j2 is not None:
                    yield j2


def iter_eqns(jaxpr) -> Iterator[Any]:
    """All equations in ``jaxpr`` and every nested sub-jaxpr (depth-first)."""
    j = _as_jaxpr(jaxpr)
    if j is None:
        return
    for eqn in j.eqns:
        yield eqn
        for sub in sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def primitive_counts(jaxpr) -> Counter:
    """``Counter`` of primitive names over the whole (recursive) trace."""
    return Counter(e.primitive.name for e in iter_eqns(jaxpr))


def iter_avals(jaxpr) -> Iterator[Tuple[str, Any]]:
    """All (where, aval) pairs in the trace: invars, constvars, every eqn's
    in/out vars (literals included), recursively."""
    j = _as_jaxpr(jaxpr)
    if j is None:
        return
    for v in list(j.invars) + list(j.constvars):
        yield ("invar", v.aval)
    for eqn in j.eqns:
        name = eqn.primitive.name
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            if aval is None and hasattr(v, "val"):  # Literal
                aval = jax_core.get_aval(v.val)
            if aval is not None:
                yield (name, aval)
        for v in eqn.outvars:
            yield (name, v.aval)
        for sub in sub_jaxprs(eqn.params):
            yield from iter_avals(sub)


# --------------------------------------------------------------------------------------
# Rule 1 — wide-dtype (f64) leakage
# --------------------------------------------------------------------------------------

def find_wide_dtypes(traced, wide: frozenset = WIDE_DTYPES) -> List[str]:
    """Every place a forbidden-width dtype appears in the trace.

    Returns human-readable locations (``"mul: float64"``); empty list == clean.
    Consts of a ClosedJaxpr are checked too — that is where a design-layer
    ``np.float64`` table sneaks into a runtime closure.
    """
    hits: List[str] = []
    if isinstance(traced, _ClosedJaxpr):
        for i, c in enumerate(traced.consts):
            dt = getattr(c, "dtype", None)
            if dt is not None and str(dt) in wide:
                hits.append(f"const[{i}]: {dt}")
    for where, aval in iter_avals(traced):
        dt = getattr(aval, "dtype", None)
        if dt is not None and str(dt) in wide:
            hits.append(f"{where}: {dt}")
    return hits


def array_leaf_wide_dtypes(tree, wide: frozenset = WIDE_DTYPES) -> List[str]:
    """Wide-dtype leaves in a pytree of device/host arrays (a pack artifact)."""
    hits = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        dt = getattr(leaf, "dtype", None)
        if dt is not None and str(dt) in wide:
            hits.append(f"{jax.tree_util.keystr(path)}: {dt}")
    return hits


# --------------------------------------------------------------------------------------
# Rule 2 — Pallas kernel extraction, forbidden primitives, dynamic shapes
# --------------------------------------------------------------------------------------

def pallas_eqns(traced) -> List[Any]:
    """Every ``pallas_call`` equation in the trace (recursive)."""
    return [e for e in iter_eqns(traced) if e.primitive.name == "pallas_call"]


def kernel_name(eqn) -> str:
    """The kernel body's registered name (``name_and_src_info`` in jax 0.4)."""
    info = eqn.params.get("name_and_src_info")
    if info is not None:
        return getattr(info, "name", str(info))
    return str(eqn.params.get("name", "<pallas>"))  # pragma: no cover


def kernel_body(eqn):
    """The raw kernel-body Jaxpr of a ``pallas_call`` equation."""
    return _as_jaxpr(eqn.params["jaxpr"])


def kernel_primitive_counts(eqn) -> Counter:
    """Primitive census of one kernel body (recursing into nested pjit)."""
    return primitive_counts(kernel_body(eqn))


def forbidden_primitives(counts: Counter,
                         allowed: Optional[frozenset] = None) -> List[str]:
    """Primitives that must never appear in a device kernel body (or, with an
    ``allowed`` set, any primitive outside that per-entry allowlist)."""
    bad = []
    for name in sorted(counts):
        if "callback" in name or name in ("infeed", "outfeed"):
            bad.append(name)
        elif allowed is not None and name not in allowed:
            bad.append(f"unallowlisted:{name}")
    return bad


def closure_callbacks(traced) -> List[str]:
    """Host-callback primitives anywhere in a runtime closure's trace — the
    obs-off path must have none (rule 2's closure-level clause)."""
    return sorted(n for n in primitive_counts(traced)
                  if "callback" in n or n in ("infeed", "outfeed"))


def dynamic_shape_avals(jaxpr) -> List[str]:
    """Avals whose shape is not a tuple of concrete ints (dynamic dims)."""
    bad = []
    for where, aval in iter_avals(jaxpr):
        shape = getattr(aval, "shape", None)
        if shape is None:
            continue
        if not all(isinstance(d, (int, np.integer)) for d in shape):
            bad.append(f"{where}: {shape}")
    return bad


# --------------------------------------------------------------------------------------
# Rule 3 — jit cache keys (recompile hazards)
# --------------------------------------------------------------------------------------

def aval_of(x):
    """The shaped aval jax would assign ``x`` as a jit argument (weak types
    preserved — a python scalar comes back ``weak_type=True``)."""
    if isinstance(x, jax.ShapeDtypeStruct):
        return jax_core.ShapedArray(x.shape, x.dtype,
                                    weak_type=getattr(x, "weak_type", False))
    aval = jax_core.get_aval(x)
    return jax_core.raise_to_shaped(aval) if hasattr(jax_core, "raise_to_shaped") else aval


def leaf_signature(x) -> Tuple[Tuple[int, ...], str, bool]:
    """(shape, dtype, weak_type) — the per-leaf component of a jit cache key."""
    a = aval_of(x)
    return (tuple(a.shape), str(a.dtype), bool(getattr(a, "weak_type", False)))


def jit_cache_key(args: Sequence[Any],
                  static: Optional[Dict[str, Any]] = None) -> tuple:
    """The structural jit cache key of one call: (treedef, per-leaf
    (shape, dtype, weak_type), sorted static kwargs).

    Two calls that produce different keys WILL trigger a recompile of the
    underlying executable; the serving contracts require key equality across
    reroutes and ticks.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tuple(args))
    sig = tuple(leaf_signature(x) for x in leaves)
    stat = tuple(sorted((k, repr(v)) for k, v in (static or {}).items()))
    return (str(treedef), sig, stat)


def weak_leaves(args: Sequence[Any]) -> List[str]:
    """Indices/paths of weak-typed leaves in a call's dynamic args — each one
    is a promotion hazard (the next strongly-typed caller forces a recompile)."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tuple(args))[0]:
        if leaf_signature(leaf)[2]:
            out.append(jax.tree_util.keystr(path))
    return out


def keys_stable(keys: Sequence[tuple]) -> bool:
    """True iff every captured call shares one cache key (no recompiles)."""
    return len({k for k in keys}) <= 1


# --------------------------------------------------------------------------------------
# Rule 4 — static VMEM accounting from lowered pallas_call footprints
# --------------------------------------------------------------------------------------

def _block_elems(block_shape) -> int:
    n = 1
    for d in block_shape:
        n *= int(d) if isinstance(d, (int, np.integer)) else 1  # Mapped dim
    return n


def pallas_footprint(eqn) -> Dict[str, Any]:
    """Static footprint of one lowered ``pallas_call``.

    Returns::

        {"grid": tuple, "operands": [(shape, dtype, full_bytes, block_bytes,
                                      pinned)], "pinned_bytes": int,
         "block_bytes": int, "prefetch_bytes": int}

    ``operands`` follows ``grid_mapping.block_mappings`` order (inputs then
    outputs); scalar-prefetch operands (PrefetchScalarGridSpec) have no block
    mapping and are accounted separately under ``prefetch_bytes``.  An operand
    is *pinned* when its block covers the full array — the whole plane is
    VMEM-resident every grid step, which is exactly what the pack's
    ``vmem()`` budget prices.
    """
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    mappings = list(gm.block_mappings)
    # avals for [inputs..., outputs...]: invars after the scalar-prefetch
    # operands line up with the leading mappings; out_avals close the list.
    out_avals = list(eqn.params.get("out_avals") or [v.aval for v in eqn.outvars])
    in_avals = [v.aval if hasattr(v, "aval") else jax_core.get_aval(v.val)
                for v in eqn.invars]
    n_prefetch = len(in_avals) + len(out_avals) - len(mappings)
    prefetch, block_ops = in_avals[:max(n_prefetch, 0)], in_avals[max(n_prefetch, 0):]
    avals = block_ops + out_avals

    operands = []
    pinned_bytes = block_bytes = 0
    n_out = len(out_avals)
    for i, (aval, bm) in enumerate(zip(avals, mappings)):
        shape = tuple(int(d) for d in aval.shape)
        itemsize = np.dtype(aval.dtype).itemsize
        full = int(np.prod(shape, dtype=np.int64)) * itemsize if shape else itemsize
        bshape = tuple(bm.block_shape)
        blk = _block_elems(bshape) * itemsize
        pinned = blk >= full
        operands.append({"shape": shape, "dtype": str(aval.dtype),
                         "full_bytes": full, "block_bytes": blk,
                         "pinned": pinned,
                         "is_output": i >= len(avals) - n_out})
        block_bytes += blk
        if pinned:
            pinned_bytes += full
    prefetch_bytes = sum(
        int(np.prod(a.shape, dtype=np.int64)) * np.dtype(a.dtype).itemsize
        for a in prefetch)
    return {"grid": grid, "operands": operands, "pinned_bytes": pinned_bytes,
            "block_bytes": block_bytes, "prefetch_bytes": prefetch_bytes,
            "n_prefetch": max(n_prefetch, 0)}


def pack_resident_bytes(eqn) -> int:
    """VMEM-resident bytes of the *pack* operands of one kernel launch: every
    pinned plane (metadata comparator planes, value/code vectors) plus the
    scalar-prefetch rows, with the activation tiles excluded.

    The activation input and the output(s) share the kernel's tile shape (the
    output avals); with grid==1 their blocks cover the full array and would
    masquerade as pinned — any pinned operand whose shape matches an output
    aval's shape is dropped, which removes exactly x2d and the outputs and
    leaves the pack planes (metadata rows are (F, n) shapes; value/code
    vectors are (1, M))."""
    fp = pallas_footprint(eqn)
    tile_shapes = {op["shape"] for op in fp["operands"] if op["is_output"]}
    # scalar-prefetch rows (routed fn_ids etc.) are per-call ROUTING operands
    # living in SMEM — they are not part of the pack's VMEM residency budget
    total = 0
    for op in fp["operands"]:
        if op["pinned"] and op["shape"] not in tile_shapes:
            total += op["full_bytes"]
    return total


# --------------------------------------------------------------------------------------
# Rule 5 — structural identity
# --------------------------------------------------------------------------------------

def trace(fn: Callable, *args, **kwargs):
    """``jax.make_jaxpr`` with kwargs folded in (trace only — never executes)."""
    if kwargs:
        return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return jax.make_jaxpr(fn)(*args)


# custom_jvp params print helper thunks with their memory address
# (``jvp_jaxpr_thunk=<function ... at 0x7f...>``); identical graphs from two
# builds differ only there, so addresses are masked out of the fingerprint.
_ADDR_RE = re.compile(r"0x[0-9a-f]+")


def fingerprint(fn: Callable, *args, **kwargs) -> str:
    """Canonical structural fingerprint of a closure: the printed jaxpr with
    object addresses masked.

    ``make_jaxpr`` names variables deterministically, so two closures print
    identically iff they trace to the same graph — the obs-off zero-overhead
    contract in one string comparison.
    """
    return _ADDR_RE.sub("0x_", str(trace(fn, *args, **kwargs)))


def structurally_identical(fn_a: Callable, fn_b: Callable, *args) -> bool:
    return fingerprint(fn_a, *args) == fingerprint(fn_b, *args)
