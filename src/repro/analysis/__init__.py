"""repro.analysis — PackLint: static jaxpr-level contract checking.

``jaxpr_lint`` holds the trace-inspection primitives, ``contracts`` the five
registered contract rules over the live mode registry, and ``report`` the
``REPORT_contracts.json`` serialization.  ``tools/check_contracts.py`` is the
CLI; ``docs/static_analysis.md`` is the rule catalog.
"""

from .contracts import ALL_MODES, FAST_FUNCS, KERNEL_ALLOWED, LintContext, RULES, rule, run
from .report import Finding, Report

__all__ = [
    "ALL_MODES",
    "FAST_FUNCS",
    "Finding",
    "KERNEL_ALLOWED",
    "LintContext",
    "RULES",
    "Report",
    "rule",
    "run",
]
