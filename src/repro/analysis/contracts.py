"""PackLint — the repo's standing contracts, checked structurally on traces.

Five rule classes, each registered in :data:`RULES` and auto-enrolled over
the live mode registry (``repro.approx.TABLE_MODES`` plus ``"exact"``) the
same way a new mode joins the conformance matrix — a mode that ships without
being lintable here fails the ``kernel_primitives`` rule's
"unregistered kernel" clause rather than silently skipping:

1. ``f64_leak``        — no design-layer float64/complex128 may appear in any
                         runtime closure's jaxpr or any pack artifact leaf.
2. ``kernel_primitives`` — every Pallas kernel body stays inside its frozen
                         per-entry primitive allowlist: no host callbacks, no
                         infeed/outfeed, no dynamic-shape avals; runtime
                         closures built with observability off contain no
                         callback primitive anywhere.
3. ``recompile_hazard`` — the jit cache key of the routed kernels is invariant
                         across reroutes (captured via a trace-only spy on the
                         real jitted entry), and ContinuousEngine serves a
                         queue from exactly two executables whose signatures
                         are stationary (tick outputs re-feed as inputs with
                         identical avals; no weak types anywhere).
4. ``vmem_budget``     — the VMEM-resident pack operands recovered from each
                         lowered ``pallas_call`` (pinned planes + prefetch
                         rows) fit the planner's own budget:
                         ``PackLayout/QuantPackLayout.vmem()``,
                         ``PackPlan.vmem()`` (+ the documented device
                         lane-padding allowance), and the per-shard
                         ``ShardedPackLayout.vmem()``.
5. ``obs_off_identity`` — for every mode, the closure built with
                         observability enabled-but-telemetry-off is
                         structurally identical (printed jaxpr equality) to
                         the closure built with observability never imported
                         into the picture at all.

The TableFlash closure (``ApproxConfig.attn_exp`` — the fused exp_neg lookup
flash attention calls from its running-softmax step, docs/table_flash.md) is
enrolled in rules 2, 4, and 5 alongside the mode matrix whenever the lint
pack carries an ``exp_neg`` member.

Everything is derived from ``jax.make_jaxpr`` / ``jax.eval_shape`` traces —
no kernel is ever executed; the numerical side of these contracts lives in
``tests/test_conformance.py``.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis import jaxpr_lint as jl
from repro.analysis.report import Finding, Report
from repro.approx import (
    FOLDABLE,
    FOLDED_MODES,
    TABLE_MODES,
    ApproxConfig,
    build_poly_pack,
    eval_folded_ref,
    eval_folded_routed,
    eval_pack_ref,
    eval_poly_pack_ref,
    eval_quant_pack_ref,
    eval_routed_poly_ref,
    eval_routed_quant_ref,
    eval_routed_ref,
    eval_sharded_ref,
    eval_table_ref,
    folded_lookup,
    from_quant_layout,
    from_spec,
    get_exact,
    make_attn_exp_fn,
    make_folded_fn,
    make_folded_routed_unary_fn,
    make_pack_fn,
    make_poly_pack_fn,
    make_quant_pack_fn,
    make_routed_unary_fn,
    make_sharded_pack_fn,
    make_table_fn,
    pack_specs,
    shard_pack,
)
from repro.core import (
    cached_table,
    design,
    function_names,
    get_function,
    pack_layout,
    plan_quant_member,
    quant_pack_layout,
)
from repro.core.packing import shard_pack_layout
from repro.kernels.table_lookup import table_lookup_pallas
from repro.kernels.table_pack_lookup import (
    poly_pack_lookup_pallas,
    quant_pack_lookup_pallas,
    sharded_pack_lookup_pallas,
    table_pack_lookup_pallas,
)
from repro.kernels.routed_pack_lookup import (
    routed_pack_grad_pallas,
    routed_pack_lookup_pallas,
    routed_poly_pack_grad_pallas,
    routed_poly_pack_lookup_pallas,
    routed_quant_pack_grad_pallas,
    routed_quant_pack_lookup_pallas,
)

EA = 1e-4
ROWS = 16  # routed modes reshape the grid into (ROWS, -1) rows
N_GRID = 2048
N_SHARDS = 2
# the fast-tier subsample (mirrors tests/test_conformance.FAST_FUNCS)
FAST_FUNCS = ("gelu", "tanh", "log")
ALL_MODES = tuple(TABLE_MODES) + ("exact",)


# --------------------------------------------------------------------------------------
# Kernel-entry allowlists (rule 2) — keyed by the pallas kernel body's
# registered name (the kernel function's __name__ in kernels/*.py).  A kernel
# that is not listed here FAILS the lint: enrolling a new kernel means adding
# its row, which is the moment to review what it is allowed to do on-device.
# --------------------------------------------------------------------------------------

# Frozen from the lowered kernel bodies at enrollment time (comparator-plane
# select + gather/FMA arithmetic; ``pjit`` covers jnp.clip/take sub-calls;
# ``get``/``swap`` are the pallas ref reads/writes).
_BASE = frozenset({
    "add", "broadcast_in_dim", "convert_element_type", "floor", "gather",
    "ge", "get", "max", "min", "mul", "pjit", "reduce_sum", "slice", "sub",
    "swap",
})
# grad kernels add the in-domain mask (d/dx of the clamp epilogue)
_GRAD = frozenset({"and", "lt"})
# masked multi-member select (sharded owners, quant/poly width groups)
_SELECT = frozenset({"gt", "select_n", "eq", "le", "iota", "squeeze", "and"})
# scalar-prefetch routed dispatch reads its fn_id row by grid position
_ROUTED = frozenset({"program_id"})
# RangeFold prologue/epilogue: Cody-Waite / Payne-Hanek octant bookkeeping
# (trig) and exponent-field bit splits (exp/log), fused in the kernel body
_FOLD = frozenset({
    "abs", "and", "bitcast_convert_type", "clz", "div", "eq", "gt",
    "is_finite", "lt", "ne", "neg", "not", "or", "rem", "round", "select_n",
    "shift_left", "shift_right_logical", "sign",
})

KERNEL_ALLOWED: Dict[str, frozenset] = {
    "_table_kernel": _BASE,
    "_table_grad_kernel": _BASE | _GRAD,
    "_pack_kernel": _BASE,
    "_pack_grad_kernel": _BASE | _GRAD,
    "_quant_kernel": _BASE,
    "_quant_grad_kernel": _BASE | _GRAD,
    "_poly_kernel": _BASE,
    "_poly_grad_kernel": _BASE | _GRAD,
    "_spack_kernel": _BASE | _SELECT,
    "_spack_grad_kernel": _BASE | _SELECT | _GRAD,
    "_folded_kernel": _BASE | _SELECT | _FOLD,
    "_folded_grad_kernel": _BASE | _SELECT | _FOLD | _GRAD,
    "_routed_kernel": _BASE | _SELECT | _ROUTED,
    "_routed_grad_kernel": _BASE | _SELECT | _ROUTED | _GRAD,
    "_routed_quant_kernel": _BASE | _SELECT | _ROUTED,
    "_routed_quant_grad_kernel": _BASE | _SELECT | _ROUTED | _GRAD,
    "_routed_poly_kernel": _BASE | _SELECT | _ROUTED,
    "_routed_poly_grad_kernel": _BASE | _SELECT | _ROUTED | _GRAD,
    # TableFlash: the fused exp_neg lookup flash attention calls in its
    # running-softmax step — _pack_kernel's body plus address saturation
    # (``max``, already in _BASE) and the underflow-to-zero tail select
    "_tableflash_kernel": _BASE | frozenset({"lt", "select_n"}),
}


# --------------------------------------------------------------------------------------
# The lint context: one cached build of every pack flavor + one cached trace
# per (mode, function, value|grad) closure, shared by all rules.
# --------------------------------------------------------------------------------------

class LintContext:
    """Shared pack builds, closures, and trace cache for one PackLint run."""

    def __init__(self, e_a: float = EA,
                 funcs: Optional[Sequence[str]] = None,
                 n_shards: int = N_SHARDS):
        self.e_a = float(e_a)
        self.funcs = tuple(funcs) if funcs is not None else tuple(function_names())
        if len(self.funcs) < 2:
            raise ValueError("PackLint needs >= 2 functions (reroute checks)")
        # folded modes read the canonical-interval core members of the pack
        cores = [c for n in self.funcs for c in FOLDABLE.get(n, ())
                 if c not in self.funcs]
        self.pack_names = self.funcs + tuple(dict.fromkeys(cores))
        self.n_shards = int(n_shards)
        self._cache: dict = {}

    # ---------------------------- pack builds -----------------------------

    def _memo(self, key, build):
        if key not in self._cache:
            self._cache[key] = build()
        return self._cache[key]

    def spec(self, name: str):
        return cached_table(name, self.e_a)

    def pack(self):
        return self._memo("pack", lambda: pack_specs(
            [self.spec(n) for n in self.pack_names]))

    def layout(self):
        return self._memo("layout", lambda: pack_layout(
            [self.spec(n) for n in self.pack_names]))

    def qpack(self):
        return self._memo("qpack", lambda: from_quant_layout(self.qlayout()))

    def qlayout(self):
        return self._memo("qlayout", lambda: quant_pack_layout(
            [plan_quant_member(n, self.e_a) for n in self.funcs]))

    def ppack(self):
        return self._memo("ppack", lambda: build_poly_pack(self.funcs, self.e_a))

    def pplan(self):
        # mirrors build_poly_pack's exact plan() call (rho=0.9, auto dtypes)
        return self._memo("pplan", lambda: design.plan(
            list(self.funcs), self.e_a, None, dtypes=design.POLY_DTYPES,
            algorithm="hierarchical", omega=0.3, rho=0.9))

    def spack(self):
        return self._memo("spack", lambda: shard_pack(self.layout(), self.n_shards))

    def slayout(self):
        return self._memo("slayout",
                          lambda: shard_pack_layout(self.layout(), self.n_shards))

    # ----------------------------- closures -------------------------------

    def x(self, name: str) -> np.ndarray:
        lo, hi = get_function(name).interval
        return np.linspace(lo, hi, N_GRID + 1)[:-1].astype(np.float32)

    def matrix(self, modes: Optional[Sequence[str]] = None
               ) -> Iterator[Tuple[str, str]]:
        from repro.approx.activations import _EXACT

        for m in (modes if modes is not None else ALL_MODES):
            for f in self.funcs:
                if m == "exact" and f not in _EXACT:
                    continue  # the canonical-interval core members are
                    # table-only: exact mode has no registered closure
                yield m, f

    def value_closure(self, mode: str, name: str) -> Callable:
        """``f(x)`` for one (mode, function) — the runtime the conformance
        matrix evaluates, as an un-evaluated closure (mirrors
        ``tests/test_conformance.approx_eval``)."""
        pk, rows = self.pack(), (lambda v: v.reshape(ROWS, -1))
        if mode == "exact":
            return get_exact(name)
        if mode == "table_ref":
            jt = from_spec(self.spec(name))
            return lambda v: eval_table_ref(jt, v)
        if mode == "table_pallas":
            jt = from_spec(self.spec(name))
            return lambda v: table_lookup_pallas(jt, v)
        if mode == "table_pack_ref":
            return lambda v: eval_pack_ref(pk, name, v)
        if mode == "table_pack":
            return lambda v: table_pack_lookup_pallas(pk, name, v)
        if mode == "quant_pack_ref":
            qp = self.qpack()
            return lambda v: eval_quant_pack_ref(qp, name, v)
        if mode == "quant_pack":
            qp = self.qpack()
            return lambda v: quant_pack_lookup_pallas(qp, name, v)
        if mode == "poly_pack_ref":
            pp = self.ppack()
            return lambda v: eval_poly_pack_ref(pp, name, v)
        if mode == "poly_pack":
            pp = self.ppack()
            return lambda v: poly_pack_lookup_pallas(pp, name, v)
        if mode == "routed_pack_ref":
            return lambda v: eval_routed_ref(pk, name, rows(v)).reshape(v.shape)
        if mode == "routed_pack":
            return lambda v: routed_pack_lookup_pallas(
                pk, name, rows(v)).reshape(v.shape)
        if mode == "routed_quant_pack_ref":
            qp = self.qpack()
            return lambda v: eval_routed_quant_ref(
                qp, name, rows(v)).reshape(v.shape)
        if mode == "routed_quant_pack":
            qp = self.qpack()
            return lambda v: routed_quant_pack_lookup_pallas(
                qp, name, rows(v)).reshape(v.shape)
        if mode == "routed_poly_pack_ref":
            pp = self.ppack()
            return lambda v: eval_routed_poly_ref(
                pp, name, rows(v)).reshape(v.shape)
        if mode == "routed_poly_pack":
            pp = self.ppack()
            return lambda v: routed_poly_pack_lookup_pallas(
                pp, name, rows(v)).reshape(v.shape)
        if mode == "sharded_pack_ref":
            sp = self.spack()
            return lambda v: eval_sharded_ref(sp, name, v)
        if mode == "sharded_pack":
            sp = self.spack()
            return lambda v: sharded_pack_lookup_pallas(sp, name, v)
        if mode == "folded_pack_ref":
            return lambda v: eval_folded_ref(pk, name, v)
        if mode == "folded_pack":
            return lambda v: folded_lookup(pk, name, v)
        if mode == "folded_routed_pack_ref":
            return lambda v: eval_folded_routed(pk, name, v, use_pallas=False)
        if mode == "folded_routed_pack":
            return lambda v: eval_folded_routed(pk, name, v, use_pallas=True)
        raise ValueError(f"unknown mode {mode!r}")  # pragma: no cover

    def unary_fn(self, mode: str, name: str) -> Callable:
        """The mode's differentiable unary (mirrors conformance
        ``approx_fn``)."""
        if mode == "exact":
            return get_exact(name)
        if mode in ("table_ref", "table_pallas"):
            return make_table_fn(from_spec(self.spec(name)),
                                 use_pallas=(mode == "table_pallas"))
        pallas = not mode.endswith("_ref")
        if mode in FOLDED_MODES:
            make = (make_folded_routed_unary_fn if "routed" in mode
                    else make_folded_fn)
            return make(self.pack(), name, use_pallas=pallas)
        if mode.startswith("routed"):
            pack = (self.ppack() if "poly" in mode
                    else self.qpack() if "quant" in mode else self.pack())
            return make_routed_unary_fn(pack, name, use_pallas=pallas)
        if mode.startswith("sharded"):
            return make_sharded_pack_fn(self.spack(), name, use_pallas=pallas)
        if mode.startswith("poly"):
            return make_poly_pack_fn(self.ppack(), name, use_pallas=pallas)
        if mode.startswith("quant"):
            return make_quant_pack_fn(self.qpack(), name, use_pallas=pallas)
        return make_pack_fn(self.pack(), name, use_pallas=pallas)

    def grad_closure(self, mode: str, name: str) -> Callable:
        fn = self.unary_fn(mode, name)
        return lambda v: jax.grad(lambda u: jnp.sum(fn(u)))(v)

    def traced(self, mode: str, name: str, kind: str):
        """Cached ClosedJaxpr of one (mode, function, value|grad) closure."""
        key = ("trace", mode, name, kind)

        def build():
            f = (self.value_closure(mode, name) if kind == "value"
                 else self.grad_closure(mode, name))
            return jl.trace(f, self.x(name))

        return self._memo(key, build)

    # ----------------------------- TableFlash -----------------------------

    def attn_x(self) -> np.ndarray:
        # flash attention feeds s - m_new <= 0; include a below-domain tail
        # so the clamp path is part of the traced closure
        return np.linspace(-20.0, 0.0, N_GRID).astype(np.float32)

    def attn_traced(self, kind: str):
        """Cached ClosedJaxpr of the TableFlash exp closure (value|grad)."""
        key = ("attn_trace", kind)

        def build():
            fn = make_attn_exp_fn(self.pack(), use_pallas=True)
            f = (fn if kind == "value"
                 else (lambda v: jax.grad(lambda u: jnp.sum(fn(u)))(v)))
            return jl.trace(f, self.attn_x())

        return self._memo(key, build)


# --------------------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------------------

RULES: Dict[str, Callable[[LintContext], List[Finding]]] = {}


def rule(name: str):
    def deco(fn):
        RULES[name] = fn
        return fn
    return deco


def run(ctx: Optional[LintContext] = None,
        rules: Optional[Sequence[str]] = None) -> Report:
    """Run the registered rules and collect a :class:`Report`."""
    ctx = ctx or LintContext()
    names = list(rules) if rules is not None else list(RULES)
    rep = Report(meta={
        "e_a": ctx.e_a, "funcs": list(ctx.funcs),
        "modes": list(ALL_MODES), "n_shards": ctx.n_shards,
        "rules": names, "jax": jax.__version__,
    })
    for name in names:
        rep.extend(RULES[name](ctx))
    return rep


# --------------------------------------------------------------------------------------
# Rule 1 — f64 leakage
# --------------------------------------------------------------------------------------

@rule("f64_leak")
def rule_f64_leak(ctx: LintContext) -> List[Finding]:
    out: List[Finding] = []
    artifacts = [("pack", ctx.pack()), ("quant_pack", ctx.qpack()),
                 ("poly_pack", ctx.ppack()), ("sharded_pack", ctx.spack())]
    for label, art in artifacts:
        hits = jl.array_leaf_wide_dtypes(art)
        out.append(Finding("f64_leak", f"artifact:{label}", not hits,
                           "; ".join(hits[:4])))
    for mode, name in ctx.matrix():
        for kind in ("value", "grad"):
            hits = jl.find_wide_dtypes(ctx.traced(mode, name, kind))
            out.append(Finding("f64_leak", f"{mode}/{name}/{kind}", not hits,
                               "; ".join(hits[:4])))
    return out


# --------------------------------------------------------------------------------------
# Rule 2 — forbidden primitives per kernel entry + callback-free closures
# --------------------------------------------------------------------------------------

def check_kernel(eqn, allowed: Optional[frozenset]) -> List[str]:
    """Violations of one lowered kernel body against its allowlist."""
    counts = jl.kernel_primitive_counts(eqn)
    bad = jl.forbidden_primitives(counts, allowed)
    bad += [f"dynamic-shape {d}" for d in jl.dynamic_shape_avals(jl.kernel_body(eqn))]
    return bad


@rule("kernel_primitives")
def rule_kernel_primitives(ctx: LintContext) -> List[Finding]:
    out: List[Finding] = []
    seen_kernels = set()
    for mode, name in ctx.matrix():
        for kind in ("value", "grad"):
            traced = ctx.traced(mode, name, kind)
            # the closures here are built with observability off — the
            # runtime serving path — so ANY callback primitive is a leak
            cb = jl.closure_callbacks(traced)
            out.append(Finding(
                "kernel_primitives", f"closure:{mode}/{name}/{kind}", not cb,
                f"callback primitives on obs-off path: {cb}" if cb else ""))
            for eqn in jl.pallas_eqns(traced):
                kname = jl.kernel_name(eqn)
                if (kname, mode, kind) in seen_kernels:
                    continue  # one verdict per kernel flavor per mode/kind
                seen_kernels.add((kname, mode, kind))
                allowed = KERNEL_ALLOWED.get(kname)
                if allowed is None:
                    out.append(Finding(
                        "kernel_primitives", f"kernel:{kname}", False,
                        f"unregistered kernel entry (mode {mode}); add an "
                        f"allowlist row to analysis.contracts.KERNEL_ALLOWED"))
                    continue
                bad = check_kernel(eqn, allowed)
                out.append(Finding(
                    "kernel_primitives", f"kernel:{kname}[{mode}/{name}/{kind}]",
                    not bad, "; ".join(bad[:6])))
    # TableFlash: the attn_exp closure is its own runtime entry (a kernel the
    # mode matrix never launches) — same obs-off + allowlist contract
    if "exp_neg" in ctx.pack_names:
        for kind in ("value", "grad"):
            traced = ctx.attn_traced(kind)
            cb = jl.closure_callbacks(traced)
            out.append(Finding(
                "kernel_primitives", f"closure:attn_exp/{kind}", not cb,
                f"callback primitives on obs-off path: {cb}" if cb else ""))
            for eqn in jl.pallas_eqns(traced):
                kname = jl.kernel_name(eqn)
                allowed = KERNEL_ALLOWED.get(kname)
                if allowed is None:
                    out.append(Finding(
                        "kernel_primitives", f"kernel:{kname}", False,
                        "unregistered kernel entry (attn_exp); add an "
                        "allowlist row to analysis.contracts.KERNEL_ALLOWED"))
                    continue
                bad = check_kernel(eqn, allowed)
                out.append(Finding(
                    "kernel_primitives", f"kernel:{kname}[attn_exp/{kind}]",
                    not bad, "; ".join(bad[:6])))
    return out


# --------------------------------------------------------------------------------------
# Rule 3 — recompile hazards: routed reroutes + the serving tick
# --------------------------------------------------------------------------------------

# the module-global jitted dispatchers every routed entry point funnels into
_ROUTED_CALLEES = ("_routed_call", "_routed_quant_call", "_routed_poly_call",
                   "_sharded_routed_call")


def capture_routed_keys(entry: Callable, calls: Sequence[tuple]) -> Tuple[list, list]:
    """Invoke ``entry(*call)`` for each call with the module-global jitted
    routed dispatchers replaced by trace-only spies; returns (cache keys,
    weak-typed leaf paths).  ``jax.eval_shape`` through the real jitted
    callee keeps result shapes exact without executing a kernel."""
    import repro.kernels.routed_pack_lookup as rk

    keys, weak = [], []

    def make_spy(real):
        def spy(*args, **kw):
            keys.append(jl.jit_cache_key(args, static=kw))
            weak.extend(jl.weak_leaves(args))
            shapes = jax.eval_shape(functools.partial(real, **kw), *args)
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        return spy

    saved = {n: getattr(rk, n) for n in _ROUTED_CALLEES}
    for n, real in saved.items():
        setattr(rk, n, make_spy(real))
    try:
        for call in calls:
            entry(*call)
    finally:
        for n, real in saved.items():
            setattr(rk, n, real)
    return keys, weak


def engine_stationarity_findings(batch: int = 2, cache_len: int = 32,
                                 prefill_len: int = 8) -> List[Finding]:
    """ContinuousEngine's two-executable invariant, proven on avals alone:
    abstract params (``jax.eval_shape(model.init, ...)``) + shape-only
    tracing of tick / prefill / refill-scatter — nothing runs."""
    from repro.models import ARCH_IDS, build_model, get_config
    from repro.serving.engine import (ContinuousEngine, cache_batch_axes,
                                      scatter_cache_slots)

    out: List[Finding] = []
    aid = next(a for a in ARCH_IDS if get_config(a).family == "dense")
    cfg = get_config(aid)
    period = max(1, cfg.attn.global_every)
    cfg = cfg.replace(d_model=64, vocab=128, remat=False, n_layers=2 * period,
                      n_heads=4, n_kv_heads=2, d_head=16, d_ff=128)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    eng = ContinuousEngine(model, params, batch, cache_len)
    out.append(Finding(
        "recompile_hazard", f"engine:{aid}:executables",
        set(eng._executables) == {"prefill", "decode_step"},
        f"executables={sorted(eng._executables)}"))

    cache = jax.eval_shape(lambda: model.init_cache(batch, cache_len))
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    sig = jax.tree_util.tree_map(lambda s: (s.shape, str(s.dtype)), cache)

    # tick stationarity: (nxt, logits, pos', cache') must re-feed tick
    # with byte-identical avals — one cache entry forever
    nxt, _, pos2, cache2 = jax.eval_shape(eng._tick, params, tok, pos, cache)
    sig2 = jax.tree_util.tree_map(lambda s: (s.shape, str(s.dtype)), cache2)
    stationary = ((nxt.shape, str(nxt.dtype)) == (tok.shape, str(tok.dtype))
                  and (pos2.shape, str(pos2.dtype)) == (pos.shape, str(pos.dtype))
                  and sig2 == sig)
    out.append(Finding("recompile_hazard", f"engine:{aid}:tick-stationary",
                       stationary,
                       "" if stationary else
                       f"tick output avals drift: tok {nxt.shape}/{nxt.dtype}, "
                       f"pos {pos2.shape}/{pos2.dtype}"))
    tick_avals = jl.trace(eng._tick, params, tok, pos, cache).out_avals
    weak = [str(a) for a in tick_avals if getattr(a, "weak_type", False)]
    out.append(Finding("recompile_hazard", f"engine:{aid}:tick-weak-types",
                       not weak, f"weak-typed tick outputs: {weak[:4]}"))

    # one prefill executable: refill reuses the same (B, S0) signature and
    # must return a cache with the original avals (scatter target)
    toks = jax.ShapeDtypeStruct((batch, prefill_len), jnp.int32)
    _, pcache = jax.eval_shape(model.prefill, params, {"tokens": toks}, cache)
    psig = jax.tree_util.tree_map(lambda s: (s.shape, str(s.dtype)), pcache)
    out.append(Finding("recompile_hazard", f"engine:{aid}:prefill-stationary",
                       psig == sig,
                       "" if psig == sig else "prefill cache avals drift"))

    axes = cache_batch_axes(model, cache_len)
    src = jax.eval_shape(lambda: model.init_cache(1, cache_len))
    scat = jax.eval_shape(lambda d, s: scatter_cache_slots(d, s, [0], axes),
                          cache, src)
    ssig = jax.tree_util.tree_map(lambda s: (s.shape, str(s.dtype)), scat)
    out.append(Finding("recompile_hazard", f"engine:{aid}:refill-scatter",
                       ssig == sig,
                       "" if ssig == sig else "scattered cache avals drift"))
    return out


@rule("recompile_hazard")
def rule_recompile_hazard(ctx: LintContext) -> List[Finding]:
    out: List[Finding] = []
    a, b = ctx.funcs[0], ctx.funcs[1]
    x2d = ctx.x(a).reshape(ROWS, -1)
    mixed = [a, b] * (ROWS // 2)
    variants = [
        ("routed_pack", routed_pack_lookup_pallas, ctx.pack),
        ("routed_pack.grad", routed_pack_grad_pallas, ctx.pack),
        ("routed_quant_pack", routed_quant_pack_lookup_pallas, ctx.qpack),
        ("routed_quant_pack.grad", routed_quant_pack_grad_pallas, ctx.qpack),
        ("routed_poly_pack", routed_poly_pack_lookup_pallas, ctx.ppack),
        ("routed_poly_pack.grad", routed_poly_pack_grad_pallas, ctx.ppack),
    ]
    for label, entry, packer in variants:
        pack = packer()
        keys, weak = capture_routed_keys(
            entry, [(pack, a, x2d), (pack, b, x2d), (pack, mixed, x2d)])
        ok = jl.keys_stable(keys) and len(keys) == 3
        out.append(Finding(
            "recompile_hazard", f"reroute:{label}", ok,
            "" if ok else f"{len(set(keys))} distinct jit cache keys over "
                          f"3 routings (expected 1)",
            {"n_calls": len(keys), "n_keys": len(set(keys))}))
        out.append(Finding("recompile_hazard", f"reroute:{label}:weak-types",
                           not weak, f"weak-typed operands: {weak[:4]}"))
    out.extend(engine_stationarity_findings())
    return out


# --------------------------------------------------------------------------------------
# Rule 4 — static VMEM accounting vs the planner's budgets
# --------------------------------------------------------------------------------------

def poly_lane_padding_allowance(plan) -> int:
    """The device PolyTablePack pads every member's zero/ramp/scale planes to
    the pack-wide max lane count; ``PackPlan.vmem()`` prices each member's own
    lanes.  The delta is a documented allowance, not a budget change —
    changing ``vmem()`` itself would shift the CI-gated BENCH_polypack
    numbers."""
    lmax = max(m.lanes for m in plan.members)
    return 3 * 4 * sum((lmax - m.lanes) * m.n_intervals for m in plan.members)


def routed_dispatch_allowance(plan) -> int:
    """Static kernels bake each member's interval count into the executable
    (a static arg); the routed kernel dispatches on fn_id at runtime, so it
    additionally pins the per-interval ``seg_count`` plane — one f32 lane per
    interval.  Priced here as a documented allowance on top of
    ``PackPlan.vmem()`` rather than folded into the planner (which budgets
    the static pack)."""
    return 4 * sum(m.n_intervals for m in plan.members)


def check_budget(resident: int, budget: int, subject: str,
                 allowance: int = 0) -> Finding:
    ok = 0 < resident <= budget + allowance
    return Finding(
        "vmem_budget", subject, ok,
        "" if ok else f"kernel pins {resident} B of pack operands but the "
                      f"planner budget is {budget} B (+{allowance} B allowance)",
        {"resident_bytes": resident, "budget_bytes": budget,
         "allowance_bytes": allowance})


@rule("vmem_budget")
def rule_vmem_budget(ctx: LintContext) -> List[Finding]:
    budgets = {
        "table_pack": (lambda: ctx.layout().vmem().padded_bytes, 0),
        "quant_pack": (lambda: ctx.qlayout().vmem().padded_bytes, 0),
        "poly_pack": (lambda: ctx.pplan().vmem().padded_bytes,
                      poly_lane_padding_allowance(ctx.pplan())),
        "sharded_pack": (lambda: ctx.slayout().vmem().padded_bytes, 0),
    }

    def family(mode: str) -> str:
        if "poly" in mode:
            return "poly_pack"
        if "quant" in mode:
            return "quant_pack"
        if mode.startswith("sharded"):
            return "sharded_pack"
        return "table_pack"

    out: List[Finding] = []
    for mode, name in ctx.matrix(modes=TABLE_MODES):
        if mode.endswith("_ref") or mode in ("table_ref", "table_pallas"):
            continue
        budget_fn, allowance = budgets[family(mode)]
        budget = budget_fn()
        if mode.startswith("routed") and family(mode) == "poly_pack":
            allowance += routed_dispatch_allowance(ctx.pplan())
        for kind in ("value", "grad"):
            eqns = jl.pallas_eqns(ctx.traced(mode, name, kind))
            if not eqns:
                out.append(Finding("vmem_budget", f"{mode}/{name}/{kind}",
                                   False, "no pallas_call in a pallas mode"))
                continue
            for i, eqn in enumerate(eqns):
                # sharded modes launch one kernel per shard; each launch must
                # fit the PER-SHARD budget independently
                suffix = f"[{i}]" if len(eqns) > 1 else ""
                out.append(check_budget(
                    jl.pack_resident_bytes(eqn), budget,
                    f"{mode}/{name}/{kind}{suffix}", allowance))
    # TableFlash pins the same full-pack planes as _pack_kernel, so it is
    # priced against the same PackLayout budget
    if "exp_neg" in ctx.pack_names:
        budget = ctx.layout().vmem().padded_bytes
        for kind in ("value", "grad"):
            eqns = jl.pallas_eqns(ctx.attn_traced(kind))
            if not eqns:
                out.append(Finding("vmem_budget", f"attn_exp/{kind}", False,
                                   "no pallas_call in the attn_exp closure"))
                continue
            for eqn in eqns:
                out.append(check_budget(jl.pack_resident_bytes(eqn), budget,
                                        f"attn_exp/{kind}"))
    return out


# --------------------------------------------------------------------------------------
# Rule 5 — obs-off structural identity
# --------------------------------------------------------------------------------------

def obs_identity_fingerprints(build: Callable[[], Callable], x) -> Tuple[str, str]:
    """(obs-never, obs-enabled-telemetry-off) fingerprints of one closure
    builder; process obs state is restored afterwards."""
    from repro.obs import config as obs_config

    old = obs_config.get_config()
    try:
        obs.disable()
        fp_never = jl.fingerprint(build(), x)
        obs.configure(enabled=True, device_telemetry=False)
        fp_disabled = jl.fingerprint(build(), x)
    finally:
        obs.configure(enabled=old.enabled,
                      device_telemetry=old.device_telemetry,
                      trace_path=old.trace_path)
    return fp_never, fp_disabled


@rule("obs_off_identity")
def rule_obs_off_identity(ctx: LintContext) -> List[Finding]:
    out: List[Finding] = []
    foldable = [n for n in ctx.funcs if n in FOLDABLE]
    for mode in ALL_MODES:
        # pick a member the mode can serve: folded modes exercise the fold
        # path only on foldable names
        name = (foldable[0] if mode in FOLDED_MODES and foldable
                else ("tanh" if "tanh" in ctx.funcs else ctx.funcs[0]))
        cfg_kw = dict(mode=mode, e_a=ctx.e_a, pack_functions=ctx.pack_names,
                      pack_shards=ctx.n_shards)
        fp_never, fp_disabled = obs_identity_fingerprints(
            lambda: ApproxConfig(**cfg_kw).unary(name), ctx.x(name))
        ok = fp_never == fp_disabled
        out.append(Finding(
            "obs_off_identity", f"unary:{mode}/{name}", ok,
            "" if ok else "obs-on(disabled) closure is structurally different "
                          "from the obs-never closure (zero-overhead contract)"))
    # the routed dispatch API has its own instrumentation wrapper
    fns = [ctx.funcs[0], ctx.funcs[1]] * (ROWS // 2)
    xr = ctx.x(ctx.funcs[0]).reshape(ROWS, -1)
    fp_never, fp_disabled = obs_identity_fingerprints(
        lambda: ApproxConfig(mode="routed_pack", e_a=ctx.e_a,
                             pack_functions=ctx.pack_names).routed_fn(fns), xr)
    out.append(Finding("obs_off_identity", "routed_fn:routed_pack",
                       fp_never == fp_disabled,
                       "" if fp_never == fp_disabled else
                       "routed_fn obs-off closure differs structurally"))
    # TableFlash's attn_exp has its own telemetry wrapper (approx.oob counter
    # + count_mask protocol) — with telemetry off it must vanish structurally
    if "exp_neg" in ctx.pack_names:
        fp_never, fp_disabled = obs_identity_fingerprints(
            lambda: ApproxConfig(mode="table_pack", e_a=ctx.e_a,
                                 pack_functions=ctx.pack_names,
                                 attn_table=True).attn_exp(), ctx.attn_x())
        out.append(Finding("obs_off_identity", "attn_exp:table_pack",
                           fp_never == fp_disabled,
                           "" if fp_never == fp_disabled else
                           "attn_exp obs-off closure differs structurally"))
    return out
