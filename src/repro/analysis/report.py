"""PackLint findings and report serialization (``REPORT_contracts.json``)."""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class Finding:
    """One checked subject under one rule.

    ``ok=True`` findings are kept in the report on purpose: the JSON artifact
    is the auditable record that a subject was *checked*, not just that
    nothing failed — a rule that silently skips a mode looks identical to a
    passing rule otherwise.
    """

    rule: str
    subject: str
    ok: bool
    detail: str = ""
    data: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d = {"rule": self.rule, "subject": self.subject, "ok": self.ok}
        if self.detail:
            d["detail"] = self.detail
        if self.data:
            d["data"] = self.data
        return d


@dataclass
class Report:
    """All findings of one PackLint run, plus run metadata."""

    findings: List[Finding] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)

    def extend(self, findings: List[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def ok(self) -> bool:
        return all(f.ok for f in self.findings)

    def failures(self) -> List[Finding]:
        return [f for f in self.findings if not f.ok]

    def by_rule(self) -> Dict[str, List[Finding]]:
        out: Dict[str, List[Finding]] = {}
        for f in self.findings:
            out.setdefault(f.rule, []).append(f)
        return out

    def to_dict(self) -> Dict[str, Any]:
        rules = {}
        for rule, fs in self.by_rule().items():
            rules[rule] = {
                "checked": len(fs),
                "failed": sum(not f.ok for f in fs),
                "findings": [f.to_dict() for f in fs],
            }
        return {
            "schema": "packlint-report-v1",
            "meta": self.meta,
            "ok": self.ok,
            "checked": len(self.findings),
            "failed": len(self.failures()),
            "rules": rules,
        }

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> str:
        text = json.dumps(self.to_dict(), indent=indent, sort_keys=True)
        if path:
            with open(path, "w") as fh:
                fh.write(text + "\n")
        return text

    def summary(self) -> str:
        lines = []
        for rule, fs in sorted(self.by_rule().items()):
            bad = [f for f in fs if not f.ok]
            mark = "FAIL" if bad else "ok"
            lines.append(f"  {rule:<24} {len(fs):>4} checked  "
                         f"{len(bad):>3} failed  [{mark}]")
            for f in bad[:20]:
                lines.append(f"    ! {f.subject}: {f.detail}")
            if len(bad) > 20:
                lines.append(f"    ... and {len(bad) - 20} more")
        head = ("PackLint: PASS" if self.ok
                else f"PackLint: FAIL ({len(self.failures())} violations)")
        return "\n".join([head] + lines)
