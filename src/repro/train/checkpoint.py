"""Fault-tolerant checkpointing: sharded-friendly, atomic, async, keep-K, and
mesh-elastic on restore.

Layout per step:  <dir>/step_<N>/manifest.json + one .npy per leaf.
  * Atomic publish: everything is written into ``step_<N>.tmp`` then os.replace'd,
    so a crash mid-write never corrupts the latest checkpoint.
  * Async: ``save_async`` snapshots to host memory on the caller thread (cheap)
    and does file IO on a worker thread; ``wait()`` joins before the next save.
  * Elastic restore: leaves are stored as FULL arrays + the target sharding is
    applied on load (device_put), so a checkpoint taken on one mesh restores onto
    any other mesh shape.
  * Multi-host: only process 0 writes (jax.process_index() guard); all hosts
    restore.  (This container is single-process; the guard is the real-cluster
    path.)
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return _SAFE.sub("_", ".".join(parts)) or "leaf"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------- save ----------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        if jax.process_index() != 0:
            return
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        if jax.process_index() != 0:
            return
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: dict) -> None:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = jax.tree_util.tree_flatten_with_path(host_tree)[0]
        manifest = {"step": step, "extra": extra, "leaves": {}}
        used = set()
        for path, leaf in leaves:
            name = _leaf_name(path)
            while name in used:
                name += "_"
            used.add(name)
            np.save(os.path.join(tmp, name + ".npy"), leaf)
            manifest["leaves"][json.dumps([_leaf_name([k]) for k in path])] = {
                "file": name + ".npy",
                "shape": list(np.shape(leaf)),
                "dtype": str(np.asarray(leaf).dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # ------------------------------ restore --------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, abstract_tree: Any,
                shardings: Any = None) -> Any:
        """Rebuild ``abstract_tree``'s structure from disk; apply ``shardings``
        (same-structure tree of jax.sharding.Sharding) if given — this is the
        elastic-resharding path."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_tree)
        shard_flat = (treedef.flatten_up_to(shardings) if shardings is not None
                      else [None] * len(flat))
        out = []
        for (path, leaf), sh in zip(flat, shard_flat):
            key = json.dumps([_leaf_name([k]) for k in path])
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(d, meta["file"]))
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return treedef.unflatten(out)

    def restore_latest(self, abstract_tree: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, abstract_tree, shardings)
