"""Training loop: pjit'd train step, grad accumulation, checkpoint/restart,
preemption handling, straggler monitoring.

Fault-tolerance contract (DESIGN.md §6):
  * checkpoints every ``ckpt_every`` steps (async, atomic, keep-K);
  * SIGTERM/SIGINT => emergency checkpoint at the next step boundary, clean exit;
  * restart: ``run()`` restores the latest checkpoint and resumes the exact data
    stream (the pipeline is counter-addressed by step — no state to replay);
  * unexpected exception => emergency checkpoint attempt, then re-raise;
  * straggler monitor: per-step wall times, warn on > straggler_factor x median
    (on a real cluster this feeds the scheduler; here it logs).
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.parallel.params import param_pspecs, shardings_from_specs, zero1_pspecs
from repro.parallel.sharding import use_sharding

from .checkpoint import CheckpointManager


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    accum: int = 1  # gradient-accumulation microbatches
    zero1: bool = True  # shard optimizer moments over the data axis too
    log_every: int = 10
    straggler_factor: float = 1.5
    opt: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)


class StragglerMonitor:
    def __init__(self, factor: float = 1.5, window: int = 50):
        self.factor = factor
        self.times: list[float] = []
        self.window = window
        self.flagged = 0

    def record(self, dt: float) -> Optional[str]:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) >= 10:
            med = float(np.median(self.times))
            if dt > self.factor * med:
                self.flagged += 1
                return (f"straggler step: {dt * 1e3:.1f}ms vs median "
                        f"{med * 1e3:.1f}ms (x{dt / med:.2f})")
        return None


def make_train_step(model, opt_cfg: adamw.AdamWConfig, accum: int = 1,
                    work_shardings=None, master_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics); state = dict.

    Weight-update sharding (WUS, ``work_shardings`` + ``master_shardings``):
    ``state['params']`` is the f32 master in the fully-2D layout; the step casts
    it ONCE to a bf16 TP-layout work copy (one all-gather over the data axis,
    outside every scan), runs fwd/bwd per microbatch against the work copy, and
    reshards each microbatch's bf16 work-layout grads straight into the f32
    master layout for accumulation — so the carried grad buffer is the SMALL
    (fully-sharded) one, and per-micro residuals die with their micro iteration
    (grad-inside-loop, not loss-inside-loop: the latter keeps every micro's
    remat carries live until the combined backward — measured +112 GB on
    yi-34b).  This is what lets >30B models keep f32 AdamW on 16 GB chips."""

    def _work(params):
        if work_shardings is None:
            return params
        return jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(
                p.astype(jnp.bfloat16), s),
            params, work_shardings)

    def _to_master(grads):
        """Work-layout grads -> f32 master layout.  Reshard FIRST (bf16 on the
        wire and in the transient), cast f32 only on the small master shard."""
        if master_shardings is None:
            return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s).astype(
                jnp.float32),
            grads, master_shardings)

    def train_step(state, batch):
        params = state["params"]
        pw = _work(params)
        loss_of = lambda w, mb: model.loss(w, mb)
        if accum == 1:
            loss, gw = jax.value_and_grad(loss_of)(pw, batch)
            grads = _to_master(gw)
        else:
            # Each microbatch is scaled by 1/accum BEFORE accumulation so the
            # carried loss/grad magnitudes match the accum=1 path step for step
            # (sum-then-divide overflows bf16 carries at large accum and drifts
            # from the accum=1 trajectory).  The loop is unrolled rather than a
            # lax.scan: scan always compiles its body, so an eager accum=1 step
            # and a scanned accum=N step go through different XLA rewrites and
            # their bf16 backward passes diverge beyond fp-noise (seen as 2*lr
            # sign-flip deltas after one AdamW step); unrolled, both paths share
            # the same per-microbatch subgraphs.  accum is small (<= ~8), so the
            # unrolled trace stays cheap, and the sequential data dependence
            # through the accumulator keeps per-micro residuals short-lived.
            micro_batches = jax.tree.map(
                lambda t: t.reshape(accum, t.shape[0] // accum, *t.shape[1:]),
                batch)
            inv = 1.0 / accum
            loss = jnp.zeros((), jnp.float32)
            grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            for a in range(accum):
                mb = jax.tree.map(lambda t: t[a], micro_batches)
                l, gw = jax.value_and_grad(loss_of)(pw, mb)
                gm = _to_master(gw)
                loss = loss + l * inv
                grads = jax.tree.map(lambda acc, g: acc + g * inv, grads, gm)
        new_params, new_opt, metrics = adamw.update(opt_cfg, params, grads,
                                                    state["opt"])
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def state_pspecs(model, mesh, zero1: bool = True, wus: bool = True):
    """Partition specs for the full train state (params + AdamW moments).

    ``wus=True`` (weight-update sharding): the stored params are the f32 master
    in the fully-2D (model x data) layout — same as the moments; the TP work
    layout exists only transiently inside the step."""
    abstract = model.abstract_params()
    pspec = param_pspecs(abstract, mesh)
    mspec = zero1_pspecs(abstract, mesh) if zero1 else pspec
    from jax.sharding import PartitionSpec as P

    return {"params": mspec if wus else pspec,
            "opt": {"m": mspec, "v": mspec, "count": P()},
            "step": P()}


def work_pspecs(model, mesh):
    """The TP work layout used inside the step (see make_train_step WUS)."""
    return param_pspecs(model.abstract_params(), mesh)


def run(model, shape, cfg: TrainConfig, mesh=None,
        log: Callable[[str], None] = print) -> Dict[str, Any]:
    """End-to-end training with restart. Returns final metrics summary."""
    from repro.data.pipeline import data_config_for

    data = SyntheticLM(data_config_for(model.cfg, shape))
    ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep)
    if mesh is not None:
        # sharded-mode approx packs: make sure each 'model' core holds its
        # values slice before the step jits (idempotent when build_model
        # already placed them under this mesh)
        approx = getattr(model.cfg, "approx", None)
        if approx is not None:
            approx.place_packs(mesh)
        wspecs = shardings_from_specs(mesh, work_pspecs(model, mesh))
        mspecs_tree = shardings_from_specs(
            mesh, zero1_pspecs(model.abstract_params(), mesh))
        train_step = make_train_step(model, cfg.opt, cfg.accum,
                                     work_shardings=wspecs,
                                     master_shardings=mspecs_tree)
    else:
        train_step = make_train_step(model, cfg.opt, cfg.accum)

    # --- build / restore state ----------------------------------------------------
    def init_state():
        params = model.init(jax.random.key(0))
        return {"params": params, "opt": adamw.init(params),
                "step": jnp.zeros((), jnp.int32)}

    stop = {"flag": False, "reason": ""}

    def _handler(signum, frame):
        stop["flag"] = True
        stop["reason"] = f"signal {signum}"

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _handler)
        except ValueError:  # non-main thread (tests)
            pass

    if mesh is not None:
        specs = state_pspecs(model, mesh, cfg.zero1)
        shardings = shardings_from_specs(mesh, specs)
        abstract = jax.eval_shape(init_state)
        step0, state = ckpt.restore_latest(abstract, shardings)
        if state is None:
            with use_sharding(mesh):
                state = jax.jit(init_state, out_shardings=shardings)()
            step0 = 0
            log("initialized fresh state")
        else:
            log(f"restored checkpoint at step {step0}")
        with use_sharding(mesh):
            jit_step = jax.jit(train_step,
                               in_shardings=(shardings, None),
                               out_shardings=(shardings, None),
                               donate_argnums=(0,))
    else:
        abstract = jax.eval_shape(init_state)
        step0, state = ckpt.restore_latest(abstract)
        if state is None:
            state = init_state()
            step0 = 0
            log("initialized fresh state")
        else:
            log(f"restored checkpoint at step {step0}")
        jit_step = jax.jit(train_step, donate_argnums=(0,))

    def _cache_size(fn) -> int:
        try:
            return fn._cache_size()
        except Exception:
            return -1

    monitor = StragglerMonitor(cfg.straggler_factor)
    losses = []
    step = int(step0 or 0)
    compile_time_s = 0.0
    rec = obs.enabled()
    tracer = obs.get_tracer() if rec else None
    step_hist = obs.get_registry().histogram("train.step_s") if rec else None
    try:
        while step < cfg.steps and not stop["flag"]:
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            if rec:
                tracer.begin("train.step", "train", step=step)
                jit_before = _cache_size(jit_step)
            t0 = time.perf_counter()
            ctx = use_sharding(mesh) if mesh is not None else _nullcontext()
            with ctx:
                state, metrics = jit_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if rec:
                end_args = {}
                if _cache_size(jit_step) > jit_before:
                    end_args["compiled"] = True
                    compile_time_s += dt
                    tracer.instant("jit.compile", "jit", phase="train.step")
                tracer.end("train.step", "train", **end_args)
                step_hist.observe(dt)
            warn = monitor.record(dt)
            if warn:
                log(f"[straggler] {warn}")
            step += 1
            losses.append(float(metrics["loss"]))
            if step % cfg.log_every == 0:
                log(f"step {step}: loss={losses[-1]:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"lr={float(metrics['lr']):.2e} ({dt * 1e3:.0f}ms)")
            if step % cfg.ckpt_every == 0:
                with obs.span("train.ckpt", "train", step=step):
                    ckpt.save_async(step, state, extra={"loss": losses[-1]})
    except BaseException:
        log("exception — attempting emergency checkpoint")
        ckpt.wait()
        ckpt.save(step, state, extra={"emergency": True})
        raise
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)

    ckpt.wait()
    with obs.span("train.ckpt", "train", step=step, final=True):
        ckpt.save(step, state, extra={"final": True, "reason": stop["reason"]})
    return {"final_step": step, "losses": losses,
            "preempted": stop["flag"], "stragglers": monitor.flagged,
            "compile_time_s": compile_time_s}


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
