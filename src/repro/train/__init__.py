"""repro.train — loop, checkpointing, fault tolerance."""
from .checkpoint import CheckpointManager
from .loop import StragglerMonitor, TrainConfig, make_train_step, run, state_pspecs
