"""repro.parallel — mesh, logical sharding rules, parameter partition specs."""

from .sharding import (
    current_mesh,
    default_rules,
    logical_to_spec,
    shard_activation,
    use_sharding,
)

__all__ = [
    "current_mesh",
    "default_rules",
    "logical_to_spec",
    "shard_activation",
    "use_sharding",
]
