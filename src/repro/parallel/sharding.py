"""Logical-axis sharding: models annotate activations with *logical* axis names;
the launcher binds them to physical mesh axes.  Without an active binding the
annotations are no-ops, so smoke tests run un-meshed.

    with use_sharding(mesh, LOGICAL_RULES):
        loss = jax.jit(train_step, ...)(...)

Rules map logical names -> mesh axis (or tuple of axes, or None).  The defaults
implement the DESIGN.md §6 layout: batch over ('pod','data'), feature/expert/vocab
/head dims over 'model', sequence unsharded.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def default_rules(mesh: Mesh) -> Dict[str, Any]:
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes) or None
    model = "model" if "model" in axes else None
    # experts also shard over the pod axis on multi-pod meshes (EP=32): halves
    # the per-chip expert work copy — what makes qwen3-235B fit 2 pods
    expert = (("pod", "model") if ("pod" in axes and model) else model)
    return {
        "batch": batch,
        "model": model,
        "expert": expert,
        "vocab": model,
        "heads": model,
        "ff": model,
    }


@contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[Dict[str, Any]] = None):
    prev = getattr(_ctx, "binding", None)
    _ctx.binding = (mesh, rules or (default_rules(mesh) if mesh else {}))
    try:
        yield
    finally:
        _ctx.binding = prev


def current_mesh() -> Optional[Mesh]:
    b = getattr(_ctx, "binding", None)
    return b[0] if b else None


def logical_to_spec(*logical) -> P:
    b = getattr(_ctx, "binding", None)
    rules = b[1] if b else {}
    return P(*(rules.get(l) if l is not None else None for l in logical))


def shard_activation(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint under the active binding; identity otherwise."""
    b = getattr(_ctx, "binding", None)
    if not b or b[0] is None:
        return x
    mesh, rules = b
    spec = P(*(rules.get(l) if l is not None else None for l in logical))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------- ShardedTablePack operand rules -------------------------------
#
# The sharded pack stacks one values slice (and one local_base/owned plane
# pair) per shard on a leading axis that lays over the mesh 'model' axis;
# the selector metadata is replicated.  These specs are what makes the VMEM
# story real: device_put with them and each core holds ONE slice, not S.


def sharded_pack_pspecs(mesh: Mesh):
    """PartitionSpecs for every :class:`repro.approx.ShardedTablePack` leaf.

    The leading (shard) axis of ``local_base`` / ``owned`` / ``values`` maps
    to 'model'; ``boundaries`` / ``inv_delta`` / ``seg_count`` replicate.
    Returns a dict keyed by field name (static fields carry no spec).
    """
    model = "model" if "model" in mesh.axis_names else None
    return {
        "boundaries": P(None, None),
        "inv_delta": P(None, None),
        "seg_count": P(None, None),
        "local_base": P(model, None, None),
        "owned": P(model, None, None),
        "values": P(model, None),
    }


def place_sharded_pack(pack, mesh: Mesh):
    """device_put a ShardedTablePack so each 'model' shard holds one slice.

    Requires ``mesh.shape['model'] == pack.n_shards``.  The returned pack is
    what the shard_map lookup path (``eval_sharded_mesh``) consumes without
    any resharding transfer.
    """
    if "model" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'model' axis")
    if mesh.shape["model"] != pack.n_shards:
        raise ValueError(
            f"mesh 'model' axis is {mesh.shape['model']} wide but the pack "
            f"has {pack.n_shards} shards")
    specs = sharded_pack_pspecs(mesh)
    kw = {
        name: (jax.device_put(getattr(pack, name),
                              NamedSharding(mesh, specs[name]))
               if name in specs else getattr(pack, name))
        for name in pack._fields
    }
    return type(pack)(**kw)
