"""Partition specs for serving caches (KV buffers, SSM/xLSTM states).

Name-based rules over the cache pytree, divisibility-aware like params.py.
Trailing-dim templates; extra leading dims (layer stacks / groups) replicate.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional

import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from .sharding import default_rules

_RULES = (
    # attention KV buffers: (..., B, W, G, D)
    (r"(^|/)(k|v|loc_k|loc_v|glob_k|glob_v|attn_k|attn_v)$",
     ("batch", None, "heads", None)),
    (r"(^|/)memory$", ("batch", None, None)),
    # per-slot position/validity buffers: (B, W) int32, batch-sharded with k/v
    (r"pos$", ("batch", None)),
    # mamba2 state: (..., B, H, P, N); conv carries: (..., B, K-1, C)
    (r"(^|/)state$", ("batch", "ff", None, None)),
    (r"(^|/)conv_x$", ("batch", None, "ff")),
    (r"(^|/)conv_[bc]$", ("batch", None, None)),
    # mLSTM: c (..., B, H, D, D); n (..., B, H, D); m (..., B, H)
    (r"(^|/)m/c$", ("batch", None, None, "model")),
    (r"(^|/)m/n$", ("batch", None, "model")),
    (r"(^|/)m/m$", ("batch", None)),
    # sLSTM: (..., B, d)
    (r"(^|/)s/[hcnm]$", ("batch", "model")),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def cache_pspecs(abstract_cache, mesh: Mesh,
                 rules: Optional[Dict[str, Any]] = None):
    rules = rules or default_rules(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def assign(path, leaf):
        ps = _path_str(path)
        for pat, template in _RULES:
            if re.search(pat, ps):
                n_extra = len(leaf.shape) - len(template)
                if n_extra < 0:
                    continue
                spec = [None] * n_extra
                for dim, logical in zip(leaf.shape[n_extra:], template):
                    ax = rules.get(logical) if logical else None
                    if ax is not None:
                        size = int(np.prod(
                            [sizes[a] for a in
                             (ax if isinstance(ax, tuple) else (ax,))]))
                        if dim % size != 0:
                            ax = None
                    spec.append(ax)
                return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(assign, abstract_cache)
