"""Parameter / optimizer-state partition specs.

Specs are inferred from leaf *paths* (regex rules over the pytree path) with the
logical->physical binding of ``sharding.default_rules``.  A dim is only sharded if
its size is at least the axis size (GSPMD pads uneven dims, which we accept — the
padding waste shows up honestly in the roofline's useful-FLOPs ratio).

Stacked-layer leaves carry extra leading dims (L,) or (groups, per_group); rules
match the TRAILING dims and the prefix is replicated.

ZeRO-1 (`zero1=True`): optimizer moments additionally shard their first
still-unsharded, large-enough dim over the data axis, so AdamW state is spread
over the whole mesh instead of only the model axis.  XLA inserts the ZeRO
gather/scatter around the (elementwise) update.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import default_rules

# (path regex, trailing-dims logical template OR list of fallback templates —
# first template whose sharded dims all divide evenly wins)
_RULES: Tuple[Tuple[str, Any], ...] = (
    # vocab-sharded embeddings; odd vocabs (whisper 51865, internvl 151655) fall
    # back to sharding d_model
    (r"(embed|unembed)/table$", [("vocab", None), (None, "model")]),
    (r"vis_proj/w$", (None, None)),
    # attention
    (r"(attn|self|cross)/wq/w$", (None, "heads", None)),
    (r"(attn|self|cross)/wk/w$", (None, "heads", None)),
    (r"(attn|self|cross)/wv/w$", (None, "heads", None)),
    (r"(attn|self|cross)/wo/w$", ("heads", None, None)),
    (r"(attn|self|cross)/[qk]n/g$", (None,)),
    # dense FFN (GLU or plain)
    (r"(mlp|shared)/w[iu]/w$", (None, "ff")),
    (r"(mlp|shared)/wd/w$", ("ff", None)),
    # MoE
    (r"experts/w[iu]$", ("expert", None, None)),
    (r"experts/wd$", ("expert", None, None)),
    (r"router/w$", (None, None)),
    # Mamba2
    (r"m/in_[zx]/w$", (None, "ff")),
    (r"m/in_[bc]/w$", (None, None)),  # state projections are tiny: replicate
    (r"m/in_dt/w$", (None, "ff")),
    (r"m/conv_x/w$", (None, "ff")),
    (r"m/conv_[bc]/w$", (None, None)),
    (r"m/(dt_bias|a_log|d_skip)$", ("ff",)),
    (r"m/norm/g$", ("ff",)),
    (r"m/out/w$", ("ff", None)),
    # xLSTM
    (r"b/w[qkv]/w$", (None, "model")),
    (r"b/wog/w$", (None, "model")),
    (r"b/w[if]/w$", (None, None)),
    (r"b/wo/w$", ("model", None)),
    (r"b/wd/w$", ("model", None)),
    (r"b/[rw][zifo]/w$", (None, "model")),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def _axis_size(ax, axis_sizes) -> int:
    return int(np.prod([axis_sizes[a] for a in
                        (ax if isinstance(ax, tuple) else (ax,))]))


def _try_template(template, shape, rules, axis_sizes):
    """Returns (spec, clean): clean=True iff every templated axis divided evenly."""
    n_extra = len(shape) - len(template)
    if n_extra < 0:
        return None, False
    spec = [None] * n_extra
    clean = True
    for dim, logical in zip(shape[n_extra:], template):
        ax = rules.get(logical) if logical else None
        if ax is not None and dim % _axis_size(ax, axis_sizes) != 0:
            ax = None
            clean = False
        spec.append(ax)
    return P(*spec), clean


def _spec_for(path_s: str, shape, rules: Dict[str, Any], axis_sizes) -> P:
    for pat, templates in _RULES:
        if re.search(pat, path_s):
            if isinstance(templates, tuple):
                templates = [templates]
            first = None
            for template in templates:
                spec, clean = _try_template(template, shape, rules, axis_sizes)
                if spec is None:
                    continue
                if first is None:
                    first = spec
                if clean:
                    return spec
            return first if first is not None else P()
    return P()  # replicate


# Optional FSDP-at-use: leaves whose per-device footprint (after model sharding)
# exceeds the threshold get a second dim sharded over the data axis and are
# gathered at use.  DISABLED by default (0): with scanned layer stacks XLA hoists
# the per-layer gathers out of the loop, materializing ALL layers at once
# (measured 171 GB temp on yi-34b).  Large models instead use weight-update
# sharding (train.loop WUS): master params fully 2D-sharded, ONE cast+gather to
# the TP work layout per step, outside the scan.
FSDP_THRESHOLD_BYTES = 0


def param_pspecs(abstract_params, mesh: Mesh,
                 rules: Optional[Dict[str, Any]] = None,
                 fsdp_threshold: int = FSDP_THRESHOLD_BYTES):
    """PartitionSpec tree matching ``abstract_params`` (from jax.eval_shape).

    Primary axis assignment is rule-based (TP); any leaf still larger than
    ``fsdp_threshold`` per device additionally shards its largest free dim over
    the data axis (weight-gathered at use; XLA inserts the all-gathers)."""
    rules = rules or default_rules(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = rules.get("batch")
    dsize = (_axis_size(data_axes, sizes) if data_axes is not None else 1)

    def assign(path, leaf):
        spec = _spec_for(_path_str(path), leaf.shape, rules, sizes)
        if data_axes is None or fsdp_threshold <= 0:
            return spec
        spec_t = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
        shards = int(np.prod([_axis_size(s, sizes) for s in spec_t
                              if s is not None] or [1]))
        dtype_bytes = getattr(leaf.dtype, "itemsize", 4)
        per_dev = int(np.prod(leaf.shape)) * dtype_bytes / shards
        if per_dev <= fsdp_threshold:
            return spec
        # shard the largest free, divisible dim over the data axis
        free = [(leaf.shape[i], i) for i in range(leaf.ndim)
                if spec_t[i] is None and leaf.shape[i] % dsize == 0]
        if not free:
            return spec
        _, dim = max(free)
        out = list(spec_t)
        out[dim] = data_axes
        return P(*out)

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


def fsdp_pspecs(abstract_params, mesh: Mesh):
    """Pure-FSDP (ZeRO-3) specs: every leaf's largest divisible dim shards over
    the FLAT device mesh (all axes); no tensor parallelism.  Used by the 'fsdp'
    perf variant (DESIGN.md §6, EXPERIMENTS.md §Perf)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    all_axes = tuple(mesh.axis_names)
    total = int(np.prod(mesh.devices.shape))

    def assign(path, leaf):
        dims = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for i in dims:
            if leaf.shape[i] % total == 0:
                spec = [None] * leaf.ndim
                spec[i] = all_axes
                return P(*spec)
        for ax in all_axes:  # fall back to a single-axis shard
            for i in dims:
                if leaf.shape[i] % sizes[ax] == 0:
                    spec = [None] * leaf.ndim
                    spec[i] = ax
                    return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


def zero1_pspecs(abstract_params, mesh: Mesh,
                 rules: Optional[Dict[str, Any]] = None):
    """Optimizer-moment specs: param spec + first free dim sharded over data."""
    rules = rules or default_rules(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = rules.get("batch")
    base = param_pspecs(abstract_params, mesh, rules)
    if data_axes is None:
        return base
    dsize = int(np.prod([sizes[a] for a in
                         (data_axes if isinstance(data_axes, tuple) else (data_axes,))]))

    def extend(leaf, spec):
        spec_t = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        flat = [a for s in spec_t if s is not None
                for a in (s if isinstance(s, tuple) else (s,))]
        if any(a in flat for a in
               (data_axes if isinstance(data_axes, tuple) else (data_axes,))):
            return P(*spec_t)  # FSDP'd leaf: data axis already in use
        out = list(spec_t)
        for i, (dim, s) in enumerate(zip(leaf.shape, spec_t)):
            if s is None and dim % dsize == 0 and dim >= dsize:
                out[i] = data_axes
                break
        return P(*out)

    return jax.tree.map(extend, abstract_params, base)


def shardings_from_specs(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
