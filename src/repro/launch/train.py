"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --reduced \\
      --steps 200 --batch 8 --seq 128

Full-size runs use the production mesh (requires real TPU devices); --reduced
shrinks the config for CPU-scale end-to-end runs (the quickstart path).  On a
real multi-host cluster this same entry point runs per host after
``jax.distributed.initialize()`` (env-driven; no code changes).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import obs
from repro.approx import TABLE_MODES
from repro.models import ShapeSpec, build_model, get_config
from repro.optim import adamw
from repro.train.loop import TrainConfig, run


def reduced_config(cfg):
    from tests.test_archs import reduced  # single source of truth for shrink rules

    return reduced(cfg.name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving shrink for CPU-scale runs")
    ap.add_argument("--mesh", choices=["none", "debug", "prod", "multipod"],
                    default="none")
    ap.add_argument("--approx-mode",
                    choices=["exact", *TABLE_MODES],
                    default=None,
                    help="nonlinearity backend; table_pack = one fused "
                         "multi-function pack + kernel for the whole network, "
                         "quant_pack = the same pack with int8/int16 entries "
                         "dequantized on read, poly_pack = the Pareto-planned "
                         "pack (degree-1..3 Horner cells, mixed widths; see "
                         "--pack-budget), routed_* = the same packs with "
                         "dynamic per-row fn_id dispatch (one executable for "
                         "every member), sharded_pack = the pack's values "
                         "split over the mesh 'model' axis (per-shard base "
                         "rebasing, psum combine)")
    ap.add_argument("--approx-ea", type=float, default=None,
                    help="override the config's error budget E_a")
    ap.add_argument("--pack-shards", type=int, default=None,
                    help="sharded_pack modes: split the pack values this many "
                         "ways (distributes when a mesh binds a matching "
                         "'model' axis; otherwise a stacked-shard sum)")
    ap.add_argument("--pack-budget", type=int, default=None,
                    help="poly_pack modes: total-bytes budget for the design-"
                         "space planner (greedy member downgrade until the "
                         "pack fits; default keeps each function's Pareto-"
                         "cheapest candidate)")
    ap.add_argument("--rope-table", action="store_true",
                    help="serve rotary embeddings from the pack's folded trig"
                         " members (any table mode; docs/range_reduction.md)")
    ap.add_argument("--attn-table", action="store_true",
                    help="TableFlash: serve flash attention's softmax exponent"
                         " from the pack's exp_neg member (any table mode; "
                         "docs/table_flash.md)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the run (train.step / "
                         "train.ckpt / design-phase spans; open in Perfetto, "
                         "validate with tools/check_trace.py)")
    ap.add_argument("--obs", action="store_true",
                    help="enable device-side approximation telemetry and "
                         "print the metric summary")
    args = ap.parse_args()

    obs.configure(enabled=True, device_telemetry=args.obs,
                  trace_path=args.trace)
    obs.reset_tracer()
    obs.reset_registry()

    cfg = get_config(args.arch)
    if args.reduced:
        import sys, os

        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))
        cfg = reduced_config(cfg)
    if (args.approx_mode is not None or args.approx_ea is not None
            or args.pack_shards is not None or args.pack_budget is not None
            or args.rope_table or args.attn_table):
        import dataclasses

        # override only what was passed; keep the config's other approx params
        kw = {}
        if args.approx_mode is not None:
            kw["mode"] = args.approx_mode
        if args.approx_ea is not None:
            kw["e_a"] = args.approx_ea
        if args.pack_shards is not None:
            kw["pack_shards"] = args.pack_shards
        if args.pack_budget is not None:
            kw["pack_budget"] = args.pack_budget
        if args.rope_table:
            kw["rope_table"] = True
        if args.attn_table:
            kw["attn_table"] = True
        cfg = cfg.replace(approx=dataclasses.replace(cfg.approx, **kw))

    mesh = None
    if args.mesh == "debug":
        from repro.launch.mesh import make_debug_mesh

        n = len(jax.devices())
        mesh = make_debug_mesh(max(1, n // 2), min(2, n))
    elif args.mesh in ("prod", "multipod"):
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))

    # mesh before model: build_model pre-places sharded approx packs over it,
    # so the activation closures capture per-core slices (no step-0 reshard)
    model = build_model(cfg, mesh=mesh)

    shape = ShapeSpec("cli", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    tc = TrainConfig(
        steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        accum=args.accum,
        opt=adamw.AdamWConfig(lr=args.lr, warmup_steps=max(1, args.steps // 20),
                              total_steps=args.steps),
    )
    t0 = time.perf_counter()
    out = run(model, shape, tc, mesh=mesh)
    wall = time.perf_counter() - t0
    steps_done = len(out["losses"])
    steady = max(wall - out["compile_time_s"], 1e-9)
    print(f"done: step={out['final_step']} "
          f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"stragglers={out['stragglers']} preempted={out['preempted']}; "
          f"{steps_done / wall:.2f} step/s wall, {steps_done / steady:.2f} "
          f"step/s steady after {out['compile_time_s']:.2f}s compile")
    if args.obs:
        import json

        print(json.dumps(obs.get_registry().summary(), indent=1,
                         default=str))
    if args.trace:
        obs.get_tracer().save(args.trace, metadata={
            "summary": {"steps": steps_done, "wall_s": wall,
                        "compile_time_s": out["compile_time_s"]},
            "metrics": obs.get_registry().summary()})
        print(f"trace written to {args.trace}")


if __name__ == "__main__":
    main()
