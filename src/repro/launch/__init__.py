"""repro.launch subpackage."""
