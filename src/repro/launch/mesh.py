"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax import and
then calls it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 single pod (16x16 data x model) or 2 pods (2 x 16 x 16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU smoke runs (requires enough host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_sharded_pack_mesh(n_shards: int, n_data: int = 1):
    """Debug mesh whose 'model' axis width matches a ShardedPack's shard count.

    ``ApproxConfig(mode="sharded_pack", pack_shards=N)`` distributes only when
    the bound mesh's 'model' axis is exactly N wide (see
    ``approx.table_pack._active_pack_mesh``); this helper builds that mesh for
    CPU smoke runs (``XLA_FLAGS=--xla_force_host_platform_device_count=...``
    must provide n_data * n_shards host devices before the first jax import).
    """
    return jax.make_mesh((n_data, n_shards), ("data", "model"))
