"""Serving launcher: batched prefill+decode over a synthetic request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --reduced \\
      --requests 8 --max-new 16

``--scheduler continuous`` (default) serves through the ContinuousEngine
(admission queue, per-slot budgets/EOS/RNG, mid-stream slot refill);
``--scheduler static`` keeps the fixed-group baseline.

ScopeKit (docs/observability.md): ``--trace PATH`` writes a Perfetto-loadable
Chrome trace of the run (request lifecycles, refill/decode spans, jit-compile
events) with the engine's metric summary embedded; ``--obs`` additionally
enables device-side approximation telemetry and prints the metric summary.
The launcher always records host-side spans, so throughput is reported both
wall-clock and steady-state (compile time excluded).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import obs
from repro.approx import TABLE_MODES
from repro.models import build_model, get_config
from repro.serving.engine import (ContinuousEngine, DecodeEngine, Request,
                                  serve_static)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "static"],
                    help="continuous = admission queue + mid-stream slot "
                         "refill; static = fixed request groups")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--approx-mode",
                    choices=["exact", *TABLE_MODES],
                    default=None,
                    help="nonlinearity backend; table_pack = one fused "
                         "multi-function pack + kernel for the whole network, "
                         "quant_pack = the same pack with int8/int16 entries "
                         "dequantized on read, poly_pack = the Pareto-planned "
                         "pack (degree-1..3 Horner cells, mixed widths; see "
                         "--pack-budget), routed_* = the same packs with "
                         "dynamic per-row fn_id dispatch (one executable for "
                         "every member), sharded_pack = the pack's values "
                         "split over the mesh 'model' axis (per-shard base "
                         "rebasing, psum combine)")
    ap.add_argument("--approx-ea", type=float, default=None,
                    help="override the config's error budget E_a")
    ap.add_argument("--pack-shards", type=int, default=None,
                    help="sharded_pack modes: split the pack values this many "
                         "ways (distributes when a mesh binds a matching "
                         "'model' axis; otherwise a stacked-shard sum)")
    ap.add_argument("--pack-budget", type=int, default=None,
                    help="poly_pack modes: total-bytes budget for the design-"
                         "space planner (greedy member downgrade until the "
                         "pack fits; default keeps each function's Pareto-"
                         "cheapest candidate)")
    ap.add_argument("--rope-table", action="store_true",
                    help="serve rotary embeddings from the pack's folded trig"
                         " members (any table mode; docs/range_reduction.md)")
    ap.add_argument("--attn-table", action="store_true",
                    help="TableFlash: serve flash attention's softmax exponent"
                         " from the pack's exp_neg member (any table mode; "
                         "docs/table_flash.md)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the run (open in "
                         "Perfetto; validate with tools/check_trace.py)")
    ap.add_argument("--obs", action="store_true",
                    help="enable device-side approximation telemetry "
                         "(out-of-domain clamps, quant saturation, routed "
                         "dispatch) and print the metric summary")
    args = ap.parse_args()

    # host-side spans are always on for the launcher (they never touch the
    # device computation); device telemetry only with --obs, and only then is
    # the model built with instrumented activation closures
    obs.configure(enabled=True, device_telemetry=args.obs,
                  trace_path=args.trace)
    obs.reset_tracer()

    cfg = get_config(args.arch)
    if args.reduced:
        import os, sys

        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))
        from tests.test_archs import reduced

        cfg = reduced(args.arch)
    if (args.approx_mode is not None or args.approx_ea is not None
            or args.pack_shards is not None or args.pack_budget is not None
            or args.rope_table or args.attn_table):
        import dataclasses

        # override only what was passed; keep the config's other approx params
        kw = {}
        if args.approx_mode is not None:
            kw["mode"] = args.approx_mode
        if args.approx_ea is not None:
            kw["e_a"] = args.approx_ea
        if args.pack_shards is not None:
            kw["pack_shards"] = args.pack_shards
        if args.pack_budget is not None:
            kw["pack_budget"] = args.pack_budget
        if args.rope_table:
            kw["rope_table"] = True
        if args.attn_table:
            kw["attn_table"] = True
        cfg = cfg.replace(approx=dataclasses.replace(cfg.approx, **kw))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, (int(n),)).astype(np.int32),
                    max_new_tokens=args.max_new)
            for n in rng.integers(4, 32, args.requests)]
    if args.scheduler == "continuous":
        engine = ContinuousEngine(model, params, args.batch, args.cache_len,
                                  temperature=args.temperature)
        t0 = time.time()
        results = engine.serve(reqs)
    else:
        engine = DecodeEngine(model, params, args.batch, args.cache_len,
                              temperature=args.temperature)
        t0 = time.time()
        results = serve_static(model, params, reqs, batch_size=args.batch,
                               cache_len=args.cache_len, engine=engine)
    dt = time.time() - t0
    total_new = sum(r.steps for r in results)  # per-request trimmed counts
    steady = max(dt - engine.compile_time_s, 1e-9)
    print(f"served {len(results)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s wall, "
          f"{total_new / steady:.1f} tok/s steady after "
          f"{engine.compile_time_s:.2f}s compile, {args.scheduler}); "
          f"{engine.batch_steps} batch rounds, wasted slot-step fraction "
          f"{engine.wasted_fraction:.2f}")
    for i, r in enumerate(results[:4]):
        print(f"  req{i}: prompt_len={r.prompt_len} steps={r.steps} "
              f"-> {r.tokens[:8].tolist()}...")
    summary = {"requests": len(results), "tokens": total_new,
               "wall_s": dt, "compile_time_s": engine.compile_time_s,
               "tok_s_wall": total_new / dt, "tok_s_steady": total_new / steady,
               "scheduler": args.scheduler}
    if args.obs:
        print(json.dumps({"metrics": obs.get_registry().summary(),
                          "engine_metrics": engine.metrics.summary()},
                         indent=1, default=str))
    if args.trace:
        obs.get_tracer().save(args.trace, metadata={
            "summary": summary,
            "metrics": {
                # engine-owned latency histograms + the global (device
                # telemetry) registry merged for the report CLI
                "histograms": engine.metrics.summary()["histograms"],
                "counters": obs.get_registry().summary()["counters"],
            }})
        print(f"trace written to {args.trace}")


if __name__ == "__main__":
    main()
