import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell on the production mesh without allocating real data.

For each cell we jit the REAL step function (train_step with AdamW, or
prefill/decode serve steps with their caches), lower against ShapeDtypeStruct
inputs, compile for the 512-host-device SPMD target, and record:
  * memory_analysis()  — per-device argument/output/temp/code bytes (fits-check)
  * cost_analysis()    — HLO FLOPs + bytes accessed (roofline numerator)
  * per-collective-type bytes parsed from the compiled HLO (collective term)

Results append to a JSON cache (benchmarks/results/dryrun.json) keyed by
(arch, shape, mesh, variant) so re-runs are incremental.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_production_mesh
from repro.models import build_model, get_config, input_specs, shapes_for
from repro.models.registry import ARCH_IDS
from repro.optim import adamw
from repro.parallel.cache_specs import cache_pspecs
from repro.parallel.params import param_pspecs, shardings_from_specs, zero1_pspecs
from repro.parallel.sharding import default_rules, use_sharding
from repro.train.loop import make_train_step

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "results", "dryrun.json")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of every typed shape in an HLO result signature."""
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"=\s*(?P<sig>\([^)]*\)|[\w\[\]{},]+(?:\{[^}]*\})?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type output bytes of the per-device program.

    Convention: we count each op's RESULT bytes (for all-reduce/permute result ==
    operand; for all-gather the result is the gathered tensor; for reduce-scatter
    the scattered shard).  Tuple results (grouped reduces) sum their elements;
    ``-start`` variants are counted, ``-done`` skipped.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["counts"] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        op = m.group("op")
        out[op] += _shape_bytes(m.group("sig"))
        out["counts"][op] += 1
    return out


def _spec_tree_to_json(tree):
    return jax.tree.map(lambda s: str(s), tree,
                        is_leaf=lambda x: hasattr(x, "_normalized_spec")
                        or type(x).__name__ == "PartitionSpec")


def _batch_axes(rules, mesh, batch_size: int):
    """Batch-dim sharding axes, or None when the batch doesn't divide (e.g. the
    long_500k single-sequence decode replicates its batch dim)."""
    ax = rules.get("batch")
    if ax is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = int(np.prod([sizes[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
    return ax if batch_size % total == 0 else None


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             variant: str = "base") -> Dict[str, Any]:
    cfg = get_config(arch_id)
    shape = {s.name: s for s in shapes_for(cfg)}.get(shape_name)
    if shape is None:
        return {"status": "skipped",
                "reason": f"{shape_name} not applicable to {arch_id} "
                          "(see DESIGN.md §5)"}
    cfg = apply_variant(cfg, variant)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh)
    t0 = time.time()

    from repro.parallel.params import fsdp_pspecs

    if variant in ("fsdp", "ddp"):
        # fsdp: ZeRO-3 flat param sharding; ddp: params replicated (small nets).
        # Both: batch over the whole mesh, no tensor-parallel activation sharding
        rules = dict(rules)
        full = tuple(mesh.axis_names)
        rules.update({"batch": full, "model": None, "expert": None,
                      "vocab": None, "heads": None, "ff": None})

    with use_sharding(mesh, rules):
        abstract_params = model.abstract_params()
        if variant == "fsdp":
            pspecs = fsdp_pspecs(abstract_params, mesh)
        elif variant == "ddp":
            from jax.sharding import PartitionSpec as P0

            pspecs = jax.tree.map(lambda _: P0(), abstract_params)
        else:
            pspecs = param_pspecs(abstract_params, mesh, rules)

        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            # default accumulation caps the live microbatch at ~8k tokens/device
            # (scan-carried residuals are the dominant activation term)
            dsize = int(np.prod([s for n, s in zip(
                mesh.axis_names, mesh.devices.shape) if n != "model"]))
            tokens_dev = shape.global_batch * shape.seq_len / max(1, dsize)
            auto_accum = max(1, int(tokens_dev // 4096))
            while shape.global_batch % (auto_accum * 1) != 0 or \
                    (shape.global_batch // auto_accum) % 1 != 0:
                auto_accum -= 1
            while auto_accum > 1 and shape.global_batch % auto_accum != 0:
                auto_accum -= 1
            accum = {"accum1": 1, "accum4": 4, "accum8": 8}.get(variant, auto_accum)
            accum = max(1, accum)
            if variant == "fsdp":
                mspecs = pspecs  # already fully sharded
                step_fn = make_train_step(model, opt_cfg, accum=accum)
                param_state_specs = pspecs
            elif variant == "ddp":
                # moments ZeRO-1-sharded over the flat mesh, params replicated
                mspecs = zero1_pspecs(abstract_params, mesh,
                                      {**rules, "batch": tuple(mesh.axis_names)})
                step_fn = make_train_step(model, opt_cfg, accum=accum)
                param_state_specs = pspecs
            else:
                # WUS: f32 master fully 2D-sharded; bf16 TP work copy per step
                mspecs = zero1_pspecs(abstract_params, mesh, rules)
                work_sh = shardings_from_specs(mesh, pspecs)
                master_sh = shardings_from_specs(mesh, mspecs)
                step_fn = make_train_step(model, opt_cfg, accum=accum,
                                          work_shardings=work_sh,
                                          master_shardings=master_sh)
                param_state_specs = mspecs
            from jax.sharding import PartitionSpec as P

            state_specs = {"params": param_state_specs,
                           "opt": {"m": mspecs, "v": mspecs, "count": P()},
                           "step": P()}
            state_sh = shardings_from_specs(mesh, state_specs)
            batch_abstract = input_specs(cfg, shape)
            bax = _batch_axes(rules, mesh, shape.global_batch)
            batch_sh = shardings_from_specs(
                mesh, jax.tree.map(lambda _: P(bax), batch_abstract))
            abstract_state = {
                "params": abstract_params,
                "opt": {"m": abstract_params_f32(abstract_params),
                        "v": abstract_params_f32(abstract_params),
                        "count": jax.ShapeDtypeStruct((), jnp.int32)},
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(abstract_state, batch_abstract)
        elif shape.kind == "prefill":
            # serving reads bf16 params (deployment norm; halves weight traffic)
            abstract_params = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
                if l.dtype == jnp.float32 else l, abstract_params)
            cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
            cspecs = cache_pspecs(cache_abs, mesh, rules)
            cache_sh = shardings_from_specs(mesh, cspecs)
            param_sh = shardings_from_specs(mesh, pspecs)
            batch_abstract = input_specs(cfg, shape)
            from jax.sharding import PartitionSpec as P

            bax = _batch_axes(rules, mesh, shape.global_batch)
            batch_sh = shardings_from_specs(
                mesh, jax.tree.map(lambda _: P(bax), batch_abstract))

            def prefill_step(params, batch, cache):
                return model.prefill(params, batch, cache)

            jitted = jax.jit(prefill_step,
                             in_shardings=(param_sh, batch_sh, cache_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(abstract_params, batch_abstract, cache_abs)
        else:  # decode
            abstract_params = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
                if l.dtype == jnp.float32 else l, abstract_params)
            cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
            cspecs = cache_pspecs(cache_abs, mesh, rules)
            cache_sh = shardings_from_specs(mesh, cspecs)
            param_sh = shardings_from_specs(mesh, pspecs)
            from jax.sharding import PartitionSpec as P

            io = input_specs(cfg, shape)
            bax = _batch_axes(rules, mesh, shape.global_batch)
            tok_sh = shardings_from_specs(mesh, P(bax, None))
            pos_sh = shardings_from_specs(mesh, P())

            def serve_step(params, tok, pos, cache):
                return model.decode_step(params, tok, pos, cache)

            jitted = jax.jit(serve_step,
                             in_shardings=(param_sh, tok_sh, pos_sh, cache_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(3,))
            lowered = jitted.lower(abstract_params, io["tok"], io["pos"], cache_abs)

        compiled = lowered.compile()

    compile_s = time.time() - t0
    try:
        mem = compiled.memory_analysis()
        mem_fields = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:
        mem_fields = {"error": repr(e)}
    try:
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax returns [dict]
            cost = cost[0] if cost else {}
    except Exception:
        cost = {}
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    n_chips = int(np.prod(mesh.devices.shape))

    record = {
        "status": "ok",
        "compile_s": round(compile_s, 1),
        "n_chips": n_chips,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "memory": mem_fields,
        "flops_per_device": float(cost.get("flops", -1)),
        "bytes_per_device": float(cost.get("bytes accessed", -1)),
        "collectives": colls,
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
        "tokens": int(shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                            else 1)),
        "kind": shape.kind,
        "variant": variant,
    }
    return record


def abstract_params_f32(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), tree)


def apply_variant(cfg, variant: str):
    """Perf-iteration variants (see EXPERIMENTS.md §Perf)."""
    if variant in ("base", "fsdp", "ddp", "accum4", "accum8"):
        return cfg
    if variant == "exact":  # paper-ablation: exact transcendentals
        from repro.approx import ApproxConfig

        return cfg.replace(approx=ApproxConfig(mode="exact"))
    if variant == "no_remat":
        return cfg.replace(remat=False)
    if variant == "cf10":  # MoE capacity factor 1.0 (20% less dispatch traffic)
        import dataclasses

        return cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    if variant == "limit4":  # device-limited routing: <=4 of 16 EP destinations
        import dataclasses

        return cfg.replace(moe=dataclasses.replace(
            cfg.moe, device_groups=16, max_groups=4, capacity_factor=1.0))
    raise KeyError(variant)


def load_results() -> Dict[str, Any]:
    path = os.path.abspath(RESULTS_PATH)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(results: Dict[str, Any]) -> None:
    path = os.path.abspath(RESULTS_PATH)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multipod", "both"])
    ap.add_argument("--variant", default="base")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch in (None, "all")) else [args.arch]
    meshes = {"single": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    results = load_results()

    for arch_id in archs:
        cfg = get_config(arch_id)
        shapes = [s.name for s in shapes_for(cfg)]
        if args.shape and args.shape != "all":
            shapes = [args.shape]
        for shape_name in shapes:
            for mp in meshes:
                key = f"{arch_id}|{shape_name}|{'2x16x16' if mp else '16x16'}|{args.variant}"
                if key in results and results[key].get("status") == "ok" \
                        and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[run] {key} ...", flush=True)
                try:
                    rec = run_cell(arch_id, shape_name, mp, args.variant)
                except Exception as e:  # record failures — they are bugs to fix
                    rec = {"status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                # merge-fresh before save: concurrent sweeps must not clobber
                results = load_results()
                results[key] = rec
                save_results(results)
                print(f"   -> {rec.get('status')} "
                      f"({rec.get('compile_s', '-')}s, "
                      f"flops/dev={rec.get('flops_per_device', '-')})", flush=True)


if __name__ == "__main__":
    main()
