"""yi-34b [dense]: 60L d=7168 56H (GQA kv=8, head_dim=128) d_ff=20480 vocab=64000 —
llama-arch GQA [arXiv:2403.04652]."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
    act="silu",
    attn=AttnConfig(rope_theta=5_000_000.0),
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
