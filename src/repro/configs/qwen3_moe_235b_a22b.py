"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4, head_dim=128) expert_ff=1536
vocab=151936, 128 experts top-8, qk-norm [hf:Qwen/Qwen3 family]."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,
    vocab=151936,
    act="silu",
    moe=MoEConfig(n_experts=128, top_k=8, n_shared=0),
    attn=AttnConfig(qk_norm=True, rope_theta=1_000_000.0),
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
