"""Assigned architecture configs (one module per arch) + the paper's own config."""
