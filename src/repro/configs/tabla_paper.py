"""tabla-paper: the paper's own experiment configuration — not a model arch but the
set of (function, interval, E_a, formats) cells of Tables 2/3, consumed by the
benchmarks and the quickstart example."""

from repro.core.quantize import PAPER_FORMATS

E_A_TABLE2 = 9.5367e-07  # Sec. 5.4 / Table 2 sweep error bound
E_A_FIG3 = 1.25e-4
E_A_WORKED = 1.22e-4  # Sec. 5.1-5.3 worked examples

# Table 2 functions with their intervals (the sweep benchmark set)
TABLE2_CELLS = {
    "log": (0.625, 15.625),
    "exp": (0.0, 5.0),
    "tan": (-1.5, 0.0),
    "tanh": (-8.0, 0.0),
    "sigmoid": (-10.0, 0.0),
    "gauss": (-6.0, 0.0),
}

# Table 3 synthesis cells (wider, both-signed intervals)
TABLE3_CELLS = {
    "tan": (-1.5, 1.5),
    "log": (0.625, 15.625),
    "exp": (0.0, 5.0),
    "tanh": (-8.0, 8.0),
    "gauss": (-6.0, 6.0),
    "sigmoid": (-10.0, 10.0),
}

FORMATS = PAPER_FORMATS
OMEGA_SWEEP = [round(0.01 * i, 2) for i in range(1, 31)]  # Fig. 6 x-axis
