"""starcoder2-3b [dense]: 30L d=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 — GQA,
RoPE, plain 2-matrix GELU MLP [arXiv:2402.19173]."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    act="gelu",
    mlp_kind="mlp",
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
