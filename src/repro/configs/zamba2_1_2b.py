"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d=2048, ssm_state=64, + ONE shared
(weight-tied) attention+MLP block (32H, d_ff=8192) applied every 6 layers
[arXiv:2411.15242].  vocab=32000."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    act="silu",
    ssm=SSMConfig(state_dim=64, head_dim=64, conv_width=4, expand=2, chunk=256),
    shared_attn_every=6,
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
