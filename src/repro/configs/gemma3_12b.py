"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8, head_dim=256) d_ff=15360
vocab=262144 — 5:1 local:global sliding-window pattern, qk-norm, 128k-class context
[hf:google/gemma-3 family].  Local layers keep a 1024-token ring KV cache, so
long_500k holds full KV on only 8/48 layers (DESIGN.md §5)."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab=262144,
    act="gelu_tanh",
    tie_embeddings=True,
    attn=AttnConfig(global_every=6, qk_norm=True, rope_theta=1_000_000.0),
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
