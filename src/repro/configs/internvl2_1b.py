"""internvl2-1b [vlm]: InternViT (STUB patch embeddings, d_vis=1024, 256 tokens) +
InternLM2 backbone: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151655
[arXiv:2404.16821]."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    act="silu",
    n_vis_tokens=256,
    d_vis=1024,
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
