"""whisper-small [audio]: enc-dec, 12L decoder d=768 12H d_ff=3072 vocab=51865,
conv frontend STUBBED to precomputed frame embeddings (B, 1500, d)
[arXiv:2212.04356]."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    act="gelu",
    mlp_kind="mlp",
    n_enc_layers=12,
    enc_len=1500,
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
