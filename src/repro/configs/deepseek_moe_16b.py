"""deepseek-moe-16b [moe]: 28L d=2048 16H (MHA, kv=16) expert_ff=1408 vocab=102400,
64 routed top-6 + 2 shared, fine-grained [arXiv:2401.06066]."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    act="silu",
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2),
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
