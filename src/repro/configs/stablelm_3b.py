"""stablelm-3b [dense]: 32L d=2560 32H (MHA) d_ff=6912 vocab=50304
[hf:stabilityai/stablelm family]."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    act="silu",
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
