"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM blocks
[arXiv:2405.04517].  d_ff=0: xLSTM blocks carry their own internal projections.
The exp-gating (mLSTM/sLSTM input gates) is THE table-backend hot spot here."""

from repro.approx import ApproxConfig
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    act="gelu",
    approx=ApproxConfig(mode="table_ref", e_a=1e-4, algorithm="hierarchical",
                        omega=0.2),
)
