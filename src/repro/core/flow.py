"""The automated design flow (paper Sec. 6): function + E_a + algorithm -> artifact.

This is the software analogue of the paper's VHDL generation: it runs an interval-
splitting algorithm, materializes the packed :class:`TableSpec`, and reports the
resource costs under both packing models (BRAM18 for paper fidelity, VMEM for the
TPU runtime).  Artifacts are cached per (function, interval, E_a, algorithm, omega)
because model constructors request the same handful of tables thousands of times.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

from repro import obs

from . import bram
from .functions import FunctionSpec, get as get_function
from .spacing import SecondDerivMax, reference_spacing
from .table import TableSpec, build_table


@dataclass(frozen=True)
class FlowReport:
    spec: TableSpec
    reference_footprint: int
    footprint: int
    reduction_pct: float
    n_intervals: int
    brams: int
    brams_reference: int
    vmem: bram.VmemCost
    measured_max_error: Optional[float] = None

    def summary(self) -> str:
        return (
            f"{self.spec.name}[{self.spec.lo},{self.spec.hi}) Ea={self.spec.e_a:g} "
            f"{self.spec.algorithm}: M_F {self.reference_footprint} -> {self.footprint} "
            f"(-{self.reduction_pct:.1f}%), intervals={self.n_intervals}, "
            f"BRAM {self.brams_reference} -> {self.brams}, "
            f"VMEM {self.vmem.padded_bytes}B ({self.vmem.fraction * 100:.3f}% of budget)"
        )


def run_flow(
    fn: FunctionSpec | str,
    e_a: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    *,
    verify_error: bool = False,
    **split_kw,
) -> FlowReport:
    fn = get_function(fn) if isinstance(fn, str) else fn
    lo = fn.interval[0] if lo is None else lo
    hi = fn.interval[1] if hi is None else hi
    spec = build_table(fn, e_a, lo, hi, algorithm, omega, **split_kw)
    oracle = SecondDerivMax(fn, lo, hi)
    ref = reference_spacing(oracle, e_a, lo, hi)
    red = 100.0 * (ref.footprint - spec.footprint) / ref.footprint
    report = FlowReport(
        spec=spec,
        reference_footprint=ref.footprint,
        footprint=spec.footprint,
        reduction_pct=red,
        n_intervals=spec.n_intervals,
        brams=bram.bram_count(spec.footprint),
        brams_reference=bram.bram_count(ref.footprint),
        vmem=bram.vmem_cost(spec.footprint, spec.n_intervals),
        measured_max_error=(spec.max_error_on_grid(fn) if verify_error else None),
    )
    return report


@lru_cache(maxsize=256)
@obs.traced("design.splitter", "design")
def cached_table(
    name: str,
    e_a: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
) -> TableSpec:
    """Memoized design-flow entry point used by model constructors."""
    return build_table(name, e_a, lo, hi, algorithm, omega)
