"""The paper's three interval-splitting algorithms (Algorithms 1-3).

All three return a partition ``P = [p_0 < p_1 < ... < p_n]`` of the input interval
``[x0, x0 + a)`` such that per-sub-interval uniform spacings (Eq. 11) never violate
the maximum approximation error ``E_a`` anywhere.

Acceptance criterion — paper erratum
------------------------------------
The pseudocode in the paper writes the split-acceptance test as

    kappa_1 + kappa_2 < kappa_parent * omega            (Alg. 1 line 13 etc.)

but its prose ("omega = 0.3 indicates that an interval split must lead to a footprint
reduction of AT LEAST 30%") and *all three* worked examples (Sec. 5.1: 415 < 770
accepted at omega=0.3; Sec. 5.2: 258 accepted; Sec. 5.3: 526 accepted with a stated
31.6% reduction vs the 30% threshold) are only consistent with

    kappa_1 + kappa_2 < kappa_parent * (1 - omega)      (reduction > omega)

We implement the example-consistent form.  ``tests/test_splitting.py`` reproduces the
paper's worked examples against it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from .functions import FunctionSpec, get as get_function
from .spacing import SecondDerivMax, delta_for, footprint


@dataclass(frozen=True)
class SplitResult:
    """Partition plus the per-sub-interval spacing/footprint sets (P, S, K)."""

    partition: np.ndarray  # (n+1,) float64, p_0 = x0, p_n = x0 + a
    spacings: np.ndarray  # (n,) float64 delta_j
    counts: np.ndarray  # (n,) int64 kappa_j = M_F(delta_j, [p_j, p_{j+1}))
    algorithm: str
    omega: float
    e_a: float

    @property
    def n_intervals(self) -> int:
        return len(self.partition) - 1

    @property
    def footprint(self) -> int:
        """M_F^P = sum_j kappa_j (Eq. 13)."""
        return int(self.counts.sum())


def _finalize(
    fn: FunctionSpec,
    oracle: SecondDerivMax,
    boundaries: List[float],
    e_a: float,
    omega: float,
    algorithm: str,
) -> SplitResult:
    p = np.asarray(sorted(set(boundaries)), dtype=np.float64)
    deltas, counts = [], []
    for lo, hi in zip(p[:-1], p[1:]):
        d = delta_for(oracle, e_a, float(lo), float(hi))
        deltas.append(d)
        counts.append(footprint(d, float(lo), float(hi)))
    return SplitResult(
        partition=p,
        spacings=np.asarray(deltas, dtype=np.float64),
        counts=np.asarray(counts, dtype=np.int64),
        algorithm=algorithm,
        omega=omega,
        e_a=e_a,
    )


def _accept(kappa_split: int, kappa_parent: int, omega: float) -> bool:
    """Example-consistent acceptance: footprint reduction strictly exceeds omega."""
    return kappa_split < kappa_parent * (1.0 - omega)


# --------------------------------------------------------------------------------------
# Algorithm 1 — Binary segmentation (recursive midpoint).
# --------------------------------------------------------------------------------------


def binary_split(
    fn: FunctionSpec | str,
    e_a: float,
    lo: float,
    hi: float,
    omega: float = 0.3,
    *,
    min_width: float = 1e-9,
    max_depth: int = 40,
    oracle: SecondDerivMax | None = None,
) -> SplitResult:
    """Algorithm 1: recursively split at the midpoint while the footprint reduction
    exceeds ``omega``."""
    fn = get_function(fn) if isinstance(fn, str) else fn
    if not (0.0 < omega <= 1.0):
        raise ValueError("omega must be in (0, 1]")
    oracle = oracle or SecondDerivMax(fn, lo, hi)

    out: List[float] = []

    def rec(a: float, b: float, depth: int) -> None:
        out.append(a)
        if depth >= max_depth or (b - a) <= 2.0 * min_width:
            out.append(b)
            return
        dp = delta_for(oracle, e_a, a, b)
        kp = footprint(dp, a, b)
        bp = 0.5 * (a + b)
        d1 = delta_for(oracle, e_a, a, bp)
        d2 = delta_for(oracle, e_a, bp, b)
        if d1 != d2:  # paper line 8: identical spacings => no point splitting
            k1 = footprint(d1, a, bp)
            k2 = footprint(d2, bp, b)
            if _accept(k1 + k2, kp, omega):
                rec(a, bp, depth + 1)
                rec(bp, b, depth + 1)
                return
        out.append(b)

    rec(float(lo), float(hi), 0)
    return _finalize(fn, oracle, out, e_a, omega, "binary")


# --------------------------------------------------------------------------------------
# Algorithm 2 — Hierarchical segmentation (recursive best-sweep-point).
# --------------------------------------------------------------------------------------


def hierarchical_split(
    fn: FunctionSpec | str,
    e_a: float,
    lo: float,
    hi: float,
    omega: float = 0.3,
    epsilon: float | None = None,
    *,
    max_depth: int = 40,
    oracle: SecondDerivMax | None = None,
) -> SplitResult:
    """Algorithm 2: sweep candidates ``p_i + j*epsilon``, split at the footprint-
    minimizing candidate when the reduction exceeds ``omega``; recurse."""
    fn = get_function(fn) if isinstance(fn, str) else fn
    if not (0.0 < omega <= 1.0):
        raise ValueError("omega must be in (0, 1]")
    if epsilon is None:
        epsilon = (hi - lo) / 1000.0  # paper's example density
    if epsilon <= 0:
        raise ValueError("epsilon must be positive")
    oracle = oracle or SecondDerivMax(fn, lo, hi)

    out: List[float] = []

    def rec(a: float, b: float, depth: int) -> None:
        out.append(a)
        j_max = int(np.floor((b - a) / epsilon + 1e-12))
        if depth >= max_depth or j_max < 2:
            out.append(b)
            return
        dp = delta_for(oracle, e_a, a, b)
        kp = footprint(dp, a, b)
        # Vectorized sweep over interior candidates j in [1, j_max - 1].
        best_cost, best_sp = None, None
        for j in range(1, j_max):
            sp = a + j * epsilon
            if sp <= a or sp >= b:
                continue
            c = footprint(delta_for(oracle, e_a, a, sp), a, sp) + footprint(
                delta_for(oracle, e_a, sp, b), sp, b
            )
            if best_cost is None or c < best_cost:
                best_cost, best_sp = c, sp
        if best_cost is not None and _accept(best_cost, kp, omega):
            rec(a, best_sp, depth + 1)
            rec(best_sp, b, depth + 1)
            return
        out.append(b)

    rec(float(lo), float(hi), 0)
    return _finalize(fn, oracle, out, e_a, omega, "hierarchical")


# --------------------------------------------------------------------------------------
# Algorithm 3 — Sequential segmentation (single left-to-right sweep).
# --------------------------------------------------------------------------------------


def sequential_split(
    fn: FunctionSpec | str,
    e_a: float,
    lo: float,
    hi: float,
    omega: float = 0.3,
    epsilon: float | None = None,
    *,
    oracle: SecondDerivMax | None = None,
) -> SplitResult:
    """Algorithm 3: sweep candidates ``x0 + i*epsilon`` once; greedily commit any
    split whose footprint reduction (vs the current tail interval) exceeds ``omega``."""
    fn = get_function(fn) if isinstance(fn, str) else fn
    if not (0.0 < omega <= 1.0):
        raise ValueError("omega must be in (0, 1]")
    if epsilon is None:
        epsilon = (hi - lo) / 50.0  # paper's example uses 0.3 on a 15-wide interval
    if epsilon <= 0:
        raise ValueError("epsilon must be positive")
    oracle = oracle or SecondDerivMax(fn, lo, hi)

    boundaries: List[float] = [float(lo)]
    x_p = float(lo)
    d_p = delta_for(oracle, e_a, x_p, hi)
    k_p = footprint(d_p, x_p, hi)
    i_max = int(np.floor((hi - lo) / epsilon + 1e-12))
    for i in range(1, i_max):
        sp = lo + i * epsilon
        if sp <= x_p or sp >= hi:
            continue
        k1 = footprint(delta_for(oracle, e_a, x_p, sp), x_p, sp)
        k2 = footprint(delta_for(oracle, e_a, sp, hi), sp, hi)
        if _accept(k1 + k2, k_p, omega):
            boundaries.append(float(sp))
            x_p = float(sp)
            d_p = delta_for(oracle, e_a, x_p, hi)
            k_p = footprint(d_p, x_p, hi)
    boundaries.append(float(hi))
    return _finalize(fn, oracle, boundaries, e_a, omega, "sequential")


ALGORITHMS = {
    "binary": binary_split,
    "hierarchical": hierarchical_split,
    "sequential": sequential_split,
}


def split(
    algorithm: str,
    fn: FunctionSpec | str,
    e_a: float,
    lo: float,
    hi: float,
    omega: float = 0.3,
    **kw,
) -> SplitResult:
    try:
        f = ALGORITHMS[algorithm]
    except KeyError:
        raise KeyError(f"unknown algorithm {algorithm!r}; known: {sorted(ALGORITHMS)}")
    return f(fn, e_a, lo, hi, omega, **kw)
