"""Argument range reduction: fold unbounded domains onto small canonical intervals.

The paper's tables live on a fixed ``[x0, x0 + a)`` with clamp/extrapolate edges, so
trig and exp over real input ranges stay out of reach of the pack.  This module is
the reduction stage in front of the lookup (the RangeFold tentpole): fold the
argument onto the canonical interval where a *small* table is accurate, look up
there, and reconstruct the full-range value from exact bookkeeping (octant index,
binary exponent).  Three folds are provided, all written in plain ``jax.numpy`` so
the SAME code runs in the jnp oracles and inside the Pallas kernel bodies — the
kernel/oracle bit-parity contract holds by construction, as for ``select_interval``.

* **Trig** (``trig_fold``): ``x = k*(pi/2) + r`` with ``r in [-pi/4, pi/4]`` and the
  quadrant ``q = k mod 4`` selecting sign/swap between ``sin_core``/``cos_core``.
  Two regimes, blended with ``where``:

  - Cody–Waite for ``|x| < 2048``: ``pi/2`` split into two exact 12-bit words plus
    an f32 tail, so ``k*word`` is exact for ``|k| <= 1304`` and the three-step
    subtraction cancels without rounding (measured ``|r|`` error < 3e-8 over the
    regime).
  - Payne–Hanek for ``|x| >= 2048``: fixed-point product of the 24-bit mantissa
    against 192 bits of ``2/pi`` (twelve 16-bit limbs), accumulated mod ``2^32``
    at scale ``2^29`` so the octant and the 29-bit fraction survive the huge
    integer part that cancels mod 4.  Mantissa halves are 12-bit so every
    ``12b x 16b`` partial product is exact in uint32.

* **Exp** (``exp_fold``): ``exp(x) = 2^k * exp(r)``, ``k = round(x/ln2)``,
  ``r in [-ln2/2, ln2/2]`` via a two-word Cody–Waite ``ln2``; reconstruction
  builds ``2^k`` from the exponent field in two factors so gradual underflow and
  overflow-to-inf match the exact exp.

* **Log** (``log_fold``): ``x = m * 2^e`` with ``m in [sqrt2/2, sqrt2)`` straight
  from the float's exponent field (subnormals pre-scaled by ``2^24``);
  ``log(x) = e*ln2 + log_core(m)`` with the same split-``ln2`` summation.

Accuracy note: the trig folds keep the table's ABSOLUTE Ea contract over the whole
finite f32 range (the fraction kept by Payne–Hanek resolves ``r`` to ~5e-8, far
below Ea=1e-4).  Folded ``exp`` necessarily has a RELATIVE contract
``|err| <= Ea * max(1, |exp(x)|)`` — the ``2^k`` reconstruction scales the core
table's absolute error — and folded ``log`` keeps the absolute contract up to the
``e*ln2`` summation rounding (< 1e-5 over f32).  ``tests/harness/fullrange.py``
verifies all of this against f64 numpy across every decade of the finite f32 range.
"""

from __future__ import annotations

import numpy as np

# --------------------------------------------------------------------------------------
# Constants (derived offline from 100-digit pi / 60-digit ln2; see docs/range_reduction.md)
# --------------------------------------------------------------------------------------

# pi/2 = PIO2_HI + PIO2_MID + PIO2_LO + O(2e-15); HI/MID carry 12 significant bits
# so k*HI and k*MID are exact f32 products for |k| <= 2^12.
PIO2_HI = np.float32(1.5703125)
PIO2_MID = np.float32(0.0004837512969970703)
PIO2_LO = np.float32(7.54979e-08)
TWO_OVER_PI = np.float32(0.63661975)
# Cody–Waite k stays exact below this; Payne–Hanek takes over above.
TRIG_CW_MAX = 2048.0
# r = fraction * (pi/2) at the 2^-29 fixed-point scale kept by Payne–Hanek.
PH_SCALE = np.float32(2.9258362e-09)
# 192 fractional bits of 2/pi as twelve 16-bit limbs: limb j holds bits
# 2^(-16j-1) .. 2^(-16j-16).  Matches the classic fdlibm expansion.
PH_LIMBS = (0xA2F9, 0x836E, 0x4E44, 0x1529, 0xFC27, 0x57D1,
            0xF534, 0xDDC0, 0xDB62, 0x9599, 0x3C43, 0x9041)

# ln2 = LN2_HI + LN2_LO + O(6e-14); HI carries 16 bits so k*HI is exact for |k| <= 2^8.
LN2_HI = np.float32(0.693145751953125)
LN2_LO = np.float32(1.4286068e-06)
INV_LN2 = np.float32(1.442695)
# |k| clamp for exp: k1 = k//2 and k2 = k-k1 must stay valid normal exponents
# ([-126, 126]); beyond the clamp the core-table edge clamp saturates to 0/inf.
EXP_K_MAX = 252

SQRT2 = np.float32(1.4142135)

# Canonical core intervals (small guard bands over pi/4 = 0.7854 and ln2/2 = 0.3466
# absorb the k-rounding half-integer boundary cases).
SIN_CORE_INTERVAL = (-0.79, 0.79)
COS_CORE_INTERVAL = (-0.79, 0.79)
EXP_CORE_INTERVAL = (-0.36, 0.36)
LOG_CORE_INTERVAL = (0.70, 1.42)


def _jnp():
    # Lazy: repro.core stays importable without jax (the design flow is numpy-only).
    import jax.numpy as jnp

    return jnp


# --------------------------------------------------------------------------------------
# Trig fold: x -> (r, q, sflip) with sin(x) = (-1)^sflip * [sin,cos,-sin,-cos][q](r)
# --------------------------------------------------------------------------------------


def _shift_mod32(jnp, v, s):
    """``(v * 2^s) mod 2^32`` for uint32 ``v`` and int32 tensor ``s`` (negative =
    truncating right shift).  XLA shifts are undefined at >= 32, so both
    directions are clamped and the out-of-range lanes forced to zero (exact:
    any uint32 times 2^(>=32) is 0 mod 2^32, and v >> (>=32) truncates to 0)."""
    sl = jnp.clip(s, 0, 31).astype(jnp.uint32)
    sr = jnp.clip(-s, 0, 31).astype(jnp.uint32)
    out = jnp.where(s >= 0, jnp.left_shift(v, sl), jnp.right_shift(v, sr))
    inrange = (s > -32) & (s < 32)
    return jnp.where(inrange, out, jnp.uint32(0))


def _payne_hanek(ax):
    """Fixed-point ``|x| * 2/pi`` mod 8 at scale ``2^29`` -> (r, q).

    ``acc`` accumulates ``y * 2^29 mod 2^32`` (y = ax * 2/pi): bit 31..29 are the
    octant (integer part mod 8), bits 28..0 the fraction.  Rounding y to the
    nearest integer and keeping the signed remainder gives ``|r| <= pi/4``.
    """
    jnp = _jnp()
    import jax

    b = jax.lax.bitcast_convert_type(ax.astype(jnp.float32), jnp.uint32)
    e = ((b >> 23) & 0xFF).astype(jnp.int32)
    m = (b & 0x7FFFFF) | 0x800000  # implicit leading bit (ax >= 2048 is normal)
    mh = (m >> 12).astype(jnp.uint32)  # high 12 mantissa bits
    ml = (m & 0xFFF).astype(jnp.uint32)  # low 12 mantissa bits
    p = e - 150  # ax = m * 2^p with integer m in [2^23, 2^24)
    acc = jnp.zeros_like(b)
    for j, limb in enumerate(PH_LIMBS):
        lj = jnp.uint32(limb)
        s1 = p + 41 - 16 * (j + 1)  # mh*limb carries an extra 2^12
        acc = acc + _shift_mod32(jnp, mh * lj, s1)
        acc = acc + _shift_mod32(jnp, ml * lj, s1 - 12)
    rounded = acc + jnp.uint32(1 << 28)
    q = ((rounded >> 29) & 3).astype(jnp.int32)
    fbits = (rounded & jnp.uint32((1 << 29) - 1)).astype(jnp.int32) - (1 << 28)
    r = fbits.astype(jnp.float32) * PH_SCALE
    return r, q


def trig_fold(x):
    """Fold f32 ``x`` for sin/cos: returns ``(r, q, sflip)``.

    ``r in [-pi/4 - eps, pi/4 + eps]`` (inside ``SIN_CORE_INTERVAL``), ``q`` the
    quadrant ``k mod 4`` of ``k = round(x * 2/pi)``, and ``sflip`` marks elements
    folded through ``|x|`` (Payne–Hanek regime with ``x < 0``) whose SIN must be
    negated on reconstruction (cos is even — no flip).  For ``|x| < pi/4`` the
    fold is exact identity (``k = 0, r = x`` bitwise), which is what makes
    folded and unfolded lookups bit-identical on the canonical interval.
    Non-finite inputs produce garbage lanes the caller masks with ``isfinite``.
    """
    jnp = _jnp()
    xf = jnp.asarray(x).astype(jnp.float32)
    ax = jnp.abs(xf)
    # Cody–Waite (signed, |x| < TRIG_CW_MAX): k*HI and k*MID exact, 3-step cancel.
    kf = jnp.round(xf * TWO_OVER_PI)
    kf = jnp.clip(kf, -4194304.0, 4194304.0)  # keep int32 cast defined on big lanes
    r_cw = ((xf - kf * PIO2_HI) - kf * PIO2_MID) - kf * PIO2_LO
    q_cw = jnp.mod(kf.astype(jnp.int32), 4)
    # Payne–Hanek on |x| (sign restored via sflip).
    r_ph, q_ph = _payne_hanek(ax)
    big = ax >= TRIG_CW_MAX
    r = jnp.where(big, r_ph, r_cw)
    q = jnp.where(big, q_ph, q_cw)
    sflip = big & (xf < 0)
    return r, q, sflip


def quadrant_select(kind: str, ys, yc, q):
    """The octant swap/sign table: ``[ys, yc, -ys, -yc][q]`` for sin,
    ``[yc, -ys, -yc, ys][q]`` for cos.  Also correct for the *derivative*
    pattern when fed core slopes (d/dr of each branch follows the same cycle)."""
    jnp = _jnp()
    if kind == "sin":
        return jnp.where(q == 0, ys, jnp.where(q == 1, yc, jnp.where(q == 2, -ys, -yc)))
    if kind == "cos":
        return jnp.where(q == 0, yc, jnp.where(q == 1, -ys, jnp.where(q == 2, -yc, ys)))
    raise ValueError(f"quadrant_select kind must be sin/cos, got {kind!r}")


def trig_reconstruct(kind: str, ys, yc, q, sflip):
    """Reassemble sin(x) or cos(x) from core values at r plus fold bookkeeping."""
    jnp = _jnp()
    y = quadrant_select(kind, ys, yc, q)
    if kind == "sin":
        y = jnp.where(sflip, -y, y)
    return y


def trig_slope_reconstruct(kind: str, ds, dc, q, sflip):
    """Chain-rule slope of the folded trig surrogate from CORE slopes at r.

    d/dr of each quadrant branch follows the same select cycle as the values;
    the inner derivative is +1 except on Payne–Hanek ``|x|`` lanes (``sflip``
    tracks ``x < 0`` there), where sin's two negations cancel and cos picks up
    the ``d|x|/dx = -1`` factor."""
    jnp = _jnp()
    sl = quadrant_select(kind, ds, dc, q)
    if kind == "cos":
        sl = jnp.where(sflip, -sl, sl)
    return sl


def trig_edges(xf, y):
    """Non-finite trig inputs (inf, -inf, NaN) all map to NaN, like jnp.sin/cos."""
    jnp = _jnp()
    return jnp.where(jnp.isfinite(xf), y, jnp.nan)


# --------------------------------------------------------------------------------------
# Exp fold: exp(x) = 2^k * exp(r), r in [-ln2/2, ln2/2]
# --------------------------------------------------------------------------------------


def exp_fold(x):
    """Fold f32 ``x`` for exp: returns ``(r, k)`` with ``exp(x) = 2^k * exp(r)``.

    ``k`` is clamped to ``[-EXP_K_MAX, EXP_K_MAX]``; beyond the clamp ``r`` runs
    off the core interval and the table's edge clamp saturates the result to the
    correct 0 / inf once the ``2^k`` factors are applied.  ``|x| < ln2/2`` is the
    exact identity (``k = 0, r = x`` bitwise)."""
    jnp = _jnp()
    xf = jnp.asarray(x).astype(jnp.float32)
    kf = jnp.round(xf * INV_LN2)
    kf = jnp.clip(kf, -float(EXP_K_MAX), float(EXP_K_MAX))
    r = (xf - kf * LN2_HI) - kf * LN2_LO
    return r, kf.astype(jnp.int32)


def pow2(k):
    """``2^k`` for int32 ``k in [-126, 127]`` straight from the exponent field."""
    jnp = _jnp()
    import jax

    return jax.lax.bitcast_convert_type(
        ((k + 127) << 23).astype(jnp.int32), jnp.float32)


def exp_reconstruct(ycore, k):
    """``ycore * 2^k`` in two exact power-of-two factors so ``2^k`` never leaves
    the normal range: gradual underflow (subnormal outputs) and overflow-to-inf
    come out right without special cases."""
    k1 = k // 2
    k2 = k - k1
    return (ycore * pow2(k1)) * pow2(k2)


def exp_edges(xf, y):
    """Pin exp's non-finite edges to the exact values (NaN->NaN, +-inf)."""
    jnp = _jnp()
    y = jnp.where(xf == jnp.inf, jnp.inf, y)
    y = jnp.where(xf == -jnp.inf, 0.0, y)
    return jnp.where(jnp.isnan(xf), jnp.nan, y)


# --------------------------------------------------------------------------------------
# Log fold: x = m * 2^e, m in [sqrt2/2, sqrt2)
# --------------------------------------------------------------------------------------


def log_fold(x):
    """Fold positive f32 ``x`` for log: returns ``(m, e)`` with ``x = m * 2^e`` and
    ``m in [sqrt2/2, sqrt2)`` (inside ``LOG_CORE_INTERVAL``).  Subnormals are
    normalized purely bitwise (count-leading-zeros shift) — arithmetic on them
    would be flushed to zero on FTZ backends (XLA CPU, TPU), but bitcasts keep
    the payload.  Non-positive and non-finite lanes produce garbage the caller
    pins with ``log_edges``."""
    jnp = _jnp()
    import jax

    xf = jnp.asarray(x).astype(jnp.float32)
    b = jax.lax.bitcast_convert_type(xf, jnp.uint32)
    mant = b & 0x7FFFFF
    field = ((b >> 23) & 0xFF).astype(jnp.int32)
    is_sub = (field == 0) & (mant != 0)
    # Subnormal x = mant * 2^-149: shift the top set bit up to position 23 so the
    # mantissa read below sees a normalized [1, 2) value; exponent follows the shift.
    shift = jnp.clip(jax.lax.clz(mant).astype(jnp.int32) - 8, 0, 31)
    mant = jnp.where(is_sub, jnp.left_shift(mant, shift.astype(jnp.uint32)), mant)
    e = jnp.where(is_sub, -126 - shift, field - 127)
    m = jax.lax.bitcast_convert_type(
        (mant & 0x7FFFFF) | (np.uint32(127) << 23), jnp.float32)  # [1, 2)
    half = m >= SQRT2
    m = jnp.where(half, m * 0.5, m)  # exact halving into [sqrt2/2, sqrt2)
    e = e + jnp.where(half, 1, 0)
    return m, e.astype(jnp.float32)


def log_reconstruct(ycore, e):
    """``e*ln2 + log_core(m)`` with the split ``ln2`` summed small-terms-first."""
    return e * LN2_HI + (ycore + e * LN2_LO)


def log_edges(xf, y):
    """Pin log's edges to the exact values: log(+-0) = -inf, log(x<0) = NaN,
    log(inf) = inf, log(NaN) = NaN.

    The zero / sign tests are BITWISE (via bitcast), not arithmetic: XLA CPU
    flushes f32 subnormals to zero in comparisons (DAZ), so ``xf == 0`` is true
    for subnormal inputs and would clobber the finite value :func:`log_fold`
    recovers bitwise.  The bitcast view sees the real payload, which makes the
    folded log MORE accurate than the backend's own ``jnp.log`` (which returns
    -inf) on subnormal arguments."""
    jnp = _jnp()
    import jax.lax as lax

    bits = lax.bitcast_convert_type(xf, jnp.uint32)
    mag = bits & jnp.uint32(0x7FFFFFFF)
    is_zero = mag == 0
    is_neg = (bits >> 31) != 0
    y = jnp.where(is_zero, -jnp.inf, y)
    y = jnp.where(is_neg & ~is_zero, jnp.nan, y)
    y = jnp.where(mag == jnp.uint32(0x7F800000), jnp.inf, y)
    return jnp.where(mag > jnp.uint32(0x7F800000), jnp.nan, y)
