"""Two-sample Student's t-test (Matlab ``ttest2`` semantics) — paper Table 1/2.

Equal-variance pooled two-sample t statistic with right-/left-/two-tailed decisions
at significance ``alpha``.  The Student-t CDF is computed from the regularized
incomplete beta function (Numerical-Recipes continued fraction) so there is no scipy
dependency.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


def _betacf(a: float, b: float, x: float, max_iter: int = 200, eps: float = 3e-12):
    """Continued fraction for the incomplete beta function (NR 6.4)."""
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c = 1.0
    d = 1.0 - qab * x / qap
    if abs(d) < 1e-300:
        d = 1e-300
    d = 1.0 / d
    h = d
    for m in range(1, max_iter + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-300:
            d = 1e-300
        c = 1.0 + aa / c
        if abs(c) < 1e-300:
            c = 1e-300
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-300:
            d = 1e-300
        c = 1.0 + aa / c
        if abs(c) < 1e-300:
            c = 1e-300
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < eps:
            return h
    return h  # pragma: no cover — converges in <60 iters for our df range


def betainc_reg(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta I_x(a, b)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_front = (
        math.lgamma(a + b)
        - math.lgamma(a)
        - math.lgamma(b)
        + a * math.log(x)
        + b * math.log1p(-x)
    )
    front = math.exp(ln_front)
    if x < (a + 1.0) / (a + b + 2.0):
        return front * _betacf(a, b, x) / a
    return 1.0 - front * _betacf(b, a, 1.0 - x) / b


def t_cdf(t: float, df: float) -> float:
    """CDF of Student's t with ``df`` degrees of freedom."""
    x = df / (df + t * t)
    p = 0.5 * betainc_reg(df / 2.0, 0.5, x)
    return 1.0 - p if t > 0 else p


@dataclass(frozen=True)
class TTestResult:
    t: float
    df: float
    p_two: float
    p_right: float  # H_a: mu1 > mu2
    p_left: float  # H_a: mu1 < mu2

    def reject(self, tail: str, alpha: float = 0.05) -> int:
        """Matlab ttest2 h-output: 1 = reject H0 at level alpha."""
        p = {"two": self.p_two, "right": self.p_right, "left": self.p_left}[tail]
        return int(p < alpha)


def ttest2(g1, g2) -> TTestResult:
    """Pooled-variance two-sample t-test (Matlab default 'Vartype'='equal')."""
    g1 = np.asarray(g1, dtype=np.float64)
    g2 = np.asarray(g2, dtype=np.float64)
    n1, n2 = len(g1), len(g2)
    if n1 < 2 or n2 < 2:
        raise ValueError("need at least 2 samples per group")
    m1, m2 = g1.mean(), g2.mean()
    v1, v2 = g1.var(ddof=1), g2.var(ddof=1)
    df = n1 + n2 - 2
    sp2 = ((n1 - 1) * v1 + (n2 - 1) * v2) / df
    denom = math.sqrt(sp2 * (1.0 / n1 + 1.0 / n2))
    if denom == 0.0:
        t = 0.0 if m1 == m2 else math.copysign(math.inf, m1 - m2)
    else:
        t = (m1 - m2) / denom
    cdf = t_cdf(t, df) if math.isfinite(t) else (1.0 if t > 0 else 0.0)
    return TTestResult(
        t=t,
        df=df,
        p_two=2.0 * min(cdf, 1.0 - cdf),
        p_right=1.0 - cdf,
        p_left=cdf,
    )


def outperforms(g1, g2, alpha: float = 0.05) -> tuple[int, int]:
    """Paper Table 2 convention: returns (right_h, left_h) for groups (G1, G2).

    G2 'outperforms' G1 iff right-tailed h == 0 and left-tailed h == 1
    (i.e. we cannot claim mu1 > mu2, and we can claim mu1 < mu2).
    """
    r = ttest2(g1, g2)
    return r.reject("right", alpha), r.reject("left", alpha)
