"""Memory-packing cost models: Xilinx BRAM18 (the paper's) and TPU VMEM (ours).

The paper (Sec. 7.2.1) counts BRAM18 primitives for 32-bit entries as

    #BRAM = 2^(ceil(log2 M_F) - 10)            [address-space allocation, depth 1024]

i.e. the synthesized address decoder allocates a power-of-two address space.  We
reproduce that formula exactly (``bram_count``) plus a generic width-aware variant
(``bram_count_packed``) for the paper's other configurations (16384x1 ... 512x36).

The TPU-side analogue (``vmem_cost``) reports the bytes a Pallas kernel must hold
resident in VMEM: packed table + selector metadata, rounded up to 512-byte sublane
multiples, against a configurable VMEM budget.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

# BRAM18 capacity by entry width (Xilinx 7-series, UG473): width -> depth
BRAM18_DEPTH = {1: 16384, 2: 8192, 4: 4096, 9: 2048, 18: 1024, 36: 512}

# The paper treats 32-bit entries as depth-1024 (width rounded up to 36 would give 512;
# the text explicitly states 1024 entries of 32 bits and uses the 2^(ceil..-10) formula).
PAPER_DEPTH_32BIT = 1024

VMEM_BYTES_V5E = 16 * 1024 * 1024  # per-core VMEM budget used by the packing report
VMEM_SUBLANE_BYTES = 512


def bram_count(footprint: int, width_bits: int = 32) -> int:
    """Paper formula: power-of-two address-space allocation at depth 1024 (32-bit)."""
    if footprint <= 0:
        raise ValueError("footprint must be positive")
    if width_bits != 32:
        return bram_count_packed(footprint, width_bits)
    addr_bits = max(10, math.ceil(math.log2(footprint)))
    return 2 ** (addr_bits - 10)


def bram_count_packed(footprint: int, width_bits: int) -> int:
    """Generic ceil-packing across BRAM18 width configurations (no address rounding)."""
    if footprint <= 0:
        raise ValueError("footprint must be positive")
    widths = sorted(BRAM18_DEPTH)
    for w in widths:
        if width_bits <= w:
            return math.ceil(footprint / BRAM18_DEPTH[w])
    # wider than 36 bits: split into 36-bit slices
    slices = math.ceil(width_bits / 36)
    return slices * math.ceil(footprint / BRAM18_DEPTH[36])


@dataclass(frozen=True)
class VmemCost:
    table_bytes: int
    meta_bytes: int
    padded_bytes: int
    budget_bytes: int

    @property
    def fraction(self) -> float:
        return self.padded_bytes / self.budget_bytes


def vmem_cost(
    footprint: int,
    n_intervals: int,
    dtype_bytes: int = 4,
    budget_bytes: int = VMEM_BYTES_V5E,
) -> VmemCost:
    """VMEM residency of a TableSpec inside the Pallas kernel."""
    table = footprint * dtype_bytes
    # boundaries (n+1), inv_delta (n), base (n), seg_count (n) lanes; metadata
    # is pinned as f32 whatever the entry width (agrees with memory_bytes).
    meta = (4 * n_intervals + 1) * 4
    pad = VMEM_SUBLANE_BYTES
    padded = math.ceil((table + meta) / pad) * pad
    return VmemCost(table, meta, padded, budget_bytes)


def vmem_cost_pack(
    footprints,
    n_intervals,
    dtype_bytes=4,
    budget_bytes: int = VMEM_BYTES_V5E,
    *,
    meta_lanes: int = 4,
    ragged_meta: bool = False,
) -> VmemCost:
    """VMEM residency of a multi-function TablePack inside the fused kernel.

    The pack concatenates every function's values into one vector; one pack
    replaces F separate (table + metadata) residencies and F kernel dispatches.

    ``dtype_bytes`` is the entry width — a scalar, or one width per member
    function for mixed-precision packs (QuantPack stores int8 and int16 codes
    side by side; metadata stays f32 regardless).  ``meta_lanes`` counts the
    per-sub-interval f32 metadata lanes: 4 for the f32 pack (boundaries,
    inv_delta, base, seg_count), 7 for QuantPack (+ scale, zero, ramp), and a
    per-member list for PolyPack (4 + 3 * (degree + 1) lanes vary with the
    member's interpolation degree; requires ``ragged_meta=True``).

    ``ragged_meta=False`` models :class:`PackLayout`'s padded (F, n_max)
    planes — the metadata cost is set by the WIDEST member, not the sum of
    per-function pinnings.  ``ragged_meta=True`` models QuantPack's flat
    concatenated lanes: ``sum_f (meta_lanes * n_f + 1)`` f32 entries, no
    padding waste (static fn_id offsets make raggedness free in the kernel).
    """
    footprints = list(footprints)
    n_list = list(n_intervals)
    if len(footprints) != len(n_list) or not footprints:
        raise ValueError("need one footprint and n_intervals per packed function")
    if isinstance(dtype_bytes, int):
        dtype_list = [dtype_bytes] * len(footprints)
    else:
        dtype_list = list(dtype_bytes)
        if len(dtype_list) != len(footprints):
            raise ValueError("need one dtype_bytes per packed function")
    table = sum(m * db for m, db in zip(footprints, dtype_list))
    if isinstance(meta_lanes, int):
        lanes_list = [meta_lanes] * len(footprints)
    else:
        lanes_list = list(meta_lanes)
        if len(lanes_list) != len(footprints):
            raise ValueError("need one meta_lanes per packed function")
        if not ragged_meta:
            raise ValueError("per-member meta_lanes requires ragged_meta=True")
    if ragged_meta:
        meta = sum((ml * n + 1) * 4 for ml, n in zip(lanes_list, n_list))
    else:
        n_max = max(n_list)
        meta = len(footprints) * (lanes_list[0] * n_max + 1) * 4  # pinned f32
    pad = VMEM_SUBLANE_BYTES
    padded = math.ceil((table + meta) / pad) * pad
    return VmemCost(table, meta, padded, budget_bytes)
