"""Fixed-point (S, W, F) formats — the paper's I/O number representation.

The hardware consumes/produces fixed-point bit vectors described by tuples
``(S, W, F)``: sign bit, total width, fractional bits (Sec. 6/7.1, Table 3).
The design flow uses this module to (a) quantize stored table values the way the
BRAM would hold them and (b) budget the quantization error against ``E_a`` in the
fidelity benchmarks.  Runtime TPU kernels use float — this module exists for
paper-faithful accounting, not the hot path.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class FixedPointFormat:
    signed: int  # S: 1 if a sign bit is present
    width: int  # W: total bits
    frac: int  # F: fractional bits

    def __post_init__(self):
        if self.signed not in (0, 1):
            raise ValueError("S must be 0 or 1")
        if self.width <= 0 or self.frac < 0:
            raise ValueError("bad (W, F)")
        if self.frac > self.width - self.signed:
            raise ValueError("F exceeds available magnitude bits")

    @property
    def scale(self) -> float:
        return float(2.0 ** self.frac)

    @property
    def resolution(self) -> float:
        return float(2.0 ** (-self.frac))

    @property
    def max_value(self) -> float:
        int_levels = 2 ** (self.width - self.signed)
        return (int_levels - 1) * self.resolution

    @property
    def min_value(self) -> float:
        if not self.signed:
            return 0.0
        return -(2.0 ** (self.width - 1 - self.frac))

    def quantize(self, x: np.ndarray) -> np.ndarray:
        """Round-to-nearest-even quantization with saturation (hardware clamp)."""
        x = np.asarray(x, dtype=np.float64)
        q = np.rint(x * self.scale) / self.scale
        return np.clip(q, self.min_value, self.max_value)

    def quantization_error_bound(self) -> float:
        """Half-ULP rounding bound inside the representable range."""
        return 0.5 * self.resolution

    def to_bits(self, x: np.ndarray) -> np.ndarray:
        """Two's-complement integer codes (for bit-exactness tests)."""
        q = self.quantize(x)
        codes = np.rint(q * self.scale).astype(np.int64)
        if self.signed:
            codes = codes & ((1 << self.width) - 1)
        return codes

    def from_bits(self, codes: np.ndarray) -> np.ndarray:
        codes = np.asarray(codes, dtype=np.int64)
        if self.signed:
            sign_bit = 1 << (self.width - 1)
            codes = np.where(codes & sign_bit, codes - (1 << self.width), codes)
        return codes.astype(np.float64) * self.resolution


# Table 3 I/O formats, keyed by function name: (input fmt, output fmt)
PAPER_FORMATS = {
    "tan": (FixedPointFormat(1, 32, 30), FixedPointFormat(1, 32, 27)),
    "log": (FixedPointFormat(0, 32, 28), FixedPointFormat(1, 32, 29)),
    "exp": (FixedPointFormat(0, 32, 29), FixedPointFormat(0, 32, 24)),
    "tanh": (FixedPointFormat(1, 32, 27), FixedPointFormat(1, 32, 31)),
    "gauss": (FixedPointFormat(1, 32, 28), FixedPointFormat(1, 32, 32 - 1)),  # see note
    "sigmoid": (FixedPointFormat(1, 32, 27), FixedPointFormat(0, 32, 32)),
}
# Note: Table 3 prints (1,32,32) for gauss output — 33 bits of sign+frac in a 32-bit
# word, impossible; we use F=31 and flag the erratum in EXPERIMENTS.md.
