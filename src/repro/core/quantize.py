"""Quantized table storage: fixed-point I/O formats and QuantPack entry codes.

Two layers live here:

1. **Fixed-point (S, W, F) formats** — the paper's I/O number representation.
   The hardware consumes/produces fixed-point bit vectors described by tuples
   ``(S, W, F)``: sign bit, total width, fractional bits (Sec. 6/7.1, Table 3).
   The design flow uses this to (a) quantize stored table values the way the
   BRAM would hold them and (b) budget the quantization error against ``E_a``
   in the fidelity benchmarks.  Paper-faithful accounting, not the hot path.

2. **Error-budgeted entry quantization for the runtime (QuantPack)** — the
   stored breakpoint values of an interval-split table are replaced by int8 /
   int16 codes that the kernel dequantizes on read.  The user's bound ``E_a``
   is split ``rho * E_a`` for interpolation (the table is built with the
   tightened bound by the existing splitting algorithms) and ``(1-rho) * E_a``
   for code rounding.  Per sub-interval the codes are affine in a **chord
   residual**: with ramp slope ``g_j = (v_last - v_first) / n_seg``,

       v_i  ~=  zero_j + g_j * i + scale_j * q_i ,      q_i at b bits

   i.e. the code stores only the deviation of ``f`` from the straight line
   across the sub-interval.  Since linear interpolation is a convex
   combination of two dequantized endpoints, the read-back error is bounded by
   ``scale_j / 2 <= (1 - rho) * E_a`` and the end-to-end bound still holds.

   Wide near-linear sub-intervals (where the splitter uses one huge
   sub-interval) have chord residuals far exceeding the rounding budget at
   int8; :func:`refine_for_quantization` therefore *re-splits* the partition
   at existing breakpoints — interval splitting applied a second time, for the
   quantization axis.  A bisection at a breakpoint reuses the same spacing
   ``delta_j`` (the Eq. 10 interpolation guarantee is untouched) but shrinks
   the chord residual ~4x per cut, so the minimal storage width per member
   function is reached after O(log) cuts.  ``plan_quant_member`` searches
   {int8, int16} x refinement and picks the cheapest feasible encoding.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

import numpy as np

from repro import obs

from .functions import FunctionSpec, get as get_function
from .table import TableSpec


@dataclass(frozen=True)
class FixedPointFormat:
    signed: int  # S: 1 if a sign bit is present
    width: int  # W: total bits
    frac: int  # F: fractional bits

    def __post_init__(self):
        if self.signed not in (0, 1):
            raise ValueError("S must be 0 or 1")
        if self.width <= 0 or self.frac < 0:
            raise ValueError("bad (W, F)")
        if self.frac > self.width - self.signed:
            raise ValueError("F exceeds available magnitude bits")

    @property
    def scale(self) -> float:
        return float(2.0 ** self.frac)

    @property
    def resolution(self) -> float:
        return float(2.0 ** (-self.frac))

    @property
    def max_value(self) -> float:
        int_levels = 2 ** (self.width - self.signed)
        return (int_levels - 1) * self.resolution

    @property
    def min_value(self) -> float:
        if not self.signed:
            return 0.0
        return -(2.0 ** (self.width - 1 - self.frac))

    def quantize(self, x: np.ndarray) -> np.ndarray:
        """Round-to-nearest-even quantization with saturation (hardware clamp)."""
        x = np.asarray(x, dtype=np.float64)
        q = np.rint(x * self.scale) / self.scale
        return np.clip(q, self.min_value, self.max_value)

    def quantization_error_bound(self) -> float:
        """Half-ULP rounding bound inside the representable range."""
        return 0.5 * self.resolution

    def to_bits(self, x: np.ndarray) -> np.ndarray:
        """Two's-complement integer codes (for bit-exactness tests)."""
        q = self.quantize(x)
        codes = np.rint(q * self.scale).astype(np.int64)
        if self.signed:
            codes = codes & ((1 << self.width) - 1)
        return codes

    def from_bits(self, codes: np.ndarray) -> np.ndarray:
        codes = np.asarray(codes, dtype=np.int64)
        if self.signed:
            sign_bit = 1 << (self.width - 1)
            codes = np.where(codes & sign_bit, codes - (1 << self.width), codes)
        return codes.astype(np.float64) * self.resolution


# Table 3 I/O formats, keyed by function name: (input fmt, output fmt)
PAPER_FORMATS = {
    "tan": (FixedPointFormat(1, 32, 30), FixedPointFormat(1, 32, 27)),
    "log": (FixedPointFormat(0, 32, 28), FixedPointFormat(1, 32, 29)),
    "exp": (FixedPointFormat(0, 32, 29), FixedPointFormat(0, 32, 24)),
    "tanh": (FixedPointFormat(1, 32, 27), FixedPointFormat(1, 32, 31)),
    "gauss": (FixedPointFormat(1, 32, 28), FixedPointFormat(1, 32, 32 - 1)),  # see note
    "sigmoid": (FixedPointFormat(1, 32, 27), FixedPointFormat(0, 32, 32)),
}
# Note: Table 3 prints (1,32,32) for gauss output — 33 bits of sign+frac in a 32-bit
# word, impossible; we use F=31 and flag the erratum in EXPERIMENTS.md.


# --------------------------------------------------------------------------------------
# QuantPack entry quantization: error-budget split + chord-residual affine codes.
# --------------------------------------------------------------------------------------

QUANT_INT_BITS = (8, 16)  # runtime storage menu (TPU-friendly byte widths)
DEFAULT_RHO = 0.9  # interpolation share of E_a; rounding gets the remaining 10 %
DEFAULT_REFINE_CAP = 2048  # max sub-intervals per function after refinement


def quant_rounding_limit(tol: float, bits: int) -> float:
    """Largest per-sub-interval residual range representable at ``bits`` with
    rounding error <= tol: range / (2^b - 1) / 2 <= tol."""
    return 2.0 * tol * (2**bits - 1)


def _sub_slices(spec: TableSpec):
    counts = np.diff(np.concatenate([spec.base, [spec.footprint]]))
    return [(int(spec.base[j]), int(spec.base[j] + counts[j]))
            for j in range(spec.n_intervals)]


def _chord_residual(values: np.ndarray) -> np.ndarray:
    """Deviation of the entries from the straight line through the endpoints."""
    k = len(values)
    if k <= 2:
        return np.zeros(k)
    ramp = values[0] + (values[-1] - values[0]) * np.arange(k) / (k - 1)
    return values - ramp


def chord_residual_ranges(spec: TableSpec) -> np.ndarray:
    """Per-sub-interval chord-residual range — what the affine codes must span."""
    out = np.zeros(spec.n_intervals)
    for j, (s0, s1) in enumerate(_sub_slices(spec)):
        r = _chord_residual(spec.values[s0:s1])
        out[j] = r.max() - r.min()
    return out


@obs.traced("design.verify_refine", "design")
def refine_for_quantization(
    spec: TableSpec, limit: float, cap: int = DEFAULT_REFINE_CAP
) -> TableSpec:
    """Re-split sub-intervals at existing breakpoints until every chord-residual
    range is <= ``limit`` (or every sub-interval is a single segment).

    Cuts land on the segment grid, so both halves keep the parent's ``delta``
    and the Eq. 10 interpolation bound; the evaluated piecewise-linear function
    is unchanged.  Each cut duplicates ONE shared breakpoint entry (the halves
    quantize it under different affine params), i.e. footprint grows by exactly
    the number of cuts, while the residual of the worst half shrinks ~4x
    (residual ~ max|f''| * len^2).  A 1-segment sub-interval has zero residual,
    so the loop always terminates.
    """
    if limit < 0:
        raise ValueError("limit must be non-negative")
    # heap of (-residual_range, j, seg_lo, seg_hi) in parent segment units
    heap = []
    for j, (s0, s1) in enumerate(_sub_slices(spec)):
        r = _chord_residual(spec.values[s0:s1])
        heapq.heappush(heap, (-(r.max() - r.min()), j, 0, s1 - s0 - 1))
    while len(heap) < cap:
        neg, j, a, b = heap[0]
        if -neg <= limit or b - a < 2:
            break
        heapq.heappop(heap)
        s0 = int(spec.base[j])
        m = (a + b) // 2
        for lo_seg, hi_seg in ((a, m), (m, b)):
            r = _chord_residual(spec.values[s0 + lo_seg : s0 + hi_seg + 1])
            heapq.heappush(heap, (-(r.max() - r.min()), j, lo_seg, hi_seg))
    subs = sorted((j, a, b) for _, j, a, b in heap)
    if len(subs) == spec.n_intervals:
        return spec  # nothing to refine
    boundaries, deltas, bases, segs, values = [], [], [], [], []
    acc = 0
    for j, a, b in subs:
        s0 = int(spec.base[j])
        d = float(spec.delta[j])
        p0 = float(spec.boundaries[j])
        # exact parent boundaries where the cut coincides with one
        boundaries.append(p0 if a == 0 else p0 + a * d)
        deltas.append(d)
        bases.append(acc)
        segs.append(b - a)
        values.append(spec.values[s0 + a : s0 + b + 1])
        acc += b - a + 1
    boundaries.append(float(spec.boundaries[-1]))
    return TableSpec(
        name=spec.name,
        lo=spec.lo,
        hi=spec.hi,
        e_a=spec.e_a,
        algorithm=spec.algorithm,
        boundaries=np.asarray(boundaries, dtype=np.float64),
        inv_delta=1.0 / np.asarray(deltas, dtype=np.float64),
        delta=np.asarray(deltas, dtype=np.float64),
        base=np.asarray(bases, dtype=np.int64),
        seg_count=np.asarray(segs, dtype=np.int64),
        values=np.concatenate(values),
    )


@dataclass(frozen=True)
class QuantMember:
    """One function's table with int-coded entries (the QuantPack member artifact).

    Dequantization (the kernel's read path, all f32 at runtime):

        v_i = zero_j + ramp_j * i + scale_j * q_i

    ``q`` holds signed two's-complement codes (int8/int16 storage); ``scale_j``
    is 0 for exactly-linear sub-intervals (the ramp already reproduces them).
    """

    spec: TableSpec  # refined: same piecewise-linear fn, quantization-split
    bits: int  # 8 or 16 — storage width of every code of this member
    rho: float  # interpolation share of e_a the table was built with
    e_a: float  # end-to-end budget (interp + rounding)
    codes: np.ndarray  # (M,) i64 signed codes in [-2^(b-1), 2^(b-1)-1]
    scale: np.ndarray  # (n,) f64 per sub-interval
    zero: np.ndarray  # (n,) f64 per sub-interval
    ramp: np.ndarray  # (n,) f64 per sub-interval chord slope per segment

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def footprint(self) -> int:
        return self.spec.footprint

    @property
    def codes_bytes(self) -> int:
        return self.footprint * (self.bits // 8)

    @property
    def meta_bytes(self) -> int:
        """Selector + dequant metadata, f32 lanes: boundaries (n+1) plus
        inv_delta/base/seg_count/scale/zero/ramp (n each)."""
        n = self.spec.n_intervals
        return (7 * n + 1) * 4

    def dequantize(self) -> np.ndarray:
        """Reconstructed f64 entry values (|v - spec.values| <= scale/2)."""
        out = np.empty(self.footprint)
        for j, (s0, s1) in enumerate(_sub_slices(self.spec)):
            i = np.arange(s1 - s0)
            out[s0:s1] = (self.zero[j] + self.ramp[j] * i
                          + self.scale[j] * self.codes[s0:s1])
        return out

    def eval(self, x: np.ndarray) -> np.ndarray:
        """f64 dequantize-on-read oracle (selector + ramp/scale FMA + lerp)."""
        ts = self.spec
        x = np.asarray(x, dtype=np.float64)
        j = np.clip(np.searchsorted(ts.boundaries, x, side="right") - 1,
                    0, ts.n_intervals - 1)
        p_j = ts.boundaries[j]
        i = np.clip(np.floor((x - p_j) * ts.inv_delta[j]).astype(np.int64),
                    0, ts.seg_count[j] - 1)
        a = ts.base[j] + i
        r = self.zero[j] + self.ramp[j] * i
        y0 = r + self.scale[j] * self.codes[a]
        y1 = r + self.ramp[j] + self.scale[j] * self.codes[a + 1]
        t = np.clip((x - (p_j + i * ts.delta[j])) * ts.inv_delta[j], 0.0, 1.0)
        return y0 + t * (y1 - y0)

    def max_error_on_grid(self, fn: Optional[FunctionSpec] = None,
                          n: int = 100_001) -> float:
        fn = fn or get_function(self.spec.name)
        xs = np.linspace(self.spec.lo, self.spec.hi, n)
        xs = xs[xs < self.spec.hi]
        return float(np.max(np.abs(self.eval(xs) - np.asarray(fn.f(xs)))))


def quantize_spec(spec: TableSpec, tol: float, bits: int, *,
                  rho: float, e_a: float) -> QuantMember:
    """Chord-residual affine quantization of (an already refined) table at
    ``bits``; every sub-interval's residual range must fit the rounding budget."""
    if bits not in QUANT_INT_BITS:
        raise ValueError(f"bits must be one of {QUANT_INT_BITS}")
    levels = 2**bits - 1
    offset = 2 ** (bits - 1)
    n = spec.n_intervals
    codes = np.zeros(spec.footprint, dtype=np.int64)
    scale = np.zeros(n)
    zero = np.zeros(n)
    ramp = np.zeros(n)
    for j, (s0, s1) in enumerate(_sub_slices(spec)):
        v = spec.values[s0:s1]
        n_seg = s1 - s0 - 1
        g = (v[-1] - v[0]) / n_seg
        resid = _chord_residual(v)
        rmin, rmax = float(resid.min()), float(resid.max())
        rng = rmax - rmin
        if rng > quant_rounding_limit(tol, bits) * (1 + 1e-12):
            raise ValueError(
                f"{spec.name!r} sub-interval {j}: residual range {rng:.3e} "
                f"exceeds the int{bits} rounding budget "
                f"{quant_rounding_limit(tol, bits):.3e}; refine first")
        if rng > 0.0:
            s = rng / levels
            q = np.clip(np.rint((resid - rmin) / s), 0, levels) - offset
            z = v[0] + rmin + s * offset
        else:  # exactly linear: the ramp reproduces the entries, codes unused
            s, q, z = 0.0, np.zeros(s1 - s0), v[0]
        codes[s0:s1] = q.astype(np.int64)
        scale[j], zero[j], ramp[j] = s, z, g
    return QuantMember(spec=spec, bits=bits, rho=rho, e_a=e_a, codes=codes,
                       scale=scale, zero=zero, ramp=ramp)


def plan_quant_member(
    fn: FunctionSpec | str,
    e_a: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    *,
    rho: float = DEFAULT_RHO,
    dtype: str = "auto",
    cap: int = DEFAULT_REFINE_CAP,
    degree: int = 1,
    budget_bytes: Optional[int] = None,
) -> QuantMember:
    """The error-budget splitter: build the table at ``rho * e_a`` with the
    existing splitting algorithms, then pick the cheapest storage width whose
    rounding error fits the remaining ``(1 - rho) * e_a``.

    ``degree``/``budget_bytes`` hand the plan to the unified design-space
    planner (``core.design``): ``degree > 1`` or a byte budget returns the
    planner's cheapest feasible :class:`~repro.core.design.PolyMember` for
    this function instead of a linear :class:`QuantMember` — same memo table,
    wider key.  ``dtype`` still restricts the storage-width menu there.

    ``dtype='auto'`` tries int8 and int16 (each with its own quantization
    refinement) and keeps the one minimizing ENTRY-STORAGE bytes, tie-broken
    by metadata bytes — the paper's M_F footprint axis.  The objective
    knowingly pays ~28 B of metadata per refinement cut to halve every stored
    code, so at loose budgets an int8 member's TOTAL bytes (codes + meta) can
    exceed int16's; force ``dtype='int16'`` when total VMEM residency is the
    binding constraint (the kernel_bench report shows both ratios).

    Registry-name plans are memoized process-wide (the ``cached_table``
    idiom): the refinement search is the expensive half of building a
    quantized pack, and packs/tests re-request the same members.
    """
    if isinstance(fn, str):
        return _plan_cached(fn, e_a, lo, hi, algorithm, omega, rho, dtype,
                            cap, degree, budget_bytes)
    return _plan(fn, e_a, lo, hi, algorithm, omega, rho, dtype, cap,
                 degree, budget_bytes)


@lru_cache(maxsize=256)
@obs.traced("design.quantize", "design")
def _plan_cached(name, e_a, lo, hi, algorithm, omega, rho, dtype, cap,
                 degree=1, budget_bytes=None):
    return _plan(name, e_a, lo, hi, algorithm, omega, rho, dtype, cap,
                 degree, budget_bytes)


def _plan(fn, e_a, lo, hi, algorithm, omega, rho, dtype, cap,
          degree=1, budget_bytes=None) -> QuantMember:
    if degree != 1 or budget_bytes is not None:
        # the unified planner owns the widened design space (deferred import:
        # design imports this module's budget helpers at module level)
        from . import design

        name = fn if isinstance(fn, str) else fn.name
        dtypes = design.POLY_DTYPES if dtype == "auto" else (
            {"int8": ("int8",), "int16": ("int16",)}[dtype])
        cands = design.enumerate_candidates(
            name, e_a, degrees=(degree,) if degree != 1 else design.POLY_DEGREES,
            dtypes=dtypes, algorithm=algorithm, omega=omega, rho=rho, cap=cap,
            lo=lo, hi=hi)
        best = min(cands, key=design._auto_key)
        if budget_bytes is not None and best.total_bytes > budget_bytes:
            raise ValueError(
                f"member budget {budget_bytes} B infeasible for {name!r}: "
                f"cheapest candidate needs {best.total_bytes} B")
        return best.member
    if not (0.0 < rho < 1.0):
        raise ValueError("rho must be in (0, 1)")
    if dtype not in ("auto", "int8", "int16"):
        raise ValueError(f"dtype must be auto|int8|int16, got {dtype!r}")
    from .flow import cached_table  # deferred: flow imports table/bram only

    name = fn if isinstance(fn, str) else fn.name
    base = cached_table(name, rho * e_a, lo, hi, algorithm=algorithm,
                        omega=omega)
    tol = (1.0 - rho) * e_a
    menu = QUANT_INT_BITS if dtype == "auto" else (int(dtype[3:]),)
    candidates = []
    for bits in menu:
        refined = refine_for_quantization(
            base, quant_rounding_limit(tol, bits), cap=cap)
        if chord_residual_ranges(refined).max(initial=0.0) > \
                quant_rounding_limit(tol, bits):
            continue  # cap hit before the width became feasible
        member = quantize_spec(refined, tol, bits, rho=rho, e_a=e_a)
        candidates.append(
            ((member.codes_bytes, member.meta_bytes), bits, member))
    if not candidates:
        raise ValueError(
            f"no feasible quantization for {name!r} at e_a={e_a:g}, rho={rho}, "
            f"dtype={dtype!r} within the {cap}-sub-interval refinement cap; "
            f"lower rho (more rounding budget) or raise the cap")
    candidates.sort(key=lambda c: (c[0], c[1]))
    return candidates[0][2]
