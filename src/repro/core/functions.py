"""Registry of target functions f(x) with analytic first/second derivatives.

The paper's spacing rule (Eq. 11) needs ``max |f''|`` over a sub-interval, so every
registered function carries a closed-form second derivative.  Callables are written
against the ``numpy`` namespace by default (the design flow is offline) but accept any
array namespace via the ``xp`` argument so the same formulas run under ``jax.numpy``
for the runtime oracles.

The six benchmark functions of the paper (Tables 2/3) are registered with the paper's
intervals; additional ML nonlinearities (gelu, silu, softplus, erf) extend the registry
for the framework integration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import numpy as np

Array = np.ndarray
FnOfX = Callable[..., Array]

_SQRT_2PI = math.sqrt(2.0 * math.pi)
_SQRT_2 = math.sqrt(2.0)
_INV_SQRT_PI = 1.0 / math.sqrt(math.pi)


def _phi(x, xp):
    """Standard normal pdf."""
    return xp.exp(-0.5 * x * x) / _SQRT_2PI


def _sigmoid(x, xp):
    # Numerically-stable logistic.
    return xp.where(x >= 0, 1.0 / (1.0 + xp.exp(-x)), xp.exp(x) / (1.0 + xp.exp(x)))


def _erf(x, xp):
    if xp is np:
        return np.vectorize(math.erf)(np.asarray(x, dtype=np.float64))
    from jax.scipy.special import erf as jerf  # lazy: core stays numpy-importable

    return jerf(x)


@dataclass(frozen=True)
class FunctionSpec:
    """A target function with analytic derivatives and a default approximation interval."""

    name: str
    f: FnOfX
    d2f: FnOfX  # second derivative (signed)
    interval: Tuple[float, float]  # paper/default interval [x0, x0 + a)
    d1f: FnOfX | None = None  # first derivative (for exact-grad mode)
    # |f''| monotonicity over typical intervals: one of {"none", "increasing",
    # "decreasing"}; "none" forces a grid max. Pure metadata fast-path hint.
    abs_d2_monotone: str = "none"
    notes: str = ""

    def max_abs_d2(self, lo: float, hi: float, grid: int = 4097) -> float:
        """max over [lo, hi] of |f''| — monotone fast path, else dense grid + endpoints."""
        if hi <= lo:
            raise ValueError(f"empty interval [{lo}, {hi})")
        d2 = self.d2f
        if self.abs_d2_monotone == "increasing":
            return float(abs(d2(np.asarray(hi))))
        if self.abs_d2_monotone == "decreasing":
            return float(abs(d2(np.asarray(lo))))
        xs = np.linspace(lo, hi, grid)
        return float(np.max(np.abs(d2(xs))))


_REGISTRY: Dict[str, FunctionSpec] = {}


def register(spec: FunctionSpec) -> FunctionSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"duplicate function spec {spec.name!r}")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> FunctionSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown function {name!r}; known: {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------------------
# The paper's six benchmark functions (Table 2 intervals).
# --------------------------------------------------------------------------------------

register(
    FunctionSpec(
        name="log",
        f=lambda x, xp=np: xp.log(x),
        d1f=lambda x, xp=np: 1.0 / x,
        d2f=lambda x, xp=np: -1.0 / (x * x),
        interval=(0.625, 15.625),
        abs_d2_monotone="decreasing",  # |f''| = 1/x^2 decreasing for x>0
        notes="paper Fig.3-5 exemplar",
    )
)

register(
    FunctionSpec(
        name="exp",
        f=lambda x, xp=np: xp.exp(x),
        d1f=lambda x, xp=np: xp.exp(x),
        d2f=lambda x, xp=np: xp.exp(x),
        interval=(0.0, 5.0),
        abs_d2_monotone="increasing",
        notes="paper Table 2",
    )
)

register(
    FunctionSpec(
        name="tan",
        f=lambda x, xp=np: xp.tan(x),
        d1f=lambda x, xp=np: 1.0 + xp.tan(x) ** 2,
        # f'' = 2 tan(x) sec^2(x) = 2 t (1 + t^2)
        d2f=lambda x, xp=np: 2.0 * xp.tan(x) * (1.0 + xp.tan(x) ** 2),
        interval=(-1.5, 0.0),
        abs_d2_monotone="none",  # |f''| decreasing on [-1.5,0) but Table 3 uses [-1.5,1.5)
        notes="paper Table 2 uses [-1.5,0), Table 3 [-1.5,1.5)",
    )
)

register(
    FunctionSpec(
        name="tanh",
        f=lambda x, xp=np: xp.tanh(x),
        d1f=lambda x, xp=np: 1.0 - xp.tanh(x) ** 2,
        # f'' = -2 t (1 - t^2)
        d2f=lambda x, xp=np: -2.0 * xp.tanh(x) * (1.0 - xp.tanh(x) ** 2),
        interval=(-8.0, 0.0),
        notes="paper Table 2 uses [-8,0), Table 3 [-8,8)",
    )
)

register(
    FunctionSpec(
        name="sigmoid",
        f=lambda x, xp=np: _sigmoid(x, xp),
        d1f=lambda x, xp=np: _sigmoid(x, xp) * (1.0 - _sigmoid(x, xp)),
        # f'' = s(1-s)(1-2s)
        d2f=lambda x, xp=np: (
            _sigmoid(x, xp) * (1.0 - _sigmoid(x, xp)) * (1.0 - 2.0 * _sigmoid(x, xp))
        ),
        interval=(-10.0, 0.0),
        notes="paper writes 1/(1+e^-x) in Table 2 ([-10,0)) and 1/(1+e^x) in Table 3",
    )
)

register(
    FunctionSpec(
        name="gauss",
        f=lambda x, xp=np: xp.exp(-0.5 * x * x),
        d1f=lambda x, xp=np: -x * xp.exp(-0.5 * x * x),
        # f'' = (x^2 - 1) e^{-x^2/2}
        d2f=lambda x, xp=np: (x * x - 1.0) * xp.exp(-0.5 * x * x),
        interval=(-6.0, 0.0),
        notes="paper Table 2 uses [-6,0), Table 3 [-6,6)",
    )
)

# --------------------------------------------------------------------------------------
# Framework nonlinearities (beyond the paper's benchmark set).
# --------------------------------------------------------------------------------------

register(
    FunctionSpec(
        name="gelu",
        # exact (erf) GELU: x * Phi(x)
        f=lambda x, xp=np: x * 0.5 * (1.0 + _erf(x / _SQRT_2, xp)),
        d1f=lambda x, xp=np: 0.5 * (1.0 + _erf(x / _SQRT_2, xp)) + x * _phi(x, xp),
        # f'' = phi(x) (2 - x^2)
        d2f=lambda x, xp=np: _phi(x, xp) * (2.0 - x * x),
        interval=(-8.0, 8.0),
    )
)

register(
    FunctionSpec(
        name="silu",
        f=lambda x, xp=np: x * _sigmoid(x, xp),
        d1f=lambda x, xp=np: _sigmoid(x, xp)
        + x * _sigmoid(x, xp) * (1.0 - _sigmoid(x, xp)),
        # f'' = 2 s(1-s) + x s(1-s)(1-2s)
        d2f=lambda x, xp=np: (
            2.0 * _sigmoid(x, xp) * (1.0 - _sigmoid(x, xp))
            + x
            * _sigmoid(x, xp)
            * (1.0 - _sigmoid(x, xp))
            * (1.0 - 2.0 * _sigmoid(x, xp))
        ),
        interval=(-10.0, 10.0),
    )
)

register(
    FunctionSpec(
        name="softplus",
        f=lambda x, xp=np: xp.where(
            x > 20.0, x, xp.log1p(xp.exp(xp.minimum(x, 20.0)))
        ),
        d1f=lambda x, xp=np: _sigmoid(x, xp),
        d2f=lambda x, xp=np: _sigmoid(x, xp) * (1.0 - _sigmoid(x, xp)),
        interval=(-10.0, 10.0),
    )
)

register(
    FunctionSpec(
        name="erf",
        f=lambda x, xp=np: _erf(x, xp),
        d1f=lambda x, xp=np: 2.0 * _INV_SQRT_PI * xp.exp(-x * x),
        d2f=lambda x, xp=np: -4.0 * x * _INV_SQRT_PI * xp.exp(-x * x),
        interval=(-4.0, 4.0),
    )
)

# exp over a negative shifted domain: the softmax backend (exp(x - max) with x-max <= 0).
register(
    FunctionSpec(
        name="exp_neg",
        f=lambda x, xp=np: xp.exp(x),
        d1f=lambda x, xp=np: xp.exp(x),
        d2f=lambda x, xp=np: xp.exp(x),
        interval=(-16.0, 0.0),
        abs_d2_monotone="increasing",
        notes="softmax exponent domain after max-subtraction; clamp at -16 (exp=1.1e-7)",
    )
)


# Sigmoid over the symmetric interval used by gate activations in the model zoo.
register(
    FunctionSpec(
        name="sigmoid_sym",
        f=lambda x, xp=np: _sigmoid(x, xp),
        d1f=lambda x, xp=np: _sigmoid(x, xp) * (1.0 - _sigmoid(x, xp)),
        d2f=lambda x, xp=np: (
            _sigmoid(x, xp) * (1.0 - _sigmoid(x, xp)) * (1.0 - 2.0 * _sigmoid(x, xp))
        ),
        interval=(-12.0, 12.0),
        notes="gate sigmoid; clamp error at +/-12 is 6.1e-6",
    )
)


# --------------------------------------------------------------------------------------
# RangeFold members: full-period trig plus the canonical-interval cores the
# reduction stage (core.range_reduce) folds onto.  sin/cos also work as plain
# bounded-table members on one period; the *_core entries are what the folded
# modes actually look up after reduction.
# --------------------------------------------------------------------------------------

register(
    FunctionSpec(
        name="sin",
        f=lambda x, xp=np: xp.sin(x),
        d1f=lambda x, xp=np: xp.cos(x),
        d2f=lambda x, xp=np: -xp.sin(x),
        interval=(-3.14159265, 3.14159265),
        notes="one period as the bounded-table default; full f32 range via RangeFold",
    )
)

register(
    FunctionSpec(
        name="cos",
        f=lambda x, xp=np: xp.cos(x),
        d1f=lambda x, xp=np: -xp.sin(x),
        d2f=lambda x, xp=np: -xp.cos(x),
        interval=(-3.14159265, 3.14159265),
        notes="one period as the bounded-table default; full f32 range via RangeFold",
    )
)

register(
    FunctionSpec(
        name="sin_core",
        f=lambda x, xp=np: xp.sin(x),
        d1f=lambda x, xp=np: xp.cos(x),
        d2f=lambda x, xp=np: -xp.sin(x),
        interval=(-0.79, 0.79),
        notes="trig fold target: [-pi/4, pi/4] plus k-rounding guard band",
    )
)

register(
    FunctionSpec(
        name="cos_core",
        f=lambda x, xp=np: xp.cos(x),
        d1f=lambda x, xp=np: -xp.sin(x),
        d2f=lambda x, xp=np: -xp.cos(x),
        interval=(-0.79, 0.79),
        notes="trig fold target: [-pi/4, pi/4] plus k-rounding guard band",
    )
)

register(
    FunctionSpec(
        name="exp_core",
        f=lambda x, xp=np: xp.exp(x),
        d1f=lambda x, xp=np: xp.exp(x),
        d2f=lambda x, xp=np: xp.exp(x),
        interval=(-0.36, 0.36),
        abs_d2_monotone="increasing",
        notes="exp fold target: [-ln2/2, ln2/2] plus guard band; exp(x)=2^k*exp_core(r)",
    )
)

register(
    FunctionSpec(
        name="log_core",
        f=lambda x, xp=np: xp.log(x),
        d1f=lambda x, xp=np: 1.0 / x,
        d2f=lambda x, xp=np: -1.0 / (x * x),
        interval=(0.70, 1.42),
        abs_d2_monotone="decreasing",
        notes="log fold target: [sqrt2/2, sqrt2); log(x)=e*ln2+log_core(m)",
    )
)
