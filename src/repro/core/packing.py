"""Mixed-width quantized table packing — the paper's stated future work
("we want to explore more efficient packing of BRAMs", Sec. 8) implemented.

BRAM18 primitives reconfigure entry width (1/2/4/9/18/36 bits).  The paper
always stores 32-bit range values; but once the interval splitter has produced
sub-intervals, each sub-interval's value RANGE is narrow, so its entries can be
stored affinely quantized at a much smaller width:

    y_q = round((y - z_j) / s_j)            stored at b_j bits
    y   ~ z_j + s_j * y_q

Error budget: the interpolation bound gets rho*Ea (the table is built with the
tightened bound) and quantization gets (1-rho)*Ea; since lerp is a convex
combination, quantized-endpoint error <= s_j/2, so the minimal width satisfying

    s_j / 2 <= (1 - rho) * Ea,   s_j = (max_j - min_j) / (2^b_j - 1)

is chosen PER SUB-INTERVAL from the width menu.  Total footprint is
``sum_j kappa_j * b_j`` bits instead of ``32 * sum_j kappa_j``.

Measured (benchmarks/paper_figs.table3_packing): with arbitrary bitfield
packing, +30-37 % per-entry savings at the paper's Ea=9.5e-7 (21-23 required
bits) and +52-59 % at the framework's activation Ea=1e-4 (13-16 bits); combined
with interval splitting: 69-92 % total vs the 32-bit Reference table.  With the
PHYSICAL BRAM18 menu (1/2/4/9/18/36) the paper-Ea case rounds UP to 36 bits on
high-resolution sub-intervals — i.e. the paper's future work only pays off on
FPGAs below Ea~1e-5 resolution or with bitfield packing across BRAM ports; an
honest negative-at-tiny-Ea result.

The runtime analogue stores int16/int8 entries in VMEM with per-sub-interval
(scale, zero) in the selector metadata — one extra FMA after the gather.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from .functions import FunctionSpec, get as get_function
from .table import TableSpec, build_table

BRAM_WIDTHS = (1, 2, 4, 9, 18, 36)  # physical BRAM18 entry widths
INT_WIDTHS = (4, 8, 16, 32)  # TPU-friendly storage menu
PACKED_WIDTHS = tuple(range(1, 37))  # arbitrary-width bitfield packing


@dataclass(frozen=True)
class QuantizedTableSpec:
    """A TableSpec whose values are stored affinely quantized per sub-interval."""

    base: TableSpec
    q_values: np.ndarray  # (M_F,) int64 codes
    scale: np.ndarray  # (n,) f64 per sub-interval
    zero: np.ndarray  # (n,) f64 per sub-interval
    bits: np.ndarray  # (n,) i64 chosen width per sub-interval
    rho: float

    @property
    def footprint_bits(self) -> int:
        counts = np.diff(np.concatenate([self.base.base,
                                         [self.base.footprint]]))
        return int(np.sum(counts * self.bits))

    @property
    def footprint_bits_fp32(self) -> int:
        return 32 * self.base.footprint

    @property
    def bit_reduction(self) -> float:
        return 1.0 - self.footprint_bits / self.footprint_bits_fp32

    def eval(self, x: np.ndarray) -> np.ndarray:
        """Dequantize-on-read evaluation (the hardware path)."""
        ts = self.base
        x = np.asarray(x, dtype=np.float64)
        j = np.clip(np.searchsorted(ts.boundaries, x, side="right") - 1,
                    0, ts.n_intervals - 1)
        p_j = ts.boundaries[j]
        i = np.clip(np.floor((x - p_j) * ts.inv_delta[j]).astype(np.int64),
                    0, ts.seg_count[j] - 1)
        a = ts.base[j] + i
        y0 = self.zero[j] + self.scale[j] * self.q_values[a]
        y1 = self.zero[j] + self.scale[j] * self.q_values[a + 1]
        t = np.clip((x - (p_j + i * ts.delta[j])) * ts.inv_delta[j], 0.0, 1.0)
        return y0 + t * (y1 - y0)

    def max_error_on_grid(self, fn: Optional[FunctionSpec] = None,
                          n: int = 100_001) -> float:
        fn = fn or get_function(self.base.name)
        xs = np.linspace(self.base.lo, self.base.hi, n)
        xs = xs[xs < self.base.hi]
        return float(np.max(np.abs(self.eval(xs) - np.asarray(fn.f(xs)))))


def _min_width(value_range: float, tol: float, menu: Tuple[int, ...]) -> int:
    """Smallest menu width b with (range / (2^b - 1)) / 2 <= tol."""
    for b in menu:
        if b >= 63:
            return b
        if value_range <= 2.0 * tol * (2**b - 1):
            return b
    return menu[-1]


def quantize_table(
    fn: FunctionSpec | str,
    e_a: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    *,
    rho: float = 0.8,
    width_menu: Tuple[int, ...] = PACKED_WIDTHS,
) -> QuantizedTableSpec:
    """Build an interval-split table at rho*Ea and quantize each sub-interval's
    entries at the minimal width keeping total error <= Ea."""
    if not (0.0 < rho < 1.0):
        raise ValueError("rho must be in (0, 1)")
    fn = get_function(fn) if isinstance(fn, str) else fn
    ts = build_table(fn, rho * e_a, lo, hi, algorithm=algorithm, omega=omega)
    tol = (1.0 - rho) * e_a
    counts = np.diff(np.concatenate([ts.base, [ts.footprint]]))
    q = np.zeros(ts.footprint, dtype=np.int64)
    scale = np.zeros(ts.n_intervals)
    zero = np.zeros(ts.n_intervals)
    bits = np.zeros(ts.n_intervals, dtype=np.int64)
    for jj in range(ts.n_intervals):
        s0, s1 = int(ts.base[jj]), int(ts.base[jj] + counts[jj])
        vals = ts.values[s0:s1]
        vmin, vmax = float(vals.min()), float(vals.max())
        b = _min_width(vmax - vmin, tol, width_menu)
        levels = 2**b - 1
        s = (vmax - vmin) / levels if vmax > vmin else 1.0
        codes = np.clip(np.rint((vals - vmin) / s), 0, levels)
        q[s0:s1] = codes.astype(np.int64)
        scale[jj], zero[jj], bits[jj] = s, vmin, b
    return QuantizedTableSpec(base=ts, q_values=q, scale=scale, zero=zero,
                              bits=bits, rho=rho)
