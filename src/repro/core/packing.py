"""Mixed-width quantized table packing — the paper's stated future work
("we want to explore more efficient packing of BRAMs", Sec. 8) implemented.

BRAM18 primitives reconfigure entry width (1/2/4/9/18/36 bits).  The paper
always stores 32-bit range values; but once the interval splitter has produced
sub-intervals, each sub-interval's value RANGE is narrow, so its entries can be
stored affinely quantized at a much smaller width:

    y_q = round((y - z_j) / s_j)            stored at b_j bits
    y   ~ z_j + s_j * y_q

Error budget: the interpolation bound gets rho*Ea (the table is built with the
tightened bound) and quantization gets (1-rho)*Ea; since lerp is a convex
combination, quantized-endpoint error <= s_j/2, so the minimal width satisfying

    s_j / 2 <= (1 - rho) * Ea,   s_j = (max_j - min_j) / (2^b_j - 1)

is chosen PER SUB-INTERVAL from the width menu.  Total footprint is
``sum_j kappa_j * b_j`` bits instead of ``32 * sum_j kappa_j``.

Measured (benchmarks/paper_figs.table3_packing): with arbitrary bitfield
packing, +30-37 % per-entry savings at the paper's Ea=9.5e-7 (21-23 required
bits) and +52-59 % at the framework's activation Ea=1e-4 (13-16 bits); combined
with interval splitting: 69-92 % total vs the 32-bit Reference table.  With the
PHYSICAL BRAM18 menu (1/2/4/9/18/36) the paper-Ea case rounds UP to 36 bits on
high-resolution sub-intervals — i.e. the paper's future work only pays off on
FPGAs below Ea~1e-5 resolution or with bitfield packing across BRAM ports; an
honest negative-at-tiny-Ea result.

The runtime analogue stores int16/int8 entries in VMEM with per-sub-interval
(scale, zero) in the selector metadata — one extra FMA after the gather.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from . import bram
from .functions import FunctionSpec, get as get_function
from .quantize import QuantMember
from .table import TableSpec, build_table

BRAM_WIDTHS = (1, 2, 4, 9, 18, 36)  # physical BRAM18 entry widths
INT_WIDTHS = (4, 8, 16, 32)  # TPU-friendly storage menu
PACKED_WIDTHS = tuple(range(1, 37))  # arbitrary-width bitfield packing


# --------------------------------------------------------------------------------------
# Multi-function pack layout — all of a model's tables as ONE BRAM/VMEM artifact.
# --------------------------------------------------------------------------------------


@dataclass(frozen=True)
class PackLayout:
    """Layout of F tables packed into one values vector + padded metadata planes.

    This is the paper's BRAM-instantiation idea applied across the WHOLE function
    set: instead of one BRAM (VMEM residency + kernel dispatch) per function, all
    range values live in a single concatenated ``values`` vector and the selector
    metadata is stored as (F, n_max)-padded planes so one kernel, indexing a
    metadata row by a static ``fn_id``, serves any member function.

      * ``boundaries``  (F, n_max+1)  right-padded with +inf — padding never wins
        a ``x >= b`` compare, so the vectorized selector needs no per-function
        comparator count;
      * ``inv_delta`` / ``delta`` (F, n_max)  padded with 1.0 (never selected);
      * ``base``        (F, n_max)  GLOBAL indices into ``values`` (the
        per-function BRAM base address A_j plus the function's pack offset);
      * ``seg_count``   (F, n_max)  padded with 1;
      * ``values``      (sum_f M_f,)  every function's packed range values.
    """

    names: Tuple[str, ...]
    specs: Tuple[TableSpec, ...]
    n_intervals: Tuple[int, ...]  # real (unpadded) sub-interval count per function
    n_max: int
    boundaries: np.ndarray  # (F, n_max+1) f64
    inv_delta: np.ndarray  # (F, n_max)   f64
    delta: np.ndarray  # (F, n_max)   f64
    base: np.ndarray  # (F, n_max)   i64 — global index into the packed values
    seg_count: np.ndarray  # (F, n_max)   i64
    value_offset: np.ndarray  # (F,)     i64 — first values index of function f
    values: np.ndarray  # (sum M_f,)   f64

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def footprint(self) -> int:
        """Total stored entries across the pack (sum of member Eq. 13 footprints)."""
        return int(len(self.values))

    def fn_id(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(
                f"function {name!r} not in pack {self.names}") from None

    def vmem(self, dtype_bytes: int = 4,
             budget_bytes: int = bram.VMEM_BYTES_V5E) -> bram.VmemCost:
        """Pack-level VMEM cost (one residency for the whole function set)."""
        return bram.vmem_cost_pack(
            [s.footprint for s in self.specs], self.n_intervals,
            dtype_bytes=dtype_bytes, budget_bytes=budget_bytes)


def pack_layout(specs: Sequence[TableSpec]) -> PackLayout:
    """Concatenate per-function TableSpecs into one PackLayout.

    Member metadata is copied verbatim (same f64 values as the per-table
    artifacts), so a runtime evaluating through the pack reproduces per-table
    evaluation bit for bit; only ``base`` is rebased by the pack offset.
    """
    if not specs:
        raise ValueError("cannot pack zero tables")
    names = tuple(s.name for s in specs)
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate function names in pack: {names}")
    n_list = tuple(s.n_intervals for s in specs)
    n_max = max(n_list)
    F = len(specs)
    boundaries = np.full((F, n_max + 1), np.inf, dtype=np.float64)
    inv_delta = np.ones((F, n_max), dtype=np.float64)
    delta = np.ones((F, n_max), dtype=np.float64)
    base = np.zeros((F, n_max), dtype=np.int64)
    seg_count = np.ones((F, n_max), dtype=np.int64)
    value_offset = np.zeros((F,), dtype=np.int64)
    acc = 0
    for f, s in enumerate(specs):
        n = s.n_intervals
        boundaries[f, : n + 1] = s.boundaries
        inv_delta[f, :n] = s.inv_delta
        delta[f, :n] = s.delta
        base[f, :n] = s.base + acc
        seg_count[f, :n] = s.seg_count
        value_offset[f] = acc
        acc += s.footprint
    return PackLayout(
        names=names,
        specs=tuple(specs),
        n_intervals=n_list,
        n_max=n_max,
        boundaries=boundaries,
        inv_delta=inv_delta,
        delta=delta,
        base=base,
        seg_count=seg_count,
        value_offset=value_offset,
        values=np.concatenate([s.values for s in specs]),
    )


# --------------------------------------------------------------------------------------
# QuantPack layout — the pack with int8/int16 entry codes + dequant metadata.
# --------------------------------------------------------------------------------------


@dataclass(frozen=True)
class QuantPackLayout:
    """F quantized tables packed into per-width code vectors + flat metadata lanes.

    Unlike :class:`PackLayout`'s (F, n_max)-padded planes, the metadata here is
    RAGGED — flat lanes concatenated per function — because quantization
    refinement (``core.quantize.refine_for_quantization``) gives members very
    different sub-interval counts and padding every plane to the widest member
    would cost more than the quantization saves.  The kernel indexes a member's
    lane segment with STATIC offsets (``fn_id`` is static), so raggedness is
    free at runtime.

      * ``boundaries``  (sum_f n_f+1,)  per-function rows back to back;
      * ``inv_delta`` / ``base`` / ``seg_count`` / ``scale`` / ``zero`` /
        ``ramp``        (sum_f n_f,)    the selector + dequant lanes;
      * ``codes8``      (M8,) int8-coded entries of every int8 member;
      * ``codes16``     (M16,) likewise for int16 members.

    ``base`` holds GLOBAL indices into the member's own width-group vector.
    Dequantize-on-read: ``v = zero_j + ramp_j * i + scale_j * q``.
    """

    names: Tuple[str, ...]
    members: Tuple[QuantMember, ...]
    n_intervals: Tuple[int, ...]
    entry_bits: Tuple[int, ...]  # 8 or 16 per member (which codes vector)
    boundaries: np.ndarray  # (sum n_f+1,) f64
    inv_delta: np.ndarray  # (sum n_f,) f64
    delta: np.ndarray  # (sum n_f,) f64
    base: np.ndarray  # (sum n_f,) i64 — global into the width-group codes
    seg_count: np.ndarray  # (sum n_f,) i64
    scale: np.ndarray  # (sum n_f,) f64
    zero: np.ndarray  # (sum n_f,) f64
    ramp: np.ndarray  # (sum n_f,) f64
    value_offset: np.ndarray  # (F,) i64 — first codes index within the group
    codes8: np.ndarray  # (M8,) i64 codes of the int8 members, concatenated
    codes16: np.ndarray  # (M16,) i64 codes of the int16 members, concatenated

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def footprint(self) -> int:
        """Total stored entries (Eq. 13 accounting, width-agnostic)."""
        return int(len(self.codes8) + len(self.codes16))

    @property
    def footprint_bytes(self) -> int:
        """Entry storage bytes — the quantization win vs ``footprint * 4``."""
        return int(len(self.codes8) + 2 * len(self.codes16))

    @property
    def meta_bytes(self) -> int:
        return sum(m.meta_bytes for m in self.members)

    def fn_id(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(
                f"function {name!r} not in pack {self.names}") from None

    def bounds_offset(self, fid: int) -> int:
        return sum(n + 1 for n in self.n_intervals[:fid])

    def lane_offset(self, fid: int) -> int:
        return sum(self.n_intervals[:fid])

    # Routed (dynamic fn_id) dispatch: the static per-member offsets above,
    # materialized as int32 vectors so a scalar-prefetch kernel can index the
    # ragged lanes and pick the width group at RUNTIME (one executable serves
    # arbitrarily mixed-function batches; see kernels/routed_pack_lookup).

    @property
    def bounds_offsets(self) -> np.ndarray:
        """(F,) int32 — per-member start into the flat ``boundaries`` lane."""
        return np.asarray([self.bounds_offset(f) for f in range(self.n_functions)],
                          dtype=np.int32)

    @property
    def lane_offsets(self) -> np.ndarray:
        """(F,) int32 — per-member start into the selector/dequant lanes."""
        return np.asarray([self.lane_offset(f) for f in range(self.n_functions)],
                          dtype=np.int32)

    def eval(self, fn, x: np.ndarray) -> np.ndarray:
        """f64 dequantize-on-read oracle for member ``fn`` (name or fn_id)."""
        fid = self.fn_id(fn) if isinstance(fn, str) else int(fn)
        return self.members[fid].eval(x)

    def vmem(self, budget_bytes: int = bram.VMEM_BYTES_V5E) -> bram.VmemCost:
        """Pack-level VMEM cost with per-member entry widths and ragged metadata."""
        return bram.vmem_cost_pack(
            [m.footprint for m in self.members], self.n_intervals,
            dtype_bytes=[b // 8 for b in self.entry_bits],
            budget_bytes=budget_bytes, meta_lanes=7, ragged_meta=True)


def quant_pack_layout(members: Sequence[QuantMember]) -> QuantPackLayout:
    """Concatenate per-function :class:`QuantMember` artifacts into one layout."""
    if not members:
        raise ValueError("cannot pack zero tables")
    names = tuple(m.name for m in members)
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate function names in pack: {names}")
    boundaries, inv_delta, delta, base, seg_count = [], [], [], [], []
    scale, zero, ramp = [], [], []
    value_offset = np.zeros((len(members),), dtype=np.int64)
    group_acc = {8: 0, 16: 0}
    codes = {8: [], 16: []}
    for f, m in enumerate(members):
        s = m.spec
        boundaries.append(s.boundaries)
        inv_delta.append(s.inv_delta)
        delta.append(s.delta)
        seg_count.append(s.seg_count)
        scale.append(m.scale)
        zero.append(m.zero)
        ramp.append(m.ramp)
        acc = group_acc[m.bits]
        base.append(s.base + acc)
        value_offset[f] = acc
        codes[m.bits].append(m.codes)
        group_acc[m.bits] = acc + m.footprint
    cat = lambda parts: (np.concatenate(parts) if parts
                         else np.zeros((0,), dtype=np.int64))
    return QuantPackLayout(
        names=names,
        members=tuple(members),
        n_intervals=tuple(m.spec.n_intervals for m in members),
        entry_bits=tuple(m.bits for m in members),
        boundaries=np.concatenate(boundaries),
        inv_delta=np.concatenate(inv_delta),
        delta=np.concatenate(delta),
        base=np.concatenate(base),
        seg_count=np.concatenate(seg_count),
        scale=np.concatenate(scale),
        zero=np.concatenate(zero),
        ramp=np.concatenate(ramp),
        value_offset=value_offset,
        codes8=cat(codes[8]),
        codes16=cat(codes[16]),
    )


# --------------------------------------------------------------------------------------
# PolyPack layout — degree-d coefficient packs from the design-space planner.
# --------------------------------------------------------------------------------------


@dataclass(frozen=True)
class PolyPackLayout:
    """F planner-designed :class:`~repro.core.design.PolyMember` tables packed
    into per-width code vectors + flat LANE-PADDED metadata.

    The QuantPack raggedness idea carries over (flat per-function metadata
    lanes, static offsets), with two new wrinkles:

      * **Three width groups.**  ``codes8`` / ``codes16`` hold integer codes;
        ``codes32`` holds the f32 members' RAW coefficients.  An f32 member's
        dequant params are pinned to ``zero = ramp = 0, scale = 1``, so the
        one dequant FMA sequence ``(zero + ramp*i) + scale*q`` is a bit-exact
        identity for it — a single kernel op order serves mixed-width packs.

      * **Lane padding to the pack max degree.**  ``zero``/``ramp``/``scale``
        are stored per (sub-interval, lane) with ``max_degree + 1`` lanes for
        EVERY member; a member of lower degree pads the extra lanes with
        zeros.  A padded lane dequantizes to exactly 0.0 (whatever code the
        clipped gather returns, ``0 + 0*i + 0*q = 0``), and a leading zero
        flows through Horner as ``0*t + c_d = c_d`` — so the uniform
        max-degree Horner the routed kernel runs is bitwise identical to the
        member's own degree-d evaluation.

    Codes are cell-major with the member's OWN stride ``degree + 1`` (no code
    padding — storage stays minimal): code of cell ``i``, lane ``l`` of
    sub-interval ``j`` lives at ``base[j] + i*(degree+1) + l`` within the
    member's width group.  Metadata index for (sub-interval ``j``, lane ``l``)
    is ``(lane_offset(fid) + j) * (max_degree+1) + l``.
    """

    names: Tuple[str, ...]
    members: Tuple["PolyMember", ...]
    n_intervals: Tuple[int, ...]
    degrees: Tuple[int, ...]  # interpolation degree per member
    entry_bits: Tuple[int, ...]  # 8 / 16 / 32 per member (which codes vector)
    max_degree: int
    boundaries: np.ndarray  # (sum n_f+1,) f64
    inv_delta: np.ndarray  # (sum n_f,) f64
    delta: np.ndarray  # (sum n_f,) f64
    base: np.ndarray  # (sum n_f,) i64 — global into the width-group codes
    seg_count: np.ndarray  # (sum n_f,) i64
    zero: np.ndarray  # (sum n_f * (max_degree+1),) f64 lane-padded
    ramp: np.ndarray  # (sum n_f * (max_degree+1),) f64 lane-padded
    scale: np.ndarray  # (sum n_f * (max_degree+1),) f64 lane-padded
    value_offset: np.ndarray  # (F,) i64 — first codes index within the group
    codes8: np.ndarray  # (M8,) i64 codes of the int8 members, concatenated
    codes16: np.ndarray  # (M16,) i64 codes of the int16 members
    codes32: np.ndarray  # (M32,) f64 raw coefficients of the f32 members

    @property
    def n_functions(self) -> int:
        return len(self.names)

    @property
    def max_lanes(self) -> int:
        return self.max_degree + 1

    @property
    def footprint(self) -> int:
        """Total stored codes (the planner's entries axis, width-agnostic)."""
        return int(len(self.codes8) + len(self.codes16) + len(self.codes32))

    @property
    def footprint_bytes(self) -> int:
        return int(len(self.codes8) + 2 * len(self.codes16)
                   + 4 * len(self.codes32))

    @property
    def meta_bytes(self) -> int:
        return sum(m.meta_bytes for m in self.members)

    def fn_id(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(
                f"function {name!r} not in pack {self.names}") from None

    def bounds_offset(self, fid: int) -> int:
        return sum(n + 1 for n in self.n_intervals[:fid])

    def lane_offset(self, fid: int) -> int:
        return sum(self.n_intervals[:fid])

    @property
    def bounds_offsets(self) -> np.ndarray:
        """(F,) int32 — per-member start into the flat ``boundaries`` lane."""
        return np.asarray([self.bounds_offset(f) for f in range(self.n_functions)],
                          dtype=np.int32)

    @property
    def lane_offsets(self) -> np.ndarray:
        """(F,) int32 — per-member start into the selector lanes."""
        return np.asarray([self.lane_offset(f) for f in range(self.n_functions)],
                          dtype=np.int32)

    def eval(self, fn, x: np.ndarray) -> np.ndarray:
        """f64 dequantize-on-read Horner oracle for member ``fn``."""
        fid = self.fn_id(fn) if isinstance(fn, str) else int(fn)
        return self.members[fid].eval(x)

    def vmem(self, budget_bytes: int = bram.VMEM_BYTES_V5E) -> bram.VmemCost:
        """Pack-level VMEM cost: per-member widths AND per-member meta lanes
        (4 selector lanes + 3 dequant lanes per coefficient)."""
        return bram.vmem_cost_pack(
            [m.entries for m in self.members], self.n_intervals,
            dtype_bytes=[b // 8 for b in self.entry_bits],
            budget_bytes=budget_bytes,
            meta_lanes=[3 + 3 * m.lanes for m in self.members],
            ragged_meta=True)


def poly_pack_layout(members: Sequence["PolyMember"]) -> PolyPackLayout:
    """Concatenate planner-built :class:`PolyMember` artifacts into one layout."""
    if not members:
        raise ValueError("cannot pack zero tables")
    names = tuple(m.name for m in members)
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate function names in pack: {names}")
    max_degree = max(m.degree for m in members)
    lmax = max_degree + 1
    boundaries, inv_delta, delta, base, seg_count = [], [], [], [], []
    zero, ramp, scale = [], [], []
    value_offset = np.zeros((len(members),), dtype=np.int64)
    group_acc = {8: 0, 16: 0, 32: 0}
    codes = {8: [], 16: [], 32: []}
    for f, m in enumerate(members):
        n = m.n_intervals
        boundaries.append(m.boundaries)
        inv_delta.append(m.inv_delta)
        delta.append(m.delta)
        seg_count.append(m.seg_count)
        # lane-pad the dequant planes to the pack max degree with zeros
        for plane, out in ((m.zero, zero), (m.ramp, ramp), (m.scale, scale)):
            padded = np.zeros((n, lmax), dtype=np.float64)
            padded[:, : m.lanes] = plane
            out.append(padded.ravel())
        acc = group_acc[m.bits]
        base.append(m.base + acc)
        value_offset[f] = acc
        codes[m.bits].append(np.asarray(m.codes, dtype=np.float64)
                             if m.bits == 32 else m.codes)
        group_acc[m.bits] = acc + m.entries
    cat_i = lambda parts: (np.concatenate(parts) if parts
                           else np.zeros((0,), dtype=np.int64))
    cat_f = lambda parts: (np.concatenate(parts) if parts
                           else np.zeros((0,), dtype=np.float64))
    return PolyPackLayout(
        names=names,
        members=tuple(members),
        n_intervals=tuple(m.n_intervals for m in members),
        degrees=tuple(m.degree for m in members),
        entry_bits=tuple(m.bits for m in members),
        max_degree=max_degree,
        boundaries=np.concatenate(boundaries),
        inv_delta=np.concatenate(inv_delta),
        delta=np.concatenate(delta),
        base=np.concatenate(base),
        seg_count=np.concatenate(seg_count),
        zero=np.concatenate(zero),
        ramp=np.concatenate(ramp),
        scale=np.concatenate(scale),
        value_offset=value_offset,
        codes8=cat_i(codes[8]),
        codes16=cat_i(codes[16]),
        codes32=cat_f(codes[32]),
    )


# --------------------------------------------------------------------------------------
# ShardedPack layout — the pack's values vector partitioned across a mesh axis.
# --------------------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardedPackLayout:
    """A :class:`PackLayout` whose ``values`` vector is partitioned over
    ``n_shards`` mesh shards at SUB-INTERVAL granularity.

    The paper instantiates one BRAM per table because the table must sit next
    to its consumer; once the multi-function pack outgrows a single core's
    VMEM, the same locality argument runs in reverse — each core should hold
    only a SLICE of the values vector.  Sub-intervals are the natural cut
    granularity: each sub-interval ``(f, j)`` owns a contiguous
    ``seg_count + 1``-entry run of ``values`` (runs never share endpoint
    entries — see ``build_table``), so a shard owning whole sub-intervals owns
    a contiguous slice and every adjacent-pair gather ``(a, a+1)`` stays
    shard-local.

      * ``owner``       (F, n_max)  which shard answers sub-interval (f, j);
        padding columns are owned by no shard (-1);
      * ``local_base``  (F, n_max)  the pack's GLOBAL ``base`` rebased into
        the owner's slice: ``local_base = base - shard_offsets[owner]``
        (0 where unowned — reads there are masked, never trusted);
      * ``shard_offsets`` (S,)      first global values index of each shard;
      * ``shard_sizes``   (S,)      real (unpadded) entries per shard.

    The selector metadata (boundaries / inv_delta / seg_count) stays
    REPLICATED — it is the small part (a few KB) and every shard must run the
    full comparator plane to know whether it owns the selected sub-interval.
    Only the values payload (the big part) is partitioned.
    """

    layout: PackLayout
    n_shards: int
    owner: np.ndarray  # (F, n_max) i64, -1 on padding columns
    local_base: np.ndarray  # (F, n_max) i64 — rebased into the owner's slice
    shard_offsets: np.ndarray  # (S,) i64
    shard_sizes: np.ndarray  # (S,) i64

    @property
    def names(self) -> Tuple[str, ...]:
        return self.layout.names

    @property
    def n_intervals(self) -> Tuple[int, ...]:
        return self.layout.n_intervals

    @property
    def footprint(self) -> int:
        return self.layout.footprint

    @property
    def max_shard_entries(self) -> int:
        """Per-shard values high-water: shards are padded to the largest slice
        so they stack into one (S, m_max) runtime operand."""
        return max(1, int(self.shard_sizes.max()))

    def shard_values(self, s: int) -> np.ndarray:
        """Shard ``s``'s slice of the packed values (unpadded)."""
        o = int(self.shard_offsets[s])
        return self.layout.values[o : o + int(self.shard_sizes[s])]

    def vmem(self, shard: Optional[int] = None, dtype_bytes: int = 4,
             budget_bytes: int = bram.VMEM_BYTES_V5E) -> bram.VmemCost:
        """Per-shard VMEM residency (``shard=None`` -> the high-water shard).

        Counts what the sharded runtime actually pins on one core: the PADDED
        values slice (``max_shard_entries`` — every shard holds the same
        operand shape) plus the replicated selector metadata (boundaries,
        inv_delta, seg_count) and the two per-shard planes (local_base,
        owned mask), all f32.  Compare against ``layout.vmem()`` — the
        replicated baseline this sharding exists to beat.
        """
        del shard  # padding makes every shard's residency the high-water one
        F = self.layout.n_functions
        n_max = self.layout.n_max
        table = self.max_shard_entries * dtype_bytes
        meta = F * (5 * n_max + 1) * 4  # 3 replicated lanes + 2 shard planes
        pad = bram.VMEM_SUBLANE_BYTES
        padded = math.ceil((table + meta) / pad) * pad
        return bram.VmemCost(table, meta, padded, budget_bytes)


def shard_pack_layout(layout: PackLayout, n_shards: int) -> ShardedPackLayout:
    """Partition a pack's values vector into ``n_shards`` contiguous slices.

    Sub-intervals are assigned to shards in pack order by their starting
    entry: sub-interval runs are never split (the adjacent-pair gather must
    stay shard-local), so the planner cuts the ``sum_f M_f`` entry span at the
    run boundaries nearest the ideal ``footprint / n_shards`` marks.  The
    resulting slices partition ``values`` exactly; ``base`` is rebased per
    shard so each slice is self-addressing from zero.
    """
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if n_shards > layout.footprint:
        raise ValueError(
            f"cannot split {layout.footprint} entries into {n_shards} shards")
    F, n_max = layout.n_functions, layout.n_max
    total = layout.footprint
    owner = np.full((F, n_max), -1, dtype=np.int64)
    sizes = np.zeros((n_shards,), dtype=np.int64)
    for f in range(F):
        for j in range(layout.n_intervals[f]):
            start = int(layout.base[f, j])
            s = min(n_shards - 1, start * n_shards // total)
            owner[f, j] = s
            sizes[s] += int(layout.seg_count[f, j]) + 1
    offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)
    local_base = np.where(owner >= 0,
                          layout.base - offsets[np.maximum(owner, 0)], 0)
    return ShardedPackLayout(
        layout=layout,
        n_shards=n_shards,
        owner=owner,
        local_base=local_base.astype(np.int64),
        shard_offsets=offsets,
        shard_sizes=sizes,
    )


@dataclass(frozen=True)
class QuantizedTableSpec:
    """A TableSpec whose values are stored affinely quantized per sub-interval."""

    base: TableSpec
    q_values: np.ndarray  # (M_F,) int64 codes
    scale: np.ndarray  # (n,) f64 per sub-interval
    zero: np.ndarray  # (n,) f64 per sub-interval
    bits: np.ndarray  # (n,) i64 chosen width per sub-interval
    rho: float

    @property
    def footprint_bits(self) -> int:
        counts = np.diff(np.concatenate([self.base.base,
                                         [self.base.footprint]]))
        return int(np.sum(counts * self.bits))

    @property
    def footprint_bits_fp32(self) -> int:
        return 32 * self.base.footprint

    @property
    def bit_reduction(self) -> float:
        return 1.0 - self.footprint_bits / self.footprint_bits_fp32

    def eval(self, x: np.ndarray) -> np.ndarray:
        """Dequantize-on-read evaluation (the hardware path)."""
        ts = self.base
        x = np.asarray(x, dtype=np.float64)
        j = np.clip(np.searchsorted(ts.boundaries, x, side="right") - 1,
                    0, ts.n_intervals - 1)
        p_j = ts.boundaries[j]
        i = np.clip(np.floor((x - p_j) * ts.inv_delta[j]).astype(np.int64),
                    0, ts.seg_count[j] - 1)
        a = ts.base[j] + i
        y0 = self.zero[j] + self.scale[j] * self.q_values[a]
        y1 = self.zero[j] + self.scale[j] * self.q_values[a + 1]
        t = np.clip((x - (p_j + i * ts.delta[j])) * ts.inv_delta[j], 0.0, 1.0)
        return y0 + t * (y1 - y0)

    def max_error_on_grid(self, fn: Optional[FunctionSpec] = None,
                          n: int = 100_001) -> float:
        fn = fn or get_function(self.base.name)
        xs = np.linspace(self.base.lo, self.base.hi, n)
        xs = xs[xs < self.base.hi]
        return float(np.max(np.abs(self.eval(xs) - np.asarray(fn.f(xs)))))


def _min_width(value_range: float, tol: float, menu: Tuple[int, ...]) -> int:
    """Smallest menu width b with (range / (2^b - 1)) / 2 <= tol."""
    for b in menu:
        if b >= 63:
            return b
        if value_range <= 2.0 * tol * (2**b - 1):
            return b
    return menu[-1]


def quantize_table(
    fn: FunctionSpec | str,
    e_a: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    *,
    rho: float = 0.8,
    width_menu: Tuple[int, ...] = PACKED_WIDTHS,
) -> QuantizedTableSpec:
    """Build an interval-split table at rho*Ea and quantize each sub-interval's
    entries at the minimal width keeping total error <= Ea."""
    if not (0.0 < rho < 1.0):
        raise ValueError("rho must be in (0, 1)")
    fn = get_function(fn) if isinstance(fn, str) else fn
    ts = build_table(fn, rho * e_a, lo, hi, algorithm=algorithm, omega=omega)
    tol = (1.0 - rho) * e_a
    counts = np.diff(np.concatenate([ts.base, [ts.footprint]]))
    q = np.zeros(ts.footprint, dtype=np.int64)
    scale = np.zeros(ts.n_intervals)
    zero = np.zeros(ts.n_intervals)
    bits = np.zeros(ts.n_intervals, dtype=np.int64)
    for jj in range(ts.n_intervals):
        s0, s1 = int(ts.base[jj]), int(ts.base[jj] + counts[jj])
        vals = ts.values[s0:s1]
        vmin, vmax = float(vals.min()), float(vals.max())
        b = _min_width(vmax - vmin, tol, width_menu)
        levels = 2**b - 1
        s = (vmax - vmin) / levels if vmax > vmin else 1.0
        codes = np.clip(np.rint((vals - vmin) / s), 0, levels)
        q[s0:s1] = codes.astype(np.int64)
        scale[jj], zero[jj], bits[jj] = s, vmin, b
    return QuantizedTableSpec(base=ts, q_values=q, scale=scale, zero=zero,
                              bits=bits, rho=rho)
