"""repro.core — the paper's contribution: interval-split table-based function
approximation (spacing rule, three splitting algorithms, packed tables, resource
models, design flow)."""

from .functions import FunctionSpec, get as get_function, names as function_names
from .spacing import SecondDerivMax, delta_for, footprint, reference_spacing
from .splitting import (
    ALGORITHMS,
    SplitResult,
    binary_split,
    hierarchical_split,
    sequential_split,
    split,
)
from .table import TableSpec, build_table
from .flow import FlowReport, cached_table, run_flow
from .bram import bram_count, bram_count_packed, vmem_cost, vmem_cost_pack
from .packing import (
    PackLayout,
    PolyPackLayout,
    QuantPackLayout,
    ShardedPackLayout,
    pack_layout,
    poly_pack_layout,
    quant_pack_layout,
    shard_pack_layout,
)
from .design import (
    DesignCandidate,
    PackPlan,
    PolyMember,
    build_poly_member,
    deriv_probe,
    enumerate_candidates,
    interp_error_const,
    pareto_front,
    plan,
    poly_cell_width,
    poly_member,
)
from .quantize import (
    FixedPointFormat,
    PAPER_FORMATS,
    QUANT_INT_BITS,
    QuantMember,
    chord_residual_ranges,
    plan_quant_member,
    quantize_spec,
    refine_for_quantization,
)
from .stats import TTestResult, outperforms, t_cdf, ttest2

__all__ = [
    "ALGORITHMS",
    "DesignCandidate",
    "FixedPointFormat",
    "FlowReport",
    "FunctionSpec",
    "PackLayout",
    "PackPlan",
    "PAPER_FORMATS",
    "PolyMember",
    "PolyPackLayout",
    "QUANT_INT_BITS",
    "QuantMember",
    "QuantPackLayout",
    "SecondDerivMax",
    "ShardedPackLayout",
    "SplitResult",
    "TTestResult",
    "TableSpec",
    "binary_split",
    "bram_count",
    "bram_count_packed",
    "build_poly_member",
    "build_table",
    "cached_table",
    "chord_residual_ranges",
    "delta_for",
    "deriv_probe",
    "enumerate_candidates",
    "footprint",
    "function_names",
    "get_function",
    "hierarchical_split",
    "interp_error_const",
    "outperforms",
    "pack_layout",
    "pareto_front",
    "plan",
    "plan_quant_member",
    "poly_cell_width",
    "poly_member",
    "poly_pack_layout",
    "quant_pack_layout",
    "quantize_spec",
    "refine_for_quantization",
    "reference_spacing",
    "run_flow",
    "sequential_split",
    "shard_pack_layout",
    "split",
    "t_cdf",
    "ttest2",
    "vmem_cost",
    "vmem_cost_pack",
]
