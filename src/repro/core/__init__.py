"""repro.core — the paper's contribution: interval-split table-based function
approximation (spacing rule, three splitting algorithms, packed tables, resource
models, design flow)."""

from .functions import FunctionSpec, get as get_function, names as function_names
from .spacing import SecondDerivMax, delta_for, footprint, reference_spacing
from .splitting import (
    ALGORITHMS,
    SplitResult,
    binary_split,
    hierarchical_split,
    sequential_split,
    split,
)
from .table import TableSpec, build_table
from .flow import FlowReport, cached_table, run_flow
from .bram import bram_count, bram_count_packed, vmem_cost, vmem_cost_pack
from .packing import (
    PackLayout,
    QuantPackLayout,
    ShardedPackLayout,
    pack_layout,
    quant_pack_layout,
    shard_pack_layout,
)
from .quantize import (
    FixedPointFormat,
    PAPER_FORMATS,
    QUANT_INT_BITS,
    QuantMember,
    chord_residual_ranges,
    plan_quant_member,
    quantize_spec,
    refine_for_quantization,
)
from .stats import TTestResult, outperforms, t_cdf, ttest2

__all__ = [
    "ALGORITHMS",
    "FixedPointFormat",
    "FlowReport",
    "FunctionSpec",
    "PackLayout",
    "PAPER_FORMATS",
    "QUANT_INT_BITS",
    "QuantMember",
    "QuantPackLayout",
    "SecondDerivMax",
    "ShardedPackLayout",
    "SplitResult",
    "TTestResult",
    "TableSpec",
    "binary_split",
    "bram_count",
    "bram_count_packed",
    "build_table",
    "cached_table",
    "chord_residual_ranges",
    "delta_for",
    "footprint",
    "function_names",
    "get_function",
    "hierarchical_split",
    "outperforms",
    "pack_layout",
    "plan_quant_member",
    "quant_pack_layout",
    "quantize_spec",
    "refine_for_quantization",
    "reference_spacing",
    "run_flow",
    "sequential_split",
    "shard_pack_layout",
    "split",
    "t_cdf",
    "ttest2",
    "vmem_cost",
    "vmem_cost_pack",
]
