"""TableFlash error contract: a provable row-wise bound on the attention output
when flash attention's running softmax serves ``exp`` from the pack's
``exp_neg`` member instead of the transcendental.

Setting.  ``_flash_inner`` scans the (padded) key axis in C chunks keeping a
running max m_c, and the two exp calls per chunk —

    p     = exp(s - m_c)          (per-key probability numerator)
    alpha = exp(m_{c-1} - m_c)    (carry rescale)

— both have non-positive arguments by construction, so they land on
``exp_neg``'s canonical domain [lo, 0] (lo = -16 in the registry) with an
underflow-to-zero tail below lo: the lookup returns exactly 0.0 there,
matching f32 exp's own underflow for the masked-slot arguments.  The
running max itself uses NO exp, so both the exact and the table path compute
*identical* m_c sequences; the approximation error enters only through the
lookup factors.

Per-lookup error.  The table guarantees |table(z) - exp(z)| <= Ea on
[lo, 0].  Below lo the zero tail leaves |0 - exp(z)| < exp(lo).  Uniformly:

    delta = Ea + exp(lo)                                       (lookup_delta)

Per-key weight error.  After the scan, the exact weight of key i telescopes
to exp(s_i - m_final) = exp(s_i - m_{c(i)}) * prod_c exp(m_{c-1} - m_c):
one p factor and at most C-1 alpha factors, every factor in [0, 1].  The
table path evaluates the SAME factor product with each factor off by at most
delta and bounded by 1 + delta (arguments are <= 0, so table values are at
most table(0) <= 1 + Ea).  A product of F factors with per-factor error
delta differs from the exact product by at most F * delta * (1+delta)^(F-1),
and F <= C:

    eps_w = C * delta * (1 + delta)^(C-1)                      (weight_error)

Output bound.  With Tp = C * kv_chunk padded keys, |v| <= Vmax, exact
weights w_i >= 0 summing to l >= 1 (the running max makes the maximal key's
weight exactly 1), approx weights summing to l_hat >= l - Tp*eps_w (masked
and pad keys have weight exactly 0 in BOTH paths — exact exp underflows to
+0.0 in f32 and the zero tail reproduces it — so they contribute no error;
keeping them under the same per-key eps_w is conservative):

    |o_hat - o| <= |sum (w_hat-w) v| / l_hat + |sum w v| * |1/l_hat - 1/l|
                <= Tp*eps_w*Vmax / l_hat + Vmax * Tp*eps_w / l_hat
                <= 2 * Tp * Vmax * eps_w / (1 - Tp*eps_w)      (flash_abs_bound)

valid whenever Tp * eps_w < 1.  Rows with NO valid key are excluded from the
contract (both paths renormalize garbage identically; callers mask them).

The bound is mathematical (infinite-precision factor arithmetic); the
empirical check in tests/test_table_flash.py adds a tiny f32-accumulation
slop on top.  See docs/table_flash.md for the worked derivation.
"""

from __future__ import annotations

import math

# exp_neg's canonical domain low edge (repro.core.functions registry): below
# it the TableFlash lookup underflows to exactly 0 while exp(z) < exp(-16)
# ~ 1.1e-7, so the tail error is bounded by exp(lo).
EXP_NEG_LO = -16.0


def lookup_delta(e_a: float, lo: float = EXP_NEG_LO) -> float:
    """Uniform per-lookup error bound over z <= 0: Ea on [lo, 0], exp(lo)
    on the underflow-to-zero tail below lo."""
    return float(e_a) + math.exp(lo)


def weight_error(n_chunks: int, delta: float) -> float:
    """Per-key weight error after C chunks: C * delta * (1+delta)^(C-1)."""
    if n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    try:
        return n_chunks * delta * (1.0 + delta) ** (n_chunks - 1)
    except OverflowError:
        # (1+delta)^(C-1) past float range: the bound is degenerate anyway
        return math.inf


def flash_abs_bound(e_a: float, n_keys: int, kv_chunk: int, v_max: float,
                    lo: float = EXP_NEG_LO) -> float:
    """Row-wise |table_flash - exact_flash| bound on the attention output.

    ``n_keys`` is the TRUE key count T; the chunked scan pads it to
    Tp = ceil(T / kv_chunk) * kv_chunk and every padded key enters the bound
    (its table weight is at most eps_w, its exact weight exactly 0).
    Returns ``math.inf`` when Tp * eps_w >= 1 — the contract degenerates and
    the caller should tighten Ea or the chunking before relying on it.
    """
    if n_keys < 1 or kv_chunk < 1:
        raise ValueError(
            f"need n_keys >= 1 and kv_chunk >= 1, got {n_keys}, {kv_chunk}")
    kv_chunk = min(kv_chunk, n_keys)
    n_chunks = -(-n_keys // kv_chunk)
    tp = n_chunks * kv_chunk
    eps_w = weight_error(n_chunks, lookup_delta(e_a, lo))
    if tp * eps_w >= 1.0:
        return math.inf
    return 2.0 * tp * float(v_max) * eps_w / (1.0 - tp * eps_w)
