"""Unified design-space planner: degree x spacing x storage width in one search.

The paper's flow fixes interpolation at degree 1 (a chord per segment) and
leaves storage width to a separate pass (``plan_quant_member``).  This module
turns both into axes of ONE search, following the polynomial-interpolation
design-space generation of the Intel paper (PAPERS.md, arXiv 2205.09504):

* **Degree** d in {1, 2, 3}: each uniform cell of width ``h`` stores the d+1
  coefficients of the interpolating polynomial through d+1 equispaced nodes
  (node spacing ``s = h / d``).  The classic remainder bound generalizes the
  paper's Eq. 10: with ``C_d = max_{t in [0, d]} |prod_i (t - i)|``,

      E  <=  max|f^(d+1)| / (d+1)!  *  s^(d+1)  *  C_d

  Inverting for the admissible cell width (``poly_cell_width``) recovers the
  paper's Eq. 11 exactly at d=1 (C_1 = 1/4  =>  h = sqrt(8 E_a / max|f''|)).

* **Spacing**: the existing splitting algorithms run unchanged — the degree-d
  remainder test is injected through :class:`_RemainderOracle`, which presents
  the generalized bound behind the ``max|f''|`` interface the splitters already
  consume.  A shared :func:`deriv_probe` cache holds one derivative range-max
  oracle per (function, interval, order), so enumerating a whole candidate
  menu never rebuilds a ``SecondDerivMax``-style grid.

* **Width**: f32, int16 or int8 coefficient storage.  Integer widths reuse the
  QuantPack chord-residual idea per *lane*: across the cells of a sub-interval
  the lane-l coefficients are coded affinely, ``c_l(i) = zero + ramp*i +
  scale*q_i``.  Since ``|p(t) - p~(t)| <= sum_l |dc_l|`` for t in [0, 1], the
  rounding budget ``(1 - rho) * E_a`` is split evenly over the d+1 lanes.
  Infeasible sub-intervals are bisected at cell boundaries (the polynomial
  pieces are untouched, so — unlike the linear QuantPack — refinement grows
  only metadata, never the stored codes).

Because the d>=2 cell-width bound leans on *numeric* third/fourth derivatives
(finite differences of the registered ``d2f``), every member build runs a
verify-and-refine loop: cell counts are increased until a dense f64 probe grid
meets the interpolation budget, so the artifact guarantee never depends on the
finite-difference estimate being tight.

On top sit the planner entry points: :func:`enumerate_candidates` builds the
feasible (degree, dtype) menu for one function, :func:`pareto_front` filters it
to the (entries, bytes) non-dominated set, and :func:`plan` picks one candidate
per function — cheapest overall when no budget is given, or
greedy-downgrade-from-preferred under ``budget_bytes`` (start every function at
its lowest-degree/widest-width candidate, repeatedly switch the function with
the largest byte saving to its cheapest candidate until the pack fits).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Sequence, Tuple

import numpy as np

from repro import obs

from .bram import VMEM_BYTES_V5E, VmemCost, vmem_cost_pack
from .functions import FunctionSpec, get as get_function
from .quantize import DEFAULT_REFINE_CAP, DEFAULT_RHO, quant_rounding_limit
from .spacing import SecondDerivMax
from .splitting import split

POLY_DEGREES = (1, 2, 3)
POLY_DTYPES = ("f32", "int16", "int8")  # widest-first = the preference order
DTYPE_BITS = {"f32": 32, "int16": 16, "int8": 8}

_FD_SAFETY = 1.05  # headroom on finite-difference derivative estimates
_PROBE_GRID_N = 8193


@lru_cache(maxsize=8)
def interp_error_const(degree: int) -> float:
    """C_d = max over [0, d] of |prod_{i=0..d} (t - i)| (node-polynomial max).

    C_1 = 1/4 makes the degree-1 remainder bound coincide with Eq. 10.
    """
    if degree < 1:
        raise ValueError("degree must be >= 1")
    t = np.linspace(0.0, float(degree), 16385)
    w = np.prod(t[:, None] - np.arange(degree + 1)[None, :], axis=1)
    return float(np.max(np.abs(w)))


def poly_cell_width(max_deriv: float, e_a: float, degree: int) -> float:
    """Largest admissible uniform cell width for a degree-``degree`` fit.

    Solves the remainder bound for ``h = d * s``; ``inf`` when the driving
    derivative vanishes (one cell spans the interval).  At degree 1 this is
    exactly Eq. 11: sqrt(8 E_a / max|f''|).
    """
    if e_a <= 0:
        raise ValueError("E_a must be positive")
    if max_deriv <= 0.0:
        return math.inf
    s = (math.factorial(degree + 1) * e_a
         / (interp_error_const(degree) * max_deriv)) ** (1.0 / (degree + 1))
    return degree * s


class DerivProbe:
    """Range-max oracle for |f^(order)|, order in {3, 4}, via finite
    differences of the registered analytic ``d2f`` on a dense grid.

    The estimate is biased up by ``_FD_SAFETY``; correctness never rests on it
    (``build_poly_member`` verifies every sub-interval on a dense grid and
    refines), it only has to be a good *sizing* guess.
    """

    def __init__(self, spec: FunctionSpec, lo: float, hi: float, order: int,
                 grid_n: int = _PROBE_GRID_N):
        if hi <= lo:
            raise ValueError(f"empty base interval [{lo}, {hi})")
        if order not in (3, 4):
            raise ValueError("DerivProbe handles orders 3 and 4")
        self.lo, self.hi = float(lo), float(hi)
        xs = np.linspace(self.lo, self.hi, grid_n)
        step = (self.hi - self.lo) / (grid_n - 1)
        vals = np.asarray(spec.d2f(xs), dtype=np.float64)
        for _ in range(order - 2):
            vals = np.gradient(vals, step)
        vals = np.abs(vals) * _FD_SAFETY
        if not np.all(np.isfinite(vals)):
            raise ValueError(
                f"|f^({order})| estimate not finite on [{lo}, {hi}) for "
                f"{spec.name!r}")
        self._vals = vals
        self._step = step
        self._n = grid_n

    def query(self, a: float, b: float) -> float:
        """max |f^(order)| over [a, b], widened to the surrounding samples."""
        if b <= a:
            raise ValueError(f"empty interval [{a}, {b})")
        a = max(a, self.lo)
        b = min(b, self.hi)
        i0 = max(0, int(math.floor((a - self.lo) / self._step)))
        i1 = min(self._n - 1, int(math.ceil((b - self.lo) / self._step)))
        if i1 <= i0:
            i1 = min(self._n - 1, i0 + 1)
        return float(np.max(self._vals[i0:i1 + 1]))


@lru_cache(maxsize=256)
def deriv_probe(name: str, lo: float, hi: float, order: int):
    """The shared derivative-probe cache (one grid per (fn, interval, order)).

    Order 2 returns the exact-endpoint :class:`SecondDerivMax`; orders 3/4
    return finite-difference :class:`DerivProbe` instances.  Every candidate
    the planner enumerates — all degrees, all widths — hits this cache, so a
    12-member pack builds each grid once.
    """
    spec = get_function(name)
    if order == 2:
        return SecondDerivMax(spec, lo, hi)
    return DerivProbe(spec, lo, hi, order)


class _RemainderOracle:
    """Adapter that speaks the splitters' ``max|f''|`` protocol but answers
    with the degree-d remainder bound.

    ``delta_for`` turns a queried max into ``sqrt(8 E_a / m)``; reporting
    ``m = 8 E_a / h_d^2`` (h_d the admissible degree-d cell width) makes the
    unmodified splitting algorithms partition by the generalized error test.
    """

    def __init__(self, probe, e_a: float, degree: int):
        self._probe = probe
        self._e_a = float(e_a)
        self._degree = int(degree)

    def max_abs_d2(self, lo: float, hi: float) -> float:
        h = poly_cell_width(self._probe.query(lo, hi), self._e_a, self._degree)
        if not math.isfinite(h):
            return 0.0  # delta_for then uses the whole interval
        return 8.0 * self._e_a / (h * h)

    query = max_abs_d2


@lru_cache(maxsize=8)
def _vandermonde_inv(degree: int) -> np.ndarray:
    """Inverse Vandermonde on the equispaced nodes t = k/d, k = 0..d.

    ``c = Vinv @ y`` are the monomial coefficients of the interpolating
    polynomial on the cell parameter t in [0, 1]; d=1 reduces to the chord
    (c0 = y0, c1 = y1 - y0).
    """
    k = np.arange(degree + 1, dtype=np.float64) / degree
    v = k[:, None] ** np.arange(degree + 1, dtype=np.float64)[None, :]
    return np.linalg.inv(v)


def _fit_cells(spec: FunctionSpec, p0: float, p1: float, n_cells: int,
               degree: int):
    """Per-cell monomial coefficients (n_cells, degree+1) over [p0, p1]."""
    vinv = _vandermonde_inv(degree)
    h = (p1 - p0) / n_cells
    grid = (np.arange(n_cells, dtype=np.float64)[:, None]
            + np.arange(degree + 1, dtype=np.float64)[None, :] / degree)
    ys = np.asarray(spec.f(p0 + h * grid), dtype=np.float64)
    return ys @ vinv.T, h


def _cells_max_error(spec: FunctionSpec, p0: float, p1: float,
                     coeffs: np.ndarray, h: float, n_pts: int) -> float:
    """Dense-grid max |poly(x) - f(x)| over [p0, p1] (Horner, f64)."""
    xs = np.linspace(p0, p1, n_pts)
    u = (xs - p0) / h
    i = np.clip(np.floor(u).astype(np.int64), 0, coeffs.shape[0] - 1)
    t = np.clip(u - i, 0.0, 1.0)
    c = coeffs[i]
    y = c[:, -1]
    for lane in range(coeffs.shape[1] - 2, -1, -1):
        y = y * t + c[:, lane]
    return float(np.max(np.abs(y - np.asarray(spec.f(xs)))))


def _lane_residual(cells: np.ndarray) -> np.ndarray:
    """Per-lane chord residual across a run of cells ((K, d+1) -> same shape).

    The affine ramp through the first/last cell's coefficients is subtracted;
    runs of <= 2 cells are exactly representable (zero residual)."""
    k = cells.shape[0]
    if k <= 2:
        return np.zeros_like(cells)
    i = np.arange(k, dtype=np.float64)[:, None]
    ramp = cells[0] + (cells[-1] - cells[0]) * i / (k - 1)
    return cells - ramp


@dataclass(frozen=True)
class PolyMember:
    """One function's degree-d coefficient table (the PolyPack member artifact).

    Storage is cell-major with stride ``lanes = degree + 1``: the code of cell
    ``i``, lane ``l`` of sub-interval ``j`` lives at ``base[j] + i*lanes + l``.
    The runtime read path (all f32) dequantizes each lane with the QuantPack
    FMA and evaluates by Horner on the cell parameter ``t``:

        c_l = (zero[j,l] + ramp[j,l] * i) + scale[j,l] * q
        y   = (...(c_d * t + c_{d-1}) * t + ...) * t + c_0

    f32 members store raw coefficients with zero = ramp = 0, scale = 1 — the
    dequant FMA is then bit-exact identity, so one op sequence serves every
    width.
    """

    name: str
    degree: int
    bits: int  # 8 | 16 | 32 (32 = raw f32 coefficients)
    rho: float  # interpolation share of e_a (1.0 effective for bits=32)
    e_a: float
    lo: float
    hi: float
    algorithm: str
    boundaries: np.ndarray  # (n+1,) f64 sub-interval delimiters
    inv_delta: np.ndarray  # (n,) f64 reciprocal cell widths
    delta: np.ndarray  # (n,) f64 cell widths
    base: np.ndarray  # (n,) i64 first code index of sub-interval j
    seg_count: np.ndarray  # (n,) i64 cells per sub-interval
    zero: np.ndarray  # (n, lanes) f64
    ramp: np.ndarray  # (n, lanes) f64
    scale: np.ndarray  # (n, lanes) f64
    codes: np.ndarray  # (entries,) i64 codes, or f64 coefficients at bits=32

    @property
    def n_intervals(self) -> int:
        return len(self.boundaries) - 1

    @property
    def lanes(self) -> int:
        return self.degree + 1

    @property
    def entries(self) -> int:
        """Stored codes — the planner's footprint axis (M_F analogue)."""
        return int(len(self.codes))

    # vmem_cost_pack duck-types on this name
    footprint = entries

    @property
    def codes_bytes(self) -> int:
        return self.entries * (self.bits // 8)

    @property
    def meta_bytes(self) -> int:
        """f32 selector + dequant metadata: boundaries (n+1) plus inv_delta/
        base/seg_count (n each) plus zero/ramp/scale ((degree+1)*n each)."""
        n = self.n_intervals
        return ((3 + 3 * self.lanes) * n + (n + 1)) * 4

    def dequantize(self) -> np.ndarray:
        """Reconstructed f64 coefficients, flat cell-major like ``codes``."""
        out = np.empty(self.entries)
        lanes = self.lanes
        for j in range(self.n_intervals):
            s0 = int(self.base[j])
            k = int(self.seg_count[j])
            q = self.codes[s0:s0 + k * lanes].reshape(k, lanes)
            i = np.arange(k, dtype=np.float64)[:, None]
            out[s0:s0 + k * lanes] = (
                self.zero[j] + self.ramp[j] * i + self.scale[j] * q).ravel()
        return out

    def eval(self, x: np.ndarray) -> np.ndarray:
        """f64 dequantize-on-read Horner oracle (selector + lane FMAs)."""
        x = np.asarray(x, dtype=np.float64)
        j = np.clip(np.searchsorted(self.boundaries, x, side="right") - 1,
                    0, self.n_intervals - 1)
        u = (x - self.boundaries[j]) * self.inv_delta[j]
        i = np.clip(np.floor(u).astype(np.int64), 0, self.seg_count[j] - 1)
        t = np.clip(u - i, 0.0, 1.0)
        a = self.base[j] + i * self.lanes
        cs = [self.zero[j, lane] + self.ramp[j, lane] * i
              + self.scale[j, lane] * self.codes[a + lane]
              for lane in range(self.lanes)]
        y = cs[-1]
        for lane in range(self.lanes - 2, -1, -1):
            y = y * t + cs[lane]
        return y

    def max_error_on_grid(self, fn: Optional[FunctionSpec] = None,
                          n: int = 100_001) -> float:
        fn = fn or get_function(self.name)
        xs = np.linspace(self.lo, self.hi, n)
        xs = xs[xs < self.hi]
        return float(np.max(np.abs(self.eval(xs) - np.asarray(fn.f(xs)))))


def build_poly_member(
    fn: FunctionSpec | str,
    e_a: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    *,
    degree: int = 1,
    bits: int = 32,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    rho: float = DEFAULT_RHO,
    cap: int = DEFAULT_REFINE_CAP,
) -> PolyMember:
    """Design one degree-``degree`` member at storage width ``bits``.

    f32 members spend the whole ``e_a`` on interpolation; integer members
    split it ``rho / (1 - rho)`` between interpolation and per-lane rounding
    (the QuantPack budget convention).  Raises ``ValueError`` when no feasible
    encoding exists within the ``cap``-sub-interval refinement limit — the
    planner treats that as "candidate not in the menu".
    """
    spec = get_function(fn) if isinstance(fn, str) else fn
    if degree not in POLY_DEGREES:
        raise ValueError(f"degree must be one of {POLY_DEGREES}")
    if bits not in (8, 16, 32):
        raise ValueError("bits must be 8, 16 or 32")
    if not (0.0 < rho < 1.0):
        raise ValueError("rho must be in (0, 1)")
    lo = spec.interval[0] if lo is None else float(lo)
    hi = spec.interval[1] if hi is None else float(hi)
    e_interp = e_a if bits == 32 else rho * e_a
    lanes = degree + 1

    probe = deriv_probe(spec.name, lo, hi, degree + 1)
    if algorithm == "reference":
        partition = np.asarray([lo, hi], dtype=np.float64)
    else:
        adapter = _RemainderOracle(probe, e_interp, degree)
        partition = split(algorithm, spec, e_interp, lo, hi, omega,
                          oracle=adapter).partition

    # Per sub-interval: size cells from the remainder bound, then VERIFY the
    # fit on a dense f64 grid and refine — the artifact guarantee must not
    # depend on the finite-difference derivative estimate.
    target = e_interp * 0.999
    subs = []  # (p0, h, coeffs (K, lanes))
    for p0, p1 in zip(partition[:-1], partition[1:]):
        p0, p1 = float(p0), float(p1)
        h0 = poly_cell_width(probe.query(p0, p1), e_interp, degree)
        k = max(1, int(math.ceil((p1 - p0) / min(h0, p1 - p0) - 1e-12)))
        for _ in range(64):
            coeffs, h = _fit_cells(spec, p0, p1, k, degree)
            n_pts = max(513, 32 * k + 1)
            if _cells_max_error(spec, p0, p1, coeffs, h, n_pts) <= target:
                break
            k = max(k + 1, int(math.ceil(k * 1.25)))
        else:  # pragma: no cover - 64 rounds shrink h by > 1e6
            raise ValueError(
                f"{spec.name!r}: degree-{degree} fit did not converge on "
                f"[{p0}, {p1})")
        subs.append((p0, h, coeffs))

    # Integer widths: bisect sub-intervals at cell boundaries until every
    # lane's chord residual fits the per-lane rounding budget.  Cuts leave the
    # polynomial pieces (hence the codes) untouched; only metadata grows.
    if bits < 32:
        limit = quant_rounding_limit((1.0 - rho) * e_a / lanes, bits)

        def worst(si, a, b):
            r = _lane_residual(subs[si][2][a:b])
            return float(np.max(r.max(axis=0) - r.min(axis=0)))

        heap = []
        for si, (_, _, coeffs) in enumerate(subs):
            heapq.heappush(heap, (-worst(si, 0, coeffs.shape[0]),
                                  si, 0, coeffs.shape[0]))
        while len(heap) < cap:
            neg, si, a, b = heap[0]
            if -neg <= limit or b - a < 2:
                break
            heapq.heappop(heap)
            m = (a + b) // 2
            for a2, b2 in ((a, m), (m, b)):
                heapq.heappush(heap, (-worst(si, a2, b2), si, a2, b2))
        if -heap[0][0] > limit * (1 + 1e-12):
            raise ValueError(
                f"no feasible int{bits} coding for {spec.name!r} at "
                f"degree {degree}, e_a={e_a:g}, rho={rho} within the "
                f"{cap}-sub-interval refinement cap")
        pieces = sorted((si, a, b) for _, si, a, b in heap)
    else:
        limit = None
        pieces = [(si, 0, s[2].shape[0]) for si, s in enumerate(subs)]

    boundaries, deltas, bases, segs = [], [], [], []
    zero, ramp, scale, codes = [], [], [], []
    levels = (2 ** bits - 1) if bits < 32 else 0
    offset = 2 ** (bits - 1) if bits < 32 else 0
    acc = 0
    for si, a, b in pieces:
        p0, h, coeffs = subs[si]
        cells = coeffs[a:b]
        k = b - a
        boundaries.append(p0 + a * h if a else p0)
        deltas.append(h)
        bases.append(acc)
        segs.append(k)
        acc += k * lanes
        if bits == 32:
            zero.append(np.zeros(lanes))
            ramp.append(np.zeros(lanes))
            scale.append(np.ones(lanes))
            codes.append(cells.ravel())
            continue
        resid = _lane_residual(cells)
        rmin = resid.min(axis=0)
        rng = resid.max(axis=0) - rmin
        g = (cells[-1] - cells[0]) / (k - 1) if k > 1 else np.zeros(lanes)
        s = np.where(rng > 0.0, rng / levels, 0.0)
        with np.errstate(invalid="ignore", divide="ignore"):
            q = np.where(s > 0.0,
                         np.clip(np.rint((resid - rmin) / np.where(s > 0, s, 1.0)),
                                 0, levels) - offset,
                         0.0)
        zero.append(np.where(rng > 0.0, cells[0] + rmin + s * offset, cells[0]))
        ramp.append(g)
        scale.append(s)
        codes.append(q.ravel())
    boundaries.append(float(partition[-1]))

    deltas = np.asarray(deltas, dtype=np.float64)
    return PolyMember(
        name=spec.name,
        degree=degree,
        bits=bits,
        rho=1.0 if bits == 32 else rho,
        e_a=float(e_a),
        lo=lo,
        hi=hi,
        algorithm=algorithm,
        boundaries=np.asarray(boundaries, dtype=np.float64),
        inv_delta=1.0 / deltas,
        delta=deltas,
        base=np.asarray(bases, dtype=np.int64),
        seg_count=np.asarray(segs, dtype=np.int64),
        zero=np.asarray(zero),
        ramp=np.asarray(ramp),
        scale=np.asarray(scale),
        codes=(np.concatenate(codes) if bits == 32
               else np.concatenate(codes).astype(np.int64)),
    )


def poly_member(
    name: str,
    e_a: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    *,
    degree: int = 1,
    bits: int = 32,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    rho: float = DEFAULT_RHO,
    cap: int = DEFAULT_REFINE_CAP,
) -> PolyMember:
    """Memoized registry-name member build (the ``cached_table`` idiom)."""
    return _member_cached(name, e_a, lo, hi, degree, bits, algorithm, omega,
                          rho, cap)


@lru_cache(maxsize=256)
@obs.traced("design.poly_member", "design")
def _member_cached(name, e_a, lo, hi, degree, bits, algorithm, omega, rho,
                   cap):
    return build_poly_member(name, e_a, lo, hi, degree=degree, bits=bits,
                             algorithm=algorithm, omega=omega, rho=rho,
                             cap=cap)


# --------------------------------------------------------------------------------------
# Candidate enumeration, Pareto filtering, budgeted selection.
# --------------------------------------------------------------------------------------


@dataclass(frozen=True)
class DesignCandidate:
    """One point of a function's design space: a built member plus its costs."""

    name: str
    degree: int
    dtype: str  # 'f32' | 'int16' | 'int8'
    entries: int
    codes_bytes: int
    meta_bytes: int
    member: PolyMember

    @property
    def bits(self) -> int:
        return DTYPE_BITS[self.dtype]

    @property
    def total_bytes(self) -> int:
        """Codes + metadata bytes (pre sublane padding) — the budget axis."""
        return self.codes_bytes + self.meta_bytes


def enumerate_candidates(
    name: str,
    e_a: float,
    *,
    degrees: Sequence[int] = POLY_DEGREES,
    dtypes: Sequence[str] = POLY_DTYPES,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    rho: float = DEFAULT_RHO,
    cap: int = DEFAULT_REFINE_CAP,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
) -> list[DesignCandidate]:
    """The feasible (degree, dtype) menu for one function, every point built
    and verified.  Infeasible integer codings are silently dropped."""
    out = []
    for degree in degrees:
        for dtype in dtypes:
            if dtype not in DTYPE_BITS:
                raise ValueError(
                    f"dtype must be one of {sorted(DTYPE_BITS)}, got {dtype!r}")
            try:
                m = poly_member(name, e_a, lo, hi, degree=degree,
                                bits=DTYPE_BITS[dtype], algorithm=algorithm,
                                omega=omega, rho=rho, cap=cap)
            except ValueError:
                continue
            out.append(DesignCandidate(
                name=name, degree=degree, dtype=dtype, entries=m.entries,
                codes_bytes=m.codes_bytes, meta_bytes=m.meta_bytes, member=m))
    if not out:
        raise ValueError(
            f"no feasible design candidate for {name!r} at e_a={e_a:g} over "
            f"degrees={tuple(degrees)}, dtypes={tuple(dtypes)}")
    return out


def pareto_front(candidates: Sequence[DesignCandidate]) -> list[DesignCandidate]:
    """The (entries, total_bytes) non-dominated subset, entries-ascending."""
    front = []
    for c in candidates:
        if any(o.entries <= c.entries and o.total_bytes <= c.total_bytes
               and (o.entries < c.entries or o.total_bytes < c.total_bytes)
               for o in candidates):
            continue
        front.append(c)
    return sorted(front, key=lambda c: (c.entries, c.total_bytes))


def _auto_key(c: DesignCandidate):
    """Cheapest-first: bytes, then entries, then lower degree / wider dtype."""
    return (c.total_bytes, c.entries, c.degree, -c.bits)


def _preferred_key(c: DesignCandidate):
    """Quality-first: lowest degree (fewest runtime FMAs), widest dtype
    (least rounding), then fewer bytes."""
    return (c.degree, -c.bits, c.total_bytes)


@dataclass(frozen=True)
class PackPlan:
    """A per-function candidate selection plus its pack-level accounting."""

    names: Tuple[str, ...]
    chosen: Tuple[DesignCandidate, ...]
    e_a: float
    budget_bytes: Optional[int]

    @property
    def members(self) -> Tuple[PolyMember, ...]:
        return tuple(c.member for c in self.chosen)

    @property
    def total_entries(self) -> int:
        return sum(c.entries for c in self.chosen)

    @property
    def total_bytes(self) -> int:
        return sum(c.total_bytes for c in self.chosen)

    def vmem(self, budget_bytes: int = VMEM_BYTES_V5E) -> VmemCost:
        """Sublane-padded VMEM residency of the planned pack."""
        return vmem_cost_pack(
            [c.entries for c in self.chosen],
            [c.member.n_intervals for c in self.chosen],
            dtype_bytes=[c.bits // 8 for c in self.chosen],
            budget_bytes=budget_bytes,
            meta_lanes=[3 + 3 * c.member.lanes for c in self.chosen],
            ragged_meta=True,
        )

    def describe(self) -> str:
        rows = [f"  {c.name:<12} d={c.degree} {c.dtype:<5} "
                f"entries={c.entries:<5} bytes={c.total_bytes}"
                for c in self.chosen]
        head = (f"PackPlan e_a={self.e_a:g} budget="
                f"{self.budget_bytes if self.budget_bytes else 'none'} "
                f"entries={self.total_entries} bytes={self.total_bytes}")
        return "\n".join([head] + rows)


@obs.traced("design.plan", "design")
def plan(
    names: Sequence[str],
    e_a: float,
    budget_bytes: Optional[int] = None,
    *,
    degrees: Sequence[int] = POLY_DEGREES,
    dtypes: Sequence[str] = POLY_DTYPES,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    rho: float = DEFAULT_RHO,
    cap: int = DEFAULT_REFINE_CAP,
    intervals: Optional[dict] = None,
) -> PackPlan:
    """Pick one design candidate per function.

    ``budget_bytes=None``: every function takes its cheapest candidate
    (bytes, then entries) — the minimal-footprint pack.  With a budget, every
    function starts at its *preferred* candidate (lowest degree, widest
    dtype — fewest runtime FMAs, least rounding) and the planner greedily
    switches the function with the largest byte saving to its cheapest
    candidate until total codes+metadata bytes fit; infeasible budgets raise
    ``ValueError``.  Every returned member independently meets the e_a bound —
    the budget trades bytes against runtime cost, never against accuracy.
    """
    names = tuple(names)
    if not names:
        raise ValueError("plan needs at least one function name")
    intervals = intervals or {}
    menus = {}
    for n in names:
        lo, hi = intervals.get(n, (None, None))
        menus[n] = enumerate_candidates(
            n, e_a, degrees=degrees, dtypes=dtypes, algorithm=algorithm,
            omega=omega, rho=rho, cap=cap, lo=lo, hi=hi)
    if budget_bytes is None:
        chosen = {n: min(menus[n], key=_auto_key) for n in names}
    else:
        if budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive")
        chosen = {n: min(menus[n], key=_preferred_key) for n in names}

        def total():
            return sum(c.total_bytes for c in chosen.values())

        while total() > budget_bytes:
            best_name, best_alt, best_save = None, None, 0
            for n in names:
                alt = min(menus[n], key=_auto_key)
                save = chosen[n].total_bytes - alt.total_bytes
                if save > best_save:
                    best_name, best_alt, best_save = n, alt, save
            if best_name is None:
                raise ValueError(
                    f"pack budget {budget_bytes} B infeasible: the cheapest "
                    f"plan for {names} at e_a={e_a:g} needs {total()} B")
            chosen[best_name] = best_alt
    return PackPlan(names=names, chosen=tuple(chosen[n] for n in names),
                    e_a=float(e_a), budget_bytes=budget_bytes)
