"""TableSpec — the compiled artifact of the design flow (the paper's 'VHDL output').

A :class:`TableSpec` packs everything the lookup hardware (Fig. 7) needs:

  * ``boundaries``  (n+1,)  sub-interval delimiters  P            — interval selector
  * ``inv_delta``   (n,)    1/delta_j reciprocals                 — address generator
  * ``base``        (n,)    BRAM base address A_j of sub-table j  — address generator
  * ``seg_count``   (n,)    kappa_j - 1 segments per sub-interval — address clamp
  * ``values``      (M_F,)  packed range values Y                 — the BRAM content

Evaluation (both the numpy oracle here and the jnp/Pallas runtimes) mirrors the
circuit: select sub-interval j, compute i = floor((x - p_j) * inv_delta_j) clamped to
[0, seg_count_j - 1], fetch y at base_j + i and base_j + i + 1, lerp.

Inputs outside [p_0, p_n) saturate to the boundary sub-intervals — the hardware
analogue of address clamping — so the spec is total on the reals.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .functions import FunctionSpec, get as get_function
from .spacing import SecondDerivMax, reference_spacing
from .splitting import SplitResult, split


@dataclass(frozen=True)
class TableSpec:
    name: str
    lo: float
    hi: float
    e_a: float
    algorithm: str
    boundaries: np.ndarray  # (n+1,) f64
    inv_delta: np.ndarray  # (n,)   f64
    delta: np.ndarray  # (n,)   f64
    base: np.ndarray  # (n,)   i64  — first table index of sub-interval j
    seg_count: np.ndarray  # (n,)   i64  — segments per sub-interval (= kappa_j - 1)
    values: np.ndarray  # (M_F,) f64  — packed breakpoint range values

    @property
    def n_intervals(self) -> int:
        return len(self.boundaries) - 1

    @property
    def footprint(self) -> int:
        """Stored entries, Eq. (13) accounting: sum of per-sub-interval kappa_j."""
        return int(len(self.values))

    def memory_bytes(self, dtype_bytes: int = 4) -> int:
        """Table + selector metadata bytes (the VMEM cost of the runtime kernel).

        Counts every metadata lane the kernel pins — boundaries (n+1), inv_delta,
        base AND seg_count (n each).  Metadata is always f32 at runtime
        (``from_spec`` pins it as float32; ``base`` indices don't even fit
        narrower types exactly), so it is charged at 4 bytes regardless of the
        entry ``dtype_bytes`` — matching :func:`repro.core.bram.vmem_cost`
        (regression-tested against it).
        """
        meta = (self.boundaries.size + self.inv_delta.size + self.base.size
                + self.seg_count.size) * 4
        return self.footprint * dtype_bytes + meta

    # ---------------------------- numpy oracle ----------------------------------

    def eval(self, x: np.ndarray) -> np.ndarray:
        """Piecewise-linear table evaluation; the ground-truth oracle for all runtimes."""
        x = np.asarray(x, dtype=np.float64)
        # interval select: j = (#boundaries <= x) - 1, clamped — the comparator plane
        j = np.searchsorted(self.boundaries, x, side="right") - 1
        j = np.clip(j, 0, self.n_intervals - 1)
        p_j = self.boundaries[j]
        i = np.floor((x - p_j) * self.inv_delta[j]).astype(np.int64)
        i = np.clip(i, 0, self.seg_count[j] - 1)
        a = self.base[j] + i
        y0 = self.values[a]
        y1 = self.values[a + 1]
        x_i = p_j + i * self.delta[j]
        t = (x - x_i) * self.inv_delta[j]
        t = np.clip(t, 0.0, 1.0)  # saturate out-of-range inputs
        return y0 + t * (y1 - y0)

    def max_error_on_grid(self, fn: Optional[FunctionSpec] = None, n: int = 200_001):
        """max |table(x) - f(x)| over a dense probe grid — must be <= e_a (+fp slack)."""
        fn = fn or get_function(self.name)
        xs = np.linspace(self.lo, self.hi, n)
        xs = xs[xs < self.hi]
        return float(np.max(np.abs(self.eval(xs) - np.asarray(fn.f(xs)))))


def build_table(
    fn: FunctionSpec | str,
    e_a: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    algorithm: str = "hierarchical",
    omega: float = 0.3,
    *,
    split_result: Optional[SplitResult] = None,
    **split_kw,
) -> TableSpec:
    """Run the design flow: split the interval, then materialize the packed table."""
    fn = get_function(fn) if isinstance(fn, str) else fn
    lo = fn.interval[0] if lo is None else lo
    hi = fn.interval[1] if hi is None else hi

    if algorithm == "reference":
        oracle = SecondDerivMax(fn, lo, hi)
        ref = reference_spacing(oracle, e_a, lo, hi)
        partition = np.asarray([lo, hi], dtype=np.float64)
        deltas = np.asarray([ref.delta])
        counts = np.asarray([ref.footprint], dtype=np.int64)
    else:
        sr = split_result or split(algorithm, fn, e_a, lo, hi, omega, **split_kw)
        partition, deltas, counts = sr.partition, sr.spacings, sr.counts

    bases, values, deltas_eff = [], [], []
    acc = 0
    for (p0, p1), d, k in zip(zip(partition[:-1], partition[1:]), deltas, counts):
        bases.append(acc)
        # kappa_j = n_seg + 1 entries (Eq. 12).  We place them to span [p0, p1]
        # EXACTLY with d_eff = len/n_seg <= delta: same footprint as the paper's
        # ceil-overshoot layout, but the last segment never extends past p1 where
        # |f''| may exceed the sub-interval max (which would break the Eq. 10
        # guarantee — caught by tests/test_properties.py on tanh).
        n_seg = int(k) - 1
        d_eff = (p1 - p0) / n_seg
        deltas_eff.append(d_eff)
        xs = p0 + d_eff * np.arange(k, dtype=np.float64)
        xs[-1] = p1  # exact, no float drift
        values.append(np.asarray(fn.f(xs), dtype=np.float64))
        acc += int(k)
    deltas = np.asarray(deltas_eff, dtype=np.float64)
    return TableSpec(
        name=fn.name,
        lo=float(lo),
        hi=float(hi),
        e_a=float(e_a),
        algorithm=algorithm,
        boundaries=np.asarray(partition, dtype=np.float64),
        inv_delta=1.0 / np.asarray(deltas, dtype=np.float64),
        delta=np.asarray(deltas, dtype=np.float64),
        base=np.asarray(bases, dtype=np.int64),
        seg_count=np.maximum(np.asarray(counts, dtype=np.int64) - 1, 1),
        values=np.concatenate(values),
    )
