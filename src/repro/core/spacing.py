"""Error-bounded uniform breakpoint spacing — the paper's *Reference* approach.

Implements Eq. (10)-(12):

    E_i      = delta_i^2 / 8 * max|f''|                        (Eq. 10)
    delta    = sqrt(8 * E_a / max_{[a,b)} |f''|)               (Eq. 11)
    M_F      = ceil((b - a) / delta) + 1                       (Eq. 12)

``max|f''|`` over arbitrary sub-intervals is needed *many* times by the splitting
algorithms (a hierarchical sweep evaluates it twice per candidate), so this module
provides :class:`SecondDerivMax` — a sparse-table range-max oracle built once per
(function, base-interval) over a dense grid, answering sub-interval max queries in
O(1).  Endpoint values are always folded in analytically so the result upper-bounds
the grid discretization for the monotone/convex segments the benchmark functions have.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .functions import FunctionSpec


class SecondDerivMax:
    """O(1) range-max queries of |f''| over sub-intervals of a base interval.

    A sparse table (binary-lifting range max) over ``grid_n`` samples of |f''|,
    plus analytic endpoint evaluation.  Build: O(n log n); query: O(1).
    """

    def __init__(self, spec: FunctionSpec, lo: float, hi: float, grid_n: int = 16385):
        if hi <= lo:
            raise ValueError(f"empty base interval [{lo}, {hi})")
        self.spec = spec
        self.lo = float(lo)
        self.hi = float(hi)
        self.grid_n = int(grid_n)
        self._xs = np.linspace(self.lo, self.hi, self.grid_n)
        vals = np.abs(np.asarray(spec.d2f(self._xs), dtype=np.float64))
        if not np.all(np.isfinite(vals)):
            raise ValueError(
                f"|f''| not finite on [{lo}, {hi}) for {spec.name!r}; "
                "the paper's bound (Eq. 10) requires a finite second derivative"
            )
        # sparse table: table[k] holds max over windows of length 2^k
        levels = max(1, int(math.floor(math.log2(self.grid_n))) + 1)
        self._table = [vals]
        for k in range(1, levels):
            prev = self._table[-1]
            half = 1 << (k - 1)
            if len(prev) <= half:
                break
            self._table.append(np.maximum(prev[:-half], prev[half:]))
        self._step = (self.hi - self.lo) / (self.grid_n - 1)

    def query(self, a: float, b: float) -> float:
        """max |f''| over [a, b] (inclusive), clipped to the base interval."""
        if b <= a:
            raise ValueError(f"empty interval [{a}, {b})")
        a = max(a, self.lo)
        b = min(b, self.hi)
        # widen to the surrounding grid points => conservative for any |f''| with
        # bounded variation between samples; endpoints folded in analytically below.
        i0 = max(0, int(math.floor((a - self.lo) / self._step)))
        i1 = min(self.grid_n - 1, int(math.ceil((b - self.lo) / self._step)))
        if i1 <= i0:
            i1 = min(self.grid_n - 1, i0 + 1)
        span = i1 - i0 + 1
        k = span.bit_length() - 1
        if k >= len(self._table):
            k = len(self._table) - 1
        w = 1 << k
        t = self._table[k]
        m = float(max(t[i0], t[i1 - w + 1]))
        # analytic endpoints (exact, independent of grid)
        d2 = self.spec.d2f
        m = max(m, abs(float(d2(np.asarray(a)))), abs(float(d2(np.asarray(b)))))
        return m


@dataclass(frozen=True)
class SpacingResult:
    delta: float
    max_abs_d2: float
    footprint: int


def delta_for(
    spec_or_maxd2, e_a: float, lo: float, hi: float
) -> float:
    """Largest admissible uniform spacing (Eq. 11), capped at the interval length.

    ``spec_or_maxd2`` is either a :class:`FunctionSpec` (direct grid max) or a
    :class:`SecondDerivMax` oracle (O(1) range queries).
    """
    if e_a <= 0:
        raise ValueError("E_a must be positive")
    if hi <= lo:
        raise ValueError(f"empty interval [{lo}, {hi})")
    if isinstance(spec_or_maxd2, SecondDerivMax):
        m = spec_or_maxd2.query(lo, hi)
    else:
        m = spec_or_maxd2.max_abs_d2(lo, hi)
    length = hi - lo
    if m <= 0.0:
        return length  # truly linear on [lo, hi): two breakpoints suffice
    return min(length, math.sqrt(8.0 * e_a / m))


def footprint(delta: float, lo: float, hi: float) -> int:
    """M_F = ceil((hi - lo)/delta) + 1 (Eq. 12), with a float-fuzz guard."""
    if delta <= 0:
        raise ValueError("delta must be positive")
    length = hi - lo
    n_seg = math.ceil(length / delta - 1e-12)
    return int(max(1, n_seg)) + 1


def reference_spacing(
    spec_or_maxd2, e_a: float, lo: float, hi: float
) -> SpacingResult:
    """The paper's *Reference* approach over [lo, hi): one uniform spacing."""
    d = delta_for(spec_or_maxd2, e_a, lo, hi)
    if isinstance(spec_or_maxd2, SecondDerivMax):
        m = spec_or_maxd2.query(lo, hi)
    else:
        m = spec_or_maxd2.max_abs_d2(lo, hi)
    return SpacingResult(delta=d, max_abs_d2=m, footprint=footprint(d, lo, hi))
