"""repro.optim — AdamW, schedules, gradient compression."""
from . import adamw
from .adamw import AdamWConfig
