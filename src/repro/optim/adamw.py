"""AdamW with decoupled weight decay, global-norm clipping, warmup-cosine schedule,
and optional bf16 gradient compression for the cross-pod all-reduce.

Plain pytree implementation (no optax dependency): state = {m, v, count}.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9)) if cfg.clip_norm > 0 else 1.0
    lr = schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step_
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gn, "lr": lr}


def compress_grads_bf16(grads):
    """Optional gradient compression before the cross-pod reduction: halves the
    inter-pod collective bytes at ~1 ulp bf16 cost (DESIGN.md §6)."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
