"""RangeFold: serve unbounded-domain transcendentals from the bounded pack.

The fold math lives in :mod:`repro.core.range_reduce`; this module is the approx
layer around it — the oracles, custom_jvp wrappers, and dispatch plumbing that
turn a reduction + a canonical-interval pack member into a full-f32-range
``sin`` / ``cos`` / ``exp`` / ``log``:

    sin(x) = +-{sin_core, cos_core}(r),     x = k*(pi/2) + r   (octant select)
    exp(x) = 2^k * exp_core(r),             r in [-ln2/2, ln2/2]
    log(x) = e*ln2 + log_core(m),           x = m * 2^e, m in [sqrt2/2, sqrt2)

Two serving shapes, mirroring the pack modes:

* **static** (``folded_pack`` / ``folded_pack_ref``): the fold runs INSIDE the
  fused Pallas kernel (prologue) together with one or two static-fn_id core
  lookups and the reconstruction epilogue
  (:func:`repro.kernels.table_pack_lookup.folded_pack_lookup_pallas`); the jnp
  oracle (:func:`eval_folded_ref`) applies the identical op sequence, so the
  kernel/oracle pair is bit-identical like every other mode pair.
* **routed** (``folded_routed_pack`` / ``folded_routed_pack_ref``): the fold and
  reconstruction run as jnp prologue/epilogue around the existing scalar-prefetch
  ROUTED kernel, which performs the core lookups with runtime fn_ids — bit
  parity reduces to the routed dispatch contract.  Only static (Python-string)
  function names fold; a traced fn_id cannot pick a fold at trace time.

Non-foldable members fall through to the plain pack paths unchanged, so the
``folded_*`` modes are a superset of ``table_pack`` / ``routed_pack``.

Error contracts (verified full-range by ``tests/harness/fullrange.py``): folded
sin/cos/log keep the pack's ABSOLUTE Ea bound over the whole finite f32 range;
folded exp is RELATIVE — ``|err| <= Ea * max(1, |exp(x)|)`` — because the
``2^k`` reconstruction scales the core table's absolute error.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.range_reduce import (exp_edges, exp_fold, exp_reconstruct,
                                     log_edges, log_fold, log_reconstruct,
                                     trig_edges, trig_fold, trig_reconstruct,
                                     trig_slope_reconstruct)

from .table_pack import (eval_pack_ref, eval_pack_slope, eval_routed_ref,
                         make_pack_fn, make_routed_unary_fn)

FOLDED_MODES = ("folded_pack", "folded_pack_ref",
                "folded_routed_pack", "folded_routed_pack_ref")

# The canonical-interval members the folds look up; ApproxConfig.pack() appends
# them to pack_functions whenever a folded mode (or rope_table) needs them.
FOLDED_CORE_MEMBERS = ("sin_core", "cos_core", "exp_core", "log_core")

# foldable member -> core members its reconstruction reads
FOLDABLE = {
    "sin": ("sin_core", "cos_core"),
    "cos": ("sin_core", "cos_core"),
    "exp": ("exp_core",),
    "log": ("log_core",),
}


def _check_cores(pack, name: str) -> None:
    missing = [c for c in FOLDABLE[name] if c not in pack.names]
    if missing:
        raise KeyError(
            f"folded {name!r} needs core members {missing} in the pack; "
            f"pack has {pack.names} (ApproxConfig.pack() appends the cores "
            f"automatically in folded modes)")


def _log_slope_mask(xf):
    """1.0 on positive NORMAL finite lanes, else 0.0 — decided BITWISE.

    XLA's f32 DAZ flush is not applied consistently across a fused
    computation (``x > 0`` can see the subnormal while ``m / x`` sees zero,
    yielding ``inf`` through a supposedly-masked lane), so the slope mask
    must not depend on arithmetic comparisons of a possibly-subnormal x.
    Subnormal lanes get slope 0 like the other non-finite/edge lanes."""
    bits = jax.lax.bitcast_convert_type(xf, jnp.uint32)
    field = (bits >> 23) & jnp.uint32(0xFF)
    pos_normal = ((bits >> 31) == 0) & (field >= 1) & (field <= 254)
    return pos_normal.astype(jnp.float32)


def _log_slope_safe_x(xf):
    """xf with non-(positive-normal) lanes replaced by 1.0, so the masked
    ``m / x`` never divides by a DAZ-flushed zero (0 * inf = NaN otherwise)."""
    mask = _log_slope_mask(xf)
    return xf * mask + (1.0 - mask)


# --------------------------------------------------------------------------------------
# jnp oracles (the *_ref runtimes; also the custom_jvp slope rules)
# --------------------------------------------------------------------------------------


def eval_folded_ref(pack, name: str, x, *, extrapolate: bool = False):
    """Fold + core lookup + reconstruct, all in jnp — the ``folded_pack_ref``
    runtime and the bit-parity oracle of the fused folded kernel.  Non-foldable
    members fall through to :func:`eval_pack_ref`."""
    if name not in FOLDABLE:
        return eval_pack_ref(pack, name, x, extrapolate=extrapolate)
    _check_cores(pack, name)
    xf = jnp.asarray(x).astype(jnp.float32)
    if name in ("sin", "cos"):
        r, q, sflip = trig_fold(xf)
        ys = eval_pack_ref(pack, "sin_core", r)
        yc = eval_pack_ref(pack, "cos_core", r)
        return trig_edges(xf, trig_reconstruct(name, ys, yc, q, sflip))
    if name == "exp":
        r, k = exp_fold(xf)
        return exp_edges(xf, exp_reconstruct(eval_pack_ref(pack, "exp_core", r), k))
    m, e = log_fold(xf)
    return log_edges(xf, log_reconstruct(eval_pack_ref(pack, "log_core", m), e))


def eval_folded_slope(pack, name: str, x, *, extrapolate: bool = False):
    """d/dx of the folded surrogate via chain rule over the CORE table slopes.

    The folds are piecewise-affine in x with unit inner derivative (trig, exp:
    ``dr/dx = 1`` inside each quadrant/octave) or the exact scale factor (log:
    ``dm/dx = m/x``), so the surrogate's derivative is the core chord slope
    transported through the reconstruction.  Non-finite / out-of-support lanes
    return 0 to keep optimizer math finite."""
    if name not in FOLDABLE:
        return eval_pack_slope(pack, name, x, extrapolate=extrapolate)
    _check_cores(pack, name)
    xf = jnp.asarray(x).astype(jnp.float32)
    if name in ("sin", "cos"):
        r, q, sflip = trig_fold(xf)
        ds = eval_pack_slope(pack, "sin_core", r)
        dc = eval_pack_slope(pack, "cos_core", r)
        sl = trig_slope_reconstruct(name, ds, dc, q, sflip)
        return jnp.where(jnp.isfinite(xf), sl, 0.0)
    if name == "exp":
        r, k = exp_fold(xf)
        sl = exp_reconstruct(eval_pack_slope(pack, "exp_core", r), k)
        # the 2^k rescale overflows exactly where exp(x) itself does; zero
        # those lanes too so optimizer math stays finite
        return jnp.where(jnp.isfinite(xf) & jnp.isfinite(sl), sl, 0.0)
    m, e = log_fold(xf)
    return _log_slope_mask(xf) * eval_pack_slope(pack, "log_core", m) \
        * (m / _log_slope_safe_x(xf))


# --------------------------------------------------------------------------------------
# static dispatch (fused fold-in-kernel) and the differentiable wrapper
# --------------------------------------------------------------------------------------


def folded_lookup(pack, name: str, x, *, extrapolate: bool = False):
    """Kernel-side ``folded_pack`` evaluation: the fused fold+lookup kernel for
    foldable members, the plain pack kernel otherwise."""
    from repro.kernels.table_pack_lookup import (folded_pack_lookup_pallas,
                                                 table_pack_lookup_pallas)

    if name in FOLDABLE:
        _check_cores(pack, name)
        return folded_pack_lookup_pallas(pack, name, x)
    return table_pack_lookup_pallas(pack, name, x, extrapolate=extrapolate)


def make_folded_fn(pack, name: str, *, use_pallas: bool = True, exact_d1=None,
                   extrapolate: bool = False):
    """Differentiable full-range unary served through the folded pack — what
    ``ApproxConfig(mode="folded_pack[_ref]").unary`` builds.  Same custom_jvp
    shape as :func:`make_pack_fn`: forward through the fused kernel (or the jnp
    oracle), tangents through the transported core chord slopes."""
    if name not in FOLDABLE:
        return make_pack_fn(pack, name, use_pallas=use_pallas,
                            exact_d1=exact_d1, extrapolate=extrapolate)
    _check_cores(pack, name)
    if use_pallas:
        from repro.kernels.table_pack_lookup import (folded_pack_grad_pallas,
                                                     folded_pack_lookup_pallas)

        fwd_impl = lambda v: folded_pack_lookup_pallas(pack, name, v)
        fused_grad = lambda v: folded_pack_grad_pallas(pack, name, v)
    else:
        fwd_impl = lambda v: eval_folded_ref(pack, name, v)
        fused_grad = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(x)
            slope = exact_d1(x)
        elif fused_grad is not None:
            y, slope = fused_grad(x)
        else:
            y = fwd_impl(x)
            slope = eval_folded_slope(pack, name, x)
        return y, slope * dx

    return f


# --------------------------------------------------------------------------------------
# routed dispatch (fold as jnp prologue/epilogue around the routed kernel)
# --------------------------------------------------------------------------------------


def _routed_core(pack, cname: str, r, use_pallas: bool):
    """One core lookup through the ROUTED path with a uniform static fn_id."""
    v = r.reshape(1, -1)
    if use_pallas:
        from repro.kernels.routed_pack_lookup import routed_pack_lookup_pallas

        out = routed_pack_lookup_pallas(pack, [cname], v)
    else:
        out = eval_routed_ref(pack, [cname], v)
    return out.reshape(r.shape)


def eval_folded_routed(pack, name: str, x, *, use_pallas: bool,
                       extrapolate: bool = False):
    """``folded_routed_pack[_ref]`` evaluation: jnp fold prologue, core lookups
    through the routed dispatch (runtime fn_ids), jnp reconstruction epilogue.

    Only static names fold — the fold choice is made at trace time, so traced
    fn_ids keep plain routed semantics (use :meth:`ApproxConfig.routed_fn`).
    Kernel and oracle share this exact function (``use_pallas`` toggles only the
    inner routed call), so the pair's bit parity follows from the routed
    dispatch contract."""
    if name not in FOLDABLE:
        v = jnp.asarray(x).reshape(1, -1)
        if use_pallas:
            from repro.kernels.routed_pack_lookup import \
                routed_pack_lookup_pallas

            out = routed_pack_lookup_pallas(pack, [name], v,
                                            extrapolate=extrapolate)
        else:
            out = eval_routed_ref(pack, [name], v, extrapolate=extrapolate)
        return out.reshape(jnp.asarray(x).shape)
    _check_cores(pack, name)
    xf = jnp.asarray(x).astype(jnp.float32)
    if name in ("sin", "cos"):
        r, q, sflip = trig_fold(xf)
        ys = _routed_core(pack, "sin_core", r, use_pallas)
        yc = _routed_core(pack, "cos_core", r, use_pallas)
        return trig_edges(xf, trig_reconstruct(name, ys, yc, q, sflip))
    if name == "exp":
        r, k = exp_fold(xf)
        yc = _routed_core(pack, "exp_core", r, use_pallas)
        return exp_edges(xf, exp_reconstruct(yc, k))
    m, e = log_fold(xf)
    yc = _routed_core(pack, "log_core", m, use_pallas)
    return log_edges(xf, log_reconstruct(yc, e))


def make_folded_routed_unary_fn(pack, name: str, *, use_pallas: bool = True,
                                exact_d1=None, extrapolate: bool = False):
    """Differentiable folded unary over the ROUTED core lookups — what
    ``ApproxConfig(mode="folded_routed_pack[_ref]").unary`` builds.  Slopes run
    through the jnp chain rule (:func:`eval_folded_slope`); like the plain
    routed unary, every foldable member shares the routed executable."""
    if name not in FOLDABLE:
        return make_routed_unary_fn(pack, name, use_pallas=use_pallas,
                                    exact_d1=exact_d1, extrapolate=extrapolate)

    fwd_impl = lambda v: eval_folded_routed(pack, name, v,
                                            use_pallas=use_pallas)

    @jax.custom_jvp
    def f(x):
        return fwd_impl(x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        y = fwd_impl(x)
        slope = exact_d1(x) if exact_d1 is not None \
            else eval_folded_slope(pack, name, x)
        return y, slope * dx

    return f
