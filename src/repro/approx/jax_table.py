"""JAX runtime of a :class:`repro.core.table.TableSpec`.

The evaluation mirrors the paper's Fig. 7 circuit, adapted to a SIMD machine
(see DESIGN.md §2):

  interval selector  — branchless comparator *plane*: one vector compare per interior
                       boundary, accumulated into running selects of (p_j, inv_d_j,
                       base_j, seg_j).  No gather, no tree: cost is n-1 FMAs/compares
                       per element, n = #sub-intervals (<= ~32 in practice).
  address generator  — i = floor((x - p_j) * inv_d_j), clamped to the sub-table.
  BRAM lookup        — one adjacent-pair gather from the packed values vector.
  interpolation      — a single FMA: y0 + t * (y1 - y0).

``eval_table_ref`` is the pure-jnp oracle (differentiable via the table slope through
``make_table_fn``); the Pallas kernel in ``repro.kernels.table_lookup`` implements the
same contract with the table VMEM-resident.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import TableSpec


class JaxTable(NamedTuple):
    """Device-ready table artifact (all leaves are jnp arrays; shapes static)."""

    boundaries: jax.Array  # (n+1,) f32
    inv_delta: jax.Array  # (n,)   f32
    delta: jax.Array  # (n,)   f32
    base: jax.Array  # (n,)   f32 (exact integers < 2^24; float keeps the VPU path)
    seg_count: jax.Array  # (n,)   f32
    values: jax.Array  # (M_F,) f32

    @property
    def n_intervals(self) -> int:
        return self.inv_delta.shape[0]

    @property
    def footprint(self) -> int:
        return self.values.shape[0]


def from_spec(spec: TableSpec, dtype=jnp.float32) -> JaxTable:
    if spec.footprint >= (1 << 24):
        raise ValueError("table footprint exceeds f32 exact-integer range")
    return JaxTable(
        boundaries=jnp.asarray(spec.boundaries, dtype=dtype),
        inv_delta=jnp.asarray(spec.inv_delta, dtype=dtype),
        delta=jnp.asarray(spec.delta, dtype=dtype),
        base=jnp.asarray(spec.base.astype(np.float64), dtype=dtype),
        seg_count=jnp.asarray(spec.seg_count.astype(np.float64), dtype=dtype),
        values=jnp.asarray(spec.values, dtype=dtype),
    )


def _select_params(jt: JaxTable, xf: jax.Array):
    """Comparator plane: per-element (p_j, inv_d_j, base_j, seg_j) as running sums.

    For sorted boundaries b_0..b_n the sub-interval parameters are
        p(x) = b_0 + sum_m [x >= b_m] (b_m - b_{m-1})   (same for invd/base/segs)
    i.e. a mux tree flattened into FMAs — no gather, no branches.
    """
    p = jnp.full_like(xf, jt.boundaries[0])
    invd = jnp.full_like(xf, jt.inv_delta[0])
    base = jnp.full_like(xf, jt.base[0])
    segs = jnp.full_like(xf, jt.seg_count[0])
    for m in range(1, jt.n_intervals):
        ge = (xf >= jt.boundaries[m]).astype(jnp.float32)
        p = p + ge * (jt.boundaries[m] - jt.boundaries[m - 1])
        invd = invd + ge * (jt.inv_delta[m] - jt.inv_delta[m - 1])
        base = base + ge * (jt.base[m] - jt.base[m - 1])
        segs = segs + ge * (jt.seg_count[m] - jt.seg_count[m - 1])
    return p, invd, base, segs


def eval_table_ref(jt: JaxTable, x: jax.Array, *, extrapolate: bool = False) -> jax.Array:
    """Pure-jnp table evaluation — the oracle for the Pallas kernel.

    ``extrapolate=False`` saturates out-of-interval inputs at the edge breakpoint
    values (the hardware's address clamp).  ``extrapolate=True`` instead lets the
    *edge segments* extend linearly (the lerp parameter is left unclamped), which is
    the right semantic for activations with linear asymptotes (gelu/silu/softplus):
    zero extra hardware, asymptotically-correct tails.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs = _select_params(jt, xf)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(jt.values, a, axis=0)
    y1 = jnp.take(jt.values, a + 1, axis=0)
    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    return (y0 + t * (y1 - y0)).astype(dtype)


def eval_table_slope(
    jt: JaxTable, x: jax.Array, *, extrapolate: bool = False
) -> jax.Array:
    """d/dx of the piecewise-linear surrogate: the segment slope (a.e. derivative)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs = _select_params(jt, xf)
    i = jnp.clip(jnp.floor((xf - p) * invd), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(jt.values, a, axis=0)
    y1 = jnp.take(jt.values, a + 1, axis=0)
    slope = (y1 - y0) * invd
    if not extrapolate:
        inside = (xf >= jt.boundaries[0]) & (xf < jt.boundaries[-1])
        slope = slope * inside.astype(jnp.float32)
    return slope.astype(dtype)


def make_table_fn(
    jt: JaxTable,
    *,
    use_pallas: bool = False,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Build a differentiable unary ``f(x)`` from a table.

    Tangent rule: table slope by default (faithful to what the hardware computes);
    pass ``exact_d1`` (a jnp-callable) to use the analytic derivative instead.
    """
    if use_pallas:
        from repro.kernels.ops import table_lookup as fwd_impl  # lazy; optional dep
        from repro.kernels.table_grad import table_lookup_grad_pallas
    else:
        fwd_impl = eval_table_ref
        table_lookup_grad_pallas = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(jt, x, extrapolate=extrapolate)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(jt, x, extrapolate=extrapolate)
            slope = exact_d1(x)
        elif use_pallas:
            # fused kernel: one selector pass yields value AND slope
            y, slope = table_lookup_grad_pallas(jt, x, extrapolate=extrapolate)
        else:
            y = fwd_impl(jt, x, extrapolate=extrapolate)
            slope = eval_table_slope(jt, x, extrapolate=extrapolate)
        return y, slope * dx

    return f
