"""JAX runtime of a :class:`repro.core.table.TableSpec`.

The evaluation mirrors the paper's Fig. 7 circuit, adapted to a SIMD machine
(see DESIGN.md §2):

  interval selector  — branchless comparator *plane*: ONE broadcast compare of x
                       against the whole boundary vector plus one sum-reduction
                       yields j = #(x >= b_m); four tiny gathers then fetch
                       (p_j, inv_d_j, base_j, seg_j).  No per-boundary FMA chain:
                       the old running-select accumulation serialized n-1
                       dependent FMAs per parameter and drifted by accumulated
                       rounding; the gather form is exact and O(1)-depth.
  address generator  — i = floor((x - p_j) * inv_d_j), clamped to the sub-table.
  BRAM lookup        — one adjacent-pair gather from the packed values vector.
  interpolation      — a single FMA: y0 + t * (y1 - y0).

``eval_table_ref`` is the pure-jnp oracle (differentiable via the table slope through
``make_table_fn``); the Pallas kernel in ``repro.kernels.table_lookup`` implements the
same contract with the table VMEM-resident.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import TableSpec


class JaxTable(NamedTuple):
    """Device-ready table artifact (all leaves are jnp arrays; shapes static)."""

    boundaries: jax.Array  # (n+1,) f32
    inv_delta: jax.Array  # (n,)   f32
    delta: jax.Array  # (n,)   f32
    base: jax.Array  # (n,)   f32 (exact integers < 2^24; float keeps the VPU path)
    seg_count: jax.Array  # (n,)   f32
    values: jax.Array  # (M_F,) f32

    @property
    def n_intervals(self) -> int:
        return self.inv_delta.shape[0]

    @property
    def footprint(self) -> int:
        return self.values.shape[0]


def from_spec(spec: TableSpec, dtype=jnp.float32) -> JaxTable:
    if spec.footprint >= (1 << 24):
        raise ValueError("table footprint exceeds f32 exact-integer range")
    return JaxTable(
        boundaries=jnp.asarray(spec.boundaries, dtype=dtype),
        inv_delta=jnp.asarray(spec.inv_delta, dtype=dtype),
        delta=jnp.asarray(spec.delta, dtype=dtype),
        base=jnp.asarray(spec.base.astype(np.float64), dtype=dtype),
        seg_count=jnp.asarray(spec.seg_count.astype(np.float64), dtype=dtype),
        values=jnp.asarray(spec.values, dtype=dtype),
    )


def select_interval(boundaries: jax.Array, n_intervals: int, xf: jax.Array) -> jax.Array:
    """Vectorized comparator plane: j(x) = clip(#(x >= b_m, m >= 1), 0, n-1).

    One broadcast compare against the (n,) interior+upper boundary row and one
    sum-reduction per element; ``boundaries`` may be right-padded (e.g. with
    ``+inf`` in a multi-function pack plane) — padding never compares true, and
    the clip pins x >= hi into the last real sub-interval (the address clamp).
    """
    j = jnp.sum((xf[..., None] >= boundaries[1:]).astype(jnp.int32), axis=-1)
    return jnp.minimum(j, n_intervals - 1)


def _select_params(jt: JaxTable, xf: jax.Array):
    """Per-element (p_j, inv_d_j, base_j, seg_j): one selector, four gathers."""
    j = select_interval(jt.boundaries, jt.n_intervals, xf)
    p = jnp.take(jt.boundaries, j, axis=0)
    invd = jnp.take(jt.inv_delta, j, axis=0)
    base = jnp.take(jt.base, j, axis=0)
    segs = jnp.take(jt.seg_count, j, axis=0)
    return p, invd, base, segs


def eval_table_ref(jt: JaxTable, x: jax.Array, *, extrapolate: bool = False) -> jax.Array:
    """Pure-jnp table evaluation — the oracle for the Pallas kernel.

    ``extrapolate=False`` saturates out-of-interval inputs at the edge breakpoint
    values (the hardware's address clamp).  ``extrapolate=True`` instead lets the
    *edge segments* extend linearly (the lerp parameter is left unclamped), which is
    the right semantic for activations with linear asymptotes (gelu/silu/softplus):
    zero extra hardware, asymptotically-correct tails.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs = _select_params(jt, xf)
    u = (xf - p) * invd
    i = jnp.clip(jnp.floor(u), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(jt.values, a, axis=0)
    y1 = jnp.take(jt.values, a + 1, axis=0)
    t = u - i
    if not extrapolate:
        t = jnp.clip(t, 0.0, 1.0)
    return (y0 + t * (y1 - y0)).astype(dtype)


def eval_table_slope(
    jt: JaxTable, x: jax.Array, *, extrapolate: bool = False
) -> jax.Array:
    """d/dx of the piecewise-linear surrogate: the segment slope (a.e. derivative)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    p, invd, base, segs = _select_params(jt, xf)
    i = jnp.clip(jnp.floor((xf - p) * invd), 0.0, segs - 1.0)
    a = (base + i).astype(jnp.int32)
    y0 = jnp.take(jt.values, a, axis=0)
    y1 = jnp.take(jt.values, a + 1, axis=0)
    slope = (y1 - y0) * invd
    if not extrapolate:
        inside = (xf >= jt.boundaries[0]) & (xf < jt.boundaries[-1])
        slope = slope * inside.astype(jnp.float32)
    return slope.astype(dtype)


def make_table_fn(
    jt: JaxTable,
    *,
    use_pallas: bool = False,
    exact_d1=None,
    extrapolate: bool = False,
):
    """Build a differentiable unary ``f(x)`` from a table.

    Tangent rule: table slope by default (faithful to what the hardware computes);
    pass ``exact_d1`` (a jnp-callable) to use the analytic derivative instead.
    """
    if use_pallas:
        from repro.kernels.ops import table_lookup as fwd_impl  # lazy; optional dep
        from repro.kernels.table_grad import table_lookup_grad_pallas
    else:
        fwd_impl = eval_table_ref
        table_lookup_grad_pallas = None

    @jax.custom_jvp
    def f(x):
        return fwd_impl(jt, x, extrapolate=extrapolate)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        if exact_d1 is not None:
            y = fwd_impl(jt, x, extrapolate=extrapolate)
            slope = exact_d1(x)
        elif use_pallas:
            # fused kernel: one selector pass yields value AND slope
            y, slope = table_lookup_grad_pallas(jt, x, extrapolate=extrapolate)
        else:
            y = fwd_impl(jt, x, extrapolate=extrapolate)
            slope = eval_table_slope(jt, x, extrapolate=extrapolate)
        return y, slope * dx

    return f
