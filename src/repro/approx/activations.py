"""Nonlinearity backend: every elementary function the model zoo evaluates can run
``exact`` (jnp transcendentals), ``table_ref`` (paper-faithful jnp table),
``table_pallas`` (fused VMEM kernel, one table per function), ``table_pack``
(ONE packed multi-function artifact + one fused kernel for the whole network),
or ``table_pack_ref`` (the pack's jnp oracle).  Configured per-model via
:class:`ApproxConfig`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.flow import cached_table
from repro.core.functions import get as get_function

from .jax_table import JaxTable, from_spec, make_table_fn
from .table_pack import TablePack, build_pack, make_pack_fn

Mode = str  # "exact" | "table_ref" | "table_pallas" | "table_pack" | "table_pack_ref"

TABLE_MODES = ("table_ref", "table_pallas", "table_pack", "table_pack_ref")
PACK_MODES = ("table_pack", "table_pack_ref")

# The function set the model zoo routes through the approx backend (post
# _TABLE_NAME remap).  One pack built over this set serves every architecture:
# gelu/silu for MLPs, tanh + sigmoid_sym for gates/softcap, softplus for SSM
# dt, exp_neg for the softmax exponent.
DEFAULT_PACK_FUNCTIONS = (
    "gelu", "silu", "tanh", "sigmoid_sym", "softplus", "exp_neg",
)

# One pack per distinct (functions, e_a, algorithm, omega, intervals) — model
# constructors re-request the same pack for every layer/activation.
_PACK_CACHE: Dict[tuple, TablePack] = {}

_EXACT: Dict[str, Callable] = {
    "gelu": lambda x: jax.nn.gelu(x, approximate=False),
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "sigmoid_sym": jax.nn.sigmoid,
    "softplus": jax.nn.softplus,
    "exp": jnp.exp,
    "exp_neg": jnp.exp,
    "erf": jax.scipy.special.erf,
    "relu": jax.nn.relu,  # piecewise-linear already; never table'd
    "identity": lambda x: x,
}

# Registry-name remaps for activations whose table spec differs from the exact name.
_TABLE_NAME = {
    "gelu_tanh": "gelu",  # tanh-GELU ~ erf-GELU within 1e-3; table targets exact GELU
    "sigmoid": "sigmoid_sym",
    "exp": "exp_neg",
}

_NEVER_TABLED = {"relu", "identity"}

# Activations with linear asymptotes: extend the edge segments linearly instead of
# saturating (see jax_table.eval_table_ref docstring).  Flat-asymptote functions
# (tanh/sigmoid/exp_neg) keep the hardware clamp — it IS their asymptote.
_EXTRAPOLATE = {"gelu", "gelu_tanh", "silu", "softplus"}


@dataclass(frozen=True)
class ApproxConfig:
    """How the model evaluates its elementary functions.

    ``e_a`` is the paper's maximum absolute approximation error; ``algorithm`` /
    ``omega`` select the interval splitter.  ``softmax_table`` additionally routes
    the attention/router softmax exponent through the exp table (ablation feature).
    """

    mode: Mode = "exact"
    e_a: float = 1e-4
    algorithm: str = "hierarchical"
    omega: float = 0.3
    exact_grad: bool = False
    softmax_table: bool = False
    interval_overrides: Dict[str, Tuple[float, float]] = field(default_factory=dict)
    pack_functions: Tuple[str, ...] = DEFAULT_PACK_FUNCTIONS

    def table_for(self, name: str) -> JaxTable:
        reg_name = _TABLE_NAME.get(name, name)
        lo, hi = self.interval_overrides.get(reg_name, (None, None))
        spec = cached_table(
            reg_name, self.e_a, lo, hi, algorithm=self.algorithm, omega=self.omega
        )
        return from_spec(spec)

    def pack(self) -> TablePack:
        """The ONE multi-function pack this config's activations share."""
        names = tuple(self.pack_functions)
        overrides = tuple(sorted(
            (k, v) for k, v in self.interval_overrides.items() if k in names))
        key = (names, self.e_a, self.algorithm, self.omega, overrides)
        if key not in _PACK_CACHE:
            _PACK_CACHE[key] = build_pack(
                names, self.e_a, algorithm=self.algorithm, omega=self.omega,
                intervals=dict(overrides))
        return _PACK_CACHE[key]

    def unary(self, name: str) -> Callable[[jax.Array], jax.Array]:
        """The activation callable for this config."""
        if self.mode == "exact" or name in _NEVER_TABLED:
            return _EXACT[name]
        if self.mode not in TABLE_MODES:
            raise ValueError(f"unknown approx mode {self.mode!r}")
        reg_name = _TABLE_NAME.get(name, name)
        exact_d1 = None
        if self.exact_grad:
            fn = get_function(reg_name)
            exact_d1 = partial(fn.d1f, xp=jnp)
        if self.mode in PACK_MODES:
            pack = self.pack()
            if reg_name not in pack.names:
                raise KeyError(
                    f"{reg_name!r} is not in pack_functions={pack.names}; add it "
                    f"to ApproxConfig.pack_functions to serve it from the pack")
            return make_pack_fn(
                pack,
                reg_name,
                use_pallas=(self.mode == "table_pack"),
                exact_d1=exact_d1,
                extrapolate=(name in _EXTRAPOLATE),
            )
        jt = self.table_for(name)
        return make_table_fn(
            jt,
            use_pallas=(self.mode == "table_pallas"),
            exact_d1=exact_d1,
            extrapolate=(name in _EXTRAPOLATE),
        )

    def softmax(self, x: jax.Array, axis: int = -1, where=None) -> jax.Array:
        """Numerically-shifted softmax; exponent optionally via the exp_neg table."""
        if not self.softmax_table or self.mode == "exact":
            return jax.nn.softmax(x, axis=axis, where=where)
        exp_fn = self.unary("exp")
        m = jnp.max(x, axis=axis, keepdims=True, where=where, initial=-1e30)
        z = x - jax.lax.stop_gradient(m)
        # table domain is [-16, 0]; clamp matches the hardware address saturation
        e = exp_fn(jnp.maximum(z, -16.0))
        if where is not None:
            e = jnp.where(where, e, 0.0)
        return e / jnp.sum(e, axis=axis, keepdims=True)


EXACT = ApproxConfig(mode="exact")


def get_exact(name: str) -> Callable:
    return _EXACT[name]
