"""Nonlinearity backend: every elementary function the model zoo evaluates can run
``exact`` (jnp transcendentals), ``table_ref`` (paper-faithful jnp table),
``table_pallas`` (fused VMEM kernel, one table per function), ``table_pack``
(ONE packed multi-function artifact + one fused kernel for the whole network),
``table_pack_ref`` (the pack's jnp oracle), ``quant_pack`` (the pack with
int8/int16 entry codes + dequantize-on-read kernels), ``quant_pack_ref``
(the quantized pack's jnp oracle), ``poly_pack`` / ``poly_pack_ref`` (the
Pareto-planned pack: per-function degree-1..3 Horner cells in the cheapest of
int8/int16/f32, picked by :func:`repro.core.design.plan`), or the ``routed_*``
variants (``routed_pack`` / ``routed_pack_ref`` / ``routed_quant_pack`` /
``routed_quant_pack_ref`` / ``routed_poly_pack`` / ``routed_poly_pack_ref``),
which serve the same packs through DYNAMIC
per-row fn_id dispatch — the function identity is a runtime operand of a
scalar-prefetch kernel, so mixed-function batches (MoE-style routed
activations; see :meth:`ApproxConfig.routed_fn`) and every member's unary
share one compiled executable — or the ``sharded_pack`` / ``sharded_pack_ref``
variants, which partition the pack's values vector ``pack_shards`` ways over
the mesh 'model' axis (per-shard base rebasing, shard-local masked lookup,
psum combine) for packs that outgrow one core's VMEM, or the ``folded_*``
variants (``folded_pack`` / ``folded_pack_ref`` / ``folded_routed_pack`` /
``folded_routed_pack_ref``), which put a RANGE-REDUCTION stage
(:mod:`repro.core.range_reduce`) in front of the pack so ``sin`` / ``cos`` /
``exp`` / ``log`` are served over the ENTIRE finite f32 domain from small
canonical-interval core members — fused fold+lookup kernel in the static
shape, jnp fold around the routed kernel in the routed shape.  Configured
per-model via :class:`ApproxConfig`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.flow import cached_table
from repro.core.functions import get as get_function

from .jax_table import JaxTable, from_spec, make_table_fn
from .range_fold import (FOLDABLE, FOLDED_CORE_MEMBERS, FOLDED_MODES,
                         make_folded_fn, make_folded_routed_unary_fn)
from .table_pack import (PolyTablePack, QuantTablePack, ShardedTablePack,
                         TablePack, build_pack, build_poly_pack,
                         build_quant_pack, build_sharded_pack,
                         make_attn_exp_fn, make_pack_fn, make_poly_pack_fn,
                         make_quant_pack_fn, make_routed_fn,
                         make_routed_unary_fn, make_sharded_pack_fn,
                         member_domain, quant_saturation_counts)

Mode = str  # "exact" | "table_ref" | "table_pallas" | "table_pack" |
#             "table_pack_ref" | "quant_pack" | "quant_pack_ref" |
#             "poly_pack" | "poly_pack_ref" |
#             "routed_pack" | "routed_pack_ref" | "routed_quant_pack" |
#             "routed_quant_pack_ref" | "routed_poly_pack" |
#             "routed_poly_pack_ref" | "sharded_pack" | "sharded_pack_ref" |
#             "folded_pack" | "folded_pack_ref" | "folded_routed_pack" |
#             "folded_routed_pack_ref"

ROUTED_MODES = ("routed_pack", "routed_pack_ref", "routed_quant_pack",
                "routed_quant_pack_ref", "routed_poly_pack",
                "routed_poly_pack_ref")
SHARDED_MODES = ("sharded_pack", "sharded_pack_ref")
PACK_MODES = ("table_pack", "table_pack_ref")
QUANT_PACK_MODES = ("quant_pack", "quant_pack_ref")
POLY_PACK_MODES = ("poly_pack", "poly_pack_ref")
TABLE_MODES = (("table_ref", "table_pallas") + PACK_MODES + QUANT_PACK_MODES
               + POLY_PACK_MODES + ROUTED_MODES + SHARDED_MODES
               + FOLDED_MODES)
# modes whose pack artifact is the quantized one (vs the f32 pack)
_QUANT_BACKED = QUANT_PACK_MODES + ("routed_quant_pack", "routed_quant_pack_ref")
# modes whose pack artifact is the Pareto-planned polynomial one
_POLY_BACKED = POLY_PACK_MODES + ("routed_poly_pack", "routed_poly_pack_ref")
# modes whose runtime is the Pallas kernels (vs a jnp oracle)
_PALLAS_BACKED = ("table_pallas", "table_pack", "quant_pack", "poly_pack",
                  "routed_pack", "routed_quant_pack", "routed_poly_pack",
                  "sharded_pack", "folded_pack", "folded_routed_pack")


def odd_extension(fn):
    """Extend an odd function's negative-half approximator to all reals.

    The paper tables tanh on its Table-2 interval [-8, 0); gates and softcap
    need both signs.  For odd f, f(x) = s * f(s*x) with s = -sign(x) reuses
    the same table with zero extra entries (the BRAM-side trick behind
    sigmoid_sym).  The mirror factor is a branchless where (not jnp.sign/abs,
    whose zero tangent at x = 0 would kill the derivative there): s is
    piecewise constant, so the chain rule yields s * f'(s*x) * s = f'(s*x)
    everywhere, including the origin.
    """

    def extended(x):
        # weak-typed mirror factor: preserves bf16/f32 inputs, accepts scalars
        s = jnp.where(jnp.asarray(x) >= 0, -1.0, 1.0)
        return s * fn(s * x)

    return extended


# Registry tables spanning only the negative half-domain of an odd function:
# every table-mode ``unary`` routes them through ``odd_extension`` so gates,
# softcap, and any other symmetric-domain consumer get correct values for
# x > 0 (the raw table would saturate to f(0) there).  Sigmoid instead remaps
# to the registered symmetric variant ``sigmoid_sym`` (see _TABLE_NAME) — the
# two halves of the ROADMAP's symmetric-domain item.
_ODD_HALF_DOMAIN = {"tanh"}

# The function set the model zoo routes through the approx backend (post
# _TABLE_NAME remap).  One pack built over this set serves every architecture:
# gelu/silu for MLPs, tanh + sigmoid_sym for gates/softcap, softplus for SSM
# dt, exp_neg for the softmax exponent.
DEFAULT_PACK_FUNCTIONS = (
    "gelu", "silu", "tanh", "sigmoid_sym", "softplus", "exp_neg",
)

# One pack per distinct (functions, e_a, algorithm, omega, intervals) — model
# constructors re-request the same pack for every layer/activation.
_PACK_CACHE: Dict[tuple, TablePack] = {}
_QUANT_PACK_CACHE: Dict[tuple, QuantTablePack] = {}
_POLY_PACK_CACHE: Dict[tuple, PolyTablePack] = {}
_SHARDED_PACK_CACHE: Dict[tuple, ShardedTablePack] = {}
# one (sin, cos) closure pair per distinct rope_table configuration — every
# layer's rotary shares the same compiled folded-trig executables
_ROPE_SIN_COS_CACHE: Dict[tuple, Callable] = {}
# one TableFlash exponent closure per distinct attn_table configuration —
# every attention layer shares the same compiled exp_neg lookup executables
_ATTN_EXP_CACHE: Dict[tuple, Callable] = {}

_EXACT: Dict[str, Callable] = {
    "gelu": lambda x: jax.nn.gelu(x, approximate=False),
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "sigmoid_sym": jax.nn.sigmoid,
    "softplus": jax.nn.softplus,
    "exp": jnp.exp,
    "exp_neg": jnp.exp,
    "sin": jnp.sin,
    "cos": jnp.cos,
    "log": jnp.log,
    "erf": jax.scipy.special.erf,
    "relu": jax.nn.relu,  # piecewise-linear already; never table'd
    "identity": lambda x: x,
}

# Registry-name remaps for activations whose table spec differs from the exact name.
_TABLE_NAME = {
    "gelu_tanh": "gelu",  # tanh-GELU ~ erf-GELU within 1e-3; table targets exact GELU
    "sigmoid": "sigmoid_sym",
    "exp": "exp_neg",
}

_NEVER_TABLED = {"relu", "identity"}

# Activations with linear asymptotes: extend the edge segments linearly instead of
# saturating (see jax_table.eval_table_ref docstring).  Flat-asymptote functions
# (tanh/sigmoid/exp_neg) keep the hardware clamp — it IS their asymptote.
_EXTRAPOLATE = {"gelu", "gelu_tanh", "silu", "softplus"}


def _routed_exact(names):
    """Exact-mode routed fallback: row-select over the exact activations."""
    for n in names:
        if not isinstance(n, str) or n not in _EXACT:
            raise KeyError(f"exact-mode routing needs activation names, "
                           f"got {n!r}")
    uniq = tuple(dict.fromkeys(names))

    def f(x):
        sel = (len(names),) + (1,) * (x.ndim - 1)
        y = None
        for u in uniq:
            yu = _EXACT[u](x)
            mask = jnp.asarray(np.asarray([n == u for n in names])).reshape(sel)
            y = yu if y is None else jnp.where(mask, yu, y)
        return y

    return f


@dataclass(frozen=True)
class ApproxConfig:
    """How the model evaluates its elementary functions.

    ``e_a`` is the paper's maximum absolute approximation error; ``algorithm`` /
    ``omega`` select the interval splitter.  ``softmax_table`` additionally routes
    the attention/router softmax exponent through the exp table (ablation feature).
    """

    mode: Mode = "exact"
    e_a: float = 1e-4
    algorithm: str = "hierarchical"
    omega: float = 0.3
    exact_grad: bool = False
    softmax_table: bool = False
    interval_overrides: Dict[str, Tuple[float, float]] = field(default_factory=dict)
    pack_functions: Tuple[str, ...] = DEFAULT_PACK_FUNCTIONS
    # quant_pack modes: interpolation gets quant_rho * e_a, code rounding the
    # rest; pack_dtype picks the stored width ("auto" = per-function cheapest
    # of int8/int16 from the budget split, or force "int8"/"int16").
    quant_rho: float = 0.9
    pack_dtype: str = "auto"
    # poly_pack modes: optional total-bytes budget handed to the design-space
    # planner (``design.plan``) — None keeps every function's Pareto-cheapest
    # candidate; a budget greedily downgrades members until the pack fits.
    # ``quant_rho`` / ``pack_dtype`` double as planner hints: the interp/quant
    # error split and the candidate dtype menu ("auto" = int8/int16/f32).
    pack_budget: Optional[int] = None
    # sharded_pack modes: how many ways the pack's values vector is split
    # (sub-interval granularity, per-shard base rebasing).  Runs distributed
    # when a use_sharding mesh binds a 'model' axis of this width, otherwise
    # as a stacked-shard-axis sum on one device — bit-identical either way.
    pack_shards: int = 2
    # serve RoPE's per-position sin/cos rotations from the folded table path
    # (any table mode; the f32 pack gains the trig core members).  Off keeps
    # exact jnp trig in the rotary embedding.
    rope_table: bool = False
    # TableFlash: serve flash attention's running-softmax exponent from the
    # pack's exp_neg member (any table mode; always the f32 pack, Pallas
    # kernel vs jnp oracle decided by the mode).  Off keeps exact jnp.exp in
    # the attention inner loop.  Error contract: repro.core.attn_error.
    attn_table: bool = False

    def table_for(self, name: str) -> JaxTable:
        reg_name = _TABLE_NAME.get(name, name)
        lo, hi = self.interval_overrides.get(reg_name, (None, None))
        spec = cached_table(
            reg_name, self.e_a, lo, hi, algorithm=self.algorithm, omega=self.omega
        )
        return from_spec(spec)

    def pack(self) -> TablePack:
        """The ONE multi-function pack this config's activations share.

        Folded modes (and ``rope_table``) extend ``pack_functions`` with the
        canonical-interval core members the range reductions look up
        (:data:`repro.approx.range_fold.FOLDED_CORE_MEMBERS`)."""
        names = tuple(self.pack_functions)
        if self.mode in FOLDED_MODES or self.rope_table:
            names += tuple(c for c in FOLDED_CORE_MEMBERS if c not in names)
        overrides = tuple(sorted(
            (k, v) for k, v in self.interval_overrides.items() if k in names))
        key = (names, self.e_a, self.algorithm, self.omega, overrides)
        if key not in _PACK_CACHE:
            _PACK_CACHE[key] = build_pack(
                names, self.e_a, algorithm=self.algorithm, omega=self.omega,
                intervals=dict(overrides))
        return _PACK_CACHE[key]

    def quant_pack(self) -> QuantTablePack:
        """The shared quantized pack (int8/int16 codes, dequantize-on-read)."""
        names = tuple(self.pack_functions)
        overrides = tuple(sorted(
            (k, v) for k, v in self.interval_overrides.items() if k in names))
        key = (names, self.e_a, self.algorithm, self.omega, overrides,
               self.quant_rho, self.pack_dtype)
        if key not in _QUANT_PACK_CACHE:
            _QUANT_PACK_CACHE[key] = build_quant_pack(
                names, self.e_a, rho=self.quant_rho, dtype=self.pack_dtype,
                algorithm=self.algorithm, omega=self.omega,
                intervals=dict(overrides))
        return _QUANT_PACK_CACHE[key]

    def poly_pack(self) -> PolyTablePack:
        """The shared Pareto-planned pack (degree-1..3 cells, mixed widths)."""
        names = tuple(self.pack_functions)
        overrides = tuple(sorted(
            (k, v) for k, v in self.interval_overrides.items() if k in names))
        key = (names, self.e_a, self.algorithm, self.omega, overrides,
               self.quant_rho, self.pack_dtype, self.pack_budget)
        if key not in _POLY_PACK_CACHE:
            _POLY_PACK_CACHE[key] = build_poly_pack(
                names, self.e_a, budget_bytes=self.pack_budget,
                rho=self.quant_rho, dtype=self.pack_dtype,
                algorithm=self.algorithm, omega=self.omega,
                intervals=dict(overrides))
        return _POLY_PACK_CACHE[key]

    def _sharded_key(self) -> tuple:
        names = tuple(self.pack_functions)
        overrides = tuple(sorted(
            (k, v) for k, v in self.interval_overrides.items() if k in names))
        return (names, self.e_a, self.algorithm, self.omega, overrides,
                self.pack_shards)

    def sharded_pack(self) -> ShardedTablePack:
        """The shared pack, values-sharded ``pack_shards`` ways over 'model'."""
        key = self._sharded_key()
        if key not in _SHARDED_PACK_CACHE:
            names, e_a, algorithm, omega, overrides, shards = key
            _SHARDED_PACK_CACHE[key] = build_sharded_pack(
                names, e_a, shards, algorithm=algorithm, omega=omega,
                intervals=dict(overrides))
        return _SHARDED_PACK_CACHE[key]

    def place_packs(self, mesh) -> None:
        """Pre-place this config's pack over ``mesh`` (the threading half of
        ``parallel.sharding.place_sharded_pack``): the cached sharded pack is
        device_put so each 'model' shard holds ONE values slice, and every
        activation closure built AFTER this call captures the placed arrays —
        step 0 then runs without the first-dispatch reshard.  Call it before
        constructing the model (``build_model(cfg, mesh=...)`` does).  No-op
        for non-sharded modes, un-meshed runs, or a 'model' axis whose width
        doesn't match ``pack_shards``; idempotent (re-placing placed arrays is
        a device_put onto their existing sharding)."""
        if mesh is None or self.mode not in SHARDED_MODES:
            return
        if ("model" not in mesh.axis_names
                or mesh.shape["model"] != self.pack_shards):
            return
        from repro.parallel.sharding import place_sharded_pack
        _SHARDED_PACK_CACHE[self._sharded_key()] = place_sharded_pack(
            self.sharded_pack(), mesh)

    def _pack_for_mode(self):
        if self.mode in _POLY_BACKED:
            return self.poly_pack()
        if self.mode in _QUANT_BACKED:
            return self.quant_pack()
        if self.mode in SHARDED_MODES:
            return self.sharded_pack()
        return self.pack()

    def unary(self, name: str) -> Callable[[jax.Array], jax.Array]:
        """The activation callable for this config."""
        if self.mode == "exact" or name in _NEVER_TABLED:
            return _EXACT[name]
        if self.mode not in TABLE_MODES:
            raise ValueError(f"unknown approx mode {self.mode!r}")
        reg_name = _TABLE_NAME.get(name, name)
        if self.mode in FOLDED_MODES and name in FOLDABLE:
            # foldable names keep their full-range identity: "exp" stays exp
            # (the 2^k split covers all of f32; no exp_neg clamp-remap needed)
            reg_name = name
        exact_d1 = None
        if self.exact_grad:
            fn = get_function(reg_name)
            exact_d1 = partial(fn.d1f, xp=jnp)
        if self.mode in (PACK_MODES + QUANT_PACK_MODES + POLY_PACK_MODES
                         + ROUTED_MODES + SHARDED_MODES + FOLDED_MODES):
            pack = self._pack_for_mode()
            foldable = self.mode in FOLDED_MODES and reg_name in FOLDABLE
            if reg_name not in pack.names and not foldable:
                # foldable members need only their CORE members in the pack
                # (pack() appends them); everything else must be a member
                raise KeyError(
                    f"{reg_name!r} is not in pack_functions={pack.names}; add it "
                    f"to ApproxConfig.pack_functions to serve it from the pack")
            if self.mode in FOLDED_MODES:
                # range-reduced full-f32-domain serving for sin/cos/exp/log;
                # non-foldable members fall through to the plain pack paths
                # inside make_folded_* (folded modes superset table/routed)
                make = make_folded_routed_unary_fn \
                    if self.mode.startswith("folded_routed") else make_folded_fn
            elif self.mode in ROUTED_MODES:
                # dynamic dispatch with uniform fn_ids: the member identity is
                # a runtime operand, so every unary shares ONE executable
                make = make_routed_unary_fn
            elif self.mode in SHARDED_MODES:
                make = make_sharded_pack_fn
            elif self.mode in POLY_PACK_MODES:
                make = make_poly_pack_fn
            else:
                make = make_quant_pack_fn if self.mode in _QUANT_BACKED \
                    else make_pack_fn
            f = make(
                pack,
                reg_name,
                use_pallas=(self.mode in _PALLAS_BACKED),
                exact_d1=exact_d1,
                extrapolate=(name in _EXTRAPOLATE),
            )
        else:
            jt = self.table_for(name)
            f = make_table_fn(
                jt,
                use_pallas=(self.mode == "table_pallas"),
                exact_d1=exact_d1,
                extrapolate=(name in _EXTRAPOLATE),
            )
        if reg_name in _ODD_HALF_DOMAIN:
            # the registry table spans [-lo, 0): mirror it so gates/softcap get
            # the full symmetric domain (tanh(x) = -tanh(-|x|) * sign(x))
            f = odd_extension(f)
        return self._maybe_instrument_unary(f, name, reg_name)

    def _maybe_instrument_unary(self, f, name: str, reg_name: str):
        """Device-side approximation telemetry, decided at closure-BUILD time.

        When ``obs.device_telemetry_enabled()`` at the moment ``unary`` builds
        the callable, the activation is wrapped to count out-of-domain
        clamp/extrapolation hits (and, on quant-backed packs, saturated
        endpoint codes) into the global metrics registry via
        ``jax.debug.callback``; the observed VALUES are untouched.  When off —
        the default — ``f`` is returned as-is, so the traced jaxpr is
        bit-identical to a build without ScopeKit and no extra executables
        appear (the conformance/obs tests assert both).  Flipping the flag
        after a model is built therefore has no effect on that model: rebuild
        the closures to instrument them.
        """
        if not obs.device_telemetry_enabled():
            return f
        if self.mode in FOLDED_MODES and reg_name in FOLDABLE:
            # folded members serve the entire finite f32 domain: the fold maps
            # every input into the core member's interval, so there is no
            # out-of-domain clamp to count
            lo, hi = -jnp.inf, jnp.inf
            quant_pack = None
        elif self.mode in (PACK_MODES + QUANT_PACK_MODES + POLY_PACK_MODES
                           + ROUTED_MODES + SHARDED_MODES + FOLDED_MODES):
            pack = self._pack_for_mode()
            lo, hi = member_domain(pack, reg_name)
            quant_pack = pack if isinstance(pack, QuantTablePack) else None
        else:
            jt = self.table_for(name)
            lo, hi = jt.boundaries[0], jt.boundaries[jt.n_intervals]
            quant_pack = None
        mirror = reg_name in _ODD_HALF_DOMAIN

        def record(oob, total, sat, sat_total):
            reg = obs.get_registry()
            reg.counter(f"approx.oob.{reg_name}").add(int(oob))
            reg.counter(f"approx.lookups.{reg_name}").add(int(total))
            if int(sat_total):
                reg.counter(f"approx.quant_sat.{reg_name}").add(int(sat))
                reg.counter(
                    f"approx.quant_gathers.{reg_name}").add(int(sat_total))

        def instrumented(x):
            xf = jnp.asarray(x).astype(jnp.float32)
            # half-domain odd members evaluate at -|x| (odd_extension): probe
            # the mirrored input so the effective domain is (lo, -lo)
            probe = jnp.minimum(xf, -xf) if mirror else xf
            oob = jnp.sum(((probe < lo) | (probe >= hi)).astype(jnp.int32))
            if quant_pack is not None:
                sat, sat_total = quant_saturation_counts(
                    quant_pack, reg_name, probe)
            else:
                sat, sat_total = jnp.zeros((), jnp.int32), 0
            jax.debug.callback(record, oob, xf.size, sat, sat_total)
            return f(x)

        return instrumented

    def routed_fn(self, fns, *, extrapolate=None) -> Callable:
        """Per-row dynamic dispatch: ``f(x)`` applies ``fns[i]`` to row i of
        ``x`` (leading axis) in ONE call — MoE-style routed activations.

        In table modes this is served by the scalar-prefetch routed kernels
        (or their jnp oracles in ``*_ref`` modes) from one compiled
        executable regardless of the routing; ``exact`` mode falls back to a
        row-select over the exact transcendentals.  ``fns`` are activation
        names (remapped like :meth:`unary`: ``sigmoid`` -> ``sigmoid_sym``,
        ``exp`` -> ``exp_neg``); half-domain odd members (tanh) are mirrored
        per row, so every row sees its full symmetric domain.
        """
        names = tuple(_TABLE_NAME.get(f, f) if isinstance(f, str) else f
                      for f in fns)
        if self.mode == "exact":
            return _routed_exact(names)
        if self.mode not in TABLE_MODES:
            raise ValueError(f"unknown approx mode {self.mode!r}")
        pack = self._pack_for_mode()
        for n in names:
            if isinstance(n, str) and n not in pack.names:
                raise KeyError(
                    f"{n!r} is not in pack_functions={pack.names}; add it to "
                    f"ApproxConfig.pack_functions to route to it")
        if extrapolate is None:
            extrapolate = tuple(n in _EXTRAPOLATE for n in pack.names)
        f = make_routed_fn(pack, names,
                           use_pallas=(self.mode in _PALLAS_BACKED),
                           extrapolate=extrapolate)
        odd = np.asarray([isinstance(n, str) and n in _ODD_HALF_DOMAIN
                          for n in names])
        if odd.any():
            def routed_odd(x, _f=f):
                # per-row odd_extension: mirror only the half-domain rows
                # (same branchless where as the unary path; s is +-1 and
                # piecewise constant, so tangents flow through f's custom_jvp
                # untouched)
                sel = (len(names),) + (1,) * (jnp.asarray(x).ndim - 1)
                m = jnp.asarray(odd).reshape(sel)
                s = jnp.where(m & (jnp.asarray(x) >= 0), -1.0, 1.0)
                return s * _f(s * x)

            f = routed_odd
        return self._maybe_instrument_routed(f, names, pack)

    def _maybe_instrument_routed(self, f, names, pack):
        """Routed-dispatch telemetry, decided at closure-build time like
        :meth:`_maybe_instrument_unary`: each execution adds this routing's
        static per-member row counts to ``approx.routed.<member>`` — across
        executions the counters form the fn_id dispatch histogram."""
        if not obs.device_telemetry_enabled():
            return f
        counts: Dict[str, int] = {}
        for n in names:
            key = n if isinstance(n, str) else pack.names[int(n)]
            counts[key] = counts.get(key, 0) + 1

        def record():
            reg = obs.get_registry()
            for member, rows in counts.items():
                reg.counter(f"approx.routed.{member}").add(rows)

        def instrumented(x):
            jax.debug.callback(record)
            return f(x)

        return instrumented

    def softmax(self, x: jax.Array, axis: int = -1, where=None) -> jax.Array:
        """Numerically-shifted softmax; exponent optionally via the exp table."""
        if not self.softmax_table or self.mode == "exact":
            return jax.nn.softmax(x, axis=axis, where=where)
        exp_fn = self.unary("exp")
        m = jnp.max(x, axis=axis, keepdims=True, where=where, initial=-1e30)
        z = x - jax.lax.stop_gradient(m)
        if self.mode in FOLDED_MODES:
            # folded exp serves the whole f32 domain — no address clamp needed
            e = exp_fn(z)
        else:
            # exp_neg table domain is [-16, 0]; clamp matches the hardware
            # address saturation
            e = exp_fn(jnp.maximum(z, -16.0))
        if where is not None:
            e = jnp.where(where, e, 0.0)
        return e / jnp.sum(e, axis=axis, keepdims=True)

    def rope_sin_cos(self) -> Optional[Callable]:
        """Table-served rotary trig: ``None`` (exact jnp sin/cos) unless
        ``rope_table`` is on in a table mode, else ``f(ang) -> (sin, cos)``
        through the folded trig members — full position range via Cody-Waite /
        Payne-Hanek reduction, served from the SAME f32 pack artifact as the
        activations (pack() appends the trig cores whenever rope_table is on).
        ``models/common.apply_rope`` threads this as its ``sin_cos`` hook."""
        if not self.rope_table or self.mode == "exact":
            return None
        if self.mode not in TABLE_MODES:
            raise ValueError(f"unknown approx mode {self.mode!r}")
        names = tuple(self.pack_functions)
        overrides = tuple(sorted(self.interval_overrides.items()))
        key = (self.mode, self.e_a, self.algorithm, self.omega, names,
               overrides)
        if key not in _ROPE_SIN_COS_CACHE:
            pack = self.pack()  # the f32 pack, with trig cores appended
            use_pallas = self.mode in _PALLAS_BACKED
            sin_fn = make_folded_fn(pack, "sin", use_pallas=use_pallas)
            cos_fn = make_folded_fn(pack, "cos", use_pallas=use_pallas)
            _ROPE_SIN_COS_CACHE[key] = lambda ang: (sin_fn(ang), cos_fn(ang))
        return _ROPE_SIN_COS_CACHE[key]

    def attn_exp(self) -> Optional[Callable]:
        """TableFlash exponent: ``None`` (exact jnp.exp in flash attention)
        unless ``attn_table`` is on in a table mode, else ``f(z) -> exp(z)``
        for z <= 0 through the pack's ``exp_neg`` member — underflow-to-zero
        tail below lo (masked keys keep weight exactly 0, like exact f32
        exp), Pallas kernel or jnp oracle by mode, always served from the
        SAME f32 pack artifact as the activations (the rope_table precedent).
        ``models/attention._flash_inner`` threads this as its ``exp_fn``
        hook; the end-to-end error contract is :mod:`repro.core.attn_error`.
        """
        if not self.attn_table or self.mode == "exact":
            return None
        if self.mode not in TABLE_MODES:
            raise ValueError(f"unknown approx mode {self.mode!r}")
        names = tuple(self.pack_functions)
        if "exp_neg" not in names:
            raise KeyError(
                f"attn_table needs 'exp_neg' in pack_functions={names}; add "
                f"it to ApproxConfig.pack_functions to serve TableFlash")
        overrides = tuple(sorted(self.interval_overrides.items()))
        key = (self.mode, self.e_a, self.algorithm, self.omega, names,
               overrides)
        if key not in _ATTN_EXP_CACHE:
            _ATTN_EXP_CACHE[key] = make_attn_exp_fn(
                self.pack(), use_pallas=(self.mode in _PALLAS_BACKED))
        return self._maybe_instrument_attn_exp(_ATTN_EXP_CACHE[key])

    def _maybe_instrument_attn_exp(self, f):
        """TableFlash clamp telemetry, decided at closure-build time like
        :meth:`_maybe_instrument_unary` (obs off returns ``f`` untouched, so
        the flash jaxpr stays bit-identical to a build without ScopeKit).

        Counts only ``probe < lo`` underflow-to-zero events into
        ``approx.oob.attn_exp``: z = 0 is the running max's own argument every
        row and is PINNED in-domain (the x = hi edge semantics from the range
        fold work), so counting it would drown the signal.  The wrapper
        advertises ``wants_count_mask``; flash attention then passes
        ``count_mask`` marking PAD key slots False — a genuine ``k_pos == -1``
        empty cache slot still counts its underflow, a chunk-padding row
        (KV_PAD sentinel) does not.
        """
        if not obs.device_telemetry_enabled():
            return f
        lo, _ = member_domain(self.pack(), "exp_neg")

        def record(oob, total):
            reg = obs.get_registry()
            reg.counter("approx.oob.attn_exp").add(int(oob))
            reg.counter("approx.lookups.attn_exp").add(int(total))

        def instrumented(x, count_mask=None):
            xf = jnp.asarray(x).astype(jnp.float32)
            under = xf < lo
            if count_mask is not None:
                under = under & count_mask
                total = jnp.sum(jnp.broadcast_to(
                    count_mask, xf.shape).astype(jnp.int32))
            else:
                total = xf.size
            jax.debug.callback(record, jnp.sum(under.astype(jnp.int32)), total)
            return f(x)

        instrumented.wants_count_mask = True
        return instrumented


EXACT = ApproxConfig(mode="exact")


def get_exact(name: str) -> Callable:
    return _EXACT[name]
